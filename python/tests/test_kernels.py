"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes/values; integer paths must match exactly,
f32 epilogues to 1e-5. This is the core correctness signal for the
compute hot-spot that the AOT artifacts embed.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bn_relu_quant, qmatmul, qmatmul_acc, quantize_act, ternary_matmul,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand_int8(rng, shape, lo=-127, hi=128):
    return rng.integers(lo, hi, shape, dtype=np.int8)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 130),
    k=st.integers(1, 96),
    f=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, f, seed):
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m, k))
    w = rand_int8(rng, (k, f))
    s = (rng.random(f, dtype=np.float32) + 0.01).astype(np.float32)
    out = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s)))
    want = np.asarray(ref.ref_qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s)))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 100),
    k=st.integers(1, 80),
    f=st.integers(1, 66),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_acc_exact(m, k, f, seed):
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m, k))
    w = rand_int8(rng, (k, f))
    out = np.asarray(qmatmul_acc(jnp.asarray(x), jnp.asarray(w)))
    want = x.astype(np.int64) @ w.astype(np.int64)
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out.astype(np.int64), want)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 64),
    f=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_ternary_matmul_is_sign_accumulation(m, k, f, seed):
    """Ternary weights: kernel result == alpha * (sum of signed activations)."""
    rng = np.random.default_rng(seed)
    x = rand_int8(rng, (m, k))
    wt = rng.integers(-1, 2, (k, f)).astype(np.int8)
    alpha = (rng.random(f, dtype=np.float32) * 0.5 + 0.01).astype(np.float32)
    out = np.asarray(ternary_matmul(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(alpha)))
    acc = x.astype(np.int64) @ wt.astype(np.int64)
    np.testing.assert_allclose(out, acc.astype(np.float32) * alpha[None, :], rtol=1e-6)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 2000),
    exp=st.integers(-10, 4),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_act_matches_ref(n, exp, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=n) * scale).astype(np.float32)
    out = np.asarray(quantize_act(jnp.asarray(x), exp=exp))
    want = np.asarray(ref.ref_quantize_act(jnp.asarray(x), exp))
    np.testing.assert_array_equal(out, want)


def test_quantize_act_saturates():
    x = jnp.asarray(np.array([1e9, -1e9, 0.0], np.float32))
    out = np.asarray(quantize_act(x, exp=0))
    np.testing.assert_array_equal(out, [127, -127, 0])


def test_quantize_act_preserves_shape_3d():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(3, 5, 7)).astype(np.float32)
    out = np.asarray(quantize_act(jnp.asarray(x), exp=-3))
    assert out.shape == (3, 5, 7)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    c=st.integers(1, 64),
    exp=st.integers(-8, 2),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_bn_relu_quant_matches_ref(m, c, exp, relu, seed):
    rng = np.random.default_rng(seed)
    y = (rng.normal(size=(m, c)) * 4).astype(np.float32)
    sc = (rng.random(c) + 0.1).astype(np.float32)
    sh = rng.normal(size=c).astype(np.float32)
    out = np.asarray(bn_relu_quant(jnp.asarray(y), jnp.asarray(sc), jnp.asarray(sh),
                                   exp_out=exp, relu=relu))
    want = np.asarray(ref.ref_bn_relu_quant(jnp.asarray(y), jnp.asarray(sc),
                                            jnp.asarray(sh), exp, relu=relu))
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("stride,pad,kh", [(1, 1, 3), (2, 1, 3), (1, 0, 1), (2, 0, 1)])
def test_im2col_conv_matches_lax(stride, pad, kh):
    """im2col+GEMM convolution equals lax.conv on integer data."""
    import jax

    rng = np.random.default_rng(1)
    x = rng.integers(-10, 10, (2, 8, 8, 3)).astype(np.int8)
    w = rng.integers(-10, 10, (kh, kh, 3, 5)).astype(np.int8)
    got = np.asarray(ref.ref_conv2d_int(jnp.asarray(x), jnp.asarray(w), stride, pad))
    want = jax.lax.conv_general_dilated(
        x.astype(np.float32), w.astype(np.float32), (stride, stride),
        [(pad, pad), (pad, pad)], dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_array_equal(got, np.asarray(want).astype(np.int32))


@pytest.mark.parametrize("bm,bf", [(8, 8), (16, 64), (64, 16), (128, 128)])
def test_qmatmul_tile_size_invariance(bm, bf):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    x = rand_int8(rng, (50, 33))
    w = rand_int8(rng, (33, 29))
    s = (rng.random(29, dtype=np.float32) + 0.01).astype(np.float32)
    base = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s)))
    tiled = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(s), bm=bm, bf=bf))
    np.testing.assert_array_equal(base, tiled)
