"""L2 model tests: shapes, quantized-pipeline invariants, sim==pallas."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile.model import (
    ModelSpec, QuantConfig, build_qmodel, eval_qmodel, forward_fp,
    forward_quant, init_params,
)

# a deliberately tiny spec so tests stay fast on one core
SPEC = ModelSpec(channels=(8, 16, 32), blocks_per_stage=1)


@pytest.fixture(scope="module")
def params():
    return init_params(SPEC, seed=0)


@pytest.fixture(scope="module")
def batch():
    return D.make_split(16, seed=42)


@pytest.fixture(scope="module")
def qmodel(params, batch):
    return build_qmodel(params, SPEC, QuantConfig(w_bits=2, cluster=4), batch[0])


def test_conv_specs_structure():
    specs = SPEC.conv_specs()
    names = [c.name for c in specs]
    assert names[0] == "stem"
    assert "s1b0proj" in names and "s2b0proj" in names  # strided stages project
    assert "s0b0proj" not in names                      # same-width stage: identity skip
    k1 = [c for c in specs if c.kh == 1]
    k3 = [c for c in specs if c.kh == 3]
    assert k1 and k3  # both op mixes present (§3.3 op-ratio analysis applies)


def test_forward_fp_shapes(params, batch):
    logits = forward_fp(params, jnp.asarray(batch[0]), SPEC)
    assert logits.shape == (16, SPEC.classes)
    logits, stats = forward_fp(params, jnp.asarray(batch[0]), SPEC, train=True)
    assert set(stats) == {c.name for c in SPEC.conv_specs()}


def test_build_qmodel_layer_inventory(qmodel):
    assert set(qmodel.layers) == {c.name for c in SPEC.conv_specs()}
    stem = qmodel.layers["stem"]
    assert stem.w_bits == 8                      # C1 stays 8-bit (§3.2)
    assert np.max(np.abs(stem.wq)) <= 127
    for name, l in qmodel.layers.items():
        if name == "stem":
            continue
        assert set(np.unique(l.wq)).issubset({-1, 0, 1}), name


def test_qmodel_activation_exponents_finite(qmodel):
    for name, l in qmodel.layers.items():
        assert -20 < l.act_exp < 10, (name, l.act_exp)


def test_forward_quant_logits_shape(qmodel, batch):
    logits = forward_quant(qmodel, jnp.asarray(batch[0][:4]), engine="sim")
    assert logits.shape == (4, SPEC.classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_sim_equals_pallas(qmodel, batch):
    """The fast sweep path and the AOT kernel path must agree bit-for-bit."""
    x = jnp.asarray(batch[0][:8])
    sim = np.asarray(forward_quant(qmodel, x, engine="sim"))
    pal = np.asarray(forward_quant(qmodel, x, engine="pallas"))
    np.testing.assert_allclose(sim, pal, rtol=1e-5, atol=1e-5)


def test_batch_invariance(qmodel, batch):
    """Per-image logits must not depend on batch composition."""
    x = jnp.asarray(batch[0][:8])
    full = np.asarray(forward_quant(qmodel, x, engine="sim"))
    one = np.asarray(forward_quant(qmodel, x[:1], engine="sim"))
    np.testing.assert_allclose(full[:1], one, rtol=1e-5, atol=1e-5)


def test_8bit_weights_close_to_fp(params, batch):
    """8a8w should track the fp32 logits closely (sanity on the pipeline)."""
    qm = build_qmodel(params, SPEC, QuantConfig(w_bits=8, cluster=4), batch[0])
    x = jnp.asarray(batch[0][:8])
    ql = np.asarray(forward_quant(qm, x, engine="sim"))
    assert np.all(np.isfinite(ql))
    # ranks should broadly agree between fp and 8-bit on an untrained net is
    # too weak a signal; instead assert the quantized activations actually
    # used the int8 range (not collapsed to zero)
    assert np.std(ql) > 0


def test_bn_recompute_changes_folds(params, batch):
    a = build_qmodel(params, SPEC, QuantConfig(w_bits=2, cluster=4, recompute_bn=True), batch[0])
    b = build_qmodel(params, SPEC, QuantConfig(w_bits=2, cluster=4, recompute_bn=False), batch[0])
    diffs = [np.max(np.abs(a.layers[n].bn_scale - b.layers[n].bn_scale)) for n in a.layers]
    assert max(diffs) > 0  # §3.2 re-estimation must actually do something


def test_eval_qmodel_range(qmodel, batch):
    acc = eval_qmodel(qmodel, batch[0], batch[1], engine="sim")
    assert 0.0 <= acc <= 1.0


def test_quant_config_tags():
    assert QuantConfig(w_bits=2, cluster=4).tag() == "8a2w_n4"
    assert QuantConfig(w_bits=2, cluster=4, ternary_mode="paper").tag() == "8a2w_n4_paper"
    assert QuantConfig(w_bits=4, cluster=64).tag() == "8a4w_n64"
