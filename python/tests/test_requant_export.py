"""compile.requant — the integer-requant export math must mirror the rust
derivation (`dfp::Requantizer::from_scale` / `LayerRequant::derive`).

A python reference of the rust algorithm (log2().floor() + .round()) is
checked against `derive_requant`'s frexp formulation across random scale
envelopes, plus the invariants the rust loader enforces on version-1
exports (mantissa range, shift bounds, sign folding, bias rounding).
No jax required — this file must stay importable without an accelerator
stack.
"""

import math
import random

import numpy as np
import pytest

from compile.requant import BIAS_FRAC, REQUANT_VERSION, derive_requant


def _round_half_away(x: float) -> int:
    return int(math.floor(x + 0.5)) if x >= 0.0 else int(math.ceil(x - 0.5))


def rust_from_scale(scale: float):
    """Reference port of rust `Requantizer::from_scale` (log2-based)."""
    e = math.floor(math.log2(scale))
    shift = 30 - e
    mult = _round_half_away(scale * 2.0 ** shift)
    if mult == 1 << 31:
        mult >>= 1
        shift -= 1
    return mult, shift


def test_matches_rust_derivation_across_random_scales():
    rng = random.Random(3)
    for _ in range(20000):
        w = np.float32(2.0 ** rng.uniform(-14, -2) * rng.uniform(1.0, 2.0))
        b = np.float32(rng.uniform(-2.0, 2.0))
        sh = np.float32(rng.uniform(-8.0, 8.0))
        if float(b) == 0.0:
            continue
        mult, shift, bias = derive_requant([w], [b], [sh])
        s0 = float(np.float64(w) * np.float64(b))
        rm, rs = rust_from_scale(abs(s0))
        rm = -rm if s0 < 0.0 else rm
        assert int(mult[0]) == rm, (w, b)
        assert int(shift[0]) == rs, (w, b)
        assert int(bias[0]) == _round_half_away(float(np.float64(sh)) * 2.0 ** BIAS_FRAC)
        # the invariants rust `LayerRequant::from_parts` enforces on load
        assert (1 << 30) <= abs(int(mult[0])) < (1 << 31)
        assert -512 <= int(shift[0]) <= 1024


def test_power_of_two_scales_are_exact():
    for e in (-20, -10, -4, 0, 3):
        mult, shift, _ = derive_requant(
            [np.float32(2.0 ** e)], [np.float32(1.0)], [np.float32(0.0)]
        )
        assert int(mult[0]) == 1 << 30
        assert int(shift[0]) == 30 - e


def test_zero_scale_is_dead_channel_and_sign_folds():
    mult, shift, bias = derive_requant(
        np.array([0.0, 0.5, 0.5], np.float32),
        np.array([1.0, -1.0, 1.0], np.float32),
        np.array([0.25, 0.0, -0.25], np.float32),
    )
    assert int(mult[0]) == 0 and int(shift[0]) == 0
    assert int(mult[1]) < 0 and int(mult[2]) > 0
    assert int(bias[0]) == 1 << (BIAS_FRAC - 2)
    assert int(bias[2]) == -(1 << (BIAS_FRAC - 2))
    assert REQUANT_VERSION == 1


def test_dtypes_match_dft_layout():
    mult, shift, bias = derive_requant(
        np.array([0.01], np.float32), np.array([1.0], np.float32), np.array([0.5], np.float32)
    )
    assert mult.dtype == np.int32
    assert shift.dtype == np.int32
    assert bias.dtype == np.int64


def test_rejects_non_finite():
    with pytest.raises(ValueError):
        derive_requant([np.float32("nan")], [np.float32(1.0)], [np.float32(0.0)])
    with pytest.raises(ValueError):
        derive_requant([np.float32(1.0)], [np.float32(1.0)], [np.float32("inf")])
