"""Quantizer correctness: DFP primitives, Algorithm 1 & 2, TWN baseline."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q

SETTINGS = dict(max_examples=40, deadline=None)


# ---------------------------------------------------------------- DFP core


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4, 8]), scale=st.floats(1e-4, 1e4),
       seed=st.integers(0, 2**31 - 1))
def test_dfp_roundtrip_error_bound(bits, scale, seed):
    """|x - dequant(quant(x))| <= 2**(exp-1) elementwise (half-ulp)."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=257) * scale).astype(np.float32)
    q, e = Q.quantize_dfp(x, bits)
    err = np.abs(Q.dequantize_dfp(q, e) - x)
    assert np.all(err <= 2.0 ** (e - 1) + 1e-12)


@settings(**SETTINGS)
@given(v=st.floats(1e-6, 1e6))
def test_choose_exp_fits_range(v):
    for bits in (2, 4, 8):
        e = Q.choose_exp(v, bits)
        assert v <= Q.qmax(bits) * 2.0**e
        # one step tighter would not fit
        assert v > Q.qmax(bits) * 2.0 ** (e - 1) or math.isclose(v, Q.qmax(bits) * 2.0 ** (e - 1))


def test_quantize_dfp_empty_and_zero():
    q, e = Q.quantize_dfp(np.zeros(5, np.float32), 8)
    assert e == 0 and np.all(q == 0)


@settings(**SETTINGS)
@given(alpha=st.floats(1e-5, 1e5))
def test_scale_u8_roundtrip(alpha):
    m, e = Q.quantize_scale_u8(alpha)
    a_hat = Q.dequantize_scale_u8(m, e)
    assert abs(a_hat - alpha) / alpha < 1.0 / 128  # normalized mantissa precision
    assert 0 <= m <= 255


def test_scale_u8_zero():
    assert Q.quantize_scale_u8(0.0) == (0, 0)
    assert Q.dequantize_scale_u8(0, 0) == 0.0


# ---------------------------------------------------- Algorithm 2 (thresholds)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 400))
def test_threshold_select_is_rms_of_some_prefix(seed, n):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=n).astype(np.float32)
    a = Q.threshold_select(w)
    s = np.sort(np.abs(w.astype(np.float64)))[::-1]
    prefixes = np.sqrt(np.cumsum(s * s) / np.arange(1, n + 1))
    assert np.min(np.abs(prefixes - a)) < 1e-9


def test_threshold_select_zero_vector():
    assert Q.threshold_select(np.zeros(16, np.float32)) == 0.0


def test_threshold_select_constant_vector():
    w = np.full(32, 0.25, np.float32)
    assert Q.threshold_select(w) == pytest.approx(0.25, rel=1e-6)


# ---------------------------------------------------- Algorithm 1 (clusters)


@pytest.mark.parametrize("mode", ["paper", "support"])
@pytest.mark.parametrize("n_cluster", [1, 4, 16])
def test_exact_ternary_recovery(mode, n_cluster):
    rng = np.random.default_rng(0)
    wq_true = rng.integers(-1, 2, (3, 3, 8, 16)).astype(np.float32)
    w = wq_true * 0.37
    t = Q.ternarize_layer(w, n_cluster, mode=mode)
    rel = np.linalg.norm(w - t.dequantize()) / np.linalg.norm(w)
    assert rel < 0.01  # only alpha-requantization (8-bit mantissa) error


@pytest.mark.parametrize("mode", ["paper", "support"])
def test_ternary_values_are_ternary(mode):
    rng = np.random.default_rng(3)
    w = rng.normal(0, 0.1, (3, 3, 16, 24)).astype(np.float32)
    t = Q.ternarize_layer(w, 4, mode=mode)
    assert set(np.unique(t.wq)).issubset({-1, 0, 1})
    assert t.wq.shape == w.shape
    assert np.all(t.alpha >= 0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       co=st.integers(2, 32), n_cluster=st.sampled_from([1, 2, 4, 8]))
def test_cluster_alpha_shared_within_cluster(seed, co, n_cluster):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, (3, 3, 4, co)).astype(np.float32)
    t = Q.ternarize_layer(w, n_cluster, mode="support")
    n_clusters = (co + n_cluster - 1) // n_cluster
    assert len(t.alpha_mant) == n_clusters
    for c in range(n_clusters):
        lo, hi = c * n_cluster, min((c + 1) * n_cluster, co)
        assert np.all(t.alpha[lo:hi] == t.alpha[lo])
        assert np.all(t.cluster_of[lo:hi] == c)


def test_smaller_clusters_do_not_increase_error():
    """More scales (smaller N) => layer approximation error monotone non-up."""
    rng = np.random.default_rng(5)
    w = rng.normal(0, 0.1, (3, 3, 32, 64)).astype(np.float32)
    errs = []
    for n in (1, 4, 16, 64):
        t = Q.ternarize_layer(w, n, mode="support")
        errs.append(np.linalg.norm(w - t.dequantize()))
    # allow tiny non-monotonicity from the 8-bit alpha requantization
    for a, b in zip(errs, errs[1:]):
        assert a <= b * 1.02


def test_paper_mode_prunes_harder_than_support():
    """§3.1: RMS-as-threshold 'helps speed up weight pruning'."""
    rng = np.random.default_rng(6)
    w = rng.normal(0, 0.1, (3, 3, 32, 32)).astype(np.float32)
    sp_paper = np.mean(Q.ternarize_layer(w, 4, mode="paper").wq == 0)
    sp_support = np.mean(Q.ternarize_layer(w, 4, mode="support").wq == 0)
    assert sp_paper > sp_support


def test_fc_layer_2d_shapes():
    rng = np.random.default_rng(7)
    w = rng.normal(0, 0.1, (128, 10)).astype(np.float32)
    t = Q.ternarize_layer(w, 4)
    assert t.wq.shape == (128, 10)
    d = Q.quantize_layer_dfp(w, 4, 4)
    assert d.wq.shape == (128, 10)
    assert np.max(np.abs(d.wq)) <= 7


# ------------------------------------------------------------- k-bit DFP


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]),
       n_cluster=st.sampled_from([1, 4, 16]))
def test_dfp_layer_within_range_and_cluster_exp(seed, bits, n_cluster):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.2, (3, 3, 8, 16)).astype(np.float32)
    d = Q.quantize_layer_dfp(w, bits, n_cluster)
    assert np.max(np.abs(d.wq)) <= Q.qmax(bits)
    # reconstruction error bounded by half-ulp of each cluster's exponent
    w_hat = d.dequantize()
    flat = w.reshape(-1, 16)
    fh = w_hat.reshape(-1, 16)
    for c in range(len(d.exp)):
        lo, hi = c * n_cluster, min((c + 1) * n_cluster, 16)
        assert np.max(np.abs(flat[:, lo:hi] - fh[:, lo:hi])) <= 2.0 ** (d.exp[c] - 1) + 1e-12


def test_dfp_4bit_better_with_smaller_clusters():
    rng = np.random.default_rng(9)
    w = (rng.normal(0, 0.1, (3, 3, 16, 64)) * (1 + 10 * rng.random((1, 1, 1, 64)))).astype(np.float32)
    e1 = np.linalg.norm(w - Q.quantize_layer_dfp(w, 4, 1).dequantize())
    e64 = np.linalg.norm(w - Q.quantize_layer_dfp(w, 4, 64).dequantize())
    assert e1 < e64


# ------------------------------------------------------------- TWN baseline


def test_twn_baseline_properties():
    rng = np.random.default_rng(11)
    w = rng.normal(0, 0.1, (3, 3, 8, 8)).astype(np.float32)
    wq, alpha = Q.ternarize_twn(w)
    assert set(np.unique(wq)).issubset({-1, 0, 1})
    assert alpha > 0
    # alpha is the mean |w| over the support
    mask = wq != 0
    np.testing.assert_allclose(alpha, np.mean(np.abs(w[mask])), rtol=1e-5)


def test_sqnr_infinite_for_perfect():
    w = np.ones((4, 4), np.float32)
    assert Q.sqnr_db(w, w) == math.inf
    assert Q.sqnr_db(w, np.zeros_like(w)) == pytest.approx(0.0)
