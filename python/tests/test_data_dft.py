"""ShapeSet generator determinism + DFT container round-trip."""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile.dft import read_dft, write_dft


# ------------------------------------------------------------------ ShapeSet


def test_sample_deterministic():
    a_img, a_lab = D.sample(seed=7, index=13)
    b_img, b_lab = D.sample(seed=7, index=13)
    np.testing.assert_array_equal(a_img, b_img)
    assert a_lab == b_lab


def test_sample_varies_with_index_and_seed():
    a, _ = D.sample(seed=7, index=13)
    b, _ = D.sample(seed=7, index=14)
    c, _ = D.sample(seed=8, index=13)
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_make_split_shapes_and_balance():
    xs, ys = D.make_split(500, seed=0)
    assert xs.shape == (500, D.IMG, D.IMG, D.CH) and xs.dtype == np.float32
    assert ys.shape == (500,) and ys.dtype == np.int32
    # roughly balanced labels
    counts = np.bincount(ys, minlength=D.CLASSES)
    assert counts.min() > 20


def test_noise_zero_is_clean_prototype_transform():
    img, lab = D.sample(seed=1, index=2, noise=0.0)
    assert np.max(np.abs(img)) <= 1.6 * 1.3  # brightness-jittered prototype range


def test_splitmix64_reference_vector():
    """Pin the PRNG to known values — rust mirrors these exactly
    (rust/src/util/rng.rs test_reference_vector)."""
    rng = D._SplitMix64(0)
    vals = [rng.next_u64() for _ in range(3)]
    assert vals == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]


# ------------------------------------------------------------------ DFT file


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dft_roundtrip_random(seed):
    rng = np.random.default_rng(seed)
    tensors = {
        "a.f32": rng.normal(size=(3, 4)).astype(np.float32),
        "b.i8": rng.integers(-128, 128, (7,), dtype=np.int8),
        "c.i32": rng.integers(-1000, 1000, (2, 2, 2), dtype=np.int32),
        "d.scalarish": rng.normal(size=(1,)).astype(np.float32),
    }
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        write_dft(p, tensors)
        back = read_dft(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_dft_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "bad.dft")
        with open(p, "wb") as f:
            f.write(b"NOPE" + b"\x00" * 16)
        with pytest.raises(ValueError):
            read_dft(p)


def test_dft_rejects_unsupported_dtype():
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        with pytest.raises(ValueError):
            write_dft(p, {"x": np.zeros(3, np.float64)})


def test_dft_empty_file_roundtrip():
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        write_dft(p, {})
        assert read_dft(p) == {}


# ------------------------------------------------------------- DFT v2 integrity


def _sample_tensors():
    return {
        "a.f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.i8": np.array([-128, 0, 127], np.int8),
    }


def test_dft_v2_magic_and_checksum_flip_rejected():
    from compile.dft import ArtifactError, fnv1a
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        write_dft(p, _sample_tensors())
        raw = bytearray(open(p, "rb").read())
        assert bytes(raw[:4]) == b"DFT2"
        # flip one payload bit: the whole-file trailer must catch it
        raw[20] ^= 0x10
        open(p, "wb").write(bytes(raw))
        with pytest.raises(ArtifactError, match="checksum"):
            read_dft(p)
        # recompute the trailer so the per-tensor checksum catches it instead
        import struct as _s
        raw[-8:] = _s.pack("<Q", fnv1a(bytes(raw[:-8])))
        open(p, "wb").write(bytes(raw))
        with pytest.raises(ArtifactError, match="tensor 'a.f32'"):
            read_dft(p)


def test_dft_v1_still_loads():
    from compile.dft import write_dft_v1
    tensors = _sample_tensors()
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        write_dft_v1(p, tensors)
        assert open(p, "rb").read(4) == b"DFT1"
        back = read_dft(p)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])


def test_dft_truncation_and_future_version_rejected():
    from compile.dft import ArtifactError
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "t.dft")
        write_dft(p, _sample_tensors())
        raw = open(p, "rb").read()
        open(p, "wb").write(raw[: len(raw) // 2])
        with pytest.raises(ArtifactError):
            read_dft(p)
        open(p, "wb").write(b"DFT9" + raw[4:])
        with pytest.raises(ArtifactError, match="unsupported"):
            read_dft(p)


def test_dft_fnv1a_reference_vectors():
    """Pin FNV-1a 64 to published vectors — rust mirrors these exactly
    (rust/src/io test_fnv1a_vectors)."""
    from compile.dft import fnv1a
    assert fnv1a(b"") == 0xCBF29CE484222325
    assert fnv1a(b"a") == 0xAF63DC4C8601EC8C
    assert fnv1a(b"foobar") == 0x85944171F73967E8
