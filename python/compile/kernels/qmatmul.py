"""Pallas quantized GEMM kernels — the paper's compute hot-spot (L1).

Two entry points sharing one tiled kernel body:

* ``qmatmul``        — generic int8 x int8 GEMM with int32 accumulate and a
                       per-output-filter f32 dequantization scale. Used by
                       the C1 (8-bit) layer, the 4-bit path (values stored
                       in int8, range [-7, 7]) and the 8a8w variant.
* ``ternary_matmul`` — the cluster-ternary contraction: weights are int8
                       restricted to {-1, 0, +1}; the MXU/ALU work is pure
                       sign-accumulation and the only multiply per output
                       element is the cluster scale α̂ applied on the final
                       accumulator — the literal kernel-level realisation of
                       the paper's "one 8-bit multiply per N·K² ternary
                       accumulations" (§3.3).

TPU mapping (see DESIGN.md §Hardware-Adaptation): grid tiles (BM, BF) with
the full K dimension resident — for this model family K = kh·kw·C ≤ 576 so
an (x-tile, w-tile, out-tile) triple is ≤ (BM+BF)·K + BM·BF words, far under
a 16 MB VMEM budget with double buffering; the contraction maps onto the
MXU as an int8 matmul. ``interpret=True`` everywhere: CPU PJRT cannot run
Mosaic custom-calls; numerics are validated on the interpret path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: BM rows of activations, BF output filters per program.
# PERF (§Perf L1, iteration 2): interpret-mode pallas lowers each grid step
# into an XLA while-loop iteration with dynamic slices — on CPU the grid
# *is* the overhead, so tiles are chosen adaptively large (few steps). The
# TPU deployment would instead use VMEM-budgeted 64x64..128x128 tiles; see
# DESIGN.md §Hardware-Adaptation for the footprint math.
BM = 64
BF = 64
CPU_BM = 4096
CPU_BF = 256


def _adaptive(m, f, bm, bf):
    """Pick tile sizes: explicit args win; otherwise cover the whole matrix
    up to the CPU_* caps (minimizing grid steps + padding)."""
    bm = bm or min(m, CPU_BM)
    bf = bf or min(f, CPU_BF)
    return bm, bf


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref):
    """One (BM, BF) output tile: integer accumulate + per-filter scale.

    PERF (§Perf L1, iteration 1): the contraction is carried in f32, not
    int32 — XLA-CPU has no fast int8 GEMM path (naive loops, ~50x slower),
    while the f32 path hits the optimized SGEMM kernels. Exactness: every
    product |x·w| <= 127·127 and partial sums stay well under 2^24 for the
    ternary (|w|<=1 -> |acc| <= K·127 ~ 1.5e5) and 4-bit (<= 1.0e6) paths,
    so f32 accumulation is bit-identical to int32. The int32 reference
    lives in `_qacc_kernel`/`qmatmul_acc`; pytest pins f32==int32. On TPU
    the same contraction maps to the MXU int8/bf16 path (DESIGN.md
    §Hardware-Adaptation).
    """
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = acc * s_ref[...][None, :]


def _pad_to(x, axis, mult):
    rem = x.shape[axis] % mult
    if rem == 0:
        return x, 0
    padw = [(0, 0)] * x.ndim
    padw[axis] = (0, mult - rem)
    return jnp.pad(x, padw), mult - rem


@functools.partial(jax.jit, static_argnames=("bm", "bf"))
def qmatmul(xq, wq, scale, *, bm: int = None, bf: int = None):
    """int8[M,K] @ int8[K,F] * scale[F] -> f32[M,F] (tiled Pallas GEMM).

    Pads M and F up to the tile sizes (zero rows / filters), runs the tiled
    kernel over a (M/bm, F/bf) grid, slices the result back.
    """
    m, k = xq.shape
    k2, f = wq.shape
    assert k == k2 and scale.shape == (f,)
    bm, bf = _adaptive(m, f, bm, bf)
    xp, _ = _pad_to(xq, 0, bm)
    wp, _ = _pad_to(wq, 1, bf)
    sp, _ = _pad_to(scale.astype(jnp.float32), 0, bf)
    mp, fp = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _qmm_kernel,
        grid=(mp // bm, fp // bf),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp), jnp.float32),
        interpret=True,
    )(xp, wp, sp)
    return out[:m, :f]


def ternary_matmul(xq, wt, alpha, *, bm: int = None, bf: int = None):
    """Cluster-ternary GEMM: int8 activations x {-1,0,+1} weights.

    ``alpha`` is the per-filter dequantized cluster scale α̂ (already
    expanded from per-cluster (mantissa, exp) pairs — the expansion is free:
    filters in a cluster share the value). Numerically identical to
    ``qmatmul``; kept distinct because the op-accounting (and the real-HW
    kernel) differ: here the inner contraction is multiplication-free.
    """
    return qmatmul(xq, wt, alpha, bm=bm, bf=bf)


def _qacc_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bf"))
def qmatmul_acc(xq, wq, *, bm: int = None, bf: int = None):
    """Raw int32 accumulator variant (scale applied by the caller)."""
    m, k = xq.shape
    _, f = wq.shape
    bm, bf = _adaptive(m, f, bm, bf)
    xp, _ = _pad_to(xq, 0, bm)
    wp, _ = _pad_to(wq, 1, bf)
    mp, fp = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _qacc_kernel,
        grid=(mp // bm, fp // bf),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp), jnp.int32),
        interpret=True,
    )(xp, wp)
    return out[:m, :f]
