"""Pure-jnp/numpy correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest + hypothesis
compare them elementwise (exact for integer paths, allclose for f32).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ref_qmatmul(xq, wq, scale):
    """int8[M,K] @ int8[K,F] -> f32[M,F], int32 accumulate, per-filter scale.

    The integer GEMM at the heart of the paper's pipeline: `scale` is the
    per-output-filter dequantization factor (cluster alpha * 2**act_exp).
    """
    acc = xq.astype(jnp.int32) @ wq.astype(jnp.int32)
    return acc.astype(jnp.float32) * scale[None, :].astype(jnp.float32)


def ref_qmatmul_acc(xq, wq):
    """int8[M,K] @ int8[K,F] -> int32[M,F] raw accumulator (no scale)."""
    return xq.astype(jnp.int32) @ wq.astype(jnp.int32)


def ref_quantize_act(x, exp, bits=8):
    """f32 -> int8 DFP with shared power-of-two exponent (round-half-even)."""
    q = (1 << (bits - 1)) - 1
    scaled = x.astype(jnp.float32) * jnp.float32(2.0 ** (-exp))
    return jnp.clip(jnp.round(scaled), -q, q).astype(jnp.int8)


def ref_dequantize_act(xq, exp):
    return xq.astype(jnp.float32) * jnp.float32(2.0**exp)


def ref_bn_relu_quant(y, scale, shift, exp_out, bits=8, relu=True):
    """Folded BN (per-channel affine) + optional ReLU + requant to int8 DFP."""
    z = y * scale[None, :] + shift[None, :]
    if relu:
        z = jnp.maximum(z, 0.0)
    return ref_quantize_act(z, exp_out, bits)


def im2col(x, kh, kw, stride=1, pad=1):
    """NHWC -> (N*Ho*Wo, kh*kw*C) patches, zero padded.

    Matches the layout the conv kernels expect: patch index varies over
    (kh, kw, C) fastest-last, rows over (N, Ho, Wo).
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(xp[:, i : i + stride * ho : stride, j : j + stride * wo : stride, :])
    patches = jnp.stack(cols, axis=3)  # (N, Ho, Wo, kh*kw, C)
    return patches.reshape(n * ho * wo, kh * kw * c), (n, ho, wo)


def ref_conv2d_int(xq, wq, stride=1, pad=1):
    """Integer conv (int8 NHWC x int8 HWIO -> int32 NHWC) via im2col GEMM."""
    kh, kw, ci, co = wq.shape
    cols, (n, ho, wo) = im2col(xq.astype(jnp.int32), kh, kw, stride, pad)
    flat = wq.reshape(-1, co).astype(jnp.int32)
    out = cols @ flat
    return out.reshape(n, ho, wo, co)


def np_round_half_even(x):
    """numpy round-half-even (np.rint) — shared by the quantizer tests."""
    return np.rint(x)
