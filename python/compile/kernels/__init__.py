"""L1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from .qmatmul import qmatmul, qmatmul_acc, ternary_matmul  # noqa: F401
from .quantize_act import bn_relu_quant, quantize_act  # noqa: F401
