"""Pallas activation (re)quantization kernels.

``quantize_act``  f32 -> int8 dynamic fixed point with a shared power-of-two
exponent (the paper's 8-bit activation path, §3). ``bn_relu_quant`` fuses
the folded-BatchNorm affine, ReLU and the requantization into one pass so
the f32 intermediate never round-trips through HBM — on TPU this is the
VPU epilogue of the matmul kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# PERF (§Perf L1, iteration 2): elementwise kernels run as a SINGLE grid
# step — interpret-mode grid iterations dominate cost on CPU, and an
# elementwise op has no cross-tile reuse to exploit anyway. (On TPU the
# epilogue fuses into the matmul kernel; see bn_relu_quant.)
BLK = 4096  # max flattened row width per (single) program


def _quant_kernel(x_ref, o_ref, *, inv_scale, q):
    x = x_ref[...] * jnp.float32(inv_scale)
    o_ref[...] = jnp.clip(jnp.round(x), -q, q).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("exp", "bits"))
def quantize_act(x, *, exp: int, bits: int = 8):
    """f32[any] -> int8 DFP: q = clip(round(x * 2**-exp)).

    Shapes are flattened to (rows, cols) internally; row-tiled grid.
    """
    q = (1 << (bits - 1)) - 1
    orig = x.shape
    flat = x.reshape(-1)
    width = min(BLK, flat.shape[0]) or 1
    pad = (-flat.shape[0]) % width
    flat = jnp.pad(flat, (0, pad)).reshape(-1, width)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, inv_scale=float(2.0 ** (-exp)), q=q),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.int8),
        interpret=True,
    )(flat)
    import math

    return out.reshape(-1)[: math.prod(orig)].reshape(orig)


def _bn_relu_quant_kernel(y_ref, s_ref, b_ref, o_ref, *, inv_scale, q, relu):
    z = y_ref[...] * s_ref[...][None, :] + b_ref[...][None, :]
    if relu:
        z = jnp.maximum(z, 0.0)
    o_ref[...] = jnp.clip(jnp.round(z * jnp.float32(inv_scale)), -q, q).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("exp_out", "bits", "relu"))
def bn_relu_quant(y, scale, shift, *, exp_out: int, bits: int = 8, relu: bool = True):
    """f32[M,C] * scale[C] + shift[C] -> relu -> int8 DFP[M,C] (fused epilogue).

    Single grid step (see BLK note): the whole epilogue is one fused
    elementwise pass — on TPU this is the VPU tail of the matmul tile.
    """
    q = (1 << (bits - 1)) - 1
    m, _c = y.shape
    out = pl.pallas_call(
        functools.partial(
            _bn_relu_quant_kernel,
            inv_scale=float(2.0 ** (-exp_out)),
            q=q,
            relu=relu,
        ),
        out_shape=jax.ShapeDtypeStruct(y.shape, jnp.int8),
        interpret=True,
    )(y, scale.astype(jnp.float32), shift.astype(jnp.float32))
    return out[:m]
