"""Accuracy sweep harness — regenerates the paper's Fig 1 / §3.3 / §3.2 / E8 data.

    python -m compile.eval_sweep [--quick]          (from python/)

Sweeps weight precision (2/4/8-bit) x cluster size N over the trained
baseline, with ablations:
  * BN recomputation on/off (§3.2, experiment E6)
  * TWN-style single-scale ternarization baseline (Li et al. [7], E8)
and writes results/sweep.json + a markdown table for EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from . import data as D
from . import quantize as Q
from .aot import ensure_weights
from .model import ModelSpec, QuantConfig, build_qmodel, eval_fp, eval_qmodel

HERE = os.path.dirname(__file__)
RESULTS_DIR = os.path.join(HERE, "..", "..", "results")


def mean_sqnr(params, spec, cfg: QuantConfig) -> float:
    """Average weight-SQNR (dB) across quantized conv layers."""
    vals = []
    for cs in spec.conv_specs():
        if cs.name == "stem":
            continue
        w = params[f"{cs.name}.w"]
        if cfg.w_bits == 2:
            t = Q.ternarize_layer(w, cfg.cluster)
            w_hat = t.dequantize()
        else:
            w_hat = Q.quantize_layer_dfp(w, cfg.w_bits, cfg.cluster).dequantize()
        vals.append(Q.sqnr_db(w, w_hat))
    return float(np.mean(vals))


def twn_accuracy(params, spec, ex, ey, calib) -> tuple:
    """E8 baseline: Li et al. per-layer single scale (Δ=0.7·E|w|, α=mean)."""
    patched = dict(params)
    sqnrs = []
    for cs in spec.conv_specs():
        if cs.name == "stem":
            continue
        w = params[f"{cs.name}.w"]
        wq, alpha = Q.ternarize_twn(w)
        patched[f"{cs.name}.w"] = wq.astype(np.float32) * alpha
        sqnrs.append(Q.sqnr_db(w, wq.astype(np.float32) * alpha))
    # evaluate as an "already quantized weights" model through the same
    # integer pipeline at 8-bit weights so activation handling is identical
    cfg = QuantConfig(w_bits=8, cluster=1)
    qm = build_qmodel(patched, spec, cfg, calib)
    return eval_qmodel(qm, ex, ey), float(np.mean(sqnrs))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small eval set")
    ap.add_argument("--n-eval", type=int, default=1024)
    ap.add_argument("--calib-n", type=int, default=256)
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)

    spec = ModelSpec()
    params = ensure_weights(spec)
    n_eval = 256 if args.quick else args.n_eval
    ex, ey = D.make_split(n_eval, seed=2)
    calib = ex[: args.calib_n]

    results = {"fp32": {"acc": eval_fp(params, spec, ex, ey)}}
    print(f"fp32: {results['fp32']['acc']:.4f}")

    clusters = [1, 2, 4, 8, 16, 32, 64]
    for bits in (8, 4, 2):
        for n in clusters:
            cfg = QuantConfig(w_bits=bits, cluster=n)
            qm = build_qmodel(params, spec, cfg, calib)
            acc = eval_qmodel(qm, ex, ey)
            key = cfg.tag()
            results[key] = {"acc": acc, "w_bits": bits, "cluster": n,
                            "sqnr_db": mean_sqnr(params, spec, cfg)}
            print(f"{key}: acc {acc:.4f}  sqnr {results[key]['sqnr_db']:.1f} dB")

    # E6 — BN recompute ablation (headline ternary config)
    for n in (4, 64):
        cfg = QuantConfig(w_bits=2, cluster=n, recompute_bn=False)
        qm = build_qmodel(params, spec, cfg, calib)
        acc = eval_qmodel(qm, ex, ey)
        results[f"8a2w_n{n}_nobn"] = {"acc": acc, "w_bits": 2, "cluster": n,
                                      "recompute_bn": False}
        print(f"8a2w_n{n} WITHOUT BN recompute: {acc:.4f}")

    # E8 — TWN baseline
    twn_acc, twn_sqnr = twn_accuracy(params, spec, ex, ey, calib)
    results["twn_baseline"] = {"acc": twn_acc, "sqnr_db": twn_sqnr}
    print(f"TWN (Li et al.) baseline: acc {twn_acc:.4f}  sqnr {twn_sqnr:.1f} dB")

    with open(os.path.join(RESULTS_DIR, "sweep.json"), "w") as f:
        json.dump(results, f, indent=1)

    # markdown table for EXPERIMENTS.md
    lines = ["| config | N | acc | Δ vs fp32 | weight SQNR (dB) |",
             "|---|---|---|---|---|"]
    fp = results["fp32"]["acc"]
    lines.append(f"| fp32 | — | {fp:.4f} | — | — |")
    for bits in (8, 4, 2):
        for n in clusters:
            r = results[f"8a{bits}w_n{n}"]
            lines.append(f"| 8a{bits}w | {n} | {r['acc']:.4f} | "
                         f"{r['acc']-fp:+.4f} | {r['sqnr_db']:.1f} |")
    with open(os.path.join(RESULTS_DIR, "sweep_table.md"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {RESULTS_DIR}/sweep.json and sweep_table.md")


if __name__ == "__main__":
    main()
