"""ShapeSet — procedural 10-class image dataset (ImageNet stand-in).

Each class is defined by (a) a low-frequency sinusoidal colour texture with
class-specific frequencies/phases and (b) a class-specific geometric mask
(disc / ring / bar / checker / wedge, parameterised by class id). A sample
is the class prototype under a random shift, horizontal flip, brightness
jitter and additive Gaussian noise — hard enough that a linear model fails
and a small conv net is needed, easy enough to train on one CPU core.

The generator is fully deterministic given (seed, index) so the Rust side
(rust/src/data/) regenerates identical request payloads for serving load.
Mirrors rust/src/data/shapeset.rs — keep the two in sync (cross-checked by
integration_runtime.rs against artifacts/shapeset_eval.dft).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import os

IMG = 24          # image side
CH = 3            # channels
CLASSES = 10
# additive noise sigma — tuned so FP32 accuracy lands in the mid/high-90s
# with visible quantization spread below it. Override: SHAPESET_NOISE env.
NOISE = float(os.environ.get("SHAPESET_NOISE", "1.0"))


@dataclass
class ShapeSetConfig:
    n: int
    seed: int = 0
    noise: float = NOISE


def _class_texture(cls: int, xx: np.ndarray, yy: np.ndarray) -> np.ndarray:
    """Class-specific smooth RGB texture in [-1, 1], shape (IMG, IMG, 3)."""
    out = np.zeros((IMG, IMG, CH), dtype=np.float32)
    for c in range(CH):
        fx = 1.0 + ((cls * 3 + c * 5) % 7) * 0.5
        fy = 1.0 + ((cls * 5 + c * 3) % 5) * 0.7
        ph = (cls * 1.7 + c * 0.9) % (2 * np.pi)
        out[..., c] = np.sin(fx * xx + ph) * np.cos(fy * yy - ph)
    return out


def _class_mask(cls: int, xx: np.ndarray, yy: np.ndarray) -> np.ndarray:
    """Class-specific geometric mask in {0, 1}, shape (IMG, IMG)."""
    r2 = xx * xx + yy * yy
    kind = cls % 5
    if kind == 0:      # disc
        m = r2 < (1.0 + 0.2 * (cls // 5)) ** 2
    elif kind == 1:    # ring
        m = (r2 > 0.8) & (r2 < 2.2 + 0.4 * (cls // 5))
    elif kind == 2:    # horizontal bar
        m = np.abs(yy) < 0.5 + 0.2 * (cls // 5)
    elif kind == 3:    # checker
        m = (np.floor(xx * (1.5 + cls // 5)) + np.floor(yy * 1.5)) % 2 == 0
    else:              # wedge
        m = (xx > 0) & (np.abs(yy) < xx * (0.8 + 0.3 * (cls // 5)))
    return m.astype(np.float32)


def _prototypes() -> np.ndarray:
    """All class prototypes, shape (CLASSES, IMG, IMG, CH), values in [-1,1]."""
    lin = np.linspace(-np.pi, np.pi, IMG, dtype=np.float32)
    yy, xx = np.meshgrid(lin, lin, indexing="ij")
    protos = np.zeros((CLASSES, IMG, IMG, CH), dtype=np.float32)
    for cls in range(CLASSES):
        tex = _class_texture(cls, xx, yy)
        mask = _class_mask(cls, xx, yy)[..., None]
        protos[cls] = tex * (0.4 + 0.6 * mask)
    return protos


_PROTOS = _prototypes()


def sample(seed: int, index: int, noise: float = None):
    """One (image, label). Deterministic in (seed, index).

    Uses SplitMix64 for the per-sample stream so the rust generator can
    reproduce it exactly. Returns (img: f32 (IMG,IMG,CH) in ~[-1.6,1.6],
    label: int).
    """
    if noise is None:
        noise = NOISE
    rng = _SplitMix64((seed << 32) ^ (index * 0x9E3779B97F4A7C15 & 0xFFFFFFFFFFFFFFFF))
    label = rng.next_below(CLASSES)
    proto = _PROTOS[label]
    dx = rng.next_below(9) - 4
    dy = rng.next_below(9) - 4
    img = np.roll(proto, (dy, dx), axis=(0, 1))
    if rng.next_below(2) == 1:
        img = img[:, ::-1, :]
    bright = 0.8 + 0.4 * rng.next_f32()
    img = img * bright
    if noise > 0:
        g = rng.normal(IMG * IMG * CH).reshape(IMG, IMG, CH)
        img = img + noise * g
    return img.astype(np.float32), label


def make_split(n: int, seed: int, noise: float = None):
    """Batch of n samples -> (images (n,IMG,IMG,CH) f32, labels (n,) i32)."""
    xs = np.zeros((n, IMG, IMG, CH), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        xs[i], ys[i] = sample(seed, i, noise)
    return xs, ys


class _SplitMix64:
    """SplitMix64 PRNG — mirrored bit-exactly in rust/src/util/rng.rs."""

    MASK = 0xFFFFFFFFFFFFFFFF

    def __init__(self, state: int):
        self.state = state & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def next_below(self, n: int) -> int:
        return self.next_u64() % n

    def next_f32(self) -> float:
        return (self.next_u64() >> 40) / float(1 << 24)

    def normal(self, n: int) -> np.ndarray:
        """Box-Muller over pairs of next_f32 — reproducible across languages."""
        m = (n + 1) // 2
        u1 = np.array([max(self.next_f32(), 1e-7) for _ in range(m)], dtype=np.float64)
        u2 = np.array([self.next_f32() for _ in range(m)], dtype=np.float64)
        r = np.sqrt(-2.0 * np.log(u1))
        out = np.concatenate([r * np.cos(2 * np.pi * u2), r * np.sin(2 * np.pi * u2)])
        return out[:n].astype(np.float32)
