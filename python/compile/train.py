"""Training (FP32 baseline) and low-precision fine-tuning (paper §4).

Build-time only — never on the request path. Usage (from python/):

    python -m compile.train                  # train FP32 baseline
    python -m compile.train --finetune       # §4: ternary-forward STE
                                             #     fine-tune (Fig 2 curve)

The FP32 run saves weights to ../models/weights_fp32.dft plus a metrics
JSON; the fine-tune run loads them, quantizes (8a2w, N=64 — the paper's
"needs retraining" configuration), and fine-tunes with the straight-through
estimator: forward uses ternarized weights + 8-bit activations, gradients
are applied to the full-precision master copy at lr 1e-4-scale (paper:
"gradient updates are performed in full precision ... learning rate
reduced to the order of 1e-4").
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import quantize as Q
from .dft import read_dft, write_dft
from .model import (
    ModelSpec, QuantConfig, build_qmodel, eval_fp, eval_qmodel, forward_fp,
    init_params,
)

MODELS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "models")

BN_MOMENTUM = 0.9


def loss_fn(params, x, y, spec):
    logits, stats = forward_fp(params, x, spec, train=True)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return nll, stats


def sgd_step(params, x, y, spec, lr, momentum, velocity):
    (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y, spec)
    new_p, new_v = {}, {}
    for k in params:
        if k.endswith(".mean") or k.endswith(".var"):
            new_p[k], new_v[k] = params[k], velocity[k]
            continue
        v = momentum * velocity[k] + grads[k]
        new_v[k] = v
        new_p[k] = params[k] - lr * v
    # BN running stats
    for name, (mu, var) in stats.items():
        new_p[f"{name}.mean"] = BN_MOMENTUM * params[f"{name}.mean"] + (1 - BN_MOMENTUM) * mu
        new_p[f"{name}.var"] = BN_MOMENTUM * params[f"{name}.var"] + (1 - BN_MOMENTUM) * var
    return new_p, new_v, loss


def train_fp(spec: ModelSpec, *, n_train=8192, n_eval=1024, batch=64, epochs=12,
             lr=0.1, momentum=0.9, seed=0, log=print) -> Dict[str, np.ndarray]:
    xs, ys = D.make_split(n_train, seed=1)
    ex, ey = D.make_split(n_eval, seed=2)
    params = init_params(spec, seed)
    velocity = {k: np.zeros_like(v) for k, v in params.items()}
    step_jit = jax.jit(sgd_step, static_argnames=("spec",))
    steps_per_epoch = n_train // batch
    rng = np.random.default_rng(seed)
    history = []
    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(n_train)
        ep_lr = lr * (0.5 ** (ep // 4))  # step decay
        losses = []
        for i in range(steps_per_epoch):
            idx = order[i * batch : (i + 1) * batch]
            params, velocity, loss = step_jit(
                params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]), spec,
                ep_lr, momentum, velocity)
            losses.append(float(loss))
        acc = eval_fp(params, spec, ex, ey)
        history.append({"epoch": ep, "loss": float(np.mean(losses)), "eval_acc": acc,
                        "lr": ep_lr, "wall_s": time.time() - t0})
        log(f"[fp32] epoch {ep:2d}  loss {np.mean(losses):.4f}  eval_acc {acc:.4f}  "
            f"lr {ep_lr:.4f}  ({time.time()-t0:.0f}s)")
    return {k: np.asarray(v) for k, v in params.items()}, history


# --------------------------------------------------------------------------
# §4 — low-precision fine-tuning (STE)
# --------------------------------------------------------------------------


def quantize_fwd_params(params, spec, cfg: QuantConfig):
    """Ternarize/quantize conv weights for the forward pass (master stays fp).

    Returns a params dict whose conv weights are α·Ŵ (dequantized ternary) —
    C1 at 8-bit, FC left in FP (paper §4: "we did not quantize the weights
    in FC layer for the training exercise")."""
    out = dict(params)
    for cs in spec.conv_specs():
        w = params[f"{cs.name}.w"]
        if cs.name == "stem":
            d = Q.quantize_layer_dfp(w, cfg.first_layer_bits, cfg.cluster)
            out[f"{cs.name}.w"] = d.dequantize()
        else:
            t = Q.ternarize_layer(w, cfg.cluster, mode=cfg.ternary_mode)
            out[f"{cs.name}.w"] = t.dequantize()
    return out


def finetune(params, spec: ModelSpec, cfg: QuantConfig, *, n_train=8192, n_eval=1024,
             batch=64, epochs=4, lr=1e-3, momentum=0.9, seed=3, log=print):
    """STE fine-tuning: fwd/bwd at w_hat = α·Ŵ, update full-precision master.

    Returns (master params, history) where history holds the Fig-2 curve:
    eval accuracy of the *quantized* model after each epoch.
    """
    xs, ys = D.make_split(n_train, seed=11)
    ex, ey = D.make_split(n_eval, seed=2)
    velocity = {k: np.zeros_like(v) for k, v in params.items()}
    step_jit = jax.jit(sgd_step, static_argnames=("spec",))
    steps_per_epoch = n_train // batch
    rng = np.random.default_rng(seed)
    history = []
    t0 = time.time()

    def q_eval(p):
        calib = ex[: cfg.calib_n]
        qm = build_qmodel(p, spec, cfg, calib)
        return eval_qmodel(qm, ex, ey)

    acc0 = q_eval(params)
    history.append({"epoch": 0, "eval_acc_quant": acc0, "wall_s": 0.0})
    log(f"[ft] epoch 0 (pre)  quant_acc {acc0:.4f}")
    for ep in range(1, epochs + 1):
        order = rng.permutation(n_train)
        losses = []
        for i in range(steps_per_epoch):
            idx = order[i * batch : (i + 1) * batch]
            # STE: gradients computed at the quantized point, applied to master
            qp = quantize_fwd_params(params, spec, cfg)
            new_qp, velocity, loss = step_jit(
                qp, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]), spec,
                lr, momentum, velocity)
            # delta computed on quantized params == gradient step; apply to master
            for k in params:
                if k.endswith(".w") and not k.startswith("fc") and k != "stem.w":
                    params[k] = params[k] + (new_qp[k] - qp[k])
                else:
                    params[k] = new_qp[k]
            losses.append(float(loss))
        acc = q_eval(params)
        history.append({"epoch": ep, "loss": float(np.mean(losses)),
                        "eval_acc_quant": acc, "wall_s": time.time() - t0})
        log(f"[ft] epoch {ep}  loss {np.mean(losses):.4f}  quant_acc {acc:.4f}  "
            f"({time.time()-t0:.0f}s)")
    return params, history


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--finetune", action="store_true")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--n-train", type=int, default=8192)
    ap.add_argument("--n-eval", type=int, default=1024)
    ap.add_argument("--cluster", type=int, default=64, help="N for --finetune")
    ap.add_argument("--out-dir", default=MODELS_DIR)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    spec = ModelSpec()

    if not args.finetune:
        epochs = args.epochs or 12
        params, history = train_fp(spec, n_train=args.n_train, n_eval=args.n_eval,
                                   epochs=epochs)
        write_dft(os.path.join(args.out_dir, "weights_fp32.dft"), params)
        with open(os.path.join(args.out_dir, "train_fp32.json"), "w") as f:
            json.dump(history, f, indent=1)
        print(f"saved weights_fp32.dft (final eval_acc {history[-1]['eval_acc']:.4f})")
    else:
        params = read_dft(os.path.join(args.out_dir, "weights_fp32.dft"))
        cfg = QuantConfig(w_bits=2, cluster=args.cluster)
        epochs = args.epochs or 4
        params, history = finetune(params, spec, cfg, n_train=args.n_train,
                                   n_eval=args.n_eval, epochs=epochs)
        write_dft(os.path.join(args.out_dir, f"weights_ft_{cfg.tag()}.dft"), params)
        with open(os.path.join(args.out_dir, f"finetune_{cfg.tag()}.json"), "w") as f:
            json.dump(history, f, indent=1)
        print(f"saved fine-tuned weights ({cfg.tag()}), "
              f"final quant_acc {history[-1]['eval_acc_quant']:.4f}")


if __name__ == "__main__":
    main()
