"""AOT lowering: quantized model variants -> HLO text artifacts (+ manifest).

The interchange format is HLO *text*, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each serving variant is lowered once per batch size with the weights baked
in as constants, so the rust hot path feeds only an f32 image batch and
reads back f32 logits — python never runs at serving time.

Run as:  python -m compile.aot --out ../artifacts     (from python/)

Produces:
    artifacts/model_<variant>_b<batch>.hlo.txt
    artifacts/manifest.json          — variants, shapes, accuracy metadata
    artifacts/eval_data.dft          — eval images + labels for rust drivers
    artifacts/qweights_<variant>.dft — quantized layers for the rust lpinfer
                                       cross-check (integration tests)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from .dft import ArtifactError, read_dft, write_dft
from .model import (
    ModelSpec, QuantConfig, build_qmodel, eval_fp, eval_qmodel, forward_fp,
    forward_quant,
)
from .requant import REQUANT_VERSION, derive_requant

HERE = os.path.dirname(__file__)
MODELS_DIR = os.path.join(HERE, "..", "..", "models")

BATCH_SIZES = (1, 8, 32)

# Serving variants: tag -> QuantConfig (None = fp32 baseline)
VARIANTS = {
    "fp32": None,
    "8a8w_n4": QuantConfig(w_bits=8, cluster=4),
    "8a4w_n4": QuantConfig(w_bits=4, cluster=4),
    "8a2w_n4": QuantConfig(w_bits=2, cluster=4),
    "8a2w_n64": QuantConfig(w_bits=2, cluster=64),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # as_hlo_text(True) == print_large_constants: the baked weights MUST be
    # in the text or the rust-side parse would silently zero-fill them.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text still elides large constants"
    return text


def ensure_weights(spec: ModelSpec) -> dict:
    """Load trained weights; train a fresh baseline if none exist yet."""
    path = os.path.join(MODELS_DIR, "weights_fp32.dft")
    if not os.path.exists(path):
        print("no trained weights found — training baseline (one-off)...")
        from .train import train_fp

        os.makedirs(MODELS_DIR, exist_ok=True)
        params, hist = train_fp(spec, epochs=14)
        write_dft(path, params)
        with open(os.path.join(MODELS_DIR, "train_fp32.json"), "w") as f:
            json.dump(hist, f, indent=1)
    return read_dft(path)


def export_qweights(path: str, qm) -> None:
    """Flatten a QModel into a .dft for the rust lpinfer pipeline."""
    t = {}
    for name, l in qm.layers.items():
        t[f"{name}.wq"] = l.wq
        t[f"{name}.w_scale"] = l.w_scale.astype(np.float32)
        t[f"{name}.bn_scale"] = l.bn_scale
        t[f"{name}.bn_shift"] = l.bn_shift
        t[f"{name}.act_exp"] = np.array([l.act_exp], np.int32)
        t[f"{name}.w_bits"] = np.array([l.w_bits], np.int32)
        rq_mult, rq_shift, rq_bias = derive_requant(
            np.asarray(l.w_scale, np.float32),
            np.asarray(l.bn_scale, np.float32),
            np.asarray(l.bn_shift, np.float32),
        )
        t[f"{name}.rq_mult"] = rq_mult
        t[f"{name}.rq_shift"] = rq_shift
        t[f"{name}.rq_bias"] = rq_bias
    t["fc.wq"] = qm.fc_wq
    t["fc.scale"] = qm.fc_scale.astype(np.float32)
    t["fc.b"] = qm.fc_b
    t["meta.in_exp"] = np.array([qm.in_exp], np.int32)
    t["meta.feat_exp"] = np.array([qm.feat_exp], np.int32)
    t["meta.cluster"] = np.array([qm.cfg.cluster], np.int32)
    t["meta.w_bits"] = np.array([qm.cfg.w_bits], np.int32)
    t["meta.requant_version"] = np.array([REQUANT_VERSION], np.int32)
    write_dft(path, t)
    _verify_export(path, t)


def _verify_export(path: str, written: dict) -> None:
    """Read an export straight back, re-verifying every v2 checksum.

    The read walks the same FNV-1a validation the rust loader uses, so a
    torn write or filesystem corruption fails here at export time instead
    of at serve time on another machine.
    """
    back = read_dft(path)
    if set(back) != set(written):
        missing = sorted(set(written) ^ set(back))
        raise ArtifactError(f"{path}: read-back tensor set mismatch: {missing}")
    for name, arr in written.items():
        if not np.array_equal(back[name], np.ascontiguousarray(arr)):
            raise ArtifactError(f"{path}: read-back payload mismatch in '{name}'")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(HERE, "..", "..", "artifacts"))
    ap.add_argument("--batches", type=int, nargs="*", default=list(BATCH_SIZES))
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    ap.add_argument("--n-eval", type=int, default=1024)
    ap.add_argument("--calib-n", type=int, default=256)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    spec = ModelSpec()
    params = ensure_weights(spec)
    ex, ey = D.make_split(args.n_eval, seed=2)
    calib = ex[: args.calib_n]

    manifest = {
        "img": spec.img, "channels": list(spec.channels),
        "classes": spec.classes, "batch_sizes": list(args.batches),
        "variants": {},
    }

    fp_acc = eval_fp(params, spec, ex, ey)
    print(f"fp32 eval accuracy: {fp_acc:.4f}")

    for tag in args.variants:
        cfg = VARIANTS[tag]
        if cfg is None:
            fwd = lambda x: (forward_fp(params, x, spec),)
            acc = fp_acc
        else:
            qm = build_qmodel(params, spec, cfg, calib)
            acc = eval_qmodel(qm, ex, ey, engine="sim")
            export_qweights(os.path.join(args.out, f"qweights_{tag}.dft"), qm)
            fwd = lambda x, qm=qm: (forward_quant(qm, x, engine="pallas"),)
        print(f"variant {tag}: eval_acc {acc:.4f}")
        files = {}
        for b in args.batches:
            shape = jax.ShapeDtypeStruct((b, spec.img, spec.img, 3), jnp.float32)
            lowered = jax.jit(fwd).lower(shape)
            text = to_hlo_text(lowered)
            fname = f"model_{tag}_b{b}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            files[str(b)] = fname
            print(f"  wrote {fname} ({len(text)//1024} KiB)")
        manifest["variants"][tag] = {
            "files": files, "eval_acc": acc,
            "w_bits": cfg.w_bits if cfg else 32,
            "cluster": cfg.cluster if cfg else 0,
            # quantized variants ship versioned integer-requant tensors in
            # their qweights export; fp32 has no quantized weights (tag 0)
            "requant_version": REQUANT_VERSION if cfg else 0,
        }

    # eval data for the rust drivers (images f32, labels i32)
    eval_t = {"images": ex[:256], "labels": ey[:256].astype(np.int32)}
    eval_path = os.path.join(args.out, "eval_data.dft")
    write_dft(eval_path, eval_t)
    _verify_export(eval_path, eval_t)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
