"""Cluster-based low-precision quantization (paper Algorithms 1 & 2) + DFP.

Dynamic fixed point (DFP): a tensor is represented as integers sharing one
power-of-two exponent, value = q * 2**exp. Weights additionally carry one
scaling factor per *cluster* of N output filters; for the 2-bit (ternary)
path the scale is the RMS alpha of Algorithm 1, itself re-quantized to an
8-bit mantissa so no datum in the pipeline is wider than 8 bits
(accumulators are 32-bit, as in the paper's "8-bit accumulation" MACs).

This module is mirrored by rust/src/quant/ (bit-for-bit on Ŵ and α̂ — see
rust/tests/integration_quant.rs).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

# --------------------------------------------------------------------------
# Dynamic fixed point primitives
# --------------------------------------------------------------------------


def qmax(bits: int) -> int:
    """Largest magnitude representable in a signed `bits`-bit integer, symmetric."""
    return (1 << (bits - 1)) - 1


def choose_exp(max_abs: float, bits: int) -> int:
    """Smallest exponent e with max_abs <= qmax * 2**e (DFP range fit)."""
    if max_abs <= 0.0:
        return 0
    return int(math.ceil(math.log2(max_abs / qmax(bits))))


def quantize_dfp(x: np.ndarray, bits: int, exp: Optional[int] = None) -> Tuple[np.ndarray, int]:
    """f32 -> (int q, exp) with value = q * 2**exp, round-to-nearest-even."""
    if exp is None:
        exp = choose_exp(float(np.max(np.abs(x))) if x.size else 0.0, bits)
    scale = 2.0 ** (-exp)
    q = np.clip(np.rint(x * scale), -qmax(bits), qmax(bits))
    dt = np.int8 if bits <= 8 else np.int32
    return q.astype(dt), exp


def dequantize_dfp(q: np.ndarray, exp: int) -> np.ndarray:
    return q.astype(np.float32) * np.float32(2.0**exp)


def quantize_scale_u8(alpha: float) -> Tuple[int, int]:
    """Positive scale -> (mantissa in [0,255], exp) with alpha ~= m * 2**exp.

    Mantissa is normalized into [128, 255] for maximum precision (paper §3.1:
    "we further quantize the scaling factors down to 8-bit").
    """
    if alpha <= 0.0:
        return 0, 0
    e = int(math.floor(math.log2(alpha))) - 7  # puts m in [128, 255]
    m = int(round(alpha / 2.0**e))
    if m > 255:  # rounding pushed it over; renormalize
        m //= 2
        e += 1
    return m, e


def dequantize_scale_u8(m: int, e: int) -> float:
    return float(m) * 2.0**e


# --------------------------------------------------------------------------
# Algorithm 2 — per-filter threshold selection (RMS alpha)
# --------------------------------------------------------------------------


def threshold_select(w: np.ndarray) -> float:
    """Paper Algorithm 2: best RMS alpha over sorted-magnitude prefixes.

    For support I_t = top-t |w|, alpha_t = sqrt(sum_{I_t} w^2 / t), and the
    approximation error with Ŵ = sign(w) on I_t is
        E(t) = sum w^2 - 2 alpha_t * S1(t) + alpha_t^2 * t
    (vectorized over all prefixes via cumulative sums). Returns alpha_{t*}.
    """
    a = np.sort(np.abs(w.ravel().astype(np.float64)))[::-1]
    if a.size == 0 or a[0] == 0.0:
        return 0.0
    s1 = np.cumsum(a)
    s2 = np.cumsum(a * a)
    t = np.arange(1, a.size + 1, dtype=np.float64)
    alpha_t = np.sqrt(s2 / t)
    total = s2[-1]
    err = total - 2.0 * alpha_t * s1 + alpha_t * alpha_t * t
    return float(alpha_t[int(np.argmin(err))])


# --------------------------------------------------------------------------
# Algorithm 1 — cluster ternarization
# --------------------------------------------------------------------------


@dataclass
class TernaryLayer:
    """Ternarized weights for one layer (HWIO, clusters along O)."""

    wq: np.ndarray              # int8 in {-1,0,+1}, HWIO
    alpha: np.ndarray           # f32 per output filter (the dequantized α̂)
    alpha_mant: np.ndarray      # u8 mantissa per cluster
    alpha_exp: np.ndarray       # i32 exponent per cluster
    cluster_size: int
    cluster_of: np.ndarray      # i32 map: filter -> cluster index

    def dequantize(self) -> np.ndarray:
        return self.wq.astype(np.float32) * self.alpha[None, None, None, :]


def ternarize_cluster(wc: np.ndarray, mode: str = "paper") -> Tuple[np.ndarray, float]:
    """Cluster ternarization — one cluster of N filters.

    wc: (n_elems_per_filter, N) column-per-filter view of the cluster.

    mode="paper" — Algorithm 1 steps 4-8 verbatim: Algorithm 2 per filter
    gives candidate thresholds alpha_i; for each t the candidate cluster
    scale is the RMS of the top-t alphas and *doubles as the pruning
    threshold* (step 7: Ŵ_i = Sign(W_i) if |W_i| >= alpha_t). The RMS
    coupling "pushes the threshold towards larger values ... helps speed
    up weight pruning" (§3.1) — i.e. it is deliberately aggressive; on
    heavily over-parameterized nets (ResNet-101) accuracy survives, on
    small nets it needs the decoupled mode below (see DESIGN.md §2).

    mode="support" — decoupled variant (cluster-level Algorithm 2): the
    support is the top-τ pooled |W| by *count*, alpha is the RMS over that
    support (eq. 1), and τ is searched to minimize the Frobenius error.
    Contains exact-ternary recovery as a fixed point.
    """
    absw = np.abs(wc.astype(np.float64))
    total = float(np.sum(absw * absw))
    if mode == "support":
        a = np.sort(absw.ravel())[::-1]
        if a.size == 0 or a[0] == 0.0:
            return np.zeros_like(wc, dtype=np.int8), 0.0
        s1, s2 = np.cumsum(a), np.cumsum(a * a)
        t = np.arange(1, a.size + 1, dtype=np.float64)
        alpha_t = np.sqrt(s2 / t)
        err = total - 2.0 * alpha_t * s1 + alpha_t * alpha_t * t
        k = int(np.argmin(err))
        best_alpha, thresh = float(alpha_t[k]), float(a[k])
        wq = (np.sign(wc) * (absw >= thresh)).astype(np.int8)
        return wq, best_alpha

    n = wc.shape[1]
    alphas = np.array([threshold_select(wc[:, j]) for j in range(n)], dtype=np.float64)
    a_sorted = np.sort(alphas)[::-1]
    best_err, best_alpha = math.inf, 0.0
    for t in range(1, n + 1):
        alpha_t = math.sqrt(float(np.sum(a_sorted[:t] ** 2)) / t)
        mask = absw >= alpha_t
        s1 = float(np.sum(absw[mask]))
        cnt = int(np.count_nonzero(mask))
        err = total - 2.0 * alpha_t * s1 + alpha_t * alpha_t * cnt
        if err < best_err:
            best_err, best_alpha = err, alpha_t
    wq = (np.sign(wc) * (absw >= best_alpha)).astype(np.int8)
    return wq, best_alpha


def ternarize_layer(w: np.ndarray, cluster_size: int, mode: str = "paper") -> TernaryLayer:
    """Paper Algorithm 1 over a full HWIO weight tensor.

    Output filters are grouped into static clusters of `cluster_size`
    consecutive filters (they accumulate into the same output feature map,
    §3: "static clustering to group filters that accumulate to the same
    output"). The final cluster may be smaller when d % N != 0.
    """
    if w.ndim == 2:  # FC layer (in, out) -> treat as 1x1xIxO
        w = w[None, None, :, :]
        squeeze = True
    else:
        squeeze = False
    kh, kw, ci, co = w.shape
    flat = w.reshape(-1, co)
    wq = np.zeros_like(flat, dtype=np.int8)
    alpha = np.zeros(co, dtype=np.float32)
    n_clusters = (co + cluster_size - 1) // cluster_size
    mants = np.zeros(n_clusters, dtype=np.uint8)
    exps = np.zeros(n_clusters, dtype=np.int32)
    cluster_of = np.zeros(co, dtype=np.int32)
    for c in range(n_clusters):
        lo, hi = c * cluster_size, min((c + 1) * cluster_size, co)
        wq_c, a = ternarize_cluster(flat[:, lo:hi], mode=mode)
        m, e = quantize_scale_u8(a)
        a_hat = dequantize_scale_u8(m, e)
        wq[:, lo:hi] = wq_c
        alpha[lo:hi] = a_hat
        mants[c], exps[c] = m, e
        cluster_of[lo:hi] = c
    wq = wq.reshape(kh, kw, ci, co)
    if squeeze:
        wq = wq[0, 0]
    return TernaryLayer(wq, alpha, mants, exps, cluster_size, cluster_of)


# --------------------------------------------------------------------------
# TWN baseline (Li et al. [7]) — for experiment E8
# --------------------------------------------------------------------------


def ternarize_twn(w: np.ndarray) -> Tuple[np.ndarray, float]:
    """Li et al. threshold Δ = 0.7·E|w|, α = mean |w| over support (one per
    layer — the baseline Algorithm 1 is compared against)."""
    a = np.abs(w.astype(np.float64))
    delta = 0.7 * float(np.mean(a))
    mask = a > delta
    alpha = float(np.mean(a[mask])) if mask.any() else 0.0
    wq = (np.sign(w) * mask).astype(np.int8)
    return wq, alpha


# --------------------------------------------------------------------------
# k-bit clustered DFP weights (4-bit / 8-bit paths)
# --------------------------------------------------------------------------


@dataclass
class DfpLayer:
    """k-bit DFP weights with one power-of-two exponent per cluster."""

    wq: np.ndarray              # int8 holding k-bit values, HWIO
    exp: np.ndarray             # i32 exponent per cluster
    bits: int
    cluster_size: int
    cluster_of: np.ndarray

    def scales(self) -> np.ndarray:
        """Per-filter f32 scale (2**exp broadcast over the cluster)."""
        return (2.0 ** self.exp.astype(np.float64))[self.cluster_of].astype(np.float32)

    def dequantize(self) -> np.ndarray:
        s = self.scales()
        if self.wq.ndim == 2:
            return self.wq.astype(np.float32) * s[None, :]
        return self.wq.astype(np.float32) * s[None, None, None, :]


def quantize_layer_dfp(w: np.ndarray, bits: int, cluster_size: int) -> DfpLayer:
    """k-bit dynamic fixed point with per-cluster shared exponent."""
    if w.ndim == 2:
        flat, co, shape2d = w, w.shape[1], True
    else:
        co, shape2d = w.shape[3], False
        flat = w.reshape(-1, co)
    n_clusters = (co + cluster_size - 1) // cluster_size
    wq = np.zeros_like(flat, dtype=np.int8)
    exps = np.zeros(n_clusters, dtype=np.int32)
    cluster_of = np.zeros(co, dtype=np.int32)
    for c in range(n_clusters):
        lo, hi = c * cluster_size, min((c + 1) * cluster_size, co)
        q, e = quantize_dfp(flat[:, lo:hi], bits)
        wq[:, lo:hi] = q
        exps[c] = e
        cluster_of[lo:hi] = c
    if not shape2d:
        wq = wq.reshape(w.shape)
    return DfpLayer(wq, exps, bits, cluster_size, cluster_of)


# --------------------------------------------------------------------------
# Quantization error metrics (E8)
# --------------------------------------------------------------------------


def sqnr_db(w: np.ndarray, w_hat: np.ndarray) -> float:
    """Signal-to-quantization-noise ratio in dB."""
    sig = float(np.sum(w.astype(np.float64) ** 2))
    noise = float(np.sum((w.astype(np.float64) - w_hat.astype(np.float64)) ** 2))
    if noise == 0.0:
        return math.inf
    return 10.0 * math.log10(sig / noise)
