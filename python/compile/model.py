"""L2 — ResNet-mini in JAX: FP32 training graph + quantized inference graph.

The model family mirrors the paper's ResNet-50/101 structure at laptop
scale (see DESIGN.md §2 for the substitution argument): a 3×3 stem,
three residual stages with both 3×3 convs and 1×1 projection shortcuts
(so the op-mix argument of §3.3 applies), BatchNorm after every conv,
global average pooling, and a linear classifier.

Two forward paths:

* ``forward_fp``       — plain f32 lax.conv graph used for training and as
                         the accuracy baseline.
* ``forward_quant``    — the paper's integer pipeline: int8 DFP activations,
                         cluster-quantized weights (ternary / 4-bit / 8-bit),
                         int32 accumulation, per-cluster α̂ scale, folded
                         (re-estimated) BatchNorm, requantization after every
                         layer. ``engine="sim"`` uses exact integer-valued
                         f32 ops (fast, vectorized — used for the accuracy
                         sweeps); ``engine="pallas"`` routes every GEMM
                         through the L1 kernels (used by pytest and the AOT
                         artifacts — bit-identical to "sim" by construction).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .kernels import qmatmul, quantize_act
from .kernels.ref import im2col

# --------------------------------------------------------------------------
# Architecture description
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvSpec:
    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    stride: int
    pad: int
    relu: bool        # ReLU after BN?
    residual: bool    # add skip connection output *before* ReLU


@dataclass(frozen=True)
class ModelSpec:
    """ResNet-mini: stem + `blocks_per_stage` basic blocks per stage."""

    img: int = 24
    channels: Tuple[int, ...] = (32, 64, 128)
    blocks_per_stage: int = 1
    classes: int = 10

    def conv_specs(self) -> List[ConvSpec]:
        specs = [ConvSpec("stem", 3, 3, 3, self.channels[0], 1, 1, True, False)]
        cin = self.channels[0]
        for s, ch in enumerate(self.channels):
            for b in range(self.blocks_per_stage):
                stride = 2 if (s > 0 and b == 0) else 1
                pre = f"s{s}b{b}"
                specs.append(ConvSpec(f"{pre}c1", 3, 3, cin, ch, stride, 1, True, False))
                specs.append(ConvSpec(f"{pre}c2", 3, 3, ch, ch, 1, 1, True, True))
                if stride != 1 or cin != ch:
                    specs.append(ConvSpec(f"{pre}proj", 1, 1, cin, ch, stride, 0, False, False))
                cin = ch
        return specs

    def feat_dim(self) -> int:
        return self.channels[-1]


# --------------------------------------------------------------------------
# Parameter init / containers  (params: flat dict name -> np/jnp array)
#   conv layers:  {name}.w (HWIO), {name}.{gamma,beta,mean,var}
#   classifier:   fc.w (D, classes), fc.b
# --------------------------------------------------------------------------


def init_params(spec: ModelSpec, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for cs in spec.conv_specs():
        fan_in = cs.kh * cs.kw * cs.cin
        std = float(np.sqrt(2.0 / fan_in))
        params[f"{cs.name}.w"] = rng.normal(0, std, (cs.kh, cs.kw, cs.cin, cs.cout)).astype(np.float32)
        params[f"{cs.name}.gamma"] = np.ones(cs.cout, np.float32)
        params[f"{cs.name}.beta"] = np.zeros(cs.cout, np.float32)
        params[f"{cs.name}.mean"] = np.zeros(cs.cout, np.float32)
        params[f"{cs.name}.var"] = np.ones(cs.cout, np.float32)
    d = spec.feat_dim()
    params["fc.w"] = rng.normal(0, np.sqrt(1.0 / d), (d, spec.classes)).astype(np.float32)
    params["fc.b"] = np.zeros(spec.classes, np.float32)
    return params


# --------------------------------------------------------------------------
# FP32 forward (training / baseline)
# --------------------------------------------------------------------------

BN_EPS = 1e-5


def _conv(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def forward_fp(params, x, spec: ModelSpec, train: bool = False):
    """f32 forward. train=True uses batch statistics and also returns them
    (for updating the running BN stats outside)."""
    batch_stats = {}

    def bn(name, y):
        if train:
            mu = jnp.mean(y, axis=(0, 1, 2))
            var = jnp.var(y, axis=(0, 1, 2))
            batch_stats[name] = (mu, var)
        else:
            mu, var = params[f"{name}.mean"], params[f"{name}.var"]
        inv = jax.lax.rsqrt(var + BN_EPS)
        return (y - mu) * inv * params[f"{name}.gamma"] + params[f"{name}.beta"]

    specs = {cs.name: cs for cs in spec.conv_specs()}

    def apply_conv(name, h):
        cs = specs[name]
        y = _conv(h, params[f"{name}.w"], cs.stride, cs.pad)
        return bn(name, y)

    h = jax.nn.relu(apply_conv("stem", x))
    cin = spec.channels[0]
    for s, ch in enumerate(spec.channels):
        for b in range(spec.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            pre = f"s{s}b{b}"
            skip = h
            h1 = jax.nn.relu(apply_conv(f"{pre}c1", h))
            h2 = apply_conv(f"{pre}c2", h1)
            if stride != 1 or cin != ch:
                skip = apply_conv(f"{pre}proj", skip)
            h = jax.nn.relu(h2 + skip)
            cin = ch
    feat = jnp.mean(h, axis=(1, 2))
    logits = feat @ params["fc.w"] + params["fc.b"]
    return (logits, batch_stats) if train else logits


# --------------------------------------------------------------------------
# Quantized model construction
# --------------------------------------------------------------------------


@dataclass
class QuantConfig:
    w_bits: int = 2            # 2 (ternary), 4 or 8
    cluster: int = 4           # N — filters per cluster (paper §3)
    a_bits: int = 8
    first_layer_bits: int = 8  # C1 stays 8-bit (paper §3.2)
    fc_bits: Optional[int] = None  # None -> same as w_bits
    recompute_bn: bool = True  # §3.2 BN re-estimation
    ternary_mode: str = "support"  # "paper" (Alg 1 verbatim) | "support" (decoupled)
    calib_n: int = 256

    def tag(self) -> str:
        t = f"{self.a_bits}a{self.w_bits}w_n{self.cluster}"
        if self.w_bits == 2 and self.ternary_mode != "support":
            t += f"_{self.ternary_mode}"
        return t


@dataclass
class QConvLayer:
    spec: ConvSpec
    wq: np.ndarray             # int8 (HWIO): {-1,0,1} ternary or k-bit values
    w_scale: np.ndarray        # f32 per output filter (α̂ or 2**exp)
    bn_scale: np.ndarray       # folded BN multiplier  (f32 per channel)
    bn_shift: np.ndarray       # folded BN offset
    act_exp: int = 0           # DFP exponent of this layer's *output* acts
    # metadata for rust export / op accounting
    cluster_size: int = 1
    w_bits: int = 8
    alpha_mant: Optional[np.ndarray] = None
    alpha_exp: Optional[np.ndarray] = None


@dataclass
class QModel:
    spec: ModelSpec
    cfg: QuantConfig
    layers: Dict[str, QConvLayer]
    fc_wq: np.ndarray
    fc_scale: np.ndarray
    fc_b: np.ndarray
    in_exp: int = 0            # input image DFP exponent
    feat_exp: int = 0          # pooled-feature DFP exponent (calibrated)


def _quantize_weights(w: np.ndarray, bits: int, cluster: int, mode: str = "support"):
    """Dispatch to Algorithm 1 (ternary) or k-bit clustered DFP."""
    if bits == 2:
        t = Q.ternarize_layer(w, cluster, mode=mode)
        return t.wq, t.alpha.astype(np.float32), t.alpha_mant, t.alpha_exp
    d = Q.quantize_layer_dfp(w, bits, cluster)
    return d.wq, d.scales(), None, d.exp


def build_qmodel(params: Dict[str, np.ndarray], spec: ModelSpec, cfg: QuantConfig,
                 calib_x: np.ndarray) -> QModel:
    """Quantize a trained FP32 model into the paper's integer pipeline.

    Calibration over `calib_x` (§3.2):
      1. quantized weights + original BN -> collect pre-BN channel stats,
         re-estimate BN (compensates the quantization variance shift);
      2. folded BN -> collect post-ReLU activation ranges -> freeze the
         per-layer DFP exponents.
    """
    layers: Dict[str, QConvLayer] = {}
    for cs in spec.conv_specs():
        w = params[f"{cs.name}.w"]
        bits = cfg.first_layer_bits if cs.name == "stem" else cfg.w_bits
        wq, w_scale, am, ae = _quantize_weights(w, bits, cfg.cluster, cfg.ternary_mode)
        layers[cs.name] = QConvLayer(
            spec=cs, wq=wq, w_scale=w_scale,
            bn_scale=np.ones(cs.cout, np.float32), bn_shift=np.zeros(cs.cout, np.float32),
            cluster_size=cfg.cluster, w_bits=bits, alpha_mant=am,
            alpha_exp=np.asarray(ae) if ae is not None else None,
        )

    fc_bits = cfg.fc_bits if cfg.fc_bits is not None else cfg.w_bits
    fc_wq, fc_scale, _, _ = _quantize_weights(params["fc.w"], fc_bits, cfg.cluster, cfg.ternary_mode)

    qm = QModel(spec=spec, cfg=cfg, layers=layers,
                fc_wq=fc_wq, fc_scale=fc_scale.astype(np.float32),
                fc_b=params["fc.b"].astype(np.float32))
    qm.in_exp = Q.choose_exp(float(np.max(np.abs(calib_x))), cfg.a_bits)

    # ---- pass 1: BN statistics under quantized weights (or reuse trained) --
    if cfg.recompute_bn:
        stats = _collect_bn_stats(qm, params, calib_x)
    else:
        stats = {n: (params[f"{n}.mean"], params[f"{n}.var"]) for n in layers}
    for name, (mu, var) in stats.items():
        g, b = params[f"{name}.gamma"], params[f"{name}.beta"]
        inv = 1.0 / np.sqrt(np.asarray(var) + BN_EPS)
        layers[name].bn_scale = (np.asarray(g) * inv).astype(np.float32)
        layers[name].bn_shift = (np.asarray(b) - np.asarray(mu) * np.asarray(g) * inv).astype(np.float32)

    # ---- pass 2: activation ranges -> DFP exponents ------------------------
    ranges, feat_max = _collect_act_ranges(qm, calib_x)
    for name, mx in ranges.items():
        layers[name].act_exp = Q.choose_exp(mx, cfg.a_bits)
    qm.feat_exp = Q.choose_exp(feat_max, cfg.a_bits)
    return qm


# ---- calibration helpers (f32 graph with quantized weights) ---------------


def _dequant_w(l: QConvLayer) -> jnp.ndarray:
    return jnp.asarray(l.wq, jnp.float32) * jnp.asarray(l.w_scale)[None, None, None, :]


def _collect_bn_stats(qm: QModel, params, calib_x) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    """Forward with quantized weights + *original* BN, recording pre-BN
    moments per conv — the paper's §3.2 variance-shift compensation."""
    spec, layers = qm.spec, qm.layers
    stats: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}

    def bn_batch(name, y):
        # Normalize with the *batch* statistics being recorded (train-mode
        # semantics): every layer then sees the input distribution it will
        # see at inference once the recomputed stats are folded in, so the
        # re-estimation is self-consistent through depth.
        mu, var = jnp.mean(y, (0, 1, 2)), jnp.var(y, (0, 1, 2))
        stats[name] = (np.asarray(mu), np.asarray(var))
        inv = jax.lax.rsqrt(var + BN_EPS)
        return (y - mu) * inv * params[f"{name}.gamma"] + params[f"{name}.beta"]

    def conv_q(name, h):
        l = layers[name]
        return bn_batch(name, _conv(h, _dequant_w(l), l.spec.stride, l.spec.pad))

    x = jnp.asarray(calib_x)
    h = jax.nn.relu(conv_q("stem", x))
    cin = spec.channels[0]
    for s, ch in enumerate(spec.channels):
        for b in range(spec.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            pre = f"s{s}b{b}"
            skip = h
            h1 = jax.nn.relu(conv_q(f"{pre}c1", h))
            h2 = conv_q(f"{pre}c2", h1)
            if stride != 1 or cin != ch:
                skip = conv_q(f"{pre}proj", skip)
            h = jax.nn.relu(h2 + skip)
            cin = ch
    return stats


def _collect_act_ranges(qm: QModel, calib_x) -> Tuple[Dict[str, float], float]:
    """Forward with quantized weights + folded BN, recording max |act| at
    every requantization point (post-ReLU / post-residual)."""
    spec, layers = qm.spec, qm.layers
    ranges: Dict[str, float] = {}

    def conv_bn(name, h):
        l = layers[name]
        y = _conv(h, _dequant_w(l), l.spec.stride, l.spec.pad)
        return y * jnp.asarray(l.bn_scale) + jnp.asarray(l.bn_shift)

    def record(name, h):
        ranges[name] = float(jnp.max(jnp.abs(h)))
        return h

    x = jnp.asarray(calib_x)
    h = record("stem", jax.nn.relu(conv_bn("stem", x)))
    cin = spec.channels[0]
    for s, ch in enumerate(spec.channels):
        for b in range(spec.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            pre = f"s{s}b{b}"
            skip = h
            h1 = record(f"{pre}c1", jax.nn.relu(conv_bn(f"{pre}c1", h)))
            h2 = conv_bn(f"{pre}c2", h1)
            if stride != 1 or cin != ch:
                skip = conv_bn(f"{pre}proj", h)
                ranges[f"{pre}proj"] = float(jnp.max(jnp.abs(skip)))
            h = record(f"{pre}c2", jax.nn.relu(h2 + skip))
            cin = ch
    feat_max = float(jnp.max(jnp.abs(jnp.mean(h, axis=(1, 2)))))
    return ranges, feat_max


# --------------------------------------------------------------------------
# Quantized inference forward
# --------------------------------------------------------------------------


def _gemm(engine, xq, wq_flat, scale):
    """int8 GEMM dispatch: pallas kernel or exact integer-valued f32 sim."""
    if engine == "pallas":
        return qmatmul(xq, wq_flat, scale)
    acc = xq.astype(jnp.float32) @ wq_flat.astype(jnp.float32)  # exact: |acc| < 2^24
    return acc * scale[None, :]


def _requant(engine, z, exp, a_bits):
    if engine == "pallas":
        return quantize_act(z, exp=int(exp), bits=a_bits)
    qmx = (1 << (a_bits - 1)) - 1
    return jnp.clip(jnp.round(z * (2.0 ** (-int(exp)))), -qmx, qmx).astype(jnp.int8)


def forward_quant(qm: QModel, x: jnp.ndarray, engine: str = "sim") -> jnp.ndarray:
    """The paper's inference pipeline on a f32 image batch -> f32 logits.

    Every intermediate activation tensor is int8 DFP; convolutions are
    integer GEMMs (int8 activations x int8/ternary weights -> int32). The
    previous layer's DFP exponent 2**exp_in is folded into the per-filter
    scale so the GEMM operands stay int8.
    """
    spec, cfg, layers = qm.spec, qm.cfg, qm.layers
    a_bits = cfg.a_bits

    def conv(name, hq, exp_in, relu=True, skip=None):
        l = layers[name]
        cs = l.spec
        cols, (n, ho, wo) = im2col(hq, cs.kh, cs.kw, cs.stride, cs.pad)
        wflat = jnp.asarray(l.wq.reshape(-1, cs.cout))
        scale = jnp.asarray(l.w_scale) * jnp.float32(2.0 ** exp_in)
        y = _gemm(engine, cols, wflat, scale).reshape(n, ho, wo, cs.cout)
        z = y * jnp.asarray(l.bn_scale) + jnp.asarray(l.bn_shift)
        if skip is not None:
            z = z + skip
        if relu:
            z = jnp.maximum(z, 0.0)
        return _requant(engine, z, l.act_exp, a_bits), z

    xq = _requant(engine, x, qm.in_exp, a_bits)
    hq, _ = conv("stem", xq, qm.in_exp)
    exp_h = layers["stem"].act_exp
    cin = spec.channels[0]
    for s, ch in enumerate(spec.channels):
        for b in range(spec.blocks_per_stage):
            stride = 2 if (s > 0 and b == 0) else 1
            pre = f"s{s}b{b}"
            h1q, _ = conv(f"{pre}c1", hq, exp_h)
            exp1 = layers[f"{pre}c1"].act_exp
            if stride != 1 or cin != ch:
                _, skip_f = conv(f"{pre}proj", hq, exp_h, relu=False)
            else:
                skip_f = hq.astype(jnp.float32) * jnp.float32(2.0 ** exp_h)
            hq, _ = conv(f"{pre}c2", h1q, exp1, relu=True, skip=skip_f)
            exp_h = layers[f"{pre}c2"].act_exp
            cin = ch

    feat = jnp.mean(hq.astype(jnp.float32) * jnp.float32(2.0 ** exp_h), axis=(1, 2))
    fq = _requant(engine, feat, qm.feat_exp, a_bits)
    logits = _gemm(engine, fq, jnp.asarray(qm.fc_wq),
                   jnp.asarray(qm.fc_scale) * jnp.float32(2.0 ** qm.feat_exp))
    return logits + jnp.asarray(qm.fc_b)


# --------------------------------------------------------------------------
# Utilities
# --------------------------------------------------------------------------


def accuracy(logits: jnp.ndarray, labels: np.ndarray) -> float:
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(labels)))


def eval_qmodel(qm: QModel, xs: np.ndarray, ys: np.ndarray, engine="sim", batch=256) -> float:
    correct = 0
    for i in range(0, len(xs), batch):
        logits = forward_quant(qm, jnp.asarray(xs[i : i + batch]), engine=engine)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])))
    return correct / len(xs)


def eval_fp(params, spec: ModelSpec, xs, ys, batch=256) -> float:
    fwd = jax.jit(lambda p, x: forward_fp(p, x, spec))
    correct = 0
    for i in range(0, len(xs), batch):
        logits = fwd(params, jnp.asarray(xs[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])))
    return correct / len(xs)
