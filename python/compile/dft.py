"""DFT — tiny binary tensor container for python <-> rust interchange.

v2 layout (little endian), the format `write_dft` emits:
    magic   b"DFT2"
    u32     tensor count
    per tensor:
        u16     name length, then utf-8 name bytes
        u8      dtype tag (0=f32, 1=i8, 2=i32, 3=u8, 4=i64)
        u8      ndim
        u32*    dims
        u64     payload byte length, then raw row-major data
        u64     FNV-1a 64 of the record (name-length field through payload)
    u64     FNV-1a 64 of every preceding byte (whole-file trailer)

v1 (b"DFT1") is the same layout without either checksum; `read_dft` still
accepts it. The rust reader/writer lives in rust/src/io/; integration tests
round-trip files written by each side through the other, and checksums are
verified on every v2 read so a corrupt export fails at load, not at serve.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC_V1 = b"DFT1"
MAGIC_V2 = b"DFT2"

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_U64 = (1 << 64) - 1

_DTYPE_TAGS = {
    np.dtype(np.float32): 0,
    np.dtype(np.int8): 1,
    np.dtype(np.int32): 2,
    np.dtype(np.uint8): 3,
    np.dtype(np.int64): 4,
}
_TAG_DTYPES = {v: k for k, v in _DTYPE_TAGS.items()}


def fnv1a(data: bytes) -> int:
    """FNV-1a 64-bit hash — the DFT v2 integrity checksum (mirrors rust)."""
    h = _FNV_OFFSET
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & _U64
    return h


class ArtifactError(ValueError):
    """A DFT file failed structural or checksum validation."""


def _encode_record(name: str, arr: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DTYPE_TAGS:
        raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
    nb = name.encode("utf-8")
    parts = [struct.pack("<H", len(nb)), nb,
             struct.pack("<BB", _DTYPE_TAGS[arr.dtype], arr.ndim)]
    for d in arr.shape:
        parts.append(struct.pack("<I", d))
    raw = arr.tobytes()
    parts.append(struct.pack("<Q", len(raw)))
    parts.append(raw)
    return b"".join(parts)


def write_dft(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name->array mapping as DFT v2 (checksummed)."""
    buf = bytearray()
    buf += MAGIC_V2
    buf += struct.pack("<I", len(tensors))
    for name, arr in tensors.items():
        rec = _encode_record(name, arr)
        buf += rec
        buf += struct.pack("<Q", fnv1a(rec))
    buf += struct.pack("<Q", fnv1a(bytes(buf)))
    with open(path, "wb") as f:
        f.write(bytes(buf))


def write_dft_v1(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write the legacy v1 layout (no checksums) — kept for compat tests."""
    with open(path, "wb") as f:
        f.write(MAGIC_V1)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            f.write(_encode_record(name, arr))


def read_dft(path: str) -> Dict[str, np.ndarray]:
    """Read a .dft file (v1 or v2) into a name->array mapping.

    v2 checksums (per-tensor and whole-file) are always verified; any
    mismatch, truncation, or unknown version raises ArtifactError naming
    the path (and tensor where known).
    """
    with open(path, "rb") as f:
        raw = f.read()

    magic = raw[:4]
    if magic == MAGIC_V1:
        version = 1
    elif magic == MAGIC_V2:
        version = 2
    elif magic[:3] == b"DFT":
        raise ArtifactError(f"{path}: unsupported DFT format version {magic[3:4]!r}")
    else:
        raise ArtifactError(f"{path}: bad magic {magic!r} (not a DFT file)")

    if version == 2:
        if len(raw) < 16:
            raise ArtifactError(f"{path}: truncated at offset {len(raw)}")
        (stored,) = struct.unpack("<Q", raw[-8:])
        computed = fnv1a(raw[:-8])
        if stored != computed:
            raise ArtifactError(
                f"{path}: whole-file checksum mismatch "
                f"(stored {stored:#018x}, computed {computed:#018x})")
        body_end = len(raw) - 8
    else:
        body_end = len(raw)

    pos = 4

    def take(n: int) -> bytes:
        nonlocal pos
        if pos + n > len(raw):
            raise ArtifactError(f"{path}: truncated at offset {pos}")
        s = raw[pos:pos + n]
        pos += n
        return s

    (count,) = struct.unpack("<I", take(4))
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        start = pos
        (nlen,) = struct.unpack("<H", take(2))
        name = take(nlen).decode("utf-8")
        tag, ndim = struct.unpack("<BB", take(2))
        if tag not in _TAG_DTYPES:
            raise ArtifactError(f"{path}: tensor '{name}': unknown dtype tag {tag}")
        dims = struct.unpack(f"<{ndim}I", take(4 * ndim)) if ndim else ()
        (blen,) = struct.unpack("<Q", take(8))
        data = take(blen)
        dt = _TAG_DTYPES[tag]
        expected = int(np.prod(dims, dtype=np.int64)) * dt.itemsize
        if blen != expected:
            raise ArtifactError(
                f"{path}: tensor '{name}': payload {blen} bytes != shape {list(dims)} * dtype")
        if version == 2:
            computed = fnv1a(raw[start:pos])
            (stored,) = struct.unpack("<Q", take(8))
            if stored != computed:
                raise ArtifactError(
                    f"{path}: checksum mismatch in tensor '{name}' "
                    f"(stored {stored:#018x}, computed {computed:#018x})")
        out[name] = np.frombuffer(data, dtype=dt).reshape(dims).copy()
    if pos != body_end:
        raise ArtifactError(
            f"{path}: corrupt: {body_end - pos} trailing bytes after last tensor record")
    return out
