"""DFT — tiny binary tensor container for python <-> rust interchange.

Layout (little endian):
    magic   b"DFT1"
    u32     tensor count
    per tensor:
        u16     name length, then utf-8 name bytes
        u8      dtype tag (0=f32, 1=i8, 2=i32, 3=u8, 4=i64)
        u8      ndim
        u32*    dims
        u64     payload byte length, then raw row-major data

The rust reader/writer lives in rust/src/io/; integration tests round-trip
files written by each side through the other.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"DFT1"

_DTYPE_TAGS = {
    np.dtype(np.float32): 0,
    np.dtype(np.int8): 1,
    np.dtype(np.int32): 2,
    np.dtype(np.uint8): 3,
    np.dtype(np.int64): 4,
}
_TAG_DTYPES = {v: k for k, v in _DTYPE_TAGS.items()}


def write_dft(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name->array mapping. Arrays are cast-checked, not converted."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPE_TAGS:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_TAGS[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_dft(path: str) -> Dict[str, np.ndarray]:
    """Read a .dft file back into a name->array mapping."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            tag, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (blen,) = struct.unpack("<Q", f.read(8))
            data = f.read(blen)
            dt = _TAG_DTYPES[tag]
            arr = np.frombuffer(data, dtype=dt).reshape(dims).copy()
            out[name] = arr
    return out
