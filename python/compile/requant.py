"""Integer-requantization export math (numpy + stdlib only, no jax).

Mirrors the rust derivation (`dfp::Requantizer::from_scale` /
`kernels::LayerRequant::derive`) so version-1 exports carry exactly the
multipliers the rust loader would otherwise re-derive from the f32
scales: the combined per-channel scale `s = w_scale * bn_scale`
(computed in f64) becomes `mult * 2^-shift` with `|mult|` normalized
into [2^30, 2^31) and the sign folded into the mantissa; `bn_shift` is
carried at BIAS_FRAC fraction bits. Kept free of jax imports so it is
unit-testable without an accelerator stack (`tests/test_requant_export.py`).
"""

from __future__ import annotations

import math

import numpy as np

# Version tag of the integer-requant export (mirrors rust
# `dfp::REQUANT_VERSION`): exports carrying it provide per-layer
# rq_mult/rq_shift/rq_bias tensors, so the rust loader skips its
# f32-derivation fallback.
REQUANT_VERSION = 1

# Fraction bits of the fixed-point bias lane (rust `dfp::BIAS_FRAC`).
BIAS_FRAC = 32


def _round_half_away(x: float) -> int:
    """f64 `.round()` semantics (ties away from zero), unlike python round."""
    return int(math.floor(x + 0.5)) if x >= 0.0 else int(math.ceil(x - 0.5))


def derive_requant(w_scale, bn_scale, bn_shift):
    """Per-channel integer requantization tensors (rq_mult, rq_shift, rq_bias).

    Raises ValueError on non-finite inputs or scales outside 2^±512,
    matching the rust loader's typed rejections.
    """
    n = len(w_scale)
    mult = np.zeros(n, np.int32)
    shift = np.zeros(n, np.int32)
    bias = np.zeros(n, np.int64)
    for c in range(n):
        s0 = float(np.float64(w_scale[c]) * np.float64(bn_scale[c]))
        if not math.isfinite(s0):
            raise ValueError(f"channel {c}: non-finite requant scale {s0}")
        if s0 != 0.0:
            # frexp gives |s0| = m * 2^e with m in [0.5, 1), exactly, so
            # floor(log2|s0|) == e - 1 without float-log rounding hazards
            _, e = math.frexp(abs(s0))
            sh = 31 - e  # == 30 - floor(log2 |s0|)
            if abs(e - 1) > 512:
                raise ValueError(f"channel {c}: requant scale out of range {s0}")
            mm = _round_half_away(abs(s0) * 2.0 ** sh)
            if mm == 1 << 31:
                # rounding bumped the mantissa out of range: renormalize
                mm >>= 1
                sh -= 1
            assert (1 << 30) <= mm < (1 << 31), (s0, mm)
            mult[c] = -mm if s0 < 0.0 else mm
            shift[c] = sh
        b = float(np.float64(bn_shift[c]))
        if not math.isfinite(b):
            raise ValueError(f"channel {c}: non-finite bn_shift {b}")
        bias[c] = _round_half_away(b * 2.0 ** BIAS_FRAC)
    return mult, shift, bias
