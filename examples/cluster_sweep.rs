//! E1/E2 — Fig 1 + §3.3 cluster-size trade-off, measured on *this* stack:
//! evaluates the exported quantized models through the pure-Rust integer
//! pipeline (lpinfer) and prints the accuracy-vs-precision table next to
//! the python sweep results (results/sweep.json) when present.
//!
//!     cargo run --release --example cluster_sweep [-- --n 128]

use anyhow::Result;
use dfp_infer::cli::Args;
use dfp_infer::io::read_dft;
use dfp_infer::json;
use dfp_infer::lpinfer::{forward_quant, QModelParams};
use dfp_infer::model::resnet_mini_default;
use dfp_infer::nn::argmax_rows;
use dfp_infer::tensor::Tensor;

fn main() -> Result<()> {
    let args = Args::from_env(false)?;
    let n: usize = args.get_or("n", 128)?;
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(dir.join("eval_data.dft").exists(), "run `make artifacts` first");

    let eval = read_dft(&dir.join("eval_data.dft"))?;
    let images = eval["images"].as_f32()?;
    let labels = eval["labels"].as_i32()?;
    let img = images.dim(1);
    let px = img * img * 3;
    let n = n.min(images.dim(0));
    let x = Tensor::new(&[n, img, img, 3], images.data()[..n * px].to_vec())?;
    let net = resnet_mini_default();

    // python full-sweep numbers, if the sweep has been run
    let sweep = std::fs::read_to_string("results/sweep.json")
        .ok()
        .and_then(|t| json::parse(&t).ok());
    let fp_ref = sweep
        .as_ref()
        .and_then(|s| s.path(&["fp32", "acc"]))
        .and_then(json::Json::as_f64);

    println!("Fig-1 reproduction (rust lpinfer on {n} images; python sweep in parens)");
    println!("{:<12} {:>10} {:>14}", "variant", "rust acc", "python (1024)");
    if let Some(fp) = fp_ref {
        println!("{:<12} {:>10} {:>14.4}", "fp32", "—", fp);
    }
    for tag in ["8a8w_n4", "8a4w_n4", "8a2w_n4", "8a2w_n64"] {
        let path = dir.join(format!("qweights_{tag}.dft"));
        if !path.exists() {
            continue;
        }
        let qmap = read_dft(&path)?;
        let params = QModelParams::from_tensors(&qmap, &net)?;
        let preds = argmax_rows(&forward_quant(&params, &net, &x));
        let correct = preds
            .iter()
            .zip(labels.data())
            .filter(|(p, l)| **p == **l as usize)
            .count();
        let py = sweep
            .as_ref()
            .and_then(|s| s.path(&[tag, "acc"]))
            .and_then(json::Json::as_f64)
            .map(|a| format!("{a:.4}"))
            .unwrap_or_else(|| "—".into());
        println!("{:<12} {:>10.4} {:>14}", tag, correct as f64 / n as f64, py);
    }
    println!("\n(full 3-bit-widths x 7-cluster-sizes sweep: python -m compile.eval_sweep;");
    println!(" table lands in results/sweep_table.md)");
    Ok(())
}
