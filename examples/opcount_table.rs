//! E3 — §3.3 performance-implication table for the paper's real networks:
//! op replacement and projected energy benefit per cluster size, under both
//! the paper's per-weight-block accounting and the output-stationary one.
//!
//!     cargo run --release --example opcount_table

use dfp_infer::model;
use dfp_infer::opcount;

fn main() {
    for name in ["resnet-18", "resnet-50", "resnet-101"] {
        let net = model::by_name(name).unwrap();
        println!(
            "\n== {} — {:.2} GMACs, {:.1} M weights, {:.0}% MACs in 3x3 layers ==",
            net.name,
            net.total_macs() as f64 / 1e9,
            net.total_weights() as f64 / 1e6,
            100.0 * net.frac_macs_3x3()
        );
        let schemes: Vec<_> =
            [1, 2, 4, 8, 16, 32, 64].iter().map(|&n| opcount::ternary_scheme(&net, n)).collect();
        println!("{}", opcount::table_3_3(&net, &schemes));
        let os4 = opcount::census_ternary_output_stationary(&net, 4);
        println!(
            "(output-stationary ablation, N=4: {:.1}% replaced — the α-scale\n\
             applied per output element instead of per N·K² weight block)",
            100.0 * os4.replaced_frac()
        );
    }
    println!("\npaper §3.3 anchors: ResNet-101 N=4 ≈ 85%, N=64 ≈ 98%; §5: ~16x benefit");
}
