//! E7 — end-to-end serving validation: the full coordinator stack (router,
//! dynamic batcher, PJRT worker, backpressure, metrics) under a closed-loop
//! synthetic ShapeSet load with mixed precision classes.
//!
//!     cargo run --release --example serve_demo [-- --requests 192 --max-wait-us 3000]

use anyhow::Result;
use dfp_infer::cli::Args;
use dfp_infer::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorFactory, PjrtExecutor, PrecisionClass, Request, Router,
};
use dfp_infer::data;
use dfp_infer::runtime::Manifest;
use dfp_infer::util::{Summary, Timer};

fn main() -> Result<()> {
    let args = Args::from_env(false)?;
    let n: usize = args.get_or("requests", 192)?;
    let max_wait: u64 = args.get_or("max-wait-us", 3_000)?;
    let dir = std::path::PathBuf::from("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    let manifest = Manifest::load(&dir.join("manifest.json"))?;
    let router = Router::from_manifest(&manifest)?;
    println!(
        "routes: fast->{}  balanced->{}  accurate->{}",
        router.route(PrecisionClass::Fast),
        router.route(PrecisionClass::Balanced),
        router.route(PrecisionClass::Accurate)
    );
    let sizes = manifest
        .variants
        .iter()
        .map(|(v, i)| (v.clone(), i.files.keys().copied().collect()))
        .collect();
    let factories: Vec<ExecutorFactory> = vec![PjrtExecutor::factory(dir, true)];
    let t_up = Timer::new();
    let coord = Coordinator::start(
        factories,
        router,
        &sizes,
        manifest.img,
        CoordinatorConfig { max_wait_us: max_wait, ..Default::default() },
    )?;
    println!("coordinator up in {:.1}s (all artifacts compiled)", t_up.elapsed_s());

    let protos = data::prototypes();
    let classes = [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate];
    let mut per_class: Vec<Summary> = vec![Summary::new(), Summary::new(), Summary::new()];
    let t = Timer::new();
    let mut rxs = Vec::new();
    for i in 0..n {
        let (img, label) = data::sample(&protos, 7, i as u64, 1.0);
        let rx = loop {
            match coord.submit(Request::new(img.clone(), classes[i % 3])) {
                Ok(rx) => break rx,
                Err(_) => std::thread::sleep(std::time::Duration::from_micros(200)),
            }
        };
        rxs.push((rx, label, i % 3));
    }
    let mut correct = [0usize; 3];
    let mut count = [0usize; 3];
    for (rx, label, cls) in rxs {
        let r = rx.recv()??;
        per_class[cls].add(r.e2e_us);
        correct[cls] += usize::from(r.predicted == label);
        count[cls] += 1;
    }
    let wall = t.elapsed_s();

    println!("\n== per-precision-class results ==");
    for (i, name) in ["fast", "balanced", "accurate"].iter().enumerate() {
        println!(
            "{:<9} acc {:.3}  latency {}",
            name,
            correct[i] as f64 / count[i] as f64,
            per_class[i].report("us")
        );
    }
    println!("\n== coordinator metrics ==\n{}", coord.metrics().report());
    println!("\ntotal: {n} requests in {wall:.2}s -> {:.1} req/s", n as f64 / wall);
    coord.shutdown();
    Ok(())
}
