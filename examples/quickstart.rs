//! Quickstart: load the AOT artifacts, classify one synthetic image at two
//! precisions, and show the op-count economics behind the choice.
//!
//!     cargo run --release --example quickstart
//!
//! (Run `make artifacts` first — it trains the baseline and exports the
//! quantized serving artifacts.)

use anyhow::{Context, Result};
use dfp_infer::data;
use dfp_infer::model;
use dfp_infer::opcount;
use dfp_infer::runtime::Engine;
use dfp_infer::tensor::Tensor;

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    // 1. the economics (paper §3.3): why serve ternary-clustered weights
    let net = model::resnet101();
    let n4 = opcount::census_ternary(&net, 4);
    let n64 = opcount::census_ternary(&net, 64);
    println!(
        "ResNet-101 op replacement: N=4 -> {:.1}%   N=64 -> {:.1}%",
        100.0 * n4.replaced_frac(),
        100.0 * n64.replaced_frac()
    );

    // 2. spin up the PJRT engine and classify one ShapeSet image
    let mut engine = Engine::new(dir)?;
    println!("PJRT platform: {}", engine.platform());
    let protos = data::prototypes();
    let (img, label) = data::sample(&protos, 42, 7, 1.0);
    let x = Tensor::new(&[1, data::IMG, data::IMG, 3], img.data().to_vec())?;

    for variant in ["fp32", "8a2w_n4"] {
        let info = engine.manifest.variants.get(variant).context("variant")?.clone();
        let exe = engine.load(variant, 1)?;
        let logits = exe.run(&x)?;
        let pred = logits
            .data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!(
            "{variant:<8} ({}b weights, N={}) -> predicted class {pred} (true {label})  offline acc {:.3}",
            info.w_bits, info.cluster, info.eval_acc
        );
    }
    Ok(())
}
