//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! This repository must build without registry access, so the subset of the
//! anyhow API that `dfp-infer` uses is reimplemented here as a path
//! dependency: [`Error`], [`Result`], the [`Context`] extension trait and
//! the `anyhow!` / `bail!` / `ensure!` macros. Semantics match upstream for
//! that subset:
//!
//! * `{e}` displays the outermost message, `{e:#}` the whole chain joined
//!   with `": "` (what `main.rs` prints);
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain;
//! * `.context(..)` / `.with_context(..)` work on `Result` (including
//!   `Result<T, Error>` itself) and on `Option`.

use std::fmt::{self, Debug, Display};

/// A dynamically typed error: an ordered chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

/// `Result<T, anyhow::Error>` with the usual default type parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost context first, root cause last.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

mod ext {
    use super::{Display, Error};

    /// Anything that can absorb a context message into an [`Error`].
    /// (Blanket impl for std errors + a concrete impl for `Error`; the two
    /// never overlap because `Error` does not implement `std::error::Error`,
    /// mirroring upstream anyhow's coherence setup.)
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> StdError for E {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait providing `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::StdError> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn test_display_plain_and_alternate() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn test_from_std_error_keeps_chain() {
        let e: Error = io_err().into();
        assert_eq!(e.root_cause(), "file gone");
    }

    #[test]
    fn test_context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: file gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn test_context_on_anyhow_result() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn test_macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
