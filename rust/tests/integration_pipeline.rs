//! End-to-end agreement of the pure-Rust pipelines with the trained model:
//! * `nn` (f32) reproduces the FP32 baseline accuracy;
//! * `lpinfer` (integer) reproduces the quantized accuracy of the exported
//!   model — the same numbers the jax "sim" path and the served artifacts
//!   produce.

mod common;

use common::{missing, repo_path};
use dfp_infer::io::read_dft;
use dfp_infer::lpinfer::{forward_quant, QModelParams};
use dfp_infer::model::resnet_mini_default;
use dfp_infer::nn::{argmax_rows, forward_fp, FpParams};
use dfp_infer::tensor::Tensor;

const N_EVAL: usize = 128; // scalar rust conv on 1 core — keep it modest

fn eval_subset() -> Option<(Tensor<f32>, Vec<i32>)> {
    if missing("artifacts/eval_data.dft") {
        return None;
    }
    let eval = read_dft(&repo_path("artifacts/eval_data.dft")).unwrap();
    let images = eval["images"].as_f32().unwrap();
    let labels = eval["labels"].as_i32().unwrap();
    let img = images.dim(1);
    let px = img * img * 3;
    let n = N_EVAL.min(images.dim(0));
    let x = Tensor::new(&[n, img, img, 3], images.data()[..n * px].to_vec()).unwrap();
    Some((x, labels.data()[..n].to_vec()))
}

#[test]
fn rust_fp32_pipeline_matches_baseline_accuracy() {
    if missing("models/weights_fp32.dft") {
        return;
    }
    let Some((x, labels)) = eval_subset() else { return };
    let net = resnet_mini_default();
    let weights = read_dft(&repo_path("models/weights_fp32.dft")).unwrap();
    let params = FpParams::from_tensors(&weights, &net).unwrap();
    let logits = forward_fp(&params, &net, &x);
    let preds = argmax_rows(&logits);
    let correct = preds.iter().zip(&labels).filter(|(p, l)| **p == **l as usize).count();
    let acc = correct as f64 / labels.len() as f64;
    eprintln!("rust nn fp32 accuracy on {} images: {acc:.4}", labels.len());
    // trained baseline is ~0.90; this subset measured 0.8945 via PJRT
    assert!(acc > 0.82, "fp32 rust pipeline accuracy {acc}");
}

#[test]
fn rust_integer_pipeline_matches_quantized_accuracy() {
    if missing("artifacts/qweights_8a2w_n4.dft") {
        return;
    }
    let Some((x, labels)) = eval_subset() else { return };
    let net = resnet_mini_default();
    let qmap = read_dft(&repo_path("artifacts/qweights_8a2w_n4.dft")).unwrap();
    let params = QModelParams::from_tensors(&qmap, &net).unwrap();
    params.validate(&net).unwrap();
    let logits = forward_quant(&params, &net, &x);
    let preds = argmax_rows(&logits);
    let correct = preds.iter().zip(&labels).filter(|(p, l)| **p == **l as usize).count();
    let acc = correct as f64 / labels.len() as f64;
    eprintln!("rust lpinfer 8a2w_n4 accuracy on {} images: {acc:.4}", labels.len());
    // python sim / served artifact measured 0.7891 on the 256-subset
    assert!(acc > 0.70, "integer pipeline accuracy {acc}");
    assert!(acc < 0.92, "integer pipeline suspiciously high: {acc}");
}

#[test]
fn integer_pipeline_tracks_fp_pipeline_on_same_inputs() {
    // quantized and fp32 logits should agree on most argmaxes
    if missing("models/weights_fp32.dft") || missing("artifacts/qweights_8a2w_n4.dft") {
        return;
    }
    let Some((x, _)) = eval_subset() else { return };
    let net = resnet_mini_default();
    let weights = read_dft(&repo_path("models/weights_fp32.dft")).unwrap();
    let fp = FpParams::from_tensors(&weights, &net).unwrap();
    let qmap = read_dft(&repo_path("artifacts/qweights_8a2w_n4.dft")).unwrap();
    let qp = QModelParams::from_tensors(&qmap, &net).unwrap();
    let n = 64.min(x.dim(0));
    let img = x.dim(1);
    let xs = Tensor::new(&[n, img, img, 3], x.data()[..n * img * img * 3].to_vec()).unwrap();
    let fp_preds = argmax_rows(&forward_fp(&fp, &net, &xs));
    let q_preds = argmax_rows(&forward_quant(&qp, &net, &xs));
    let agree = fp_preds.iter().zip(&q_preds).filter(|(a, b)| a == b).count();
    eprintln!("fp-vs-ternary argmax agreement: {agree}/{n}");
    assert!(agree as f64 / n as f64 > 0.7);
}
