//! Full-stack serving test: coordinator + PJRT executors + real artifacts
//! + the rust ShapeSet load generator (the E7 validation path).

mod common;

use common::{missing, repo_path};
use dfp_infer::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorFactory, PjrtExecutor, PrecisionClass, Request, Router,
};
use dfp_infer::data;
use dfp_infer::runtime::Manifest;

fn start_real() -> Option<Coordinator> {
    if missing("artifacts/manifest.json") {
        return None;
    }
    let dir = repo_path("artifacts");
    let manifest = Manifest::load(&dir.join("manifest.json")).unwrap();
    let router = Router::from_manifest(&manifest).unwrap();
    let sizes = manifest
        .variants
        .iter()
        .map(|(v, i)| (v.clone(), i.files.keys().copied().collect()))
        .collect();
    let factories: Vec<ExecutorFactory> = vec![PjrtExecutor::factory(dir, false)];
    Some(
        Coordinator::start(
            factories,
            router,
            &sizes,
            manifest.img,
            CoordinatorConfig { max_wait_us: 3_000, ..Default::default() },
        )
        .unwrap(),
    )
}

#[test]
fn serves_mixed_precision_load_end_to_end() {
    let Some(coord) = start_real() else { return };
    let protos = data::prototypes();
    let classes = [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate];
    let n = 24;
    let mut rxs = Vec::new();
    let mut labels = Vec::new();
    for i in 0..n {
        let (img, label) = data::sample(&protos, 0, i as u64, 1.0);
        labels.push(label);
        rxs.push(
            coord
                .submit(Request::new(img, classes[i % 3]))
                .unwrap(),
        );
    }
    let mut correct = 0;
    let mut variants_seen = std::collections::BTreeSet::new();
    for (rx, label) in rxs.into_iter().zip(labels) {
        let r = rx.recv().expect("response").expect("typed serve result");
        assert_eq!(r.logits.len(), 10);
        assert!(r.logits.iter().all(|v| v.is_finite()));
        variants_seen.insert(r.variant.clone());
        correct += usize::from(r.predicted == label);
    }
    let m = coord.metrics();
    eprintln!(
        "e2e: {}/{} correct, variants {:?}, occupancy {:.2}, batches {}",
        correct,
        n,
        variants_seen,
        m.occupancy(),
        m.batches
    );
    assert!(variants_seen.len() >= 2, "router should spread classes over variants");
    assert!(correct as f64 / n as f64 > 0.5, "mixed-precision accuracy above chance");
    assert_eq!(m.requests as usize, n);
    assert!(m.batches >= 1 && m.batches <= n as u64);
    coord.shutdown();
}

#[test]
fn metrics_latency_ordering_holds_under_load() {
    let Some(coord) = start_real() else { return };
    let protos = data::prototypes();
    let mut rxs = Vec::new();
    for i in 0..16 {
        let (img, _) = data::sample(&protos, 1, i as u64, 1.0);
        rxs.push(coord.submit(Request::new(img, PrecisionClass::Accurate)).unwrap());
    }
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert!(r.e2e_us >= r.queue_us, "e2e {} < queue {}", r.e2e_us, r.queue_us);
    }
    let m = coord.metrics();
    assert!(m.e2e_us_p99 >= m.e2e_us_p50);
    assert!(m.exec_us_p99 >= m.exec_us_p50);
    coord.shutdown();
}
