//! Steady-state allocation accounting for the forward pass.
//!
//! The acceptance bar of the forward-planning subsystem (DESIGN.md
//! §forward-plan): once a [`ForwardWorkspace`] has been sized by a warm-up
//! call, every subsequent `forward_quant_into` with the same batch shape
//! must perform **zero heap allocations** — input quantization, im2col (or
//! the 1×1 direct path), every fused GEMM, the residual lane, GAP, FC and
//! the logits write all run inside the arena.
//!
//! Measured with a counting global allocator wrapping the system one. The
//! guarantee holds at **any registry thread count**: multi-threaded GEMMs
//! dispatch row blocks onto the persistent `WorkerPool` from a
//! stack-resident job record (workers are spawned once, at registry
//! construction), so no spawn, channel send or box touches the heap on the
//! request path. Both a single-threaded and a threaded registry — the
//! latter with a B=4 batched forward — are asserted below. The model must
//! carry its load-built caches (epilogue cache + forward plan), which
//! every loader provides.
//!
//! This file deliberately contains a single #[test]: the counter is global,
//! and a concurrently running sibling test would pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dfp_infer::kernels::KernelRegistry;
use dfp_infer::lpinfer::{forward_quant_into, forward_quant_with, ForwardWorkspace, QModelParams};
use dfp_infer::model::{bottleneck_mini, resnet_mini};
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;
use dfp_infer::util::SplitMix64;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System` unchanged; the counter is a
// relaxed atomic increment with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_forward_makes_zero_heap_allocations() {
    let net = resnet_mini(8, &[4, 8, 8], 1, 3);
    let scheme = Scheme::parse("8a2w_n4@stem=i8").unwrap();
    // synthetic() builds the load-time caches exactly like the dft loader
    let params = QModelParams::synthetic(&net, 90, &scheme);
    assert!(!params.epilogues().is_empty(), "zero-alloc steady state needs the load-built caches");
    assert!(!params.forward_plan().is_empty());
    let reg = KernelRegistry::new(None, 1); // single-threaded baseline; threaded window below
    let mut rng = SplitMix64::new(91);
    let n = 2usize;
    let x = Tensor::new(&[n, 8, 8, 3], rng.normal(n * 8 * 8 * 3)).unwrap();

    let want = forward_quant_with(&params, &net, &x, &reg);

    let mut ws = ForwardWorkspace::new();
    let mut logits = vec![0f32; n * net.fc_out];
    // warm-up: sizes the arena (allocates) and faults the buffers in
    forward_quant_into(&params, &net, &x, &reg, &mut ws, &mut logits);
    assert_eq!(&logits[..], want.data(), "workspace path must match the allocating path");

    // steady state: repeat requests through the warmed arena. The per-layer
    // profiler and the engine counters are on (their defaults) — the zero
    // bar below is the proof that telemetry rides the steady state for free,
    // and snapshot() itself is allocation-free (it runs inside the window).
    logits.fill(0.0);
    let before = allocs();
    let eng_before = dfp_infer::telemetry::engine().snapshot();
    for _ in 0..3 {
        forward_quant_into(&params, &net, &x, &reg, &mut ws, &mut logits);
    }
    let eng_after = dfp_infer::telemetry::engine().snapshot();
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state forward_quant_into allocated {} time(s) over 3 requests (profiling on)",
        after - before
    );
    assert_eq!(&logits[..], want.data(), "steady-state logits must stay bit-exact");

    // the same window must have been fully observed by the engine counters:
    // 3 forwards, each dispatching one GEMM per conv (stem + 3 blocks of
    // c1/c2 + the s1/s2 projections = 9) plus the FC
    let d = eng_after.since(&eng_before);
    assert_eq!(d.forwards, 3, "engine must count each steady-state forward");
    assert_eq!(d.gemm_dispatches(), 30, "9 convs + fc per forward, 3 forwards");
    assert!(d.forward_ns > 0, "per-forward wall time must accumulate");

    // a smaller batch through the same arena also stays allocation-free
    // (buffers are a high-water mark, never shrunk)
    let x1 = Tensor::new(&[1, 8, 8, 3], rng.normal(8 * 8 * 3)).unwrap();
    let want1 = forward_quant_with(&params, &net, &x1, &reg);
    let mut logits1 = vec![0f32; net.fc_out];
    let before = allocs();
    forward_quant_into(&params, &net, &x1, &reg, &mut ws, &mut logits1);
    let after = allocs();
    assert_eq!(after - before, 0, "smaller batch must reuse the high-water arena");
    assert_eq!(&logits1[..], want1.data());

    // the bottleneck family (1x1-3x3-1x1 blocks, stem max pool, identity
    // *and* projection shortcuts): every step kind of the planned-arena
    // interpreter — Conv, ConvSkip, ConvToSkip, IdentitySkip, Pool — must
    // hold the same zero-allocation bar
    let bnet = bottleneck_mini(16, &[4, 8], 3);
    let bparams = QModelParams::synthetic(&bnet, 95, &scheme);
    assert!(!bparams.forward_plan().is_empty());
    let xb = Tensor::new(&[n, 16, 16, 3], rng.normal(n * 16 * 16 * 3)).unwrap();
    let wantb = forward_quant_with(&bparams, &bnet, &xb, &reg);
    let mut wsb = ForwardWorkspace::new();
    let mut logitsb = vec![0f32; n * bnet.fc_out];
    forward_quant_into(&bparams, &bnet, &xb, &reg, &mut wsb, &mut logitsb);
    assert_eq!(&logitsb[..], wantb.data(), "bottleneck workspace path must match");
    let before = allocs();
    for _ in 0..3 {
        forward_quant_into(&bparams, &bnet, &xb, &reg, &mut wsb, &mut logitsb);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "bottleneck steady-state forward allocated {} time(s) over 3 requests",
        after - before
    );
    assert_eq!(&logitsb[..], wantb.data(), "bottleneck steady-state logits must stay bit-exact");

    // the threaded path: GEMM row blocks now dispatch onto the persistent
    // WorkerPool from a stack-resident job record, and the latch/queue are
    // futex-backed — nothing on the request path touches the heap, so the
    // zero bar holds at threads > 1 exactly as it does single-threaded.
    // B=4 makes every stride-1 conv wide enough (4·8·8 = 256 rows) that
    // the splitter genuinely fans out instead of collapsing to one block.
    let reg2 = KernelRegistry::new(None, 2); // workers spawn here, before the window
    let b = 4usize;
    let x4 = Tensor::new(&[b, 8, 8, 3], rng.normal(b * 8 * 8 * 3)).unwrap();
    let want4 = forward_quant_with(&params, &net, &x4, &reg2);
    let mut ws4 = ForwardWorkspace::new();
    let mut logits4 = vec![0f32; b * net.fc_out];
    forward_quant_into(&params, &net, &x4, &reg2, &mut ws4, &mut logits4);
    assert_eq!(&logits4[..], want4.data(), "threaded batched workspace path must match");
    let before = allocs();
    for _ in 0..3 {
        forward_quant_into(&params, &net, &x4, &reg2, &mut ws4, &mut logits4);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "threaded B=4 steady-state forward allocated {} time(s) over 3 requests",
        after - before
    );
    assert_eq!(&logits4[..], want4.data(), "threaded batched logits must stay bit-exact");
}
