//! Property tests for the buffer liveness planner (DESIGN.md §graph).
//!
//! Two guarantees are load-bearing for the zero-allocation forward:
//!
//! 1. **Safety** — [`color_intervals`] never lets two simultaneously-live
//!    tensors overlap in arena bytes, for *any* set of lifetimes, not just
//!    the ones real networks produce. Checked over randomized interval
//!    sets including adversarial shapes (nested, chained, all-overlapping).
//! 2. **Economy** — on the real model families the planned arena never
//!    exceeds the legacy high-water sizing (input + two ping-pong slabs of
//!    the largest conv output), i.e. the planner is a pure win.

use dfp_infer::graph::{color_intervals, ArenaLayout, Lifetime};
use dfp_infer::lpinfer::ForwardPlan;
use dfp_infer::model::{bottleneck_mini, resnet101, resnet18, resnet50, resnet_mini};
use dfp_infer::util::SplitMix64;

/// The planner's contract, checked pairwise: tensors whose live intervals
/// overlap must occupy disjoint byte ranges, and every placement must fit
/// inside the reported total.
fn assert_layout_sound(reqs: &[Lifetime], layout: &ArenaLayout) {
    assert_eq!(layout.offsets.len(), reqs.len());
    for (i, r) in reqs.iter().enumerate() {
        assert!(
            layout.offsets[i] + r.size <= layout.total,
            "tensor {i} ([{}, {}] size {}) placed past the arena total {}",
            r.start,
            r.end,
            r.size,
            layout.total
        );
    }
    for a in 0..reqs.len() {
        for b in a + 1..reqs.len() {
            if !reqs[a].overlaps(&reqs[b]) || reqs[a].size == 0 || reqs[b].size == 0 {
                continue;
            }
            let (ao, bo) = (layout.offsets[a], layout.offsets[b]);
            let clash = ao < bo + reqs[b].size && bo < ao + reqs[a].size;
            assert!(
                !clash,
                "live tensors {a} ([{}, {}] @ {ao}+{}) and {b} ([{}, {}] @ {bo}+{}) share bytes",
                reqs[a].start,
                reqs[a].end,
                reqs[a].size,
                reqs[b].start,
                reqs[b].end,
                reqs[b].size,
            );
        }
    }
}

#[test]
fn prop_random_lifetimes_never_share_bytes_while_live() {
    let mut rng = SplitMix64::new(0x11FE);
    for case in 0..200u64 {
        let n = 1 + rng.next_below(24) as usize;
        let horizon = 1 + rng.next_below(32) as usize;
        let reqs: Vec<Lifetime> = (0..n)
            .map(|_| {
                let start = rng.next_below(horizon as u64) as usize;
                let end = start + rng.next_below((horizon - start) as u64 + 1) as usize;
                // zero-sized requests allowed: they must stay harmless
                let size = rng.next_below(65) as usize;
                Lifetime { size, start, end }
            })
            .collect();
        let layout = color_intervals(&reqs);
        assert_layout_sound(&reqs, &layout);
        // determinism: same requests, same layout
        let again = color_intervals(&reqs);
        assert_eq!(again.offsets, layout.offsets, "case {case} not deterministic");
        assert_eq!(again.total, layout.total);
    }
}

#[test]
fn adversarial_interval_shapes_stay_sound() {
    // everything alive at once: the arena must be the exact sum
    let all: Vec<Lifetime> =
        (0..8).map(|i| Lifetime { size: 16 + i, start: 0, end: 10 }).collect();
    let l = color_intervals(&all);
    assert_layout_sound(&all, &l);
    assert_eq!(l.total, all.iter().map(|r| r.size).sum::<usize>());

    // a strict chain: only neighbors overlap (at their shared step), so the
    // true peak demand is the largest adjacent pair; first-fit is greedy,
    // not optimal, but must land between that and the no-reuse sum
    let chain: Vec<Lifetime> =
        (0..8).map(|i| Lifetime { size: 8 * (i + 1), start: i, end: i + 1 }).collect();
    let l = color_intervals(&chain);
    assert_layout_sound(&chain, &l);
    let sum: usize = chain.iter().map(|r| r.size).sum();
    assert!(l.total >= 8 * 7 + 8 * 8 && l.total < sum, "total {}", l.total);

    // nested intervals: outer blocks every inner from offset 0
    let nested: Vec<Lifetime> = (0..6)
        .map(|i| Lifetime { size: 10, start: i, end: 11 - i })
        .collect();
    let l = color_intervals(&nested);
    assert_layout_sound(&nested, &l);
}

#[test]
fn planned_arena_never_exceeds_legacy_high_water_on_model_families() {
    let nets = [
        resnet_mini(8, &[4, 8, 8], 1, 3),
        resnet_mini(8, &[4, 8, 8], 2, 3),
        resnet_mini(8, &[5, 9, 13], 1, 3),
        resnet_mini(16, &[8, 16, 32], 2, 10),
        bottleneck_mini(8, &[2], 2),
        bottleneck_mini(16, &[4, 8], 3),
        resnet18(),
        resnet50(),
        resnet101(),
    ];
    for net in &nets {
        let plan = ForwardPlan::build(net)
            .unwrap_or_else(|e| panic!("{} must be plannable: {e}", net.name));
        assert!(plan.n_steps() > 0, "{}", net.name);
        let (planned, legacy) = (plan.planned_act_elems(), plan.legacy_act_elems());
        assert!(
            planned <= legacy,
            "{}: planned arena {planned} elems exceeds legacy high-water {legacy}",
            net.name
        );
    }
}
