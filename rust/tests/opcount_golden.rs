//! Golden op-census numbers for the paper-scale networks.
//!
//! The §3.3 claims are pure arithmetic over layer shapes, so they get
//! exact golden values rather than tolerance bands: any change to the
//! ResNet-50/101 layer tables, to the FC accounting, or to the per-block
//! multiply amortization (`mults = ceil(macs / N·K²)`) shows up as a hard
//! diff here. The paper's headline — ternary N=4 replaces ~85 % of
//! ResNet-101 multiplies with 8-bit accumulations — is the anchor.

use dfp_infer::model::{resnet101, resnet50};
use dfp_infer::opcount::{census_ternary, table_3_3, ternary_scheme};

#[test]
fn resnet50_census_matches_golden() {
    let net = resnet50();
    assert_eq!(net.layers.len(), 53, "1 stem + 16 blocks x 3 + 4 projections");
    assert_eq!(net.total_weights(), 25_502_912);

    let c4 = census_ternary(&net, 4);
    assert_eq!(c4.total_macs, 3_857_973_248);
    assert_eq!(c4.mults, 641_961_984);
    assert_eq!(c4.accums, 3_739_959_296);
    assert!((c4.replaced_frac() - 0.8336).abs() < 5e-4, "N=4 replaced {}", c4.replaced_frac());

    let c16 = census_ternary(&net, 16);
    assert!((c16.replaced_frac() - 0.9355).abs() < 5e-4, "N=16 replaced {}", c16.replaced_frac());
    let c64 = census_ternary(&net, 64);
    assert!((c64.replaced_frac() - 0.9609).abs() < 5e-4, "N=64 replaced {}", c64.replaced_frac());
}

#[test]
fn resnet101_census_matches_golden_and_paper_claim() {
    let net = resnet101();
    assert_eq!(net.layers.len(), 104, "1 stem + 33 blocks x 3 + 4 projections");
    assert_eq!(net.total_weights(), 44_442_816);

    let c4 = census_ternary(&net, 4);
    assert_eq!(c4.total_macs, 7_570_194_432);
    assert_eq!(c4.mults, 1_133_285_376);
    assert_eq!(c4.accums, 7_452_180_480);
    // the paper's §3.3 headline: N=4 "can potentially replace 85% of
    // multiplications in Resnet-101"
    assert!((c4.replaced_frac() - 0.8503).abs() < 5e-4, "N=4 replaced {}", c4.replaced_frac());

    let c16 = census_ternary(&net, 16);
    assert!((c16.replaced_frac() - 0.9509).abs() < 5e-4, "N=16 replaced {}", c16.replaced_frac());
    let c64 = census_ternary(&net, 64);
    assert!((c64.replaced_frac() - 0.9760).abs() < 5e-4, "N=64 replaced {}", c64.replaced_frac());
}

#[test]
fn replacement_fraction_monotone_and_cross_network_ordering() {
    // deeper net → 1x1/3x3 mix shifts → N=4 replaces slightly more on 101
    let f50 = census_ternary(&resnet50(), 4).replaced_frac();
    let f101 = census_ternary(&resnet101(), 4).replaced_frac();
    assert!(f101 > f50, "ResNet-101 {f101} vs ResNet-50 {f50}");
    for net in [resnet50(), resnet101()] {
        let mut last = 0.0;
        for n in [4usize, 16, 64] {
            let f = census_ternary(&net, n).replaced_frac();
            assert!(f > last, "{} N={n}: {f} <= {last}", net.name);
            last = f;
        }
    }
}

#[test]
fn table_rows_stay_greppable() {
    // the CI smoke (and the README excerpt) grep these exact cells
    let net = resnet101();
    let schemes = [ternary_scheme(&net, 4), ternary_scheme(&net, 64)];
    let t = table_3_3(&net, &schemes);
    assert!(t.contains("| 8a2w_n4@conv1=i8 | 1133285376 | 7452180480 | 85.0% |"), "{t}");
    assert!(t.contains("| 8a2w_n64@conv1=i8 |"), "{t}");
}
