//! Scheme grammar property tests: `Scheme::parse(s).to_string() == s` for
//! the legacy variant grammar and the extended `@layer=` override syntax,
//! plus JSON round-trips, override precedence and error cases.

use dfp_infer::scheme::{LayerPolicy, Scheme, WeightCodec};
use dfp_infer::testing::{check, Gen};
use dfp_infer::util::SplitMix64;

/// Generates canonical scheme strings: a random legacy base plus up to two
/// overrides. Override clusters are drawn from values never used as base
/// clusters, so the canonical form always prints them (`:nN`).
struct SchemeStrGen;

const BASES: [&str; 8] = ["2w", "2wp", "3w", "4w", "5w", "6w", "7w", "8w"];
const CLUSTERS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
const OV_PATTERNS: [&str; 5] = ["stem", "fc", "s0b0c1", "s2*", "*proj"];
const OV_CODECS: [&str; 5] = ["t", "tp", "i3", "i4", "i8"];
const OV_CLUSTERS: [usize; 3] = [3, 12, 48]; // disjoint from CLUSTERS

impl Gen for SchemeStrGen {
    type Value = String;

    fn generate(&self, rng: &mut SplitMix64) -> String {
        let base = BASES[rng.next_below(BASES.len() as u64) as usize];
        let n = CLUSTERS[rng.next_below(CLUSTERS.len() as u64) as usize];
        let mut s = format!("8a{base}_n{n}");
        for _ in 0..rng.next_below(3) {
            let pat = OV_PATTERNS[rng.next_below(OV_PATTERNS.len() as u64) as usize];
            let codec = OV_CODECS[rng.next_below(OV_CODECS.len() as u64) as usize];
            s.push_str(&format!("@{pat}={codec}"));
            if rng.next_below(2) == 1 {
                let c = OV_CLUSTERS[rng.next_below(OV_CLUSTERS.len() as u64) as usize];
                s.push_str(&format!(":n{c}"));
            }
        }
        s
    }

    fn shrink(&self, v: &String) -> Vec<String> {
        // drop the last override
        match v.rfind('@') {
            Some(i) => vec![v[..i].to_string()],
            None => Vec::new(),
        }
    }
}

#[test]
fn prop_scheme_string_roundtrip() {
    check(300, &SchemeStrGen, |s| {
        let scheme = Scheme::parse(s).map_err(|e| format!("'{s}' failed to parse: {e}"))?;
        let printed = scheme.to_string();
        if printed != *s {
            return Err(format!("'{s}' printed as '{printed}'"));
        }
        // JSON round-trip must reproduce the same scheme
        let back = Scheme::from_json(&scheme.to_json()).map_err(|e| format!("json: {e}"))?;
        if back != scheme {
            return Err(format!("'{s}' json round-trip mismatch"));
        }
        Ok(())
    });
}

#[test]
fn prop_policy_for_respects_bits_of_last_matching_override() {
    // structural property on generated schemes: for a literal layer name,
    // policy_for returns the policy of the LAST override matching it
    check(200, &SchemeStrGen, |s| {
        let scheme = Scheme::parse(s).map_err(|e| e.to_string())?;
        for layer in ["stem", "fc", "s0b0c1", "s2b0c2", "s1b0proj", "elsewhere"] {
            let got = scheme.policy_for(layer).clone();
            let want = scheme
                .overrides()
                .iter()
                .rev()
                .find(|(pat, _)| matches_name(pat, layer))
                .map(|(_, p)| p.clone())
                .unwrap_or_else(|| scheme.default_policy().clone());
            if got != want {
                return Err(format!("'{s}': policy_for({layer}) = {got:?}, want {want:?}"));
            }
        }
        Ok(())
    });
}

/// Independent (test-side) matcher for the patterns SchemeStrGen emits.
fn matches_name(pat: &str, name: &str) -> bool {
    match pat {
        "s2*" => name.starts_with("s2"),
        "*proj" => name.ends_with("proj"),
        p => p == name,
    }
}

#[test]
fn override_precedence_is_deterministic() {
    let tern = |n| LayerPolicy::new("t".parse::<WeightCodec>().unwrap(), n).unwrap();
    let i8p = |n| LayerPolicy::new(WeightCodec::I8, n).unwrap();
    let s = Scheme::uniform(8, tern(4))
        .unwrap()
        .with_override("s1*", i8p(4))
        .unwrap()
        .with_override("*c1", tern(64))
        .unwrap();
    // both globs match s1b0c1; the later one wins
    assert_eq!(s.policy_for("s1b0c1"), &tern(64));
    // only the first matches s1b0c2
    assert_eq!(s.policy_for("s1b0c2"), &i8p(4));
    // neither matches the stem
    assert_eq!(s.policy_for("stem"), &tern(4));
}

#[test]
fn unknown_layer_names_are_rejected_by_validation() {
    let known = ["stem", "s0b0c1", "s0b0c2", "fc"];
    assert!(Scheme::parse("8a2w_n4@stem=i8").unwrap().validate_layers(known).is_ok());
    let err = Scheme::parse("8a2w_n4@conv7=i8").unwrap().validate_layers(known).unwrap_err();
    assert!(err.to_string().contains("conv7"), "{err}");
    // a glob matching nothing is equally a configuration bug
    assert!(Scheme::parse("8a2w_n4@s9*=i8").unwrap().validate_layers(known).is_err());
}

#[test]
fn degenerate_schemes_fail_to_construct() {
    assert!(Scheme::parse("8a2w_n0").is_err(), "cluster 0 must be rejected");
    assert!(Scheme::parse("8a2w_n4@fc=i8:n0").is_err());
    assert!(Scheme::parse("fp32").is_err());
    assert!(Scheme::parse("8a2w_n4@@fc=i8").is_err());
    assert!(LayerPolicy::new(WeightCodec::Dfp { bits: 8 }, 4).is_err(), "dfp-8 is spelled i8");
}
