//! Fused-integer vs f32-reference requantization equivalence.
//!
//! Tolerance policy (DESIGN.md §requant): the fused epilogue's multiplier
//! is exact to one part in 2^31 and its bias/skip lanes carry ≥16 fraction
//! bits, so — measured in per-layer lockstep, where both paths consume the
//! same reference activations — a fused code can differ from the f32
//! reference only when the real pre-quantization value lies within a hair
//! of a round-half-even boundary: **at most 1 output code** at any
//! requantization point. That bound is asserted here across random scales,
//! every registry kernel, all schemes (N ∈ {4,16,64}, ternary/i4/i8 and
//! mixed) and thread counts. Free-running logits are additionally checked
//! for bit-identity across kernels/threads (the fused path is pure integer,
//! so kernel choice cannot change them).

use dfp_infer::kernels::{KernelRegistry, SimdTier, TierChoice, ALL_KERNELS};
use dfp_infer::lpinfer::{forward_quant_with, paths_divergence, QConvParams, QModelParams};
use dfp_infer::model::{bottleneck_mini, resnet50, resnet_mini};
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;
use dfp_infer::testing::{check, Gen};
use dfp_infer::util::SplitMix64;

const SCHEMES: [&str; 6] = [
    "8a2w_n4",
    "8a2w_n16",
    "8a2w_n64",
    "8a4w_n4",
    "8a8w_n4",
    "8a2w_n4@stem=i8@s2*=i4@fc=i8",
];

#[derive(Debug, Clone)]
struct Case {
    seed: u64,
    scheme: &'static str,
}

struct CaseGen;

impl Gen for CaseGen {
    type Value = Case;

    fn generate(&self, rng: &mut SplitMix64) -> Case {
        Case {
            seed: rng.next_u64(),
            scheme: SCHEMES[rng.next_below(SCHEMES.len() as u64) as usize],
        }
    }
}

/// A synthetic model with *randomized* per-channel scales: α̂-like w_scale
/// magnitudes spanning the realistic export envelope (2^-12..2^-5 — real
/// cluster scales track weight magnitudes, ~1e-3..1e-1), signed bn_scale
/// (BN folding can be negative), dead channels possible, large bn_shift
/// offsets and varied activation exponents. The envelope matters: the
/// 1-code bound is a statement about the *fused* path's error (≤ 2^-16 of
/// a grid step); with far larger scale products the f32 *reference's* own
/// rounding error passes half a grid step in residual-cancellation corners
/// and the comparison would measure the reference, not the fused path.
fn randomized_model(net: &dfp_infer::model::Network, seed: u64, scheme: &Scheme) -> QModelParams {
    let mut params = QModelParams::synthetic(net, seed, scheme);
    let mut rng = SplitMix64::new(seed ^ 0xBEEF);
    let names: Vec<String> = params.convs().keys().cloned().collect();
    for n in &names {
        let (wq, policy, cout) = {
            let p = &params.convs()[n];
            (p.wq.clone(), p.policy.clone(), p.w_scale.len())
        };
        let w_scale: Vec<f32> = (0..cout)
            .map(|_| {
                2f32.powi(-6 - rng.next_below(7) as i32)
                    * (1.0 + rng.next_below(100) as f32 / 100.0)
            })
            .collect();
        let bn_scale: Vec<f32> =
            (0..cout).map(|_| (rng.next_below(300) as f32 - 150.0) / 100.0).collect();
        let bn_shift: Vec<f32> =
            (0..cout).map(|_| (rng.next_below(160) as f32 - 80.0) / 10.0).collect();
        let act_exp = -2 - rng.next_below(5) as i32;
        let rebuilt = QConvParams::new(wq, w_scale, bn_scale, bn_shift, act_exp, policy)
            .expect("finite randomized scales");
        // the invalidating setter: the epilogue cache is derived state, and
        // this is the only mutation path, so it can never go stale
        params.set_conv(n.clone(), rebuilt);
    }
    // restore the load-time cached epilogues (set_conv cleared them)
    params.rebuild_epilogues(net).expect("test nets are plannable");
    params
}

/// Tier settings every test machine can exercise: forced scalar plus the
/// best detected tier (which is also scalar on machines without SIMD).
fn test_tiers() -> [TierChoice; 2] {
    [TierChoice::Forced(SimdTier::Scalar), TierChoice::Auto]
}

#[test]
fn prop_fused_requant_within_one_code_of_f32_reference() {
    check(10, &CaseGen, |case| {
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let scheme = Scheme::parse(case.scheme).map_err(|e| e.to_string())?;
        let params = randomized_model(&net, case.seed, &scheme);
        params.validate(&net).map_err(|e| e.to_string())?;
        let mut rng = SplitMix64::new(case.seed ^ 1);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        for kind in ALL_KERNELS {
            for tier in test_tiers() {
                for threads in [1usize, 2, 4] {
                    let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                    let d = paths_divergence(&params, &net, &x, &reg);
                    if d.max_code_ulp > 1 {
                        return Err(format!(
                            "scheme={} kernel={kind} tier={tier} threads={threads}: lockstep divergence {} codes (bound 1)",
                            case.scheme, d.max_code_ulp
                        ));
                    }
                    if !d.logit_max_abs_diff.is_finite() {
                        return Err(format!(
                            "scheme={} kernel={kind} tier={tier} threads={threads}: non-finite logit divergence",
                            case.scheme
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fused_logits_bit_identical_across_kernels_tiers_and_threads() {
    // the integer path has no float on it, so kernel/tier/thread choice
    // must not move a single bit of the logits — even with adversarial
    // scales, and on channel counts that leave SIMD tail lanes (5/9/13)
    for (neti, net) in
        [resnet_mini(8, &[4, 8, 8], 1, 3), resnet_mini(8, &[5, 9, 13], 1, 3)].iter().enumerate()
    {
        for (i, variant) in SCHEMES.iter().enumerate() {
            let scheme = Scheme::parse(variant).unwrap();
            let params = randomized_model(net, 4000 + 100 * neti as u64 + i as u64, &scheme);
            let mut rng = SplitMix64::new(4100 + i as u64);
            let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
            let want = forward_quant_with(&params, net, &x, &KernelRegistry::auto());
            assert!(want.data().iter().all(|v| v.is_finite()), "{variant}");
            for kind in ALL_KERNELS {
                for tier in test_tiers() {
                    for threads in [1usize, 2, 4] {
                        let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                        let got = forward_quant_with(&params, net, &x, &reg);
                        assert_eq!(
                            got.data(),
                            want.data(),
                            "net={neti} scheme={variant} kernel={kind} tier={tier} threads={threads}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn bottleneck_lockstep_and_bit_identity() {
    // ResNet-50-shaped bottleneck blocks (1x1-3x3-1x1, stem maxpool,
    // projection *and* identity shortcuts) through the planned step
    // interpreter, with the adversarial scale envelope
    for (bi, net) in
        [bottleneck_mini(16, &[4, 8], 3), bottleneck_mini(8, &[2], 2)].iter().enumerate()
    {
        let hw = net.input_hw;
        for (i, variant) in ["8a2w_n4", "8a4w_n4", "8a2w_n4@stem=i8"].iter().enumerate() {
            let scheme = Scheme::parse(variant).unwrap();
            let params = randomized_model(net, 7000 + 100 * bi as u64 + i as u64, &scheme);
            params.validate(net).unwrap();
            let mut rng = SplitMix64::new(7100 + 10 * bi as u64 + i as u64);
            let x = Tensor::new(&[2, hw, hw, 3], rng.normal(2 * hw * hw * 3)).unwrap();
            let d = paths_divergence(&params, net, &x, &KernelRegistry::auto());
            assert!(
                d.max_code_ulp <= 1,
                "{}: scheme={variant} lockstep divergence {} codes (bound 1)",
                net.name,
                d.max_code_ulp
            );
            let want = forward_quant_with(&params, net, &x, &KernelRegistry::auto());
            assert!(want.data().iter().all(|v| v.is_finite()), "{variant}");
            for kind in ALL_KERNELS {
                for threads in [1usize, 2] {
                    let reg = KernelRegistry::new(Some(kind), threads);
                    let got = forward_quant_with(&params, net, &x, &reg);
                    assert_eq!(
                        got.data(),
                        want.data(),
                        "{}: scheme={variant} kernel={kind} threads={threads}",
                        net.name
                    );
                }
            }
        }
    }
}

/// Full paper-scale lockstep: ResNet-50 at 224², ternary N=4 with an i8
/// stem, through every requantization point of all 53 convs. Minutes of
/// work — CI runs it in release mode via
/// `cargo test --release --test requant_equivalence -- --ignored`.
#[test]
#[ignore = "paper-scale; run in release mode with -- --ignored"]
fn full_scale_resnet50_lockstep_within_one_code() {
    let net = resnet50();
    let scheme = Scheme::parse("8a2w_n4@conv1=i8").unwrap();
    let params = QModelParams::synthetic(&net, 224, &scheme);
    params.validate(&net).unwrap();
    let mut rng = SplitMix64::new(225);
    let x = Tensor::new(&[1, 224, 224, 3], rng.normal(224 * 224 * 3)).unwrap();
    let d = paths_divergence(&params, &net, &x, &KernelRegistry::new(None, 4));
    assert!(d.max_code_ulp <= 1, "paper-scale lockstep divergence {} codes", d.max_code_ulp);
    assert!(d.logit_max_abs_diff.is_finite());
}

#[test]
fn benign_scales_stay_within_policy_bound() {
    // with the synthetic export's benign scales the two paths agree to
    // within the documented 1-code bound (in practice exactly: divergence
    // needs a value within float-eps of a rounding boundary)
    let net = resnet_mini(8, &[4, 8, 8], 1, 3);
    let params = QModelParams::synthetic(&net, 7, &Scheme::parse("8a2w_n4").unwrap());
    let mut rng = SplitMix64::new(8);
    let x = Tensor::new(&[1, 8, 8, 3], rng.normal(8 * 8 * 3)).unwrap();
    let d = paths_divergence(&params, &net, &x, &KernelRegistry::auto());
    assert!(d.max_code_ulp <= 1, "divergence {}", d.max_code_ulp);
}
