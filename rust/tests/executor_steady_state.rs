//! Steady-state allocation accounting for the serving executor.
//!
//! [`LpExecutor`] owns one `ForwardWorkspace` arena per worker, and the
//! coordinator hands `Executor::run_batch_into` a reusable per-worker
//! logits buffer — so after one warm-up batch, a steady-state request must
//! perform **zero heap allocations** end to end, at B > 1 and with a
//! multi-threaded kernel registry (the GEMMs dispatch row blocks onto the
//! persistent `WorkerPool` from a stack-resident job record).
//!
//! This file deliberately contains a single #[test]: the counter is global,
//! and a concurrently running sibling test would pollute the measurement.
//! (`alloc_steady_state.rs` covers the raw `forward_quant_into` path; this
//! one covers the executor/coordinator serving path on top of it.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use dfp_infer::coordinator::{Executor, LpExecutor};
use dfp_infer::kernels::KernelRegistry;
use dfp_infer::lpinfer::QModelParams;
use dfp_infer::model::resnet_mini;
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;
use dfp_infer::util::SplitMix64;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System` unchanged; the counter is a
// relaxed atomic increment with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn executor_steady_state_batches_make_zero_heap_allocations() {
    let net = resnet_mini(8, &[4, 8, 8], 1, 3);
    let scheme = Scheme::parse("8a2w_n4@stem=i8").unwrap();
    let params = QModelParams::synthetic(&net, 90, &scheme);
    let variants: BTreeMap<String, QModelParams> = [("8a2w_n4".to_string(), params)].into_iter().collect();
    // threaded registry: the steady-state bar must hold across the pool
    let mut exec = LpExecutor::new(net.clone(), variants, KernelRegistry::new(None, 2), vec![1, 4]).unwrap();

    let b = 4usize;
    let mut rng = SplitMix64::new(91);
    let x = Tensor::new(&[b, 8, 8, 3], rng.normal(b * 8 * 8 * 3)).unwrap();

    // the allocating wrapper is the oracle (and also warms nothing: it
    // builds a fresh logits tensor per call, exactly what serving avoids)
    let want = exec.run_batch("8a2w_n4", b, &x).unwrap();
    assert_eq!(want.shape(), &[b, 3]);
    assert!(want.data().iter().all(|v| v.is_finite()));

    // per-worker logits arena, as coordinator::worker_loop keeps it
    let mut logits = vec![0f32; b * net.fc_out];
    // warm-up: sizes the executor's workspace arena for this batch shape
    exec.run_batch_into("8a2w_n4", b, &x, &mut logits).unwrap();
    assert_eq!(&logits[..], want.data(), "borrowed-output path must match the allocating wrapper");

    logits.fill(0.0);
    let before = allocs();
    for _ in 0..3 {
        exec.run_batch_into("8a2w_n4", b, &x, &mut logits).unwrap();
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state executor batch (B={b}, 2 threads) allocated {} time(s) over 3 requests",
        after - before
    );
    assert_eq!(&logits[..], want.data(), "steady-state logits must stay bit-exact");

    // a smaller batch through the same arena also stays allocation-free
    let x1 = Tensor::new(&[1, 8, 8, 3], rng.normal(8 * 8 * 3)).unwrap();
    let want1 = exec.run_batch("8a2w_n4", 1, &x1).unwrap();
    let before = allocs();
    exec.run_batch_into("8a2w_n4", 1, &x1, &mut logits[..net.fc_out]).unwrap();
    let after = allocs();
    assert_eq!(after - before, 0, "smaller batch must reuse the executor's high-water arena");
    assert_eq!(&logits[..net.fc_out], want1.data());
}
