//! Kernel-equivalence property tests (mini-framework from
//! `dfp_infer::testing`): every GEMM in the registry must produce bit-exact
//! `i32` accumulators for the same operands, across random shapes, cluster
//! sizes and thread counts — and therefore `forward_quant` logits must be
//! invariant under every registry choice.

use dfp_infer::kernels::{
    gemm_i8_dense, gemm_packed_i4, gemm_packed_ternary, KernelKind, KernelRegistry, PackedI4Matrix,
    PackedLayer, PackedTernaryMatrix, SimdTier, ThreadPool, TierChoice, ALL_KERNELS,
};
use dfp_infer::lpinfer::{forward_quant_with, QModelParams};
use dfp_infer::model::resnet_mini;
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;
use dfp_infer::testing::{check, Gen};
use dfp_infer::util::SplitMix64;

/// Random GEMM case: (m, k, f, activation sparsity, seed).
#[derive(Debug, Clone)]
struct GemmCase {
    m: usize,
    k: usize,
    f: usize,
    sparse: bool,
    seed: u64,
}

struct GemmCaseGen;

impl Gen for GemmCaseGen {
    type Value = GemmCase;

    fn generate(&self, rng: &mut SplitMix64) -> GemmCase {
        GemmCase {
            m: 1 + rng.next_below(24) as usize,
            k: 1 + rng.next_below(96) as usize,
            f: 1 + rng.next_below(80) as usize,
            sparse: rng.next_below(2) == 1,
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &GemmCase) -> Vec<GemmCase> {
        let mut out = Vec::new();
        for (m, k, f) in [(1, v.k, v.f), (v.m, 1, v.f), (v.m, v.k, 1)] {
            if (m, k, f) != (v.m, v.k, v.f) {
                out.push(GemmCase { m, k, f, ..v.clone() });
            }
        }
        out
    }
}

fn activations(c: &GemmCase) -> Tensor<i8> {
    let mut rng = SplitMix64::new(c.seed);
    let data: Vec<i8> = (0..c.m * c.k)
        .map(|_| {
            let v = (rng.next_below(255) as i16 - 127) as i8;
            if c.sparse && v < 0 {
                0
            } else {
                v
            }
        })
        .collect();
    Tensor::new(&[c.m, c.k], data).unwrap()
}

#[test]
fn prop_packed_ternary_bit_exact_vs_dense() {
    check(120, &GemmCaseGen, |c| {
        let a = activations(c);
        let mut rng = SplitMix64::new(c.seed ^ 0xABCD);
        let wd = Tensor::new(
            &[c.k, c.f],
            (0..c.k * c.f).map(|_| rng.next_below(3) as i8 - 1).collect::<Vec<i8>>(),
        )
        .unwrap();
        let wp = PackedTernaryMatrix::from_hwio(&wd).map_err(|e| e.to_string())?;
        let want = gemm_i8_dense(&a, &wd);
        for threads in [1usize, 2, 4] {
            let got = gemm_packed_ternary(&a, &wp, &ThreadPool::new(threads));
            if got.data() != want.data() {
                return Err(format!("ternary mismatch at {c:?} threads={threads}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_packed_i4_bit_exact_vs_dense() {
    check(120, &GemmCaseGen, |c| {
        let a = activations(c);
        let mut rng = SplitMix64::new(c.seed ^ 0x1234);
        let wd = Tensor::new(
            &[c.k, c.f],
            (0..c.k * c.f).map(|_| rng.next_below(16) as i8 - 8).collect::<Vec<i8>>(),
        )
        .unwrap();
        let wp = PackedI4Matrix::from_hwio(&wd).map_err(|e| e.to_string())?;
        let want = gemm_i8_dense(&a, &wd);
        for threads in [1usize, 2, 4] {
            let got = gemm_packed_i4(&a, &wp, &ThreadPool::new(threads));
            if got.data() != want.data() {
                return Err(format!("i4 mismatch at {c:?} threads={threads}"));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_roundtrip_preserves_codes_across_cluster_sizes() {
    // the packed layout is cluster-agnostic; scales are pure metadata
    for cluster in [4usize, 16, 64] {
        let mut rng = SplitMix64::new(cluster as u64);
        let (k, f) = (18, 64);
        let codes: Vec<i8> = (0..k * f).map(|_| rng.next_below(3) as i8 - 1).collect();
        let mut p = PackedTernaryMatrix::from_codes(&codes, k, f).unwrap();
        let alphas: Vec<f32> = (0..f).map(|i| 0.01 * (1 + i / cluster) as f32).collect();
        p.set_cluster_scales(&alphas, cluster);
        assert_eq!(p.scales.len(), f.div_ceil(cluster));
        assert_eq!(p.to_dense().data(), &codes[..], "cluster={cluster}");
    }
}

/// Tier settings every test machine can exercise: forced scalar plus the
/// best detected tier (which is also scalar on machines without SIMD).
fn test_tiers() -> [TierChoice; 2] {
    [TierChoice::Forced(SimdTier::Scalar), TierChoice::Auto]
}

#[test]
fn forward_quant_invariant_under_registry_choice_tiers_and_threads() {
    // logits bit-identical for every kernel choice x SIMD tier x thread
    // count, for ternary (N in {4,16,64}) and 4-bit models
    let net = resnet_mini(8, &[8, 16, 16], 1, 5);
    let mut rng = SplitMix64::new(77);
    let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
    for (i, variant) in ["8a2w_n4", "8a2w_n16", "8a2w_n64", "8a4w_n4"].iter().enumerate() {
        let scheme = Scheme::parse(variant).unwrap();
        let params = QModelParams::synthetic(&net, 1000 + i as u64, &scheme);
        params.validate(&net).unwrap();
        let want = forward_quant_with(&params, &net, &x, &KernelRegistry::auto());
        assert!(want.data().iter().all(|v| v.is_finite()));
        for kind in ALL_KERNELS {
            for tier in test_tiers() {
                for threads in [1usize, 2, 4] {
                    let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                    let got = forward_quant_with(&params, &net, &x, &reg);
                    assert_eq!(
                        got.data(),
                        want.data(),
                        "scheme={variant} kernel={kind} tier={tier} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn simd_tier_bit_exact_on_unaligned_k_and_f() {
    // K and F deliberately not multiples of any vector width (8 for AVX2
    // i32 lanes, 4/2 for NEON): the tail-lane handling must agree with the
    // scalar kernels bit for bit, for every registry kernel, both fused
    // entry points and 1/2/4 threads
    use dfp_infer::kernels::LayerRequant;
    let mut rng = SplitMix64::new(4242);
    for (m, k, f) in [(3, 7, 5), (5, 13, 31), (4, 9, 33), (7, 27, 65), (2, 31, 37), (1, 1, 1)] {
        let a = Tensor::new(
            &[m, k],
            (0..m * k)
                .map(|_| {
                    let v = (rng.next_below(255) as i16 - 127) as i8;
                    if v < -60 {
                        0
                    } else {
                        v
                    }
                })
                .collect::<Vec<i8>>(),
        )
        .unwrap();
        let wd = Tensor::new(
            &[k, f],
            (0..k * f).map(|_| rng.next_below(3) as i8 - 1).collect::<Vec<i8>>(),
        )
        .unwrap();
        let packed = PackedLayer::build(&wd, &[], 0);
        let w_scale: Vec<f32> = (0..f).map(|i| 0.001 * (1 + i % 7) as f32).collect();
        let bn_scale: Vec<f32> = (0..f).map(|i| 1.0 - 0.03 * (i % 5) as f32).collect();
        let bn_shift: Vec<f32> = (0..f).map(|i| 0.2 * (i % 3) as f32 - 0.2).collect();
        let epi = LayerRequant::derive(&w_scale, &bn_scale, &bn_shift).unwrap().resolve(-4, -4, true);
        let skip: Vec<i64> =
            (0..m * f).map(|_| rng.next_below(1 << 22) as i64 - (1 << 21)).collect();
        let scalar =
            KernelRegistry::with_tier(Some(KernelKind::I8Dense), TierChoice::Forced(SimdTier::Scalar), 1);
        let want = scalar.gemm(&a, &wd, &packed);
        let want_fused = scalar.gemm_fused(&a, &packed, &wd, &epi, Some(&skip));
        let want_skip = scalar.gemm_fused_skip(&a, &packed, &wd, &epi);
        // per-row maxima of the skip lane, as the forward pass carries them
        let skip_row_max: Vec<i64> = (0..m)
            .map(|r| skip[r * f..(r + 1) * f].iter().map(|s| s.saturating_abs()).max().unwrap())
            .collect();
        for kind in ALL_KERNELS {
            for tier in test_tiers() {
                for threads in [1usize, 2, 4] {
                    let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                    let ctx = format!("m={m} k={k} f={f} kernel={kind} tier={tier} threads={threads}");
                    assert_eq!(reg.gemm(&a, &wd, &packed).data(), want.data(), "gemm {ctx}");
                    assert_eq!(
                        reg.gemm_fused(&a, &packed, &wd, &epi, Some(&skip)).data(),
                        want_fused.data(),
                        "fused {ctx}"
                    );
                    assert_eq!(
                        reg.gemm_fused_skip(&a, &packed, &wd, &epi).data(),
                        want_skip.data(),
                        "fused-skip {ctx}"
                    );
                    // borrowed-output entry points over dirty arenas, with
                    // and without carried skip maxima — bit-exact vs the
                    // allocating wrappers for every kernel x tier x threads
                    let mut out_i32 = vec![i32::MIN; m * f];
                    reg.gemm_into(a.data(), m, k, f, &packed, wd.data(), &mut out_i32);
                    assert_eq!(&out_i32[..], want.data(), "gemm_into {ctx}");
                    let mut scratch = vec![i32::MAX; m * f];
                    for skip_max in [None, Some(&skip_row_max[..])] {
                        let mut out_i8 = vec![-5i8; m * f];
                        reg.gemm_fused_into(
                            a.data(),
                            m,
                            k,
                            f,
                            &packed,
                            wd.data(),
                            &epi,
                            Some(&skip),
                            skip_max,
                            &mut out_i8,
                            &mut scratch,
                        );
                        assert_eq!(
                            &out_i8[..],
                            want_fused.data(),
                            "fused_into {ctx} max={}",
                            skip_max.is_some()
                        );
                    }
                    let mut out_i64 = vec![i64::MAX; m * f];
                    let mut row_max = vec![-7i64; m];
                    reg.gemm_fused_skip_into(
                        a.data(),
                        m,
                        k,
                        f,
                        &packed,
                        wd.data(),
                        &epi,
                        &mut out_i64,
                        Some(&mut row_max),
                        &mut scratch,
                    );
                    assert_eq!(&out_i64[..], want_skip.data(), "fused_skip_into {ctx}");
                    let want_max: Vec<i64> = (0..m)
                        .map(|r| {
                            want_skip.data()[r * f..(r + 1) * f]
                                .iter()
                                .map(|s| s.saturating_abs())
                                .max()
                                .unwrap()
                        })
                        .collect();
                    assert_eq!(row_max, want_max, "carried skip maxima {ctx}");
                }
            }
        }
    }
}

#[test]
fn mixed_scheme_layers_carry_policies_and_logits_stay_bit_exact() {
    // the paper's mixed configuration: i8 stem, ternary-N4 interior, i4
    // tail stage, i8 FC — one model, per-layer policies, and logits must
    // be bit-identical for every kernel force and thread count
    let net = resnet_mini(8, &[8, 16, 16], 1, 5);
    let scheme = Scheme::parse("8a2w_n4@stem=i8@s2*=i4@fc=i8").unwrap();
    scheme.validate_for(&net).unwrap();
    let params = QModelParams::synthetic(&net, 321, &scheme);
    params.validate(&net).unwrap();

    // per-layer policies honored end to end, including the packed encodings
    let convs = params.convs();
    assert_eq!(convs["stem"].policy.w_bits(), 8);
    assert!(
        convs["stem"].packed.ternary.is_none() && convs["stem"].packed.i4.is_none(),
        "random i8 stem codes must not fit a sub-8-bit packing"
    );
    assert_eq!(convs["s0b0c1"].policy.w_bits(), 2);
    assert!(convs["s0b0c1"].packed.ternary.is_some());
    assert_eq!(convs["s2b0c1"].policy.w_bits(), 4);
    let tail = &convs["s2b0c1"].packed;
    assert!(tail.i4.is_some() && tail.ternary.is_none(), "i4 tail packs i4 but not ternary");
    assert_eq!(params.scheme.policy_for("fc").w_bits(), 8);

    let mut rng = SplitMix64::new(88);
    let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
    let want = forward_quant_with(&params, &net, &x, &KernelRegistry::auto());
    assert!(want.data().iter().all(|v| v.is_finite()));
    for kind in ALL_KERNELS {
        for tier in test_tiers() {
            for threads in [1usize, 2, 4] {
                let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                let got = forward_quant_with(&params, &net, &x, &reg);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "mixed scheme, kernel={kind} tier={tier} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn registry_auto_uses_packed_engines_when_available() {
    let net = resnet_mini(8, &[4, 4, 4], 1, 3);
    let tern = QModelParams::synthetic(&net, 9, &Scheme::parse("8a2w_n4").unwrap());
    let reg = KernelRegistry::auto();
    for p in tern.convs().values() {
        assert_eq!(reg.select(&p.packed), dfp_infer::kernels::KernelKind::PackedTernary);
    }
    let i4 = QModelParams::synthetic(&net, 9, &Scheme::parse("8a4w_n4").unwrap());
    // 4-bit codes almost surely exceed ternary range somewhere
    assert!(i4
        .convs()
        .values()
        .any(|p| reg.select(&p.packed) == dfp_infer::kernels::KernelKind::PackedI4));
}
