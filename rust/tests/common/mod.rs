//! Shared helpers for integration tests.

use std::path::PathBuf;

/// Repo-root relative path (tests run from the crate root).
pub fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Skip (return true) when build artifacts are absent — integration tests
/// need `make artifacts` to have run; unit tests never depend on it.
pub fn missing(rel: &str) -> bool {
    let p = repo_path(rel);
    if p.exists() {
        false
    } else {
        eprintln!("SKIP: {} not found (run `make artifacts`)", p.display());
        true
    }
}
