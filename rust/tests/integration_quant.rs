//! Cross-language quantizer agreement: the Rust Algorithm 1/2 must match
//! the python implementation on the *trained* weights (the artifacts the
//! server actually runs were produced by the python side; the rust side
//! powers analysis and the lpinfer cross-check — they must agree).

mod common;

use common::{missing, repo_path};
use dfp_infer::io::read_dft;
use dfp_infer::quant::{self, TernaryMode};

#[test]
fn rust_ternarizer_matches_python_export() {
    if missing("models/weights_fp32.dft") || missing("artifacts/qweights_8a2w_n4.dft") {
        return;
    }
    let weights = read_dft(&repo_path("models/weights_fp32.dft")).unwrap();
    let qexport = read_dft(&repo_path("artifacts/qweights_8a2w_n4.dft")).unwrap();
    let cluster = qexport["meta.cluster"].as_i32().unwrap().data()[0] as usize;
    assert_eq!(cluster, 4);

    let mut layers_checked = 0;
    let mut total = 0usize;
    let mut mismatched = 0usize;
    for (name, t) in &weights {
        let Some(layer) = name.strip_suffix(".w") else { continue };
        if layer == "stem" || layer == "fc" {
            continue; // stem is 8-bit in this config; fc layout is 2-D
        }
        let w = t.as_f32().unwrap();
        let shape = w.shape();
        let n_filters = *shape.last().unwrap();
        let epf = w.len() / n_filters;
        let ours =
            quant::ternarize_layer(w.data(), epf, n_filters, cluster, TernaryMode::Support).unwrap();

        let theirs_codes = qexport[&format!("{layer}.wq")].as_i8().unwrap();
        let theirs_scale = qexport[&format!("{layer}.w_scale")].as_f32().unwrap();
        assert_eq!(theirs_codes.len(), ours.codes.len(), "{layer}: size");

        // codes: allow a tiny mismatch rate from f64 tie-breaking at the
        // exact threshold boundary (sort order of equal values)
        let diff = theirs_codes
            .data()
            .iter()
            .zip(&ours.codes)
            .filter(|(a, b)| a != b)
            .count();
        total += ours.codes.len();
        mismatched += diff;
        assert!(
            (diff as f64) <= 0.001 * ours.codes.len() as f64,
            "{layer}: {diff}/{} ternary codes differ",
            ours.codes.len()
        );

        // per-filter alpha within one 8-bit-mantissa ulp
        for f in 0..n_filters {
            let a = theirs_scale.data()[f];
            let b = ours.alpha[f];
            assert!(
                (a - b).abs() <= a.abs().max(b.abs()) / 64.0 + 1e-9,
                "{layer}: alpha[{f}] {a} vs {b}"
            );
        }
        layers_checked += 1;
    }
    assert!(layers_checked >= 8, "only {layers_checked} layers checked");
    eprintln!("cross-language ternary agreement: {mismatched}/{total} codes differ");
}

#[test]
fn rust_dfp_quantizer_matches_python_stem() {
    if missing("models/weights_fp32.dft") || missing("artifacts/qweights_8a2w_n4.dft") {
        return;
    }
    let weights = read_dft(&repo_path("models/weights_fp32.dft")).unwrap();
    let qexport = read_dft(&repo_path("artifacts/qweights_8a2w_n4.dft")).unwrap();
    let cluster = qexport["meta.cluster"].as_i32().unwrap().data()[0] as usize;

    let w = weights["stem.w"].as_f32().unwrap();
    let n_filters = *w.shape().last().unwrap();
    let epf = w.len() / n_filters;
    let ours = quant::quantize_layer_dfp(w.data(), epf, n_filters, 8, cluster).unwrap();
    let theirs = qexport["stem.wq"].as_i8().unwrap();
    // round-half-even in numpy vs rust must agree exactly
    let diff = theirs.data().iter().zip(&ours.codes).filter(|(a, b)| a != b).count();
    assert_eq!(diff, 0, "stem 8-bit codes differ in {diff} places");
}

#[test]
fn ternary_export_metadata_consistent() {
    if missing("artifacts/qweights_8a2w_n4.dft") {
        return;
    }
    let qexport = read_dft(&repo_path("artifacts/qweights_8a2w_n4.dft")).unwrap();
    assert_eq!(qexport["meta.w_bits"].as_i32().unwrap().data()[0], 2);
    for (name, t) in &qexport {
        let Some(layer) = name.strip_suffix(".wq") else { continue };
        if layer == "stem" {
            continue;
        }
        let codes = t.as_i8().unwrap();
        assert!(
            codes.data().iter().all(|&c| (-1..=1).contains(&c)),
            "{layer}: non-ternary code"
        );
    }
}

#[test]
fn twn_baseline_worse_sqnr_than_clustered() {
    // E8 shape: per-layer single-scale TWN must not beat clustered alphas.
    if missing("models/weights_fp32.dft") {
        return;
    }
    let weights = read_dft(&repo_path("models/weights_fp32.dft")).unwrap();
    let w = weights["s2b0c1.w"].as_f32().unwrap();
    let n_filters = *w.shape().last().unwrap();
    let epf = w.len() / n_filters;

    let clustered = quant::ternarize_layer(w.data(), epf, n_filters, 4, TernaryMode::Support).unwrap();
    let ours = quant::sqnr_db(w.data(), &clustered.dequantize());

    let (codes, alpha) = quant::ternarize_twn(w.data());
    let twn_back: Vec<f32> = codes.iter().map(|&c| f32::from(c) * alpha as f32).collect();
    let twn = quant::sqnr_db(w.data(), &twn_back);
    eprintln!("sqnr clustered N=4: {ours:.2} dB vs TWN single-scale: {twn:.2} dB");
    assert!(ours > twn - 0.3, "clustered {ours} should be >= TWN {twn}");
}
