//! Corruption fuzz suite for the trustworthy artifact lifecycle: every way
//! a `qweights_*.dft` export can rot on disk — flipped bits, truncation at
//! any structural boundary, out-of-range packed codes, a requant version
//! from the future — must surface as a **typed error**, never a panic and
//! never a silently-wrong load. The legacy v1 container must keep loading.

use dfp_infer::dfp::REQUANT_VERSION;
use dfp_infer::io::{
    read_dft, verify_dft, write_dft, write_dft_v1, AnyTensor, ArtifactError, TensorMap,
};
use dfp_infer::lpinfer::QModelParams;
use dfp_infer::model::{resnet_mini, Network};
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;

fn tiny_net() -> Network {
    resnet_mini(8, &[4, 4, 4], 1, 3)
}

/// A real (small) quantized model serialized the way the exporter writes it.
fn fixture_map() -> TensorMap {
    let net = tiny_net();
    QModelParams::synthetic(&net, 42, &Scheme::parse("8a2w_n4").unwrap()).to_tensors()
}

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("dfp_integrity_{tag}_{}.dft", std::process::id()))
}

/// Walk the v2 container structure and collect every section boundary:
/// magic, count, and per record the name-length/name/dtype/ndim/dims/
/// payload-length/payload/checksum edges, plus the file trailer.
fn section_boundaries(raw: &[u8]) -> Vec<usize> {
    let mut b = vec![0usize, 2, 4, 6, 8];
    let count = u32::from_le_bytes(raw[4..8].try_into().unwrap()) as usize;
    let mut pos = 8usize;
    for _ in 0..count {
        let nlen = u16::from_le_bytes(raw[pos..pos + 2].try_into().unwrap()) as usize;
        b.push(pos + 2); // after name length
        pos += 2 + nlen;
        b.push(pos); // after name
        pos += 1; // dtype tag
        b.push(pos);
        let ndim = raw[pos] as usize;
        pos += 1 + 4 * ndim; // ndim + dims
        b.push(pos);
        let blen = u64::from_le_bytes(raw[pos..pos + 8].try_into().unwrap()) as usize;
        pos += 8;
        b.push(pos); // payload start
        pos += blen;
        b.push(pos); // payload end
        pos += 8; // record checksum
        b.push(pos);
    }
    b.push(raw.len() - 8); // trailer start
    b.push(raw.len() - 1); // mid-trailer
    b.retain(|&x| x < raw.len());
    b.sort_unstable();
    b.dedup();
    b
}

#[test]
fn test_truncation_at_every_section_boundary_is_typed() {
    let p = tmpfile("trunc_src");
    write_dft(&p, &fixture_map()).unwrap();
    let raw = std::fs::read(&p).unwrap();
    let cuts = section_boundaries(&raw);
    assert!(cuts.len() > 20, "expected many boundaries, got {}", cuts.len());
    let q = tmpfile("trunc");
    for &cut in &cuts {
        std::fs::write(&q, &raw[..cut]).unwrap();
        let err = read_dft(&q)
            .err()
            .unwrap_or_else(|| panic!("truncation at {cut}/{} must not load", raw.len()));
        // typed, and it names the file it is about
        assert!(err.path().ends_with(q.file_name().unwrap()), "cut {cut}: {err}");
        // verify_dft walks the same decode path — must agree
        assert!(verify_dft(&q).is_err(), "verify accepted truncation at {cut}");
    }
    std::fs::remove_file(&p).ok();
    std::fs::remove_file(&q).ok();
}

#[test]
fn test_single_bit_flips_are_detected_everywhere() {
    let p = tmpfile("flip_src");
    write_dft(&p, &fixture_map()).unwrap();
    let raw = std::fs::read(&p).unwrap();
    let q = tmpfile("flip");
    // deterministic sample across the whole file, varying the bit position
    let step = (raw.len() / 97).max(1);
    let mut flips = 0usize;
    for i in (0..raw.len()).step_by(step) {
        let mut bad = raw.clone();
        bad[i] ^= 1u8 << (i % 8);
        std::fs::write(&q, &bad).unwrap();
        let err = read_dft(&q)
            .err()
            .unwrap_or_else(|| panic!("bit flip at byte {i} must not load"));
        assert!(err.path().ends_with(q.file_name().unwrap()), "byte {i}: {err}");
        flips += 1;
    }
    assert!(flips >= 90, "sampled only {flips} flips");
    // the untouched file still loads — the fixture itself is sound
    assert!(read_dft(&p).is_ok());
    std::fs::remove_file(&p).ok();
    std::fs::remove_file(&q).ok();
}

#[test]
fn test_payload_flip_is_checksum_mismatch_not_silent() {
    let map = fixture_map();
    let p = tmpfile("payload_flip");
    write_dft(&p, &map).unwrap();
    let mut raw = std::fs::read(&p).unwrap();
    // flip a byte well inside the body (a tensor payload, past the header)
    let mid = raw.len() / 2;
    raw[mid] ^= 0x10;
    std::fs::write(&p, &raw).unwrap();
    match read_dft(&p) {
        Err(ArtifactError::ChecksumMismatch { stored, computed, .. }) => {
            assert_ne!(stored, computed);
        }
        other => panic!("expected ChecksumMismatch, got {other:?}"),
    }
    std::fs::remove_file(&p).ok();
}

/// Helper: mutate one tensor in a valid map, re-serialize through the real
/// writer (so all container checksums are *valid*), and load. The container
/// accepts it — the corruption must be caught by the semantic layer
/// (`QModelParams::from_tensors`), proving deep validation is a separate
/// line of defense behind the checksums.
fn load_mutated(
    map: &TensorMap,
    mutate: impl FnOnce(&mut TensorMap),
    tag: &str,
) -> anyhow::Result<QModelParams> {
    let mut m = map.clone();
    mutate(&mut m);
    let p = tmpfile(tag);
    write_dft(&p, &m).unwrap();
    let reread = read_dft(&p).expect("container checksums are valid by construction");
    let out = QModelParams::from_tensors(&reread, &tiny_net());
    std::fs::remove_file(&p).ok();
    out
}

#[test]
fn test_out_of_range_packed_codes_rejected_by_deep_validation() {
    let map = fixture_map();
    // control: the fixture itself passes the deep gate
    assert!(load_mutated(&map, |_| {}, "codes_ok").is_ok());
    // find a conv code tensor and push one code far outside the 2-bit range
    let name = map.keys().find(|k| k.ends_with(".wq") && *k != "fc.wq").unwrap().clone();
    let err = load_mutated(
        &map,
        |m| {
            let t = m[&name].as_i8().unwrap().clone();
            let mut d = t.data().to_vec();
            d[0] = 125;
            m.insert(name.clone(), AnyTensor::I8(Tensor::new(t.shape(), d).unwrap()));
        },
        "codes_bad",
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(!msg.is_empty());
}

#[test]
fn test_requant_version_from_the_future_is_rejected() {
    let map = fixture_map();
    let err = load_mutated(
        &map,
        |m| {
            m.insert(
                "meta.requant_version".into(),
                AnyTensor::I32(Tensor::new(&[1], vec![REQUANT_VERSION + 1]).unwrap()),
            );
        },
        "rq_future",
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("requant_version"), "{msg}");
}

#[test]
fn test_corrupt_requant_envelope_rejected_by_deep_validation() {
    let map = fixture_map();
    let name = map.keys().find(|k| k.ends_with(".rq_shift")).unwrap().clone();
    let err = load_mutated(
        &map,
        |m| {
            let t = m[&name].as_i32().unwrap().clone();
            let mut d = t.data().to_vec();
            d[0] = 10_000; // far outside any sane requant shift envelope
            m.insert(name.clone(), AnyTensor::I32(Tensor::new(t.shape(), d).unwrap()));
        },
        "rq_envelope",
    )
    .unwrap_err();
    assert!(!format!("{err:#}").is_empty());
}

#[test]
fn test_v1_container_still_loads_and_serves() {
    let map = fixture_map();
    let p = tmpfile("v1_compat");
    write_dft_v1(&p, &map).unwrap();
    // bytes round-trip exactly, checksums simply absent
    let reread = read_dft(&p).unwrap();
    assert_eq!(reread, map);
    let report = verify_dft(&p).unwrap();
    assert_eq!(report.version, 1);
    assert!(report.tensors.iter().all(|t| t.checksum.is_none()));
    // and the deep gate accepts it: v1 exports keep serving
    assert!(QModelParams::from_tensors(&reread, &tiny_net()).is_ok());
    std::fs::remove_file(&p).ok();
}

#[test]
fn test_unknown_future_container_version_is_typed() {
    let p = tmpfile("future_version");
    std::fs::write(&p, b"DFT7\x00\x00\x00\x00").unwrap();
    match read_dft(&p) {
        Err(ArtifactError::UnsupportedVersion { version, .. }) => assert_eq!(version, 7),
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
    std::fs::write(&p, b"JPEGnot a dft").unwrap();
    assert!(matches!(read_dft(&p), Err(ArtifactError::BadMagic { .. })));
    std::fs::remove_file(&p).ok();
}
