//! Property-based tests (own mini-framework, `dfp_infer::testing`) on the
//! numeric and coordinator invariants.

use dfp_infer::coordinator::BatchPolicy;
use dfp_infer::dfp;
use dfp_infer::json;
use dfp_infer::quant::{self, TernaryMode};
use dfp_infer::testing::{check, Gen, PairGen, RangeGen, VecF32Gen};
use dfp_infer::util::SplitMix64;

#[test]
fn prop_dfp_roundtrip_error_bounded() {
    // |x - dq(q(x))| <= half ulp of the chosen exponent, all bit widths
    let gen = VecF32Gen { min_len: 1, max_len: 300, sigma: 5.0 };
    check(150, &gen, |v| {
        for bits in [2u32, 4, 8] {
            let (q, e) = dfp::quantize(v, bits, None);
            let back = dfp::dequantize(&q, e);
            for (a, b) in v.iter().zip(&back) {
                let bound = 2f32.powi(e - 1) + 1e-9;
                if (a - b).abs() > bound {
                    return Err(format!("bits={bits} e={e}: |{a}-{b}| > {bound}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dfp_codes_in_range() {
    let gen = VecF32Gen { min_len: 1, max_len: 200, sigma: 100.0 };
    check(100, &gen, |v| {
        for bits in [2u32, 4, 8] {
            let (q, _) = dfp::quantize(v, bits, None);
            let m = dfp::qmax(bits) as i8;
            if q.iter().any(|&c| c.abs() > m) {
                return Err(format!("code out of {bits}-bit range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scale_u8_relative_error() {
    struct PosGen;
    impl Gen for PosGen {
        type Value = f64;
        fn generate(&self, rng: &mut SplitMix64) -> f64 {
            let mag = rng.range_f32(-12.0, 12.0);
            f64::from(rng.next_f32() + 0.001) * 10f64.powf(f64::from(mag) / 4.0)
        }
    }
    check(300, &PosGen, |&a| {
        let s = dfp::ScaleU8::quantize(a);
        let back = s.dequantize();
        if (back - a).abs() / a > 1.0 / 128.0 {
            return Err(format!("alpha {a} -> {back}"));
        }
        Ok(())
    });
}

#[test]
fn prop_ternary_layer_invariants() {
    // codes ternary; alpha shared per cluster; error never above all-zero
    let gen = PairGen(
        VecF32Gen { min_len: 9 * 8, max_len: 9 * 8, sigma: 0.2 },
        RangeGen { lo: 1, hi: 8 },
    );
    check(60, &gen, |(w, n)| {
        for mode in [TernaryMode::Paper, TernaryMode::Support] {
            let t = quant::ternarize_layer(w, 9, 8, *n, mode).map_err(|e| e.to_string())?;
            if t.codes.iter().any(|&c| !(-1..=1).contains(&c)) {
                return Err("non-ternary code".into());
            }
            for f in 0..8 {
                if t.alpha[f] != t.alpha[(f / n) * n] {
                    return Err(format!("{mode:?}: alpha not shared in cluster (f={f})"));
                }
            }
            let back = t.dequantize();
            let err: f64 = w
                .iter()
                .zip(&back)
                .map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2))
                .sum();
            let zero_err: f64 = w.iter().map(|&a| f64::from(a).powi(2)).sum();
            if err > zero_err * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("{mode:?}: err {err} worse than all-zero {zero_err}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_threshold_select_minimizes_over_prefixes() {
    // returned alpha must achieve the minimal prefix error (brute force)
    let gen = VecF32Gen { min_len: 2, max_len: 120, sigma: 1.0 };
    check(80, &gen, |w| {
        if w.iter().all(|&x| x == 0.0) {
            return Ok(());
        }
        let alpha = quant::threshold_select(w);
        let mut mags: Vec<f64> = w.iter().map(|&x| f64::from(x).abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let total: f64 = mags.iter().map(|m| m * m).sum();
        // brute-force: the returned alpha must be the prefix-RMS achieving
        // the minimal prefix error E(t) = total - 2*a*S1(t) + a^2*t
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        let mut best = (f64::INFINITY, 0.0f64);
        for (i, &m) in mags.iter().enumerate() {
            s1 += m;
            s2 += m * m;
            let t = (i + 1) as f64;
            let a = (s2 / t).sqrt();
            let err = total - 2.0 * a * s1 + a * a * t;
            if err < best.0 {
                best = (err, a);
            }
        }
        if (alpha - best.1).abs() > 1e-12 * best.1.max(1.0) {
            return Err(format!("alpha {alpha} != argmin prefix alpha {}", best.1));
        }
        Ok(())
    });
}

#[test]
fn prop_batch_policy_invariants() {
    // plan() result always an available size; padding < smallest cover;
    // deadline flush guaranteed for non-empty queues
    struct PolicyGen;
    impl Gen for PolicyGen {
        type Value = (Vec<usize>, usize, u64);
        fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
            let n_sizes = 1 + rng.next_below(4) as usize;
            let sizes: Vec<usize> = (0..n_sizes).map(|_| 1 + rng.next_below(64) as usize).collect();
            let pending = rng.next_below(100) as usize;
            let age = rng.next_below(10_000);
            (sizes, pending, age)
        }
    }
    check(300, &PolicyGen, |(sizes, pending, age)| {
        let p = BatchPolicy::new(sizes.clone(), 2_000).expect("non-empty positive sizes");
        match p.plan(*pending, *age, None) {
            None => {
                if *pending >= p.max_batch() {
                    return Err("full queue not flushed".into());
                }
                if *pending > 0 && *age >= 2_000 {
                    return Err("deadline expired but no flush".into());
                }
            }
            Some(b) => {
                if !p.sizes().contains(&b) {
                    return Err(format!("planned batch {b} not an artifact size"));
                }
                if *pending == 0 {
                    return Err("flushed an empty queue".into());
                }
            }
        }
        // best_fit covers n (or is the max)
        let bf = p.best_fit(*pending.max(&1));
        if bf < *pending.max(&1) && bf != p.max_batch() {
            return Err(format!("best_fit {bf} covers neither {pending} nor max"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    // random JSON trees survive serialize -> parse
    struct JsonGen;
    fn gen_value(rng: &mut SplitMix64, depth: usize) -> json::Json {
        match if depth > 3 { rng.next_below(4) } else { rng.next_below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.next_below(2) == 1),
            2 => json::Json::Num((rng.next_below(2_000_001) as f64 - 1e6) / 64.0),
            3 => {
                let n = rng.next_below(8) as usize;
                json::Json::Str((0..n).map(|_| (b'a' + rng.next_below(26) as u8) as char).collect())
            }
            4 => {
                let n = rng.next_below(5) as usize;
                json::Json::Arr((0..n).map(|_| gen_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.next_below(5) as usize;
                json::Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    impl Gen for JsonGen {
        type Value = json::Json;
        fn generate(&self, rng: &mut SplitMix64) -> json::Json {
            gen_value(rng, 0)
        }
    }
    check(200, &JsonGen, |j| {
        let text = j.to_string();
        let back = json::parse(&text).map_err(|e| e.to_string())?;
        if &back != j {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        let pretty = json::parse(&j.to_string_pretty()).map_err(|e| e.to_string())?;
        if &pretty != j {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packing_roundtrip() {
    use dfp_infer::dfp::packing;
    struct CodesGen;
    impl Gen for CodesGen {
        type Value = Vec<i8>;
        fn generate(&self, rng: &mut SplitMix64) -> Vec<i8> {
            let n = 1 + rng.next_below(600) as usize;
            (0..n).map(|_| rng.next_below(3) as i8 - 1).collect()
        }
    }
    check(150, &CodesGen, |codes| {
        let packed = packing::pack_ternary(codes);
        if packing::unpack_ternary(&packed, codes.len()) != *codes {
            return Err("ternary pack mismatch".into());
        }
        let nibbles: Vec<i8> = codes.iter().map(|&c| c * 5).collect();
        let p4 = packing::pack_i4(&nibbles);
        if packing::unpack_i4(&p4, nibbles.len()) != nibbles {
            return Err("i4 pack mismatch".into());
        }
        Ok(())
    });
}
