//! Batch-equivalence property suite: a batched forward over B images must
//! produce logits bit-identical to B independent single-image forwards,
//! across every kernel encoding, SIMD tier, thread count and clustering
//! scheme — and the persistent worker pool must stay correct when two
//! registries share it under concurrent GEMM traffic.
//!
//! This is the lockdown for the batched `ForwardPlan` path: a batch of B
//! images runs each convolution as ONE im2col GEMM over B·H·W rows, so any
//! cross-image leakage (wrong row offsets, shared-scratch clobbering, a
//! pool block straddling an image boundary incorrectly) shows up as a
//! bitwise logits mismatch here.

use std::sync::Arc;

use dfp_infer::kernels::{KernelRegistry, SimdTier, TierChoice, WorkerPool, ALL_KERNELS};
use dfp_infer::lpinfer::{forward_quant_with, QModelParams};
use dfp_infer::model::{bottleneck_mini, resnet_mini, Network};
use dfp_infer::scheme::Scheme;
use dfp_infer::tensor::Tensor;
use dfp_infer::util::SplitMix64;

/// Tier settings every test machine can exercise: forced scalar plus the
/// best detected tier (which is also scalar on machines without SIMD).
fn test_tiers() -> [TierChoice; 2] {
    [TierChoice::Forced(SimdTier::Scalar), TierChoice::Auto]
}

/// Deterministic batch of `b` images for `net`, plus the same images as
/// `b` standalone single-image tensors (bit-identical pixel data).
fn batch_and_singles(net: &Network, b: usize, seed: u64) -> (Tensor<f32>, Vec<Tensor<f32>>) {
    let img = net.input_hw;
    let per = img * img * 3;
    let mut rng = SplitMix64::new(seed);
    let pixels = rng.normal(b * per);
    let batch = Tensor::new(&[b, img, img, 3], pixels.clone()).unwrap();
    let singles = (0..b)
        .map(|i| Tensor::new(&[1, img, img, 3], pixels[i * per..(i + 1) * per].to_vec()).unwrap())
        .collect();
    (batch, singles)
}

/// Reference logits: `b` independent single-image forwards, concatenated
/// in batch order. Computed with a forced-scalar single-threaded registry
/// so the oracle itself has no batching, no SIMD and no pool involvement.
fn singles_oracle(params: &QModelParams, net: &Network, singles: &[Tensor<f32>], classes: usize) -> Vec<f32> {
    let reg = KernelRegistry::with_tier(None, TierChoice::Forced(SimdTier::Scalar), 1);
    let mut out = Vec::with_capacity(singles.len() * classes);
    for x in singles {
        let logits = forward_quant_with(params, net, x, &reg);
        assert_eq!(logits.shape(), &[1, classes]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
        out.extend_from_slice(logits.data());
    }
    out
}

#[test]
fn batched_forward_bit_identical_to_singles_across_registry_configs() {
    // resnet-mini over every clustering width the paper sweeps (N in
    // {4,16,64}) plus the 4-bit encoding, with a mixed i8 stem so the
    // dense, ternary-packed and i4-packed GEMM paths all carry the batch
    let net = resnet_mini(8, &[4, 4, 4], 1, 3);
    let classes = 3;
    for (i, variant) in ["8a2w_n4@stem=i8", "8a2w_n16", "8a2w_n64", "8a4w_n4"].iter().enumerate() {
        let scheme = Scheme::parse(variant).unwrap();
        let params = QModelParams::synthetic(&net, 2000 + i as u64, &scheme);
        params.validate(&net).unwrap();
        for b in [1usize, 2, 4, 8] {
            let (batch, singles) = batch_and_singles(&net, b, 0x5EED ^ ((b as u64) << 8) ^ i as u64);
            let want = singles_oracle(&params, &net, &singles, classes);
            for kind in ALL_KERNELS {
                for tier in test_tiers() {
                    for threads in [1usize, 2, 4] {
                        let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                        let got = forward_quant_with(&params, &net, &batch, &reg);
                        assert_eq!(got.shape(), &[b, classes]);
                        assert_eq!(
                            got.data(),
                            &want[..],
                            "scheme={variant} B={b} kernel={kind} tier={tier} threads={threads}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn batched_forward_bit_identical_on_bottleneck_stem_pool_family() {
    // the ResNet-50-style family from the graph planner: 1x1-3x3-1x1
    // bottlenecks behind a 3x3/s2 stem max pool — the pool window indexing
    // must shift per image exactly like the im2col row offsets do
    let net = bottleneck_mini(16, &[4, 8], 3);
    let classes = 3;
    let scheme = Scheme::parse("8a2w_n4@stem=i8").unwrap();
    let params = QModelParams::synthetic(&net, 95, &scheme);
    params.validate(&net).unwrap();
    for b in [1usize, 2, 4, 8] {
        let (batch, singles) = batch_and_singles(&net, b, 0xB077 + b as u64);
        let want = singles_oracle(&params, &net, &singles, classes);
        for kind in ALL_KERNELS {
            for threads in [1usize, 2, 4] {
                let reg = KernelRegistry::with_tier(Some(kind), TierChoice::Auto, threads);
                let got = forward_quant_with(&params, &net, &batch, &reg);
                assert_eq!(got.shape(), &[b, classes]);
                assert_eq!(got.data(), &want[..], "bottleneck B={b} kernel={kind} threads={threads}");
            }
        }
    }
}

#[test]
fn two_registries_sharing_one_pool_interleave_safely() {
    // Pool-robustness satellite: two kernel registries built over ONE
    // persistent WorkerPool, driven from two OS threads that fire batched
    // forwards concurrently. Every forward must stay bit-identical to its
    // single-owner baseline — no cross-registry block mixup, no deadlock.
    let net = resnet_mini(8, &[4, 4, 4], 1, 3);
    let scheme = Scheme::parse("8a2w_n4").unwrap();
    let params = QModelParams::synthetic(&net, 7, &scheme);
    let (batch, singles) = batch_and_singles(&net, 4, 0xC0FFEE);
    let want = singles_oracle(&params, &net, &singles, 3);

    let pool = Arc::new(WorkerPool::new(4));
    let reg_a = KernelRegistry::with_pool(None, TierChoice::Auto, Arc::clone(&pool));
    let reg_b = KernelRegistry::with_pool(None, TierChoice::Forced(SimdTier::Scalar), Arc::clone(&pool));

    std::thread::scope(|s| {
        for (name, reg) in [("auto", &reg_a), ("scalar", &reg_b)] {
            let (params, net, batch, want) = (&params, &net, &batch, &want);
            s.spawn(move || {
                for round in 0..8 {
                    let got = forward_quant_with(params, net, batch, reg);
                    assert_eq!(got.data(), &want[..], "registry={name} round={round}");
                }
            });
        }
    });
    drop(reg_a);
    drop(reg_b);
    // the shared pool must still be serviceable and shut down cleanly
    let reg = KernelRegistry::with_pool(None, TierChoice::Auto, pool);
    let got = forward_quant_with(&params, &net, &batch, &reg);
    assert_eq!(got.data(), &want[..]);
}
