//! PJRT runtime integration: load real artifacts, execute, cross-check the
//! served numbers against the exported eval set, and validate the rust
//! ShapeSet generator against the python export.

mod common;

use common::{missing, repo_path};
use dfp_infer::data;
use dfp_infer::io::read_dft;
use dfp_infer::runtime::Engine;
use dfp_infer::tensor::Tensor;

#[test]
fn engine_loads_and_serves_fp32() {
    if missing("artifacts/manifest.json") {
        return;
    }
    let mut engine = Engine::new(&repo_path("artifacts")).unwrap();
    assert_eq!(engine.platform(), "cpu");
    let eval = read_dft(&repo_path("artifacts/eval_data.dft")).unwrap();
    let images = eval["images"].as_f32().unwrap();
    let labels = eval["labels"].as_i32().unwrap();
    let img = images.dim(1);
    let px = img * img * 3;

    let batch = 8;
    let exe = engine.load("fp32", batch).unwrap();
    let mut correct = 0;
    let n = 64;
    for chunk in (0..n).step_by(batch) {
        let x = Tensor::new(
            &[batch, img, img, 3],
            images.data()[chunk * px..(chunk + batch) * px].to_vec(),
        )
        .unwrap();
        let logits = exe.run(&x).unwrap();
        assert_eq!(logits.shape(), &[batch, 10]);
        for i in 0..batch {
            let row = &logits.data()[i * 10..(i + 1) * 10];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == labels.data()[chunk + i] as usize);
        }
    }
    let acc = correct as f64 / n as f64;
    eprintln!("PJRT fp32 accuracy on {n}: {acc}");
    assert!(acc > 0.8, "served fp32 accuracy {acc}");
}

#[test]
fn engine_rejects_wrong_shapes_and_unknown_variants() {
    if missing("artifacts/manifest.json") {
        return;
    }
    let mut engine = Engine::new(&repo_path("artifacts")).unwrap();
    assert!(engine.load("nope", 1).is_err());
    assert!(engine.load("fp32", 7).is_err()); // only 1/8/32 compiled
    let exe = engine.load("fp32", 1).unwrap();
    let bad = Tensor::<f32>::zeros(&[2, 24, 24, 3]);
    assert!(exe.run(&bad).is_err());
}

#[test]
fn quantized_variant_beats_chance_and_fp32_stays_better() {
    if missing("artifacts/manifest.json") {
        return;
    }
    let mut engine = Engine::new(&repo_path("artifacts")).unwrap();
    let eval = read_dft(&repo_path("artifacts/eval_data.dft")).unwrap();
    let images = eval["images"].as_f32().unwrap();
    let labels = eval["labels"].as_i32().unwrap();
    let img = images.dim(1);
    let px = img * img * 3;
    let batch = 32;
    let n = 96;
    let mut accs = Vec::new();
    for variant in ["fp32", "8a2w_n64"] {
        let exe = engine.load(variant, batch).unwrap();
        let mut correct = 0;
        for chunk in (0..n).step_by(batch) {
            let x = Tensor::new(
                &[batch, img, img, 3],
                images.data()[chunk * px..(chunk + batch) * px].to_vec(),
            )
            .unwrap();
            let logits = exe.run(&x).unwrap();
            for i in 0..batch {
                let row = &logits.data()[i * 10..(i + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += usize::from(pred == labels.data()[chunk + i] as usize);
            }
        }
        accs.push(correct as f64 / n as f64);
    }
    eprintln!("fp32 {} vs 8a2w_n64 {}", accs[0], accs[1]);
    assert!(accs[1] > 0.5, "ternary n64 above chance");
    assert!(accs[0] >= accs[1] - 0.02, "fp32 should not lose to ternary");
}

#[test]
fn rust_shapeset_matches_python_export() {
    if missing("artifacts/eval_data.dft") {
        return;
    }
    let eval = read_dft(&repo_path("artifacts/eval_data.dft")).unwrap();
    let images = eval["images"].as_f32().unwrap();
    let labels = eval["labels"].as_i32().unwrap();
    let n = 32.min(images.dim(0));
    // eval split uses seed=2 and the module default noise (1.0)
    let (xs, ys) = data::make_split(n, 2, 1.0);
    for i in 0..n {
        assert_eq!(ys[i] as i32, labels.data()[i], "label {i}");
    }
    let px = data::IMG * data::IMG * data::CH;
    let mut max_diff = 0.0f32;
    for i in 0..n * px {
        max_diff = max_diff.max((xs.data()[i] - images.data()[i]).abs());
    }
    eprintln!("rust-vs-python ShapeSet max abs diff over {n} images: {max_diff}");
    // PRNG stream is bit-exact; only libm sin/cos rounding differs
    assert!(max_diff < 1e-3, "generators diverged: {max_diff}");
}
