//! Resilience suite for the serving coordinator: the invariant under test
//! is that **every submitted request receives exactly one reply** — a
//! `Response` or a typed `ServeError` — under injected executor panics,
//! 10× overload, expired deadlines, quarantine, and shutdown races.
//! Faults come from `testing::chaos::FaultyExecutor` on a deterministic
//! schedule, so failures reproduce exactly.

use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use dfp_infer::coordinator::{
    Coordinator, CoordinatorConfig, DegradeConfig, Executor, ExecutorFactory, LpExecutor,
    MockExecutor, PrecisionClass, Request, Router, ServeError, ServeResult,
};
use dfp_infer::kernels::KernelRegistry;
use dfp_infer::model::resnet_mini_default;
use dfp_infer::runtime::Manifest;
use dfp_infer::tensor::Tensor;
use dfp_infer::testing::chaos::{ChaosConfig, FaultyExecutor};

const MANIFEST: &str = r#"{
  "img": 8, "classes": 4, "batch_sizes": [1, 4],
  "variants": {
    "fp32":    {"files": {"1": "a", "4": "b"}, "eval_acc": 0.9, "w_bits": 32, "cluster": 0},
    "8a4w_n4": {"files": {"1": "c", "4": "d"}, "eval_acc": 0.88, "w_bits": 4, "cluster": 4},
    "8a2w_n4": {"files": {"1": "e", "4": "f"}, "eval_acc": 0.8,  "w_bits": 2, "cluster": 4}
  }
}"#;

const VARIANTS: [&str; 3] = ["fp32", "8a4w_n4", "8a2w_n4"];

fn sizes() -> BTreeMap<String, Vec<usize>> {
    VARIANTS.iter().map(|v| (v.to_string(), vec![1, 4])).collect()
}

fn mock() -> MockExecutor {
    MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a4w_n4", &[1, 4]), ("8a2w_n4", &[1, 4])])
}

fn start(factories: Vec<ExecutorFactory>, cfg: CoordinatorConfig) -> Coordinator {
    let m = Manifest::from_json_text(MANIFEST).unwrap();
    let router = Router::from_manifest(&m).unwrap();
    Coordinator::start(factories, router, &sizes(), 8, cfg).unwrap()
}

fn image(v: f32) -> Tensor<f32> {
    Tensor::new(&[8, 8, 3], vec![v; 192]).unwrap()
}

/// The no-hang guard: a reply must arrive well within the suite budget.
fn recv_one(rx: &Receiver<ServeResult>) -> ServeResult {
    rx.recv_timeout(Duration::from_secs(10)).expect("request lost: no reply within 10s")
}

#[test]
fn test_no_request_lost_under_panics_at_10x_overload() {
    // every 3rd batch on each worker panics; offered load is ~10x what a
    // tiny admission queue absorbs, so Overloaded submit errors are part
    // of the expected outcome set
    let factories: Vec<ExecutorFactory> = (0..2)
        .map(|_| {
            Box::new(|| {
                let mut inner = mock();
                inner.delay_us_per_image = 200;
                Ok(Box::new(FaultyExecutor::new(inner, ChaosConfig::panic_every(3)))
                    as Box<dyn Executor>)
            }) as ExecutorFactory
        })
        .collect();
    let c = start(
        factories,
        CoordinatorConfig {
            max_queue: 16,
            max_wait_us: 500,
            quarantine_after: 1_000, // isolate panics without quarantining
            ..Default::default()
        },
    );
    let classes =
        [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate];
    let total = 160;
    let mut rxs = Vec::new();
    let mut rejected = 0u32;
    for i in 0..total {
        match c.submit(Request::new(image(i as f32), classes[i % 3])) {
            Ok(rx) => rxs.push(rx),
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        // no pacing: keep offered load far past capacity
    }
    let mut served = 0u32;
    let mut failed = 0u32;
    for rx in &rxs {
        match recv_one(rx) {
            Ok(r) => {
                assert_eq!(r.predicted, 3); // mock argmax = last class
                served += 1;
            }
            Err(ServeError::ExecutorFailed(msg)) => {
                assert!(msg.contains("panic"), "unexpected failure: {msg}");
                failed += 1;
            }
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    assert_eq!(served + failed + rejected, total as u32, "a request went unaccounted");
    assert!(served > 0, "panicking executors must not take down all traffic");
    assert!(failed > 0, "panic injection never fired");
    let m = c.metrics();
    assert!(m.worker_panics > 0);
    assert_eq!(m.quarantined, 0);
    let report = c.shutdown();
    assert!(report.drained, "shutdown failed to drain in time: {report:?}");
}

#[test]
fn test_expired_deadlines_are_answered_not_executed() {
    let factory: ExecutorFactory = Box::new(|| {
        let mut slow = mock();
        slow.delay_us_per_image = 5_000;
        Ok(Box::new(slow) as Box<dyn Executor>)
    });
    let c = start(
        vec![factory],
        CoordinatorConfig { max_wait_us: 500, ..Default::default() },
    );
    // a burst with 1ms deadlines against a 5ms/image executor: the head
    // of the burst is served, the tail expires in queue
    let rxs: Vec<_> = (0..12)
        .map(|i| {
            c.submit(
                Request::new(image(i as f32), PrecisionClass::Fast)
                    .with_deadline(Duration::from_millis(1)),
            )
            .unwrap()
        })
        .collect();
    let mut expired = 0;
    let mut served = 0;
    for rx in &rxs {
        match recv_one(rx) {
            Ok(_) => served += 1,
            Err(ServeError::DeadlineExceeded) => expired += 1,
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    assert_eq!(served + expired, 12);
    assert!(expired > 0, "no deadline ever expired under a 5ms/image executor");
    assert_eq!(c.metrics().deadline_missed, expired as u64);
    // an already-expired deadline short-circuits before queueing
    let rx = c
        .submit(Request::new(image(0.0), PrecisionClass::Fast).with_deadline(Duration::ZERO))
        .unwrap();
    assert_eq!(recv_one(&rx).unwrap_err(), ServeError::DeadlineExceeded);
    c.shutdown();
}

#[test]
fn test_overload_degrades_then_sheds_along_the_ladder() {
    let factory: ExecutorFactory = Box::new(|| {
        let mut slow = mock();
        slow.delay_us_per_image = 2_000;
        Ok(Box::new(slow) as Box<dyn Executor>)
    });
    let c = start(
        vec![factory],
        CoordinatorConfig {
            max_wait_us: 500,
            degrade: DegradeConfig {
                degrade_watermark: 2,
                shed_watermark: 10,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let rxs: Vec<_> = (0..40)
        .map(|i| c.submit(Request::new(image(i as f32), PrecisionClass::Accurate)).unwrap())
        .collect();
    let mut degraded = 0;
    let mut full = 0;
    let mut shed = 0;
    for rx in &rxs {
        match recv_one(rx) {
            Ok(r) if r.degraded => {
                assert_ne!(r.class, PrecisionClass::Accurate);
                assert_ne!(r.variant, "fp32");
                degraded += 1;
            }
            Ok(r) => {
                assert_eq!(r.variant, "fp32");
                full += 1;
            }
            Err(ServeError::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    assert_eq!(degraded + full + shed, 40);
    assert!(degraded > 0, "queue past the degrade watermark never degraded");
    let m = c.metrics();
    assert_eq!(m.degraded, degraded as u64);
    assert_eq!(m.shed, shed as u64);
    c.shutdown();
}

#[test]
fn test_quarantine_after_consecutive_panics_with_survivor() {
    // worker 0 always panics and must be quarantined after 2 strikes;
    // worker 1 is healthy and keeps the service alive
    let always_faulty: ExecutorFactory = Box::new(|| {
        Ok(Box::new(FaultyExecutor::new(mock(), ChaosConfig::panic_every(1)))
            as Box<dyn Executor>)
    });
    let healthy: ExecutorFactory = Box::new(|| Ok(Box::new(mock()) as Box<dyn Executor>));
    let c = start(
        vec![always_faulty, healthy],
        CoordinatorConfig { max_wait_us: 200, quarantine_after: 2, ..Default::default() },
    );
    // drive traffic until the faulty worker has struck out
    let mut failures = 0;
    for i in 0..60 {
        let rx = c.submit(Request::new(image(i as f32), PrecisionClass::Fast)).unwrap();
        if recv_one(&rx).is_err() {
            failures += 1;
        }
        if c.metrics().quarantined > 0 {
            break;
        }
    }
    let m = c.metrics();
    assert!(m.quarantined >= 1, "faulty worker never quarantined (failures={failures})");
    assert!(m.worker_panics >= 2);
    // post-quarantine: the healthy worker serves everything
    for i in 0..10 {
        let rx = c.submit(Request::new(image(i as f32), PrecisionClass::Balanced)).unwrap();
        recv_one(&rx).expect("healthy worker must serve after quarantine");
    }
    assert!(c.shutdown().drained);
}

#[test]
fn test_all_workers_quarantined_yields_typed_errors_not_hangs() {
    let always_faulty: ExecutorFactory = Box::new(|| {
        Ok(Box::new(FaultyExecutor::new(mock(), ChaosConfig::panic_every(1)))
            as Box<dyn Executor>)
    });
    let c = start(
        vec![always_faulty],
        CoordinatorConfig { max_wait_us: 200, quarantine_after: 1, ..Default::default() },
    );
    // first request trips the quarantine; every reply stays typed
    for i in 0..8 {
        let rx = c.submit(Request::new(image(i as f32), PrecisionClass::Fast)).unwrap();
        match recv_one(&rx) {
            Err(ServeError::ExecutorFailed(_)) | Err(ServeError::ShuttingDown) => {}
            other => panic!("expected a typed failure, got {other:?}"),
        }
    }
    let m = c.metrics();
    assert_eq!(m.quarantined, 1);
    assert!(c.shutdown().drained, "drain must not wait on a quarantined worker");
}

#[test]
fn test_injected_errors_reply_without_panicking_worker() {
    let factory: ExecutorFactory = Box::new(|| {
        Ok(Box::new(FaultyExecutor::new(mock(), ChaosConfig::error_every(2)))
            as Box<dyn Executor>)
    });
    let c = start(
        vec![factory],
        CoordinatorConfig { max_wait_us: 200, ..Default::default() },
    );
    let mut served = 0;
    let mut failed = 0;
    for i in 0..10 {
        let rx = c.submit(Request::new(image(i as f32), PrecisionClass::Fast)).unwrap();
        match recv_one(&rx) {
            Ok(_) => served += 1,
            Err(ServeError::ExecutorFailed(msg)) => {
                assert!(msg.contains("injected error"), "{msg}");
                failed += 1;
            }
            Err(e) => panic!("unexpected reply: {e}"),
        }
    }
    assert_eq!(served + failed, 10);
    assert!(served > 0 && failed > 0);
    // errors are not panics: no quarantine, no panic counter
    let m = c.metrics();
    assert_eq!(m.worker_panics, 0);
    assert_eq!(m.quarantined, 0);
    c.shutdown();
}

// ---------------------------------------------------------------- hot-swap

/// Shared-store serving stack on the real `LpExecutor`: every worker sees
/// the same `VariantStore`, the coordinator gets the matching reload hook.
fn start_swap_stack(
    seed: u64,
    workers: usize,
) -> (Coordinator, dfp_infer::tensor::Tensor<f32>) {
    let store = LpExecutor::synthetic_store(seed);
    let registry = KernelRegistry::auto();
    let net = resnet_mini_default();
    let m = LpExecutor::synthetic_manifest();
    let router = Router::from_manifest(&m).unwrap();
    let sizes: BTreeMap<String, Vec<usize>> =
        m.variants.keys().map(|v| (v.clone(), m.batch_sizes.clone())).collect();
    let factories: Vec<ExecutorFactory> = (0..workers)
        .map(|_| {
            LpExecutor::store_factory(
                net.clone(),
                Arc::clone(&store),
                registry.clone(),
                m.batch_sizes.clone(),
            )
        })
        .collect();
    let c = Coordinator::start(
        factories,
        router,
        &sizes,
        m.img,
        CoordinatorConfig { max_wait_us: 300, ..Default::default() },
    )
    .unwrap();
    c.install_reload_hook(LpExecutor::reload_hook(store));
    let n = m.img * m.img * 3;
    let img = dfp_infer::tensor::Tensor::new(&[m.img, m.img, 3], vec![0.5; n]).unwrap();
    (c, img)
}

fn swap_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("dfp_swap_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn test_hot_swap_under_load_loses_no_request() {
    let dir = swap_dir("live");
    LpExecutor::export_synthetic_artifacts(&dir, 99).unwrap();
    let (c, img) = start_swap_stack(7, 2);
    assert_eq!(c.serving_generation(), 0);

    // fill the queues, swap while they drain, keep submitting
    let mut rxs: Vec<_> = (0..6)
        .map(|_| c.submit(Request::new(img.clone(), PrecisionClass::Fast)).unwrap())
        .collect();
    let report = c.reload(&dir).expect("reload of a valid artifact set");
    assert_eq!(report.generation, 1);
    assert_eq!(report.variants.len(), 3, "whole ladder must swap: {:?}", report.variants);
    assert_eq!(c.serving_generation(), 1);
    rxs.extend(
        (0..6).map(|_| c.submit(Request::new(img.clone(), PrecisionClass::Fast)).unwrap()),
    );
    // the invariant: a reload mid-traffic loses nothing and fails nothing
    for rx in &rxs {
        recv_one(rx).expect("request lost or failed across a hot swap");
    }
    assert!(c.shutdown().drained);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn test_corrupt_artifact_reload_rolls_back_and_serving_continues() {
    let dir = swap_dir("rollback");
    LpExecutor::export_synthetic_artifacts(&dir, 99).unwrap();
    // flip one byte in the middle of one weight file: the checksummed
    // container must reject it, and the swap must never become visible
    let victim = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| Some(e.ok()?.path()))
        .find(|p| p.extension().is_some_and(|e| e == "dft"))
        .expect("exported set has a .dft file");
    let mut raw = std::fs::read(&victim).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0x01;
    std::fs::write(&victim, &raw).unwrap();

    let (c, img) = start_swap_stack(7, 1);
    let err = c.reload(&dir).expect_err("corrupt artifact set must be rejected");
    let msg = err.to_string();
    assert!(msg.contains("previous generation"), "{msg}");
    assert!(msg.contains("checksum mismatch"), "{msg}");
    assert_eq!(c.serving_generation(), 0, "failed reload must not bump the generation");

    // and a reload from a directory that does not exist is equally typed
    let missing = dir.join("nope");
    let err = c.reload(&missing).expect_err("missing dir must be rejected");
    assert!(err.to_string().contains("previous generation"), "{err}");
    assert_eq!(c.serving_generation(), 0);

    // rollback is not a degraded state: the old generation keeps serving
    for _ in 0..3 {
        let rx = c.submit(Request::new(img.clone(), PrecisionClass::Fast)).unwrap();
        recv_one(&rx).expect("serving must continue after a rejected reload");
    }
    assert!(c.shutdown().drained);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn test_shutdown_races_with_inflight_submits() {
    let factory: ExecutorFactory = Box::new(|| {
        let mut slow = mock();
        slow.delay_us_per_image = 300;
        Ok(Box::new(slow) as Box<dyn Executor>)
    });
    let c = Arc::new(start(
        vec![factory],
        CoordinatorConfig { max_wait_us: 300, ..Default::default() },
    ));
    let submitters: Vec<_> = (0..4)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..200 {
                    match c.submit(Request::new(image(i as f32), PrecisionClass::Fast)) {
                        Ok(rx) => rxs.push(rx),
                        // overload or the shutdown door closing: both typed
                        Err(ServeError::Overloaded) | Err(ServeError::ShuttingDown) => {}
                        Err(e) => panic!("thread {t}: unexpected submit error: {e}"),
                    }
                }
                rxs
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10));
    let report = c.shutdown_within(Duration::from_secs(10));
    assert!(report.drained, "drain timed out: {report:?}");
    // every accepted submit — including any that raced the drain — must
    // still resolve to exactly one typed reply
    for h in submitters {
        for rx in h.join().unwrap() {
            match recv_one(&rx) {
                Ok(_) | Err(ServeError::ShuttingDown) => {}
                Err(e) => panic!("unexpected reply during shutdown race: {e}"),
            }
        }
    }
}
