//! Mini property-testing framework (proptest is not available offline).
//!
//! `check(n, gen, prop)` runs `prop` on `n` generated cases and, on
//! failure, greedily shrinks the failing case via the generator's `shrink`
//! before panicking with a reproducible seed. Used by
//! `rust/tests/proptests.rs` on the coordinator/quantizer invariants.

pub mod chaos;

use crate::util::SplitMix64;

/// A value generator with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value;

    /// Candidate smaller versions of a failing value (simplest first).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `n` random cases (seeded deterministically unless
/// `PROPTEST_SEED` is set). Panics with the shrunk counterexample.
pub fn check<G: Gen>(n: usize, gen: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let seed = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE_u64);
    let mut rng = SplitMix64::new(seed);
    for case in 0..n {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // greedy shrink
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}): {best_msg}\ncounterexample: {best:?}"
            );
        }
    }
}

// ------------------------------------------------------------ generators

/// Uniform usize in [lo, hi].
pub struct RangeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for RangeGen {
    type Value = usize;

    fn generate(&self, rng: &mut SplitMix64) -> usize {
        self.lo + rng.next_below((self.hi - self.lo + 1) as u64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of f32 ~ N(0, sigma), length in [min_len, max_len].
pub struct VecF32Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub sigma: f32,
}

impl Gen for VecF32Gen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut SplitMix64) -> Vec<f32> {
        let n = self.min_len + rng.next_below((self.max_len - self.min_len + 1) as u64) as usize;
        rng.normal(n).into_iter().map(|x| x * self.sigma).collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // zero out elements (simpler values)
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_passing_property() {
        check(100, &RangeGen { lo: 1, hi: 50 }, |&n| {
            if n >= 1 && n <= 50 {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn test_failing_property_shrinks() {
        check(100, &RangeGen { lo: 0, hi: 1000 }, |&n| {
            if n < 500 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn test_vec_gen_bounds() {
        let g = VecF32Gen { min_len: 2, max_len: 9, sigma: 1.0 };
        let mut rng = SplitMix64::new(1);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!((2..=9).contains(&v.len()));
        }
    }

    #[test]
    fn test_pair_gen() {
        let g = PairGen(RangeGen { lo: 1, hi: 4 }, RangeGen { lo: 10, hi: 20 });
        check(50, &g, |&(a, b)| {
            if a <= 4 && b >= 10 {
                Ok(())
            } else {
                Err("bounds".into())
            }
        });
    }
}
