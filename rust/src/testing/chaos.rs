//! Deterministic fault injection for the serving coordinator.
//!
//! [`FaultyExecutor`] wraps any [`Executor`] and injects failures on a
//! fixed, seedless schedule driven by a call counter — panic every Nth
//! batch, error every Mth, add fixed latency — so resilience tests
//! (`rust/tests/serving_resilience.rs`) reproduce exactly across runs and
//! machines. No randomness: the Kth `run_batch_into` call always behaves
//! the same way.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::Executor;
use crate::tensor::Tensor;

/// Fault schedule for a [`FaultyExecutor`]. All mechanisms are off by
/// default; a zero period disables that fault.
#[derive(Debug, Clone, Default)]
pub struct ChaosConfig {
    /// panic on every Nth `run_batch_into` call (1-based: with
    /// `panic_every = 3`, calls 3, 6, 9, ... panic)
    pub panic_every: usize,
    /// return an `Err` on every Mth call (checked after the panic rule)
    pub error_every: usize,
    /// fixed latency added to every call (including faulty ones)
    pub added_latency: Duration,
}

impl ChaosConfig {
    pub fn panic_every(n: usize) -> Self {
        Self { panic_every: n, ..Default::default() }
    }

    pub fn error_every(n: usize) -> Self {
        Self { error_every: n, ..Default::default() }
    }
}

/// [`Executor`] wrapper injecting deterministic faults per
/// [`ChaosConfig`]. Delegates everything else to the inner executor.
pub struct FaultyExecutor<E: Executor> {
    inner: E,
    cfg: ChaosConfig,
    calls: usize,
}

impl<E: Executor> FaultyExecutor<E> {
    pub fn new(inner: E, cfg: ChaosConfig) -> Self {
        Self { inner, cfg, calls: 0 }
    }

    /// Total `run_batch_into` calls observed (faulty ones included).
    pub fn calls(&self) -> usize {
        self.calls
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }
}

impl<E: Executor> Executor for FaultyExecutor<E> {
    fn run_batch_into(
        &mut self,
        variant: &str,
        batch: usize,
        x: &Tensor<f32>,
        logits: &mut [f32],
    ) -> Result<()> {
        self.calls += 1;
        if !self.cfg.added_latency.is_zero() {
            std::thread::sleep(self.cfg.added_latency);
        }
        if self.cfg.panic_every > 0 && self.calls % self.cfg.panic_every == 0 {
            panic!("chaos: injected panic on call {}", self.calls);
        }
        if self.cfg.error_every > 0 && self.calls % self.cfg.error_every == 0 {
            anyhow::bail!("chaos: injected error on call {}", self.calls);
        }
        self.inner.run_batch_into(variant, batch, x, logits)
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.inner.batch_sizes(variant)
    }

    fn img(&self) -> usize {
        self.inner.img()
    }

    fn classes(&self) -> usize {
        self.inner.classes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExecutor;

    fn mock() -> MockExecutor {
        MockExecutor::new(4, 3, &[("v", &[1, 2])])
    }

    fn input(batch: usize) -> Tensor<f32> {
        Tensor::new(&[batch, 4, 4, 3], vec![1.0; batch * 48]).unwrap()
    }

    #[test]
    fn test_panic_schedule_is_deterministic() {
        let mut e = FaultyExecutor::new(mock(), ChaosConfig::panic_every(3));
        let x = input(1);
        let mut logits = vec![0.0; 3];
        for call in 1..=9 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                e.run_batch_into("v", 1, &x, &mut logits)
            }));
            if call % 3 == 0 {
                assert!(r.is_err(), "call {call} must panic");
            } else {
                assert!(r.unwrap().is_ok(), "call {call} must succeed");
            }
        }
        assert_eq!(e.calls(), 9);
        // only the non-panicking calls reached the inner executor
        assert_eq!(e.inner().executed.len(), 6);
    }

    #[test]
    fn test_error_schedule() {
        let mut e = FaultyExecutor::new(mock(), ChaosConfig::error_every(2));
        let x = input(1);
        let mut logits = vec![0.0; 3];
        assert!(e.run_batch_into("v", 1, &x, &mut logits).is_ok());
        let err = e.run_batch_into("v", 1, &x, &mut logits).unwrap_err();
        assert!(err.to_string().contains("injected error"), "{err}");
        assert!(e.run_batch_into("v", 1, &x, &mut logits).is_ok());
    }

    #[test]
    fn test_no_faults_is_transparent() {
        let mut plain = mock();
        let mut wrapped = FaultyExecutor::new(mock(), ChaosConfig::default());
        let x = input(2);
        let mut a = vec![0.0; 6];
        let mut b = vec![0.0; 6];
        plain.run_batch_into("v", 2, &x, &mut a).unwrap();
        wrapped.run_batch_into("v", 2, &x, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(wrapped.batch_sizes("v"), vec![1, 2]);
        assert_eq!(wrapped.img(), 4);
        assert_eq!(wrapped.classes(), 3);
    }
}
