//! Analytic op-count and energy model — reproduces the paper's §3.3
//! performance claims exactly (they are arithmetic over layer shapes):
//!
//! * clustering N filters gives **one 8-bit multiply per N·K² ternary
//!   accumulations** (per output pixel of a cluster: N·K²·Cin accumulates,
//!   Cin·? — in the paper's counting, the scale multiply amortizes over the
//!   N·K² weights of the cluster that contribute to one output column);
//! * on ResNet-101, N=4 replaces ≈85 % of multiplies with 8-bit adds,
//!   N=64 replaces ≈98 %;
//! * the "16× performance-power benefit" projection of §5 from MAC
//!   energy/area scaling.

use crate::model::Network;

/// Op census for one network under a quantization configuration.
#[derive(Debug, Clone)]
pub struct OpCensus {
    pub network: String,
    pub cluster: usize,
    /// total multiply-accumulates (the FP32 baseline's multiply count)
    pub total_macs: u64,
    /// multiplies remaining in the quantized pipeline
    ///   = C1 layer MACs (8-bit mult) + one scale multiply per (cluster x output pixel)
    pub mults: u64,
    /// ternary accumulations (additions replacing multiplies)
    pub accums: u64,
}

impl OpCensus {
    /// Fraction of baseline multiplies replaced by 8-bit accumulations.
    pub fn replaced_frac(&self) -> f64 {
        1.0 - self.mults as f64 / self.total_macs as f64
    }

    /// Low-precision ops per remaining multiply.
    pub fn accums_per_mult(&self) -> f64 {
        self.accums as f64 / self.mults as f64
    }
}

/// Count ops for a ternary-clustered network with the paper's §3.3
/// accounting: "one 8-bit multiplication for the entire cluster (N·K²) of
/// ternary accumulations" — i.e. the scale multiply amortizes over each
/// N·K² weight-block of MACs, `mults_layer = macs / (N·K²)`. With the
/// real ResNet-101 3x3/1x1 mix this reproduces the 85 % (N=4) and ≈98 %
/// (N=64) replacement claims. C1 stays full 8-bit multiplies (§3.2).
pub fn census_ternary(net: &Network, cluster: usize) -> OpCensus {
    let mut mults = 0u64;
    let mut accums = 0u64;
    for (i, l) in net.layers.iter().enumerate() {
        let macs = l.macs();
        if i == 0 {
            mults += macs; // C1 stays 8-bit multiplies (§3.2)
            continue;
        }
        let block = (cluster * l.kh * l.kw) as u64; // N*K^2
        mults += macs.div_ceil(block);
        accums += macs;
    }
    // FC layer: ternary too (paper: "the rest of the layers including FC");
    // K=1 for a fully connected "1x1" block.
    let fc_macs = (net.fc_in * net.fc_out) as u64;
    mults += fc_macs.div_ceil(cluster as u64);
    accums += fc_macs;
    OpCensus {
        network: net.name.clone(),
        cluster,
        total_macs: net.total_macs(),
        mults,
        accums,
    }
}

/// Alternative output-stationary accounting: one α̂ multiply per *output
/// element* of a cluster (`out_hw² · ceil(cout/N)` per layer) — what an
/// accumulate-then-scale dataflow would pay. Strictly fewer multiplies
/// than the paper's per-block accounting; reported as an ablation in the
/// bench harness (E3).
pub fn census_ternary_output_stationary(net: &Network, cluster: usize) -> OpCensus {
    let mut mults = 0u64;
    let mut accums = 0u64;
    for (i, l) in net.layers.iter().enumerate() {
        let macs = l.macs();
        if i == 0 {
            mults += macs;
            continue;
        }
        mults += (l.out_hw * l.out_hw) as u64 * l.cout.div_ceil(cluster) as u64;
        accums += macs;
    }
    let fc_macs = (net.fc_in * net.fc_out) as u64;
    mults += net.fc_out.div_ceil(cluster) as u64;
    accums += fc_macs;
    OpCensus { network: net.name.clone(), cluster, total_macs: net.total_macs(), mults, accums }
}

/// The paper's per-block statement: one 8-bit multiply per N·K² ternary
/// accumulations for a cluster of N KxK filters.
pub fn accums_per_mult_block(n: usize, k: usize) -> u64 {
    (n * k * k) as u64
}

// ---------------------------------------------------------------------------
// Energy / performance projection (§5 "potential 16x benefit")
// ---------------------------------------------------------------------------

/// Relative energy of a multiply at `bits` precision vs an FP32 multiply
/// (quadratic scaling of multiplier area/energy with operand width — the
/// standard model behind the paper's 16x projection; cf. Horowitz ISSCC'14).
pub fn mult_energy_rel(bits: u32) -> f64 {
    (f64::from(bits) / 32.0).powi(2)
}

/// Relative energy of an add at `bits` precision vs an FP32 multiply.
/// Adders scale ~linearly with width and an int add is far cheaper than a
/// fp32 multiply; the 0.1 baseline ratio follows the Horowitz numbers
/// (int8 add ~0.03pJ vs fp32 mult ~3.7pJ => ~1/100; we use a conservative
/// 32-bit-accumulate cost of ~1/25 of an fp32 multiply).
pub fn add_energy_rel(bits: u32) -> f64 {
    0.04 * f64::from(bits) / 32.0
}

/// Energy model for a whole-network census: relative to all-FP32 MACs.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// fp32 baseline energy (normalized so one fp32 MAC = 1.0 + add share)
    pub fp32: f64,
    /// quantized pipeline energy under the census
    pub quant: f64,
}

impl EnergyModel {
    pub fn speedup(&self) -> f64 {
        self.fp32 / self.quant
    }
}

/// Project energy for a ternary-clustered census: remaining multiplies are
/// 8-bit, accumulations are 32-bit adds fed by 8-bit operands.
pub fn project_energy(census: &OpCensus) -> EnergyModel {
    let fp32_mac = 1.0 + add_energy_rel(32); // fp32 mult + fp32 add per MAC
    let fp32 = census.total_macs as f64 * fp32_mac;
    let quant = census.mults as f64 * (mult_energy_rel(8) + add_energy_rel(32))
        + census.accums as f64 * add_energy_rel(32);
    EnergyModel { fp32, quant }
}

/// Markdown table of §3.3 for a set of cluster sizes (the E3 harness).
pub fn table_3_3(net: &Network, clusters: &[usize]) -> String {
    let mut out = String::from(
        "| N | mults remaining | accums | % replaced | accums/mult | est. speedup |\n|---|---|---|---|---|---|\n",
    );
    for &n in clusters {
        let c = census_ternary(net, n);
        let e = project_energy(&c);
        out.push_str(&format!(
            "| {} | {} | {} | {:.1}% | {:.0} | {:.1}x |\n",
            n,
            c.mults,
            c.accums,
            100.0 * c.replaced_frac(),
            c.accums_per_mult(),
            e.speedup(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet101, resnet50, resnet_mini_default};

    #[test]
    fn test_accums_per_mult_block() {
        assert_eq!(accums_per_mult_block(4, 3), 36);
        assert_eq!(accums_per_mult_block(64, 3), 576);
        assert_eq!(accums_per_mult_block(4, 1), 4);
    }

    #[test]
    fn test_resnet101_n4_replaces_about_85_percent() {
        // §3.3: "using this block size can potentially replace 85% of
        // multiplications in Resnet-101 convolution layers"
        let c = census_ternary(&resnet101(), 4);
        let f = c.replaced_frac();
        assert!((0.80..0.92).contains(&f), "N=4 replaced {f}");
    }

    #[test]
    fn test_output_stationary_fewer_mults() {
        let net = resnet101();
        let paper = census_ternary(&net, 4);
        let os = census_ternary_output_stationary(&net, 4);
        assert!(os.mults < paper.mults);
        assert!(os.replaced_frac() > paper.replaced_frac());
    }

    #[test]
    fn test_resnet101_n64_replaces_about_98_percent() {
        let c = census_ternary(&resnet101(), 64);
        let f = c.replaced_frac();
        assert!((0.96..0.999).contains(&f), "N=64 replaced {f}");
    }

    #[test]
    fn test_monotone_in_cluster_size() {
        let net = resnet50();
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8, 16, 32, 64] {
            let f = census_ternary(&net, n).replaced_frac();
            assert!(f >= last, "N={n}: {f} < {last}");
            last = f;
        }
    }

    #[test]
    fn test_energy_projection_order_16x() {
        // §5: "potential 16X performance-power benefit" for the full 8-bit
        // pipeline vs fp32 — our model should land in the same decade.
        let c = census_ternary(&resnet101(), 64);
        let s = project_energy(&c).speedup();
        assert!((8.0..40.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn test_mini_census_consistency() {
        let net = resnet_mini_default();
        let c = census_ternary(&net, 4);
        assert!(c.accums < c.total_macs); // C1 not ternary
        assert!(c.mults < c.total_macs);
        assert!(c.replaced_frac() > 0.5);
    }

    #[test]
    fn test_energy_model_units() {
        assert!((mult_energy_rel(8) - 1.0 / 16.0).abs() < 1e-12);
        assert!(add_energy_rel(32) < mult_energy_rel(32));
    }

    #[test]
    fn test_table_renders() {
        let t = table_3_3(&resnet101(), &[4, 64]);
        assert!(t.contains("| 4 |") && t.contains("| 64 |"));
    }
}
