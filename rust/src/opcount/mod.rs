//! Analytic op-count and energy model — reproduces the paper's §3.3
//! performance claims exactly (they are arithmetic over layer shapes):
//!
//! * clustering N filters gives **one 8-bit multiply per N·K² ternary
//!   accumulations** (per output pixel of a cluster: N·K²·Cin accumulates,
//!   Cin·? — in the paper's counting, the scale multiply amortizes over the
//!   N·K² weights of the cluster that contribute to one output column);
//! * on ResNet-101, N=4 replaces ≈85 % of multiplies with 8-bit adds,
//!   N=64 replaces ≈98 %;
//! * the "16× performance-power benefit" projection of §5 from MAC
//!   energy/area scaling.

use crate::model::Network;
use crate::quant::TernaryMode;
use crate::scheme::{LayerPolicy, Scheme, WeightCodec};

/// Op census for one network under a quantization scheme.
#[derive(Debug, Clone)]
pub struct OpCensus {
    pub network: String,
    /// compact name of the scheme this census counts
    pub scheme: String,
    /// total multiply-accumulates (the FP32 baseline's multiply count)
    pub total_macs: u64,
    /// multiplies remaining in the quantized pipeline
    ///   = non-ternary layer MACs (8-bit mult) + one scale multiply per weight block
    pub mults: u64,
    /// ternary accumulations (additions replacing multiplies)
    pub accums: u64,
}

impl OpCensus {
    /// Fraction of baseline multiplies replaced by 8-bit accumulations.
    pub fn replaced_frac(&self) -> f64 {
        1.0 - self.mults as f64 / self.total_macs as f64
    }

    /// Low-precision ops per remaining multiply.
    pub fn accums_per_mult(&self) -> f64 {
        self.accums as f64 / self.mults as f64
    }
}

/// The paper's §3.3 configuration as a [`Scheme`]: cluster-N ternary
/// everywhere (including FC), except the first conv which stays full 8-bit
/// (§3.2 keeps C1 high-precision).
pub fn ternary_scheme(net: &Network, cluster: usize) -> Scheme {
    let tern = LayerPolicy::new(WeightCodec::Ternary { mode: TernaryMode::Support }, cluster)
        .expect("cluster >= 1");
    let stem = LayerPolicy::new(WeightCodec::I8, cluster).expect("cluster >= 1");
    Scheme::uniform(8, tern)
        .and_then(|s| s.with_override(&net.layers[0].name, stem))
        .expect("valid ternary scheme")
}

/// Count ops for a network under a mixed-precision scheme with the paper's
/// §3.3 accounting: a ternary layer's MACs all become accumulations, and
/// "one 8-bit multiplication for the entire cluster (N·K²) of ternary
/// accumulations" — the scale multiply amortizes over each N·K²
/// weight-block, `mults_layer = macs / (N·K²)`. Non-ternary layers (i8 /
/// k-bit DFP) keep their MACs as multiplies. With [`ternary_scheme`] on the
/// real ResNet-101 3x3/1x1 mix this reproduces the 85 % (N=4) and ≈98 %
/// (N=64) replacement claims.
pub fn census(net: &Network, scheme: &Scheme) -> OpCensus {
    let mut mults = 0u64;
    let mut accums = 0u64;
    let mut count = |macs: u64, kh: usize, kw: usize, policy: &LayerPolicy| match policy.codec {
        WeightCodec::Ternary { .. } => {
            let block = (policy.cluster * kh * kw) as u64; // N*K^2
            mults += macs.div_ceil(block);
            accums += macs;
        }
        WeightCodec::Dfp { .. } | WeightCodec::I8 => mults += macs,
    };
    for l in &net.layers {
        count(l.macs(), l.kh, l.kw, scheme.policy_for(&l.name));
    }
    // FC: K=1 for a fully connected "1x1" block.
    count((net.fc_in * net.fc_out) as u64, 1, 1, scheme.policy_for("fc"));
    OpCensus {
        network: net.name.clone(),
        scheme: scheme.name(),
        total_macs: net.total_macs(),
        mults,
        accums,
    }
}

/// Convenience wrapper: [`census`] under [`ternary_scheme`] — the paper's
/// ternary-N configuration with an 8-bit first conv.
pub fn census_ternary(net: &Network, cluster: usize) -> OpCensus {
    census(net, &ternary_scheme(net, cluster))
}

/// Alternative output-stationary accounting: one α̂ multiply per *output
/// element* of a cluster (`out_hw² · ceil(cout/N)` per layer) — what an
/// accumulate-then-scale dataflow would pay. Strictly fewer multiplies
/// than the paper's per-block accounting; reported as an ablation in the
/// bench harness (E3).
pub fn census_ternary_output_stationary(net: &Network, cluster: usize) -> OpCensus {
    let mut mults = 0u64;
    let mut accums = 0u64;
    for (i, l) in net.layers.iter().enumerate() {
        let macs = l.macs();
        if i == 0 {
            mults += macs;
            continue;
        }
        mults += (l.out_hw * l.out_hw) as u64 * l.cout.div_ceil(cluster) as u64;
        accums += macs;
    }
    let fc_macs = (net.fc_in * net.fc_out) as u64;
    mults += net.fc_out.div_ceil(cluster) as u64;
    accums += fc_macs;
    OpCensus {
        network: net.name.clone(),
        scheme: format!("{}-os", ternary_scheme(net, cluster)),
        total_macs: net.total_macs(),
        mults,
        accums,
    }
}

/// The paper's per-block statement: one 8-bit multiply per N·K² ternary
/// accumulations for a cluster of N KxK filters.
pub fn accums_per_mult_block(n: usize, k: usize) -> u64 {
    (n * k * k) as u64
}

// ---------------------------------------------------------------------------
// Energy / performance projection (§5 "potential 16x benefit")
// ---------------------------------------------------------------------------

/// Relative energy of a multiply at `bits` precision vs an FP32 multiply
/// (quadratic scaling of multiplier area/energy with operand width — the
/// standard model behind the paper's 16x projection; cf. Horowitz ISSCC'14).
pub fn mult_energy_rel(bits: u32) -> f64 {
    (f64::from(bits) / 32.0).powi(2)
}

/// Relative energy of an add at `bits` precision vs an FP32 multiply.
/// Adders scale ~linearly with width and an int add is far cheaper than a
/// fp32 multiply; the 0.1 baseline ratio follows the Horowitz numbers
/// (int8 add ~0.03pJ vs fp32 mult ~3.7pJ => ~1/100; we use a conservative
/// 32-bit-accumulate cost of ~1/25 of an fp32 multiply).
pub fn add_energy_rel(bits: u32) -> f64 {
    0.04 * f64::from(bits) / 32.0
}

/// Energy model for a whole-network census: relative to all-FP32 MACs.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// fp32 baseline energy (normalized so one fp32 MAC = 1.0 + add share)
    pub fp32: f64,
    /// quantized pipeline energy under the census
    pub quant: f64,
}

impl EnergyModel {
    pub fn speedup(&self) -> f64 {
        self.fp32 / self.quant
    }
}

/// Project energy for a ternary-clustered census: remaining multiplies are
/// 8-bit, accumulations are 32-bit adds fed by 8-bit operands.
pub fn project_energy(census: &OpCensus) -> EnergyModel {
    let fp32_mac = 1.0 + add_energy_rel(32); // fp32 mult + fp32 add per MAC
    let fp32 = census.total_macs as f64 * fp32_mac;
    let quant = census.mults as f64 * (mult_energy_rel(8) + add_energy_rel(32))
        + census.accums as f64 * add_energy_rel(32);
    EnergyModel { fp32, quant }
}

/// Markdown table of §3.3 for a set of schemes (the E3 harness). Rows are
/// labeled by scheme name; build the paper's cluster sweep with
/// [`ternary_scheme`], or pass mixed schemes directly.
pub fn table_3_3(net: &Network, schemes: &[Scheme]) -> String {
    let mut out = String::from(
        "| scheme | mults remaining | accums | % replaced | accums/mult | est. speedup |\n|---|---|---|---|---|---|\n",
    );
    for s in schemes {
        let c = census(net, s);
        let e = project_energy(&c);
        out.push_str(&format!(
            "| {} | {} | {} | {:.1}% | {:.0} | {:.1}x |\n",
            c.scheme,
            c.mults,
            c.accums,
            100.0 * c.replaced_frac(),
            c.accums_per_mult(),
            e.speedup(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet101, resnet50, resnet_mini_default};

    #[test]
    fn test_accums_per_mult_block() {
        assert_eq!(accums_per_mult_block(4, 3), 36);
        assert_eq!(accums_per_mult_block(64, 3), 576);
        assert_eq!(accums_per_mult_block(4, 1), 4);
    }

    #[test]
    fn test_resnet101_n4_replaces_about_85_percent() {
        // §3.3: "using this block size can potentially replace 85% of
        // multiplications in Resnet-101 convolution layers"
        let c = census_ternary(&resnet101(), 4);
        let f = c.replaced_frac();
        assert!((0.80..0.92).contains(&f), "N=4 replaced {f}");
    }

    #[test]
    fn test_output_stationary_fewer_mults() {
        let net = resnet101();
        let paper = census_ternary(&net, 4);
        let os = census_ternary_output_stationary(&net, 4);
        assert!(os.mults < paper.mults);
        assert!(os.replaced_frac() > paper.replaced_frac());
    }

    #[test]
    fn test_resnet101_n64_replaces_about_98_percent() {
        let c = census_ternary(&resnet101(), 64);
        let f = c.replaced_frac();
        assert!((0.96..0.999).contains(&f), "N=64 replaced {f}");
    }

    #[test]
    fn test_monotone_in_cluster_size() {
        let net = resnet50();
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8, 16, 32, 64] {
            let f = census_ternary(&net, n).replaced_frac();
            assert!(f >= last, "N={n}: {f} < {last}");
            last = f;
        }
    }

    #[test]
    fn test_energy_projection_order_16x() {
        // §5: "potential 16X performance-power benefit" for the full 8-bit
        // pipeline vs fp32 — our model should land in the same decade.
        let c = census_ternary(&resnet101(), 64);
        let s = project_energy(&c).speedup();
        assert!((8.0..40.0).contains(&s), "speedup {s}");
    }

    #[test]
    fn test_mini_census_consistency() {
        let net = resnet_mini_default();
        let c = census_ternary(&net, 4);
        assert!(c.accums < c.total_macs); // C1 not ternary
        assert!(c.mults < c.total_macs);
        assert!(c.replaced_frac() > 0.5);
    }

    #[test]
    fn test_energy_model_units() {
        assert!((mult_energy_rel(8) - 1.0 / 16.0).abs() < 1e-12);
        assert!(add_energy_rel(32) < mult_energy_rel(32));
    }

    #[test]
    fn test_table_renders() {
        let net = resnet101();
        let schemes = [ternary_scheme(&net, 4), ternary_scheme(&net, 64)];
        let t = table_3_3(&net, &schemes);
        assert!(t.contains("| 8a2w_n4@conv1=i8 |") && t.contains("| 8a2w_n64@conv1=i8 |"), "{t}");
    }

    #[test]
    fn test_census_accepts_mixed_schemes() {
        let net = resnet101();
        let paper = census_ternary(&net, 4);
        // same scheme spelled explicitly gives identical numbers
        let explicit = census(&net, &Scheme::parse("8a2w_n4@conv1=i8").unwrap());
        assert_eq!(explicit.mults, paper.mults);
        assert_eq!(explicit.accums, paper.accums);
        // keeping a whole stage at i8 strictly lowers the replaced fraction
        let partial = census(&net, &Scheme::parse("8a2w_n4@conv1=i8@s3*=i8").unwrap());
        assert!(partial.mults > paper.mults);
        assert!(partial.replaced_frac() < paper.replaced_frac());
        // an all-i8 scheme replaces nothing
        let none = census(&net, &Scheme::parse("8a8w_n4").unwrap());
        assert_eq!(none.mults, none.total_macs);
        assert_eq!(none.accums, 0);
    }
}
