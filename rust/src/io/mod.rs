//! DFT binary tensor container — Rust side of the python<->rust interchange.
//!
//! Format (little endian), mirrored in `python/compile/dft.py`:
//! ```text
//! magic  b"DFT1"
//! u32    tensor count
//! per tensor:
//!   u16  name length + utf-8 name
//!   u8   dtype tag (0=f32 1=i8 2=i32 3=u8 4=i64)
//!   u8   ndim
//!   u32* dims
//!   u64  payload length + raw row-major bytes
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, Element, Tensor};

const MAGIC: &[u8; 4] = b"DFT1";

/// A dtype-erased tensor as stored in a DFT file.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyTensor {
    F32(Tensor<f32>),
    I8(Tensor<i8>),
    I32(Tensor<i32>),
    U8(Tensor<u8>),
    I64(Tensor<i64>),
}

impl AnyTensor {
    pub fn dtype(&self) -> DType {
        match self {
            AnyTensor::F32(_) => DType::F32,
            AnyTensor::I8(_) => DType::I8,
            AnyTensor::I32(_) => DType::I32,
            AnyTensor::U8(_) => DType::U8,
            AnyTensor::I64(_) => DType::I64,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => t.shape(),
            AnyTensor::I8(t) => t.shape(),
            AnyTensor::I32(t) => t.shape(),
            AnyTensor::U8(t) => t.shape(),
            AnyTensor::I64(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor<f32>> {
        match self {
            AnyTensor::F32(t) => Ok(t),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&Tensor<i8>> {
        match self {
            AnyTensor::I8(t) => Ok(t),
            other => bail!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&Tensor<i32>> {
        match self {
            AnyTensor::I32(t) => Ok(t),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i64(&self) -> Result<&Tensor<i64>> {
        match self {
            AnyTensor::I64(t) => Ok(t),
            other => bail!("expected i64 tensor, got {:?}", other.dtype()),
        }
    }
}

/// Name -> tensor mapping (ordered, for deterministic writes).
pub type TensorMap = BTreeMap<String, AnyTensor>;

// ---------------------------------------------------------------- writing

fn put_bytes<T: Element>(out: &mut Vec<u8>, t: &Tensor<T>) {
    // all supported element types are plain-old-data; serialize natively LE
    let bytes = unsafe {
        std::slice::from_raw_parts(
            t.data().as_ptr().cast::<u8>(),
            t.len() * std::mem::size_of::<T>(),
        )
    };
    out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn encode_tensor(out: &mut Vec<u8>, name: &str, t: &AnyTensor) {
    let nb = name.as_bytes();
    out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
    out.extend_from_slice(nb);
    out.push(t.dtype() as u8);
    let shape = t.shape();
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match t {
        AnyTensor::F32(t) => put_bytes(out, t),
        AnyTensor::I8(t) => put_bytes(out, t),
        AnyTensor::I32(t) => put_bytes(out, t),
        AnyTensor::U8(t) => put_bytes(out, t),
        AnyTensor::I64(t) => put_bytes(out, t),
    }
}

/// Write a DFT file.
pub fn write_dft(path: &Path, tensors: &TensorMap) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        encode_tensor(&mut buf, name, t);
    }
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(&buf))
        .with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------------- reading

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated DFT file at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

fn decode_vec<T: Element>(raw: &[u8]) -> Vec<T> {
    let n = raw.len() / std::mem::size_of::<T>();
    let mut out = vec![T::default(); n];
    unsafe {
        std::ptr::copy_nonoverlapping(
            raw.as_ptr(),
            out.as_mut_ptr().cast::<u8>(),
            n * std::mem::size_of::<T>(),
        );
    }
    out
}

/// Read a DFT file into a [`TensorMap`].
pub fn read_dft(path: &Path) -> Result<TensorMap> {
    let mut raw = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut raw))
        .with_context(|| format!("reading {}", path.display()))?;
    let mut c = Cursor { buf: &raw, pos: 0 };
    if c.take(4)? != MAGIC {
        bail!("{}: bad magic", path.display());
    }
    let count = c.u32()?;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let nlen = c.u16()? as usize;
        let name = String::from_utf8(c.take(nlen)?.to_vec()).context("tensor name utf8")?;
        let dtype = DType::from_tag(c.u8()?)?;
        let ndim = c.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let blen = c.u64()? as usize;
        let payload = c.take(blen)?;
        let expected: usize = shape.iter().product::<usize>() * dtype.size_of();
        if blen != expected {
            bail!("{name}: payload {blen} bytes != shape {shape:?} * dtype");
        }
        let t = match dtype {
            DType::F32 => AnyTensor::F32(Tensor::new(&shape, decode_vec(payload))?),
            DType::I8 => AnyTensor::I8(Tensor::new(&shape, decode_vec(payload))?),
            DType::I32 => AnyTensor::I32(Tensor::new(&shape, decode_vec(payload))?),
            DType::U8 => AnyTensor::U8(Tensor::new(&shape, decode_vec(payload))?),
            DType::I64 => AnyTensor::I64(Tensor::new(&shape, decode_vec(payload))?),
        };
        out.insert(name, t);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dfp_infer_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn test_roundtrip_all_dtypes() {
        let mut m = TensorMap::new();
        m.insert("a".into(), AnyTensor::F32(Tensor::new(&[2, 2], vec![1.0, -2.5, 3.25, 0.0]).unwrap()));
        m.insert("b".into(), AnyTensor::I8(Tensor::new(&[3], vec![-128i8, 0, 127]).unwrap()));
        m.insert("c".into(), AnyTensor::I32(Tensor::new(&[1], vec![-70000]).unwrap()));
        m.insert("d".into(), AnyTensor::U8(Tensor::new(&[2], vec![0u8, 255]).unwrap()));
        m.insert("e".into(), AnyTensor::I64(Tensor::new(&[1], vec![1i64 << 40]).unwrap()));
        let p = tmpfile("roundtrip.dft");
        write_dft(&p, &m).unwrap();
        let back = read_dft(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_empty_map() {
        let p = tmpfile("empty.dft");
        write_dft(&p, &TensorMap::new()).unwrap();
        assert!(read_dft(&p).unwrap().is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_bad_magic_rejected() {
        let p = tmpfile("bad.dft");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(read_dft(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_truncated_rejected() {
        let mut m = TensorMap::new();
        m.insert("x".into(), AnyTensor::F32(Tensor::new(&[4], vec![1.0; 4]).unwrap()));
        let p = tmpfile("trunc.dft");
        write_dft(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        std::fs::write(&p, &raw[..raw.len() - 3]).unwrap();
        assert!(read_dft(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_accessors() {
        let t = AnyTensor::F32(Tensor::new(&[2], vec![1.0, 2.0]).unwrap());
        assert!(t.as_f32().is_ok());
        assert!(t.as_i8().is_err());
        assert!(t.as_i64().is_err());
        assert_eq!(t.shape(), &[2]);
        let t64 = AnyTensor::I64(Tensor::new(&[1], vec![5i64]).unwrap());
        assert!(t64.as_i64().is_ok());
    }
}
