//! DFT binary tensor container — Rust side of the python<->rust interchange.
//!
//! Two format versions, mirrored in `python/compile/dft.py`:
//!
//! **v2** (current, written by [`write_dft`]) — little endian:
//! ```text
//! magic  b"DFT2"
//! u32    tensor count
//! per tensor:
//!   u16  name length + utf-8 name
//!   u8   dtype tag (0=f32 1=i8 2=i32 3=u8 4=i64)
//!   u8   ndim
//!   u32* dims
//!   u64  payload length + raw row-major bytes
//!   u64  FNV-1a 64 of the record (name-length field through payload)
//! u64    FNV-1a 64 of every preceding byte (magic through last record)
//! ```
//! **v1** (`b"DFT1"`) is the same layout without either checksum; readers
//! still accept it so pre-v2 exports keep loading.
//!
//! Every read failure is a typed [`ArtifactError`] naming the offending
//! path (and tensor where known) — corrupt bytes must surface as an error
//! the caller can match on, never a panic and never a silently-wrong load.
//! [`verify_dft`] walks the same decode path but returns a per-tensor
//! integrity report for the `verify-artifact` CLI.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::{DType, Element, Tensor};

const MAGIC_V1: &[u8; 4] = b"DFT1";
const MAGIC_V2: &[u8; 4] = b"DFT2";

// ------------------------------------------------------------ FNV-1a 64

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit hash — the DFT v2 integrity checksum. Not cryptographic;
/// chosen because it is a dozen lines in both Rust and Python (no deps),
/// and detects every single-bit flip and truncation we fuzz for.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

// ------------------------------------------------------------ typed errors

/// Typed artifact-load failure. Every variant names the file; tensor-level
/// variants name the tensor. Implements [`std::error::Error`], so `?` in
/// `anyhow` contexts converts it while `match` still sees the structure.
#[derive(Debug)]
pub enum ArtifactError {
    /// OS-level read/open failure.
    Io { path: PathBuf, source: std::io::Error },
    /// First four bytes are not any DFT magic.
    BadMagic { path: PathBuf, found: [u8; 4] },
    /// A DFT magic from a format revision this reader does not know.
    UnsupportedVersion { path: PathBuf, version: u8 },
    /// File ends before the structure says it should.
    Truncated { path: PathBuf, offset: usize },
    /// A stored checksum does not match the bytes (`tensor: None` = the
    /// whole-file trailer).
    ChecksumMismatch { path: PathBuf, tensor: Option<String>, stored: u64, computed: u64 },
    /// Shape/payload disagreement for a named tensor.
    BadShape { path: PathBuf, tensor: String, detail: String },
    /// Structural corruption that is not shape-specific (bad dtype tag,
    /// non-utf8 name, trailing garbage, ...).
    Corrupt { path: PathBuf, detail: String },
}

impl ArtifactError {
    /// The artifact path the error is about (every variant carries one).
    pub fn path(&self) -> &Path {
        match self {
            ArtifactError::Io { path, .. }
            | ArtifactError::BadMagic { path, .. }
            | ArtifactError::UnsupportedVersion { path, .. }
            | ArtifactError::Truncated { path, .. }
            | ArtifactError::ChecksumMismatch { path, .. }
            | ArtifactError::BadShape { path, .. }
            | ArtifactError::Corrupt { path, .. } => path,
        }
    }
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Io { path, source } => {
                write!(f, "{}: io error: {source}", path.display())
            }
            ArtifactError::BadMagic { path, found } => {
                write!(f, "{}: bad magic {:?} (not a DFT file)", path.display(), found)
            }
            ArtifactError::UnsupportedVersion { path, version } => {
                write!(f, "{}: unsupported DFT format version {version}", path.display())
            }
            ArtifactError::Truncated { path, offset } => {
                write!(f, "{}: truncated at offset {offset}", path.display())
            }
            ArtifactError::ChecksumMismatch { path, tensor, stored, computed } => match tensor {
                Some(t) => write!(
                    f,
                    "{}: checksum mismatch in tensor '{t}' (stored {stored:#018x}, computed {computed:#018x})",
                    path.display()
                ),
                None => write!(
                    f,
                    "{}: whole-file checksum mismatch (stored {stored:#018x}, computed {computed:#018x})",
                    path.display()
                ),
            },
            ArtifactError::BadShape { path, tensor, detail } => {
                write!(f, "{}: tensor '{tensor}': {detail}", path.display())
            }
            ArtifactError::Corrupt { path, detail } => {
                write!(f, "{}: corrupt: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// A dtype-erased tensor as stored in a DFT file.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyTensor {
    F32(Tensor<f32>),
    I8(Tensor<i8>),
    I32(Tensor<i32>),
    U8(Tensor<u8>),
    I64(Tensor<i64>),
}

impl AnyTensor {
    pub fn dtype(&self) -> DType {
        match self {
            AnyTensor::F32(_) => DType::F32,
            AnyTensor::I8(_) => DType::I8,
            AnyTensor::I32(_) => DType::I32,
            AnyTensor::U8(_) => DType::U8,
            AnyTensor::I64(_) => DType::I64,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => t.shape(),
            AnyTensor::I8(t) => t.shape(),
            AnyTensor::I32(t) => t.shape(),
            AnyTensor::U8(t) => t.shape(),
            AnyTensor::I64(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor<f32>> {
        match self {
            AnyTensor::F32(t) => Ok(t),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&Tensor<i8>> {
        match self {
            AnyTensor::I8(t) => Ok(t),
            other => bail!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&Tensor<i32>> {
        match self {
            AnyTensor::I32(t) => Ok(t),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i64(&self) -> Result<&Tensor<i64>> {
        match self {
            AnyTensor::I64(t) => Ok(t),
            other => bail!("expected i64 tensor, got {:?}", other.dtype()),
        }
    }
}

/// Name -> tensor mapping (ordered, for deterministic writes).
pub type TensorMap = BTreeMap<String, AnyTensor>;

// ---------------------------------------------------------------- writing

fn put_bytes<T: Element>(out: &mut Vec<u8>, t: &Tensor<T>) {
    // all supported element types are plain-old-data; serialize natively LE
    let bytes = unsafe {
        std::slice::from_raw_parts(
            t.data().as_ptr().cast::<u8>(),
            t.len() * std::mem::size_of::<T>(),
        )
    };
    out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn encode_tensor(out: &mut Vec<u8>, name: &str, t: &AnyTensor) {
    let nb = name.as_bytes();
    out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
    out.extend_from_slice(nb);
    out.push(t.dtype() as u8);
    let shape = t.shape();
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match t {
        AnyTensor::F32(t) => put_bytes(out, t),
        AnyTensor::I8(t) => put_bytes(out, t),
        AnyTensor::I32(t) => put_bytes(out, t),
        AnyTensor::U8(t) => put_bytes(out, t),
        AnyTensor::I64(t) => put_bytes(out, t),
    }
}

fn write_file(path: &Path, buf: &[u8]) -> Result<()> {
    std::fs::File::create(path)
        .and_then(|mut f| f.write_all(buf))
        .with_context(|| format!("writing {}", path.display()))
}

/// Write a DFT **v2** file: per-tensor FNV-1a checksums plus a whole-file
/// checksum trailer.
pub fn write_dft(path: &Path, tensors: &TensorMap) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_V2);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let start = buf.len();
        encode_tensor(&mut buf, name, t);
        let sum = fnv1a(&buf[start..]);
        buf.extend_from_slice(&sum.to_le_bytes());
    }
    let file_sum = fnv1a(&buf);
    buf.extend_from_slice(&file_sum.to_le_bytes());
    write_file(path, &buf)
}

/// Write the legacy **v1** layout (no checksums). Kept so the v1
/// backward-compat path stays testable; new exports should use
/// [`write_dft`].
pub fn write_dft_v1(path: &Path, tensors: &TensorMap) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_V1);
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        encode_tensor(&mut buf, name, t);
    }
    write_file(path, &buf)
}

// ---------------------------------------------------------------- reading

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
        if self.pos + n > self.buf.len() {
            return Err(ArtifactError::Truncated {
                path: self.path.to_path_buf(),
                offset: self.pos,
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16, ArtifactError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, ArtifactError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ArtifactError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8, ArtifactError> {
        Ok(self.take(1)?[0])
    }
}

fn decode_vec<T: Element>(raw: &[u8]) -> Vec<T> {
    let n = raw.len() / std::mem::size_of::<T>();
    let mut out = vec![T::default(); n];
    unsafe {
        std::ptr::copy_nonoverlapping(
            raw.as_ptr(),
            out.as_mut_ptr().cast::<u8>(),
            n * std::mem::size_of::<T>(),
        );
    }
    out
}

/// Per-tensor row of a [`verify_dft`] integrity report.
#[derive(Debug, Clone)]
pub struct TensorReport {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub payload_bytes: usize,
    /// stored FNV-1a checksum (`None` on a v1 file, which carries none)
    pub checksum: Option<u64>,
}

/// Whole-file result of [`verify_dft`].
#[derive(Debug, Clone)]
pub struct DftReport {
    /// DFT format version (1 or 2)
    pub version: u8,
    pub tensors: Vec<TensorReport>,
    pub file_bytes: usize,
}

/// One decoded tensor record plus its integrity metadata.
struct Record {
    name: String,
    dtype: DType,
    shape: Vec<usize>,
    payload: std::ops::Range<usize>,
    checksum: Option<u64>,
}

fn corrupt(path: &Path, detail: String) -> ArtifactError {
    ArtifactError::Corrupt { path: path.to_path_buf(), detail }
}

/// Decode the container structure, verifying checksums on v2. Shared by
/// [`read_dft`] (which materializes tensors) and [`verify_dft`] (which
/// only reports). Returns the format version and the record table.
fn decode(path: &Path, raw: &[u8]) -> Result<(u8, Vec<Record>), ArtifactError> {
    let mut c = Cursor { buf: raw, pos: 0, path };
    let magic: [u8; 4] = c.take(4)?.try_into().unwrap();
    let version = match &magic {
        m if m == MAGIC_V1 => 1,
        m if m == MAGIC_V2 => 2,
        m if &m[..3] == b"DFT" => {
            return Err(ArtifactError::UnsupportedVersion {
                path: path.to_path_buf(),
                version: m[3].wrapping_sub(b'0'),
            })
        }
        _ => return Err(ArtifactError::BadMagic { path: path.to_path_buf(), found: magic }),
    };
    // v2: the trailer checksum covers everything before it — verify first,
    // so any single flipped bit (header, name, shape, or payload) surfaces
    // as a checksum error before we interpret the bytes at all.
    let body_end = if version == 2 {
        let n = raw.len();
        if n < 12 {
            return Err(ArtifactError::Truncated { path: path.to_path_buf(), offset: n });
        }
        let stored = u64::from_le_bytes(raw[n - 8..].try_into().unwrap());
        let computed = fnv1a(&raw[..n - 8]);
        if stored != computed {
            return Err(ArtifactError::ChecksumMismatch {
                path: path.to_path_buf(),
                tensor: None,
                stored,
                computed,
            });
        }
        n - 8
    } else {
        raw.len()
    };
    let count = c.u32()?;
    let mut records = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let start = c.pos;
        let nlen = c.u16()? as usize;
        let name = String::from_utf8(c.take(nlen)?.to_vec())
            .map_err(|_| corrupt(path, format!("non-utf8 tensor name at offset {start}")))?;
        let dtype = DType::from_tag(c.u8()?)
            .map_err(|e| corrupt(path, format!("tensor '{name}': {e}")))?;
        let ndim = c.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let blen = c.u64()? as usize;
        c.take(blen)?;
        let payload = c.pos - blen..c.pos;
        let expected = shape.iter().product::<usize>() * dtype.size_of();
        if blen != expected {
            return Err(ArtifactError::BadShape {
                path: path.to_path_buf(),
                tensor: name,
                detail: format!("payload {blen} bytes != shape {shape:?} * dtype {dtype:?}"),
            });
        }
        let checksum = if version == 2 {
            let computed = fnv1a(&raw[start..c.pos]);
            let stored = c.u64()?;
            if stored != computed {
                return Err(ArtifactError::ChecksumMismatch {
                    path: path.to_path_buf(),
                    tensor: Some(name),
                    stored,
                    computed,
                });
            }
            Some(stored)
        } else {
            None
        };
        records.push(Record { name, dtype, shape, payload, checksum });
    }
    if c.pos != body_end {
        return Err(corrupt(
            path,
            format!("{} trailing bytes after last tensor record", body_end - c.pos),
        ));
    }
    Ok((version, records))
}

fn read_raw(path: &Path) -> Result<Vec<u8>, ArtifactError> {
    let mut raw = Vec::new();
    std::fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut raw))
        .map_err(|source| ArtifactError::Io { path: path.to_path_buf(), source })?;
    Ok(raw)
}

/// Read a DFT file (v1 or v2) into a [`TensorMap`], verifying all v2
/// checksums. Any malformed input yields a typed [`ArtifactError`].
pub fn read_dft(path: &Path) -> Result<TensorMap, ArtifactError> {
    let raw = read_raw(path)?;
    let (_, records) = decode(path, &raw)?;
    let mut out = TensorMap::new();
    for r in records {
        let payload = &raw[r.payload];
        let mk = |detail: String| ArtifactError::BadShape {
            path: path.to_path_buf(),
            tensor: r.name.clone(),
            detail,
        };
        let t = match r.dtype {
            DType::F32 => Tensor::new(&r.shape, decode_vec(payload)).map(AnyTensor::F32),
            DType::I8 => Tensor::new(&r.shape, decode_vec(payload)).map(AnyTensor::I8),
            DType::I32 => Tensor::new(&r.shape, decode_vec(payload)).map(AnyTensor::I32),
            DType::U8 => Tensor::new(&r.shape, decode_vec(payload)).map(AnyTensor::U8),
            DType::I64 => Tensor::new(&r.shape, decode_vec(payload)).map(AnyTensor::I64),
        }
        .map_err(|e| mk(e.to_string()))?;
        if out.insert(r.name.clone(), t).is_some() {
            return Err(corrupt(path, format!("duplicate tensor name '{}'", r.name)));
        }
    }
    Ok(out)
}

/// Walk a DFT file's full decode-and-checksum path without materializing
/// tensors; returns a per-tensor integrity report. The `verify-artifact`
/// CLI builds its table from this.
pub fn verify_dft(path: &Path) -> Result<DftReport, ArtifactError> {
    let raw = read_raw(path)?;
    let (version, records) = decode(path, &raw)?;
    Ok(DftReport {
        version,
        file_bytes: raw.len(),
        tensors: records
            .into_iter()
            .map(|r| TensorReport {
                name: r.name,
                dtype: r.dtype,
                shape: r.shape,
                payload_bytes: r.payload.len(),
                checksum: r.checksum,
            })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("dfp_infer_test_{}_{}", std::process::id(), name));
        p
    }

    fn sample_map() -> TensorMap {
        let mut m = TensorMap::new();
        m.insert("a".into(), AnyTensor::F32(Tensor::new(&[2, 2], vec![1.0, -2.5, 3.25, 0.0]).unwrap()));
        m.insert("b".into(), AnyTensor::I8(Tensor::new(&[3], vec![-128i8, 0, 127]).unwrap()));
        m.insert("c".into(), AnyTensor::I32(Tensor::new(&[1], vec![-70000]).unwrap()));
        m.insert("d".into(), AnyTensor::U8(Tensor::new(&[2], vec![0u8, 255]).unwrap()));
        m.insert("e".into(), AnyTensor::I64(Tensor::new(&[1], vec![1i64 << 40]).unwrap()));
        m
    }

    #[test]
    fn test_fnv1a_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn test_roundtrip_all_dtypes() {
        let m = sample_map();
        let p = tmpfile("roundtrip.dft");
        write_dft(&p, &m).unwrap();
        let back = read_dft(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_v1_still_loads() {
        let m = sample_map();
        let p = tmpfile("v1.dft");
        write_dft_v1(&p, &m).unwrap();
        assert_eq!(&std::fs::read(&p).unwrap()[..4], MAGIC_V1);
        let back = read_dft(&p).unwrap();
        assert_eq!(m, back);
        let rep = verify_dft(&p).unwrap();
        assert_eq!(rep.version, 1);
        assert!(rep.tensors.iter().all(|t| t.checksum.is_none()));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_empty_map() {
        let p = tmpfile("empty.dft");
        write_dft(&p, &TensorMap::new()).unwrap();
        assert!(read_dft(&p).unwrap().is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_bad_magic_rejected() {
        let p = tmpfile("bad.dft");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(matches!(read_dft(&p), Err(ArtifactError::BadMagic { .. })));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_future_version_rejected() {
        let p = tmpfile("v9.dft");
        std::fs::write(&p, b"DFT9\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        match read_dft(&p) {
            Err(ArtifactError::UnsupportedVersion { version, .. }) => assert_eq!(version, 9),
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_truncated_rejected() {
        let mut m = TensorMap::new();
        m.insert("x".into(), AnyTensor::F32(Tensor::new(&[4], vec![1.0; 4]).unwrap()));
        let p = tmpfile("trunc.dft");
        write_dft(&p, &m).unwrap();
        let raw = std::fs::read(&p).unwrap();
        std::fs::write(&p, &raw[..raw.len() - 3]).unwrap();
        // dropping trailer bytes makes the file-level checksum unreadable
        let err = read_dft(&p).unwrap_err();
        assert!(
            matches!(err, ArtifactError::Truncated { .. } | ArtifactError::ChecksumMismatch { .. }),
            "{err}"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_bit_flip_names_tensor() {
        let m = sample_map();
        let p = tmpfile("flip.dft");
        write_dft(&p, &m).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        // flip one payload bit of tensor 'a' (first record after the 8-byte
        // header: 2 name + 1 name byte + 1 dtype + 1 ndim + 8 dims + 8 len)
        let payload_off = 8 + 2 + 1 + 1 + 1 + 8 + 8;
        raw[payload_off] ^= 0x40;
        // the whole-file trailer catches it first...
        std::fs::write(&p, &raw).unwrap();
        assert!(matches!(
            read_dft(&p),
            Err(ArtifactError::ChecksumMismatch { tensor: None, .. })
        ));
        // ...and with the trailer recomputed, the per-tensor sum names 'a'
        let n = raw.len();
        let fixed = fnv1a(&raw[..n - 8]);
        raw[n - 8..].copy_from_slice(&fixed.to_le_bytes());
        std::fs::write(&p, &raw).unwrap();
        match read_dft(&p) {
            Err(ArtifactError::ChecksumMismatch { tensor: Some(t), .. }) => assert_eq!(t, "a"),
            other => panic!("expected per-tensor ChecksumMismatch, got {other:?}"),
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_verify_report() {
        let m = sample_map();
        let p = tmpfile("verify.dft");
        write_dft(&p, &m).unwrap();
        let rep = verify_dft(&p).unwrap();
        assert_eq!(rep.version, 2);
        assert_eq!(rep.tensors.len(), m.len());
        assert!(rep.tensors.iter().all(|t| t.checksum.is_some()));
        assert_eq!(rep.tensors[0].name, "a");
        assert_eq!(rep.tensors[0].shape, vec![2, 2]);
        assert_eq!(rep.tensors[0].payload_bytes, 16);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_error_names_path() {
        let p = tmpfile("missing_nonexistent.dft");
        let err = read_dft(&p).unwrap_err();
        assert!(err.to_string().contains("missing_nonexistent"), "{err}");
        assert_eq!(err.path(), p);
    }

    #[test]
    fn test_accessors() {
        let t = AnyTensor::F32(Tensor::new(&[2], vec![1.0, 2.0]).unwrap());
        assert!(t.as_f32().is_ok());
        assert!(t.as_i8().is_err());
        assert!(t.as_i64().is_err());
        assert_eq!(t.shape(), &[2]);
        let t64 = AnyTensor::I64(Tensor::new(&[1], vec![5i64]).unwrap());
        assert!(t64.as_i64().is_ok());
    }
}
