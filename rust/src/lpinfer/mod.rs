//! Low-precision integer inference pipeline — the paper's "full 8-bit
//! compute pipeline" in pure Rust, with an **integer-only activation
//! path**: from the i32 GEMM accumulators, folded batch-norm + activation
//! rescale + ReLU clamp run as fixed-point integer arithmetic fused into
//! the kernel epilogue ([`crate::kernels::epilogue`]), and residuals are
//! carried on an integer skip lane — no f32 tensor is materialized between
//! conv layers (see DESIGN.md §requant).
//!
//! Every conv/FC GEMM dispatches through [`crate::kernels::KernelRegistry`],
//! so sub-8-bit layers run on the packed multiply-free engines while logits
//! stay bit-exact across kernels and thread counts (property-tested in
//! `rust/tests/kernels_equivalence.rs`).
//!
//! The serving entry point is [`forward_quant_into`]: an interpreter over
//! the [`ForwardPlan`]'s scheduled step list, built at model load by
//! lowering the layer DAG ([`crate::graph`]) and interval-coloring every
//! activation lifetime into one arena (see the [`plan`] module and
//! DESIGN.md §graph/§forward-plan) — pointwise (1×1/s1/p0) convs skip
//! im2col entirely, a batch of B images runs each conv as one GEMM over
//! B·H·W rows (bit-identical to B single-image forwards, see
//! `rust/tests/batch_equivalence.rs`), and the steady state performs zero
//! heap allocations per request at any registry thread count. Unplannable
//! layer tables fail at load with a typed [`GraphError`] naming the
//! offending layer.
//!
//! The original f32 epilogue survives as [`forward_quant_ref`] — the
//! op-for-op mirror of `python/compile/model.py::forward_quant(engine="sim")`
//! — and [`paths_divergence`] runs both pipelines in per-layer lockstep to
//! bound their divergence (≤ 1 output code per requantization point,
//! asserted in `rust/tests/requant_equivalence.rs`).

pub mod plan;

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::dfp::{fx_rescale, round_half_even, Requantizer, REQUANT_VERSION, SKIP_FRAC};
use crate::graph::GraphError;
use crate::io::{AnyTensor, TensorMap};
use crate::kernels::{KernelRegistry, LayerRequant, PackedLayer, ResolvedEpilogue};
use crate::model::{ConvLayer, Network};
use crate::nn::{im2col, im2col_into, maxpool2d, maxpool2d_into};
use crate::scheme::{LayerPolicy, Scheme, WeightCodec};
use crate::telemetry::{self, ForwardProfile};
use crate::tensor::Tensor;

use plan::{slot, slot_mut, split_src_dst, TensorRef};

pub use crate::kernels::{gemm_i8, gemm_i8_dense};
pub use plan::{ConvDims, ExecStep, ForwardPlan, ForwardWorkspace};

/// Quantized parameters for one conv layer.
#[derive(Debug, Clone)]
pub struct QConvParams {
    /// int8 codes, HWIO ({-1,0,1} for ternary layers).
    pub wq: Tensor<i8>,
    /// per-output-filter dequantization scale (α̂ or 2^exp).
    pub w_scale: Vec<f32>,
    pub bn_scale: Vec<f32>,
    pub bn_shift: Vec<f32>,
    /// DFP exponent of this layer's output activations.
    pub act_exp: i32,
    /// this layer's precision policy (codec + α̂/exp cluster size).
    pub policy: LayerPolicy,
    /// packed encodings of `wq` for the kernels/ dispatch (built once here,
    /// so the hot path never re-derives or unpacks weights).
    pub packed: PackedLayer,
    /// per-channel integer requantization (fixed-point multiplier + shift
    /// + bias) the fused epilogue consumes — derived from the f32 scales,
    /// or loaded from a versioned export (`rq_mult`/`rq_shift`/`rq_bias`).
    pub requant: LayerRequant,
}

impl QConvParams {
    /// Build layer params, deriving the integer requantization from the
    /// f32 scales and packing `wq` into every encoding it fits. Errors on
    /// non-finite scales (see [`LayerRequant::derive`]).
    pub fn new(
        wq: Tensor<i8>,
        w_scale: Vec<f32>,
        bn_scale: Vec<f32>,
        bn_shift: Vec<f32>,
        act_exp: i32,
        policy: LayerPolicy,
    ) -> Result<Self> {
        let requant = LayerRequant::derive(&w_scale, &bn_scale, &bn_shift)?;
        Self::with_requant(wq, w_scale, bn_scale, bn_shift, act_exp, policy, requant)
    }

    /// Build layer params from pre-computed integer requantization tensors
    /// (the versioned-export load path).
    pub fn with_requant(
        wq: Tensor<i8>,
        w_scale: Vec<f32>,
        bn_scale: Vec<f32>,
        bn_shift: Vec<f32>,
        act_exp: i32,
        policy: LayerPolicy,
        requant: LayerRequant,
    ) -> Result<Self> {
        ensure!(
            requant.len() == w_scale.len(),
            "requant has {} channels but the layer has {}",
            requant.len(),
            w_scale.len()
        );
        let packed = PackedLayer::build(&wq, &w_scale, policy.cluster);
        Ok(Self { wq, w_scale, bn_scale, bn_shift, act_exp, policy, packed, requant })
    }
}

/// Whole quantized model (mirrors the python `QModel` export). Precision is
/// carried by `scheme` — one [`LayerPolicy`] per layer instead of global
/// bits/cluster scalars, so mixed models (i8 stem, ternary interior,
/// i4 tail) are first-class.
#[derive(Debug, Clone)]
pub struct QModelParams {
    /// per-layer quantized params. Private because the [`EpilogueCache`] is
    /// *derived* from these: all mutation goes through
    /// [`QModelParams::set_conv`], which invalidates the cache, so in-place
    /// scale edits can never serve stale epilogues (read via
    /// [`QModelParams::convs()`]).
    convs: BTreeMap<String, QConvParams>,
    pub fc_wq: Tensor<i8>,
    pub fc_scale: Vec<f32>,
    pub fc_b: Vec<f32>,
    pub in_exp: i32,
    pub feat_exp: i32,
    /// the mixed-precision scheme these params realize (`convs[*].policy`
    /// and the FC policy are resolved from it).
    pub scheme: Scheme,
    /// packed encodings of `fc_wq` (same dispatch as the conv layers).
    pub fc_packed: PackedLayer,
    /// resolved requantization epilogues, built once at load
    /// ([`EpilogueCache`]): `exp_in`/`act_target` are fixed per loaded
    /// model, so `forward_quant` borrows these instead of calling
    /// `LayerRequant::resolve` per conv per forward. Empty for
    /// hand-assembled params — the forward pass then resolves on the fly,
    /// producing identical results. Private (read via
    /// [`QModelParams::epilogues`]) because it is *derived* state: only
    /// [`QModelParams::rebuild_epilogues`] may refresh it, so external code
    /// cannot install a cache that disagrees with the conv scales.
    epilogues: EpilogueCache,
    /// load-time forward plan (buffer geometry for [`ForwardWorkspace`]),
    /// rebuilt alongside the epilogue cache. Empty for hand-assembled
    /// params — the forward pass then derives one per call.
    plan: ForwardPlan,
}

/// Every [`ResolvedEpilogue`] the fused forward pass needs, keyed by layer:
/// the own-grid epilogue (ReLU fused) for each non-projection conv, and the
/// *consumer*-grid epilogue (no ReLU) for each projection conv feeding the
/// integer residual lane. Derived from the [`ForwardPlan`]'s scheduled step
/// list — the plan is the single source of truth for the residual-block
/// structure; nothing here re-walks the layer table.
///
/// The cache is derived state: after mutating `convs[*]` scales/requant in
/// place, call [`QModelParams::rebuild_epilogues`] (loaders do this for
/// you).
#[derive(Debug, Clone, Default)]
pub struct EpilogueCache {
    /// own-grid epilogues keyed by layer, each tagged with the `exp_in` it
    /// was resolved for (the layer's own `act_exp` is fixed by its params,
    /// so the input exponent pins the resolution completely)
    own: BTreeMap<String, (i32, ResolvedEpilogue)>,
    /// consumer-grid epilogues of projection convs, tagged with
    /// `(exp_in, act_target)`
    proj: BTreeMap<String, (i32, i32, ResolvedEpilogue)>,
}

impl EpilogueCache {
    /// Resolve every epilogue `plan`'s step list will ask for: each
    /// [`ExecStep::Conv`] / [`ExecStep::ConvSkip`] layer gets its own-grid
    /// epilogue keyed by the exponent of the activation it reads, and each
    /// [`ExecStep::ConvToSkip`] projection gets its consumer-grid epilogue.
    /// Returns an empty cache (forward falls back to on-the-fly resolution)
    /// when a layer the plan schedules is missing from `convs`.
    pub fn from_plan(
        convs: &BTreeMap<String, QConvParams>,
        in_exp: i32,
        net: &Network,
        plan: &ForwardPlan,
    ) -> Self {
        let mut cache = Self::default();
        // the exponent governing a planned tensor's codes: the producing
        // layer's act_exp, or the network input exponent
        let exp_of = |t: &TensorRef| -> Option<i32> {
            match t.exp_from {
                None => Some(in_exp),
                Some(li) => convs.get(&net.layers[li].name).map(|p| p.act_exp),
            }
        };
        for s in &plan.steps {
            match s {
                ExecStep::Conv { layer, src, .. } | ExecStep::ConvSkip { layer, src, .. } => {
                    let name = &net.layers[*layer].name;
                    let (Some(p), Some(e)) = (convs.get(name), exp_of(src)) else {
                        return Self::default();
                    };
                    cache.own.insert(name.clone(), (e, p.requant.resolve(e, p.act_exp, true)));
                }
                ExecStep::ConvToSkip { layer, src, target } => {
                    let name = &net.layers[*layer].name;
                    let tgt = convs.get(&net.layers[*target].name).map(|p| p.act_exp);
                    let (Some(p), Some(e), Some(te)) = (convs.get(name), exp_of(src), tgt)
                    else {
                        return Self::default();
                    };
                    cache.proj.insert(name.clone(), (e, te, p.requant.resolve(e, te, false)));
                }
                ExecStep::IdentitySkip { .. } | ExecStep::Pool { .. } => {}
            }
        }
        cache
    }

    /// The cached own-grid epilogue of a non-projection conv, provided it
    /// was resolved for this `exp_in`. The cache records the exponent chain
    /// it was built against, so running a model against a network whose
    /// residual-block walk implies different exponents simply *misses* and
    /// falls back to on-the-fly resolution — a stale entry can never serve.
    pub fn own(&self, layer: &str, exp_in: i32) -> Option<&ResolvedEpilogue> {
        self.own.get(layer).and_then(|(e, epi)| (*e == exp_in).then_some(epi))
    }

    /// The cached consumer-grid epilogue of a projection conv, provided it
    /// was resolved for this `(exp_in, act_target)` pair (see
    /// [`EpilogueCache::own`] for why the exponents are validated).
    pub fn proj(&self, layer: &str, exp_in: i32, act_target: i32) -> Option<&ResolvedEpilogue> {
        self.proj
            .get(layer)
            .and_then(|(ei, at, epi)| (*ei == exp_in && *at == act_target).then_some(epi))
    }

    /// Number of cached epilogues.
    pub fn len(&self) -> usize {
        self.own.len() + self.proj.len()
    }

    /// True when nothing is cached (forward resolves on the fly).
    pub fn is_empty(&self) -> bool {
        self.own.is_empty() && self.proj.is_empty()
    }
}

impl QModelParams {
    /// Load from a `qweights_<tag>.dft` produced by `python -m compile.aot`
    /// or [`QModelParams::to_tensors`].
    ///
    /// Requant versioning: exports carrying `meta.requant_version == 1`
    /// provide per-layer `rq_mult`/`rq_shift`/`rq_bias` integer tensors and
    /// load them verbatim; older exports (no version tag) fall back to
    /// deriving the integer multipliers from the f32 scales, bit-identically
    /// to what the exporter would have written. A *newer* version is
    /// rejected instead of misread.
    pub fn from_tensors(map: &TensorMap, net: &Network) -> Result<Self> {
        let f32v = |name: &str| -> Result<Vec<f32>> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_f32()?
                .data()
                .to_vec())
        };
        let i32s = |name: &str| -> Result<i32> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_i32()?
                .data()[0])
        };
        let requant_version = match map.get("meta.requant_version") {
            Some(t) => t.as_i32()?.data()[0],
            None => 0,
        };
        ensure!(
            requant_version <= REQUANT_VERSION,
            "export has requant_version {requant_version}, newer than the supported {REQUANT_VERSION} — \
             upgrade this binary or re-export the artifact"
        );
        let cluster = i32s("meta.cluster")? as usize;
        let model_bits = i32s("meta.w_bits")? as u32;
        let default_policy = LayerPolicy::new(WeightCodec::from_w_bits(model_bits)?, cluster)?;
        // reconstruct the scheme the export realizes: the model-wide policy
        // plus a named override for every layer whose recorded w_bits differ
        let mut scheme = Scheme::uniform(8, default_policy.clone())?;
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let n = &l.name;
            let layer_bits = i32s(&format!("{n}.w_bits"))? as u32;
            let policy = if layer_bits == model_bits {
                default_policy.clone()
            } else {
                let p = LayerPolicy::new(WeightCodec::from_w_bits(layer_bits)?, cluster)?;
                scheme = scheme.with_override(n, p.clone())?;
                p
            };
            let wq = map
                .get(&format!("{n}.wq"))
                .with_context(|| format!("missing {n}.wq"))?
                .as_i8()?
                .clone();
            let w_scale = f32v(&format!("{n}.w_scale"))?;
            let bn_scale = f32v(&format!("{n}.bn_scale"))?;
            let bn_shift = f32v(&format!("{n}.bn_shift"))?;
            let act_exp = i32s(&format!("{n}.act_exp"))?;
            let params = if requant_version >= 1 {
                let requant = LayerRequant::from_parts(
                    rq_tensor(map, n, "rq_mult")?.as_i32()?.data().to_vec(),
                    rq_tensor(map, n, "rq_shift")?.as_i32()?.data().to_vec(),
                    rq_tensor(map, n, "rq_bias")?.as_i64()?.data().to_vec(),
                )
                .with_context(|| format!("layer {n}"))?;
                QConvParams::with_requant(wq, w_scale, bn_scale, bn_shift, act_exp, policy, requant)
            } else {
                // f32 fallback: derive the integer multipliers at load time
                QConvParams::new(wq, w_scale, bn_scale, bn_shift, act_exp, policy)
            };
            convs.insert(n.clone(), params.with_context(|| format!("layer {n}"))?);
        }
        // exports may record a distinct FC precision (QuantConfig.fc_bits);
        // without the optional fc.w_bits entry the FC follows the default
        if let Some(t) = map.get("fc.w_bits") {
            let fc_bits = t.as_i32()?.data()[0] as u32;
            if fc_bits != model_bits {
                let p = LayerPolicy::new(WeightCodec::from_w_bits(fc_bits)?, cluster)?;
                scheme = scheme.with_override("fc", p)?;
            }
        }
        let fc_wq = map.get("fc.wq").context("missing fc.wq")?.as_i8()?.clone();
        let fc_scale = f32v("fc.scale")?;
        let fc_packed = PackedLayer::build(&fc_wq, &fc_scale, scheme.policy_for("fc").cluster);
        let mut out = Self {
            convs,
            fc_wq,
            fc_scale,
            fc_b: f32v("fc.b")?,
            in_exp: i32s("meta.in_exp")?,
            feat_exp: i32s("meta.feat_exp")?,
            scheme,
            fc_packed,
            epilogues: EpilogueCache::default(),
            plan: ForwardPlan::default(),
        };
        // loaded codes must actually fit the scheme the export declares
        out.validate(net)?;
        out.rebuild_epilogues(net).with_context(|| {
            format!("cannot build a forward plan for network '{}'", net.name)
        })?;
        Ok(out)
    }

    /// Serialize to the `qweights_*.dft` tensor layout, including the
    /// integer requantization tensors (`rq_mult`/`rq_shift`/`rq_bias` per
    /// layer) tagged `meta.requant_version = 1` — so serving never has to
    /// re-derive multipliers from f32, and [`QModelParams::from_tensors`]
    /// round-trips the model exactly.
    pub fn to_tensors(&self) -> TensorMap {
        let f32t = |v: &[f32]| AnyTensor::F32(Tensor::new(&[v.len()], v.to_vec()).expect("1-d"));
        let i32t = |v: Vec<i32>| {
            let n = v.len();
            AnyTensor::I32(Tensor::new(&[n], v).expect("1-d"))
        };
        let i64t = |v: Vec<i64>| {
            let n = v.len();
            AnyTensor::I64(Tensor::new(&[n], v).expect("1-d"))
        };
        let scalar = |x: i32| i32t(vec![x]);
        let mut map = TensorMap::new();
        for (n, p) in &self.convs {
            map.insert(format!("{n}.wq"), AnyTensor::I8(p.wq.clone()));
            map.insert(format!("{n}.w_scale"), f32t(&p.w_scale));
            map.insert(format!("{n}.bn_scale"), f32t(&p.bn_scale));
            map.insert(format!("{n}.bn_shift"), f32t(&p.bn_shift));
            map.insert(format!("{n}.act_exp"), scalar(p.act_exp));
            map.insert(format!("{n}.w_bits"), scalar(p.policy.w_bits() as i32));
            map.insert(format!("{n}.rq_mult"), i32t(p.requant.mult.clone()));
            map.insert(format!("{n}.rq_shift"), i32t(p.requant.shift.clone()));
            map.insert(format!("{n}.rq_bias"), i64t(p.requant.bias_fx.clone()));
        }
        map.insert("fc.wq".into(), AnyTensor::I8(self.fc_wq.clone()));
        map.insert("fc.scale".into(), f32t(&self.fc_scale));
        map.insert("fc.b".into(), f32t(&self.fc_b));
        map.insert(
            "fc.w_bits".into(),
            scalar(self.scheme.policy_for("fc").w_bits() as i32),
        );
        map.insert("meta.in_exp".into(), scalar(self.in_exp));
        map.insert("meta.feat_exp".into(), scalar(self.feat_exp));
        map.insert(
            "meta.cluster".into(),
            scalar(self.scheme.default_policy().cluster as i32),
        );
        map.insert(
            "meta.w_bits".into(),
            scalar(self.scheme.default_policy().w_bits() as i32),
        );
        map.insert("meta.requant_version".into(), scalar(REQUANT_VERSION));
        map
    }

    /// Deterministic synthetic model (random codes, benign scales) for
    /// tests, benches and the artifact-free serving demo. Every layer's
    /// code range follows its `scheme` policy (ternary -> {-1,0,1},
    /// i4 -> [-7,7], i8 -> [-127,127]), so mixed schemes produce genuinely
    /// mixed models.
    pub fn synthetic(net: &Network, seed: u64, scheme: &Scheme) -> Self {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(seed);
        let mut code = move |n: usize, qmax: i64| -> Vec<i8> {
            (0..n).map(|_| (rng.next_below((2 * qmax + 1) as u64) as i64 - qmax) as i8).collect()
        };
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let policy = scheme.policy_for(&l.name).clone();
            let qmax = crate::dfp::qmax(policy.w_bits()).min(127) as i64;
            convs.insert(
                l.name.clone(),
                QConvParams::new(
                    Tensor::new(&[l.kh, l.kw, l.cin, l.cout], code(l.kh * l.kw * l.cin * l.cout, qmax))
                        .expect("conv shape"),
                    vec![0.1 / qmax as f32; l.cout],
                    vec![1.0; l.cout],
                    vec![0.0; l.cout],
                    -4,
                    policy,
                )
                .expect("benign synthetic scales"),
            );
        }
        let fc_policy = scheme.policy_for("fc").clone();
        let fc_qmax = crate::dfp::qmax(fc_policy.w_bits()).min(127) as i64;
        let fc_wq = Tensor::new(&[net.fc_in, net.fc_out], code(net.fc_in * net.fc_out, fc_qmax))
            .expect("fc shape");
        let fc_scale = vec![0.1 / fc_qmax as f32; net.fc_out];
        let fc_packed = PackedLayer::build(&fc_wq, &fc_scale, fc_policy.cluster);
        let mut params = Self {
            convs,
            fc_wq,
            fc_scale,
            fc_b: vec![0.0; net.fc_out],
            in_exp: -5,
            feat_exp: -5,
            scheme: scheme.clone(),
            fc_packed,
            epilogues: EpilogueCache::default(),
            plan: ForwardPlan::default(),
        };
        params
            .rebuild_epilogues(net)
            .expect("synthetic model requires a plannable network");
        params
    }

    /// Rebuild the load-time caches — the [`ForwardPlan`] and the
    /// resolved-epilogue cache derived from its step list — from the
    /// current conv params and network. Loaders call this; it is also how
    /// [`QModelParams::set_conv`] edits regain their cached epilogues
    /// (until then the forward pass resolves on the fly, with identical
    /// results). Unplannable layer tables fail with a typed [`GraphError`]
    /// naming the first unsupported layer — loaders surface it instead of
    /// silently serving an empty plan.
    pub fn rebuild_epilogues(&mut self, net: &Network) -> std::result::Result<(), GraphError> {
        let plan = ForwardPlan::build(net)?;
        self.epilogues = EpilogueCache::from_plan(&self.convs, self.in_exp, net, &plan);
        self.plan = plan;
        Ok(())
    }

    /// The load-time resolved-epilogue cache (read-only; see
    /// [`QModelParams::rebuild_epilogues`]).
    pub fn epilogues(&self) -> &EpilogueCache {
        &self.epilogues
    }

    /// The load-time forward plan (read-only; rebuilt by
    /// [`QModelParams::rebuild_epilogues`]).
    pub fn forward_plan(&self) -> &ForwardPlan {
        &self.plan
    }

    /// Per-layer quantized params, read-only (mutation goes through
    /// [`QModelParams::set_conv`]).
    pub fn convs(&self) -> &BTreeMap<String, QConvParams> {
        &self.convs
    }

    /// One layer's params, if present.
    pub fn conv(&self, name: &str) -> Option<&QConvParams> {
        self.convs.get(name)
    }

    /// Insert or replace one layer's params, **invalidating** the resolved-
    /// epilogue cache: the cache is derived from the conv scales, so any
    /// edit clears it and the forward pass resolves epilogues on the fly
    /// (bit-identical results) until [`QModelParams::rebuild_epilogues`]
    /// restores the cached fast path. This is the only mutation path to
    /// `convs`, which makes serving a stale epilogue unrepresentable.
    pub fn set_conv(&mut self, name: impl Into<String>, p: QConvParams) {
        self.convs.insert(name.into(), p);
        self.epilogues = EpilogueCache::default();
    }

    /// Deep-check the params against the network description *and* the
    /// declared scheme: layer shapes must match the net, every layer's
    /// codes must fit the range its [`LayerPolicy`] codec promises (a full
    /// sweep — the packed encodings in [`PackedLayer`] are built from these
    /// same validated dense codes), every f32 scale must be finite, and the
    /// DFP exponents must sit inside the envelope the integer requantizer
    /// supports. A corrupt artifact must fail here, never serve.
    pub fn validate(&self, net: &Network) -> Result<()> {
        let check_codes = |name: &str, codes: &[i8], policy: &LayerPolicy| -> Result<()> {
            let qmax = crate::dfp::qmax(policy.w_bits());
            if let Some(&c) = codes.iter().find(|&&c| i32::from(c).abs() > qmax) {
                bail!(
                    "{name}: code {c} exceeds |code| <= {qmax} declared by codec '{}' of scheme '{}'",
                    policy.codec,
                    self.scheme
                );
            }
            Ok(())
        };
        let check_finite = |name: &str, what: &str, v: &[f32]| -> Result<()> {
            if let Some((c, &x)) = v.iter().enumerate().find(|(_, x)| !x.is_finite()) {
                bail!("{name}: non-finite {what} {x} at channel {c}");
            }
            Ok(())
        };
        // the integer requantizer's shift arithmetic is bounded by the ±512
        // exponent envelope (see LayerRequant::from_parts); an exponent
        // outside it can only come from a corrupt export
        let check_exp = |name: &str, what: &str, e: i32| -> Result<()> {
            ensure!((-512..=512).contains(&e), "{name}: {what} {e} outside [-512, 512]");
            Ok(())
        };
        check_exp("meta", "in_exp", self.in_exp)?;
        check_exp("meta", "feat_exp", self.feat_exp)?;
        for l in &net.layers {
            let p = self.convs.get(&l.name).with_context(|| format!("no params for {}", l.name))?;
            let want = [l.kh, l.kw, l.cin, l.cout];
            if p.wq.shape() != want {
                bail!("{}: weight shape {:?} != {:?}", l.name, p.wq.shape(), want);
            }
            if p.w_scale.len() != l.cout || p.bn_scale.len() != l.cout || p.bn_shift.len() != l.cout
            {
                bail!("{}: scale length mismatch", l.name);
            }
            if p.requant.len() != l.cout {
                bail!("{}: requant channel count {} != {}", l.name, p.requant.len(), l.cout);
            }
            check_codes(&l.name, p.wq.data(), &p.policy)?;
            check_finite(&l.name, "w_scale", &p.w_scale)?;
            check_finite(&l.name, "bn_scale", &p.bn_scale)?;
            check_finite(&l.name, "bn_shift", &p.bn_shift)?;
            check_exp(&l.name, "act_exp", p.act_exp)?;
        }
        if self.fc_wq.dim(0) != net.fc_in || self.fc_wq.dim(1) != net.fc_out {
            bail!("fc shape mismatch");
        }
        if self.fc_scale.len() != net.fc_out || self.fc_b.len() != net.fc_out {
            bail!("fc: scale/bias length mismatch");
        }
        check_codes("fc", self.fc_wq.data(), self.scheme.policy_for("fc"))?;
        check_finite("fc", "scale", &self.fc_scale)?;
        check_finite("fc", "bias", &self.fc_b)?;
        Ok(())
    }
}

/// Look up one of a layer's versioned integer-requant tensors, with a
/// load-error message naming the missing entry.
fn rq_tensor<'m>(map: &'m TensorMap, layer: &str, suffix: &str) -> Result<&'m AnyTensor> {
    map.get(&format!("{layer}.{suffix}"))
        .with_context(|| format!("versioned requant export is missing {layer}.{suffix}"))
}

/// f32 -> int8 DFP requantization (round-half-even, symmetric clip). Used
/// at the pipeline *entry* (quantizing the input image) and by the f32
/// reference path; the layer-to-layer hot path requantizes in integers
/// (see [`crate::kernels::epilogue`]).
pub fn requant(x: &[f32], exp: i32) -> Vec<i8> {
    let mut out = vec![0i8; x.len()];
    requant_into(x, exp, &mut out);
    out
}

/// Borrowed-output [`requant`] (the workspace entry path).
pub fn requant_into(x: &[f32], exp: i32, out: &mut [i8]) {
    assert_eq!(x.len(), out.len(), "requant: {} values into {} slots", x.len(), out.len());
    let scale = 2f64.powi(-exp);
    for (o, &v) in out.iter_mut().zip(x) {
        *o = round_half_even(f64::from(v) * scale).clamp(-127.0, 127.0) as i8;
    }
}

// ---------------------------------------------------------------------------
// fused integer path (the serving hot path)
// ---------------------------------------------------------------------------

/// One conv through the fused integer pipeline: im2col, registry GEMM with
/// the requant epilogue fused in, straight to i8 codes on the epilogue's
/// target grid. `epi` is the resolved epilogue (borrowed from the model's
/// [`EpilogueCache`] on the hot path); `skip` is the integer residual lane
/// (already on this layer's target grid at [`SKIP_FRAC`] fraction bits).
fn qconv_fused(
    x: &Tensor<i8>,
    l: &ConvLayer,
    p: &QConvParams,
    epi: &ResolvedEpilogue,
    skip: Option<&Tensor<i64>>,
    reg: &KernelRegistry,
) -> Tensor<i8> {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    // the HWIO buffer *is* the flat (kh*kw*cin, cout) GEMM operand — the
    // registry reads it borrowed, no clone/reshape
    let out = reg.gemm_fused(&cols, &p.packed, &p.wq, epi, skip.map(Tensor::data));
    out.reshape(&[n, ho, wo, l.cout]).expect("conv output shape")
}

/// A projection conv evaluated straight onto the integer residual lane of
/// the layer that will consume it (`epi` targets the *consuming* layer's
/// activation grid, no ReLU). Replaces the f32 `z` tensor the reference
/// path keeps for residuals.
fn qconv_to_skip(
    x: &Tensor<i8>,
    l: &ConvLayer,
    p: &QConvParams,
    epi: &ResolvedEpilogue,
    reg: &KernelRegistry,
) -> Tensor<i64> {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    let out = reg.gemm_fused_skip(&cols, &p.packed, &p.wq, epi);
    out.reshape(&[n, ho, wo, l.cout]).expect("conv output shape")
}

/// Borrow a layer's cached own-grid epilogue, or resolve it on the fly —
/// for hand-assembled params (empty cache) or when the cached entry was
/// built for a different input exponent (mismatched network). Identical
/// result either way.
fn own_epi<'a>(
    params: &'a QModelParams,
    name: &str,
    p: &QConvParams,
    exp_in: i32,
) -> Cow<'a, ResolvedEpilogue> {
    match params.epilogues.own(name, exp_in) {
        Some(e) => Cow::Borrowed(e),
        None => Cow::Owned(p.requant.resolve(exp_in, p.act_exp, true)),
    }
}

/// Borrow a projection conv's cached consumer-grid epilogue, or resolve it
/// on the fly (see [`own_epi`]).
fn proj_epi<'a>(
    params: &'a QModelParams,
    name: &str,
    p: &QConvParams,
    exp_in: i32,
    act_target: i32,
) -> Cow<'a, ResolvedEpilogue> {
    match params.epilogues.proj(name, exp_in, act_target) {
        Some(e) => Cow::Borrowed(e),
        None => Cow::Owned(p.requant.resolve(exp_in, act_target, false)),
    }
}

/// Identity-skip path: re-align i8 activations at `exp_h` onto the integer
/// residual lane of a layer whose grid is `act_target` — a pure shift
/// (exact whenever `SKIP_FRAC + exp_h - act_target >= 0`, which holds for
/// every realistic exponent pair).
fn dequant_to_skip(hq: &Tensor<i8>, exp_h: i32, act_target: i32) -> Tensor<i64> {
    let s = SKIP_FRAC + exp_h - act_target;
    hq.map(|v| fx_rescale(i64::from(v), -s))
}

/// Borrowed-output [`dequant_to_skip`] that also records the per-row max
/// `|skip|` while the values are in registers — the consuming epilogue's
/// vector gate reads `rows` maxima instead of re-scanning the lane. `f` is
/// the consuming layer's channel count (one lane row per output pixel).
fn dequant_to_skip_into(hq: &[i8], exp_h: i32, act_target: i32, f: usize, out: &mut [i64], row_max: &mut [i64]) {
    assert_eq!(hq.len(), out.len(), "identity skip: {} codes into {} lane slots", hq.len(), out.len());
    assert_eq!(out.len(), row_max.len() * f, "identity skip: lane is not {} rows x {f}", row_max.len());
    let s = SKIP_FRAC + exp_h - act_target;
    for (r, mx) in row_max.iter_mut().enumerate() {
        let mut m = 0i64;
        for c in 0..f {
            let v = fx_rescale(i64::from(hq[r * f + c]), -s);
            out[r * f + c] = v;
            m = m.max(v.saturating_abs());
        }
        *mx = m;
    }
}

/// Prepare one conv's GEMM operand: the NHWC `input` buffer itself for a
/// pointwise layer (its im2col is the identity), otherwise im2col into the
/// `cols` arena (parallel over patch-row blocks on the registry's pool).
#[allow(clippy::too_many_arguments)]
fn conv_operand<'a>(
    reg: &KernelRegistry,
    l: &ConvLayer,
    d: &ConvDims,
    n: usize,
    h: usize,
    w: usize,
    input: &'a [i8],
    cols: &'a mut [i8],
) -> &'a [i8] {
    let m = n * d.m;
    if d.direct {
        debug_assert_eq!(input.len(), m * d.k, "pointwise conv operand shape");
        input
    } else {
        let (ho, wo) = im2col_into(
            input,
            n,
            h,
            w,
            l.cin,
            l.kh,
            l.kw,
            l.stride,
            l.pad,
            &mut cols[..m * d.k],
            reg.pool(),
        );
        debug_assert_eq!((ho, wo), (d.ho, d.wo), "planned vs actual conv output grid");
        &cols[..m * d.k]
    }
}

/// One conv through the workspace path: [`conv_operand`], then the fused
/// borrowed-output GEMM with the `acc` arena as accumulator scratch.
/// Fills the profile row `li` (this conv's network layer index): the
/// im2col/GEMM time split by plain stores, and the zero-skip row tallies
/// attributed from global counter deltas (exact single-threaded).
#[allow(clippy::too_many_arguments)]
fn run_conv(
    reg: &KernelRegistry,
    l: &ConvLayer,
    d: &ConvDims,
    p: &QConvParams,
    epi: &ResolvedEpilogue,
    n: usize,
    h: usize,
    w: usize,
    input: &[i8],
    cols: &mut [i8],
    acc: &mut [i32],
    skip: Option<&[i64]>,
    skip_max: Option<&[i64]>,
    out: &mut [i8],
    prof: &mut ForwardProfile,
    li: usize,
) {
    let (rp0, rs0) = telemetry::rows_now();
    let t0 = Instant::now();
    let m = n * d.m;
    let a = conv_operand(reg, l, d, n, h, w, input, cols);
    let col_ns = t0.elapsed().as_nanos() as u64;
    reg.gemm_fused_into(a, m, d.k, d.f, &p.packed, p.wq.data(), epi, skip, skip_max, out, acc);
    prof.im2col_ns[li] = col_ns;
    prof.gemm_ns[li] = (t0.elapsed().as_nanos() as u64).saturating_sub(col_ns);
    let (rp1, rs1) = telemetry::rows_now();
    prof.rows_probed[li] = rp1.wrapping_sub(rp0);
    prof.rows_skipped[li] = rs1.wrapping_sub(rs0);
}

/// [`run_conv`] onto the i64 residual lane (projection convs), carrying the
/// per-row max `|skip|` for the consuming layer's vector gate.
#[allow(clippy::too_many_arguments)]
fn run_conv_skip(
    reg: &KernelRegistry,
    l: &ConvLayer,
    d: &ConvDims,
    p: &QConvParams,
    epi: &ResolvedEpilogue,
    n: usize,
    h: usize,
    w: usize,
    input: &[i8],
    cols: &mut [i8],
    acc: &mut [i32],
    out: &mut [i64],
    row_max: &mut [i64],
    prof: &mut ForwardProfile,
    li: usize,
) {
    let (rp0, rs0) = telemetry::rows_now();
    let t0 = Instant::now();
    let m = n * d.m;
    let a = conv_operand(reg, l, d, n, h, w, input, cols);
    let col_ns = t0.elapsed().as_nanos() as u64;
    reg.gemm_fused_skip_into(a, m, d.k, d.f, &p.packed, p.wq.data(), epi, out, Some(row_max), acc);
    prof.im2col_ns[li] = col_ns;
    prof.gemm_ns[li] = (t0.elapsed().as_nanos() as u64).saturating_sub(col_ns);
    let (rp1, rs1) = telemetry::rows_now();
    prof.rows_probed[li] = rp1.wrapping_sub(rp0);
    prof.rows_skipped[li] = rs1.wrapping_sub(rs0);
}

/// Forward a f32 image batch through the integer pipeline with the default
/// (auto, single-thread) kernel registry. Returns logits.
pub fn forward_quant(params: &QModelParams, net: &Network, x: &Tensor<f32>) -> Tensor<f32> {
    forward_quant_with(params, net, x, &KernelRegistry::auto())
}

/// Forward pass with an explicit kernel registry (kernel choice + threads),
/// integer-only between layers: i8 activations, i32 accumulators, fused
/// integer requant epilogues, i64 residual lane. The only f32 tensors are
/// the input image and the output logits. Logits are bit-identical for
/// every registry configuration.
///
/// Allocating wrapper over [`forward_quant_into`] with a throwaway
/// [`ForwardWorkspace`]; serving paths keep a workspace per worker and call
/// [`forward_quant_into`] directly for the zero-allocation steady state.
pub fn forward_quant_with(
    params: &QModelParams,
    net: &Network,
    x: &Tensor<f32>,
    reg: &KernelRegistry,
) -> Tensor<f32> {
    let mut ws = ForwardWorkspace::new();
    let mut logits = Tensor::<f32>::zeros(&[x.dim(0), params.fc_b.len()]);
    forward_quant_into(params, net, x, reg, &mut ws, logits.data_mut());
    logits
}

/// The steady-state forward pass: run the whole integer pipeline through a
/// reusable [`ForwardWorkspace`], writing logits into the caller's buffer
/// (`n × classes`, row-major).
///
/// After the first call has sized the workspace for a batch shape, repeat
/// calls with the same (or smaller) batch perform **zero heap allocations**
/// when the model carries its load-built caches ([`EpilogueCache`] +
/// [`ForwardPlan`]) — at any registry thread count, since threaded GEMMs
/// dispatch onto the persistent [`crate::kernels::WorkerPool`] instead of
/// spawning scoped threads (asserted for single-threaded, threaded, and
/// threaded-batched registries by `rust/tests/alloc_steady_state.rs`).
/// A batch of `n` images runs each conv as **one GEMM over `n·H·W` rows**,
/// amortizing the packed-weight decode across the batch; batched logits
/// are bit-identical to `n` independent single-image forwards
/// (property-tested in `rust/tests/batch_equivalence.rs`) and to
/// [`forward_quant_with`] for every registry configuration and workspace
/// history.
pub fn forward_quant_into(
    params: &QModelParams,
    net: &Network,
    x: &Tensor<f32>,
    reg: &KernelRegistry,
    ws: &mut ForwardWorkspace,
    logits: &mut [f32],
) {
    let t_total = Instant::now();
    let (n, h, w) = (x.dim(0), x.dim(1), x.dim(2));
    let ncls = params.fc_b.len();
    assert_eq!(logits.len(), n * ncls, "logits buffer is not {n}x{ncls}");
    // borrow the load-time plan; hand-built params or off-nominal input
    // geometry derive one locally (allocates — the steady state never does)
    let local_plan;
    let plan: &ForwardPlan = if params.plan.matches(net, h, w) {
        &params.plan
    } else {
        local_plan = ForwardPlan::build_for(net, h, w).unwrap_or_else(|e| {
            panic!("forward_quant: cannot plan network '{}': {e}", net.name)
        });
        &local_plan
    };
    assert_eq!(x.dim(3), plan.in_c, "input channels != stem cin");
    ws.ensure(plan, n);
    let ForwardWorkspace { act, cols, acc, skip, skip_max, sums, fq, fc_acc, profile } = ws;
    // the exponent governing a planned tensor's codes (BTreeMap lookup:
    // allocation-free)
    let exp_of = |t: &TensorRef| -> i32 {
        match t.exp_from {
            None => params.in_exp,
            Some(li) => params.convs[&net.layers[li].name].act_exp,
        }
    };

    // quantize input image to int8 DFP (pipeline entry: f32 is allowed
    // here) into the input's planned arena slot
    let t = Instant::now();
    requant_into(x.data(), params.in_exp, slot_mut(act, n, &plan.input));
    profile.quantize_ns = t.elapsed().as_nanos() as u64;

    // interpret the scheduled step list over the planned arena offsets
    for step in &plan.steps {
        match step {
            ExecStep::Conv { layer, src, dst } => {
                let l = &net.layers[*layer];
                let p = &params.convs[&l.name];
                let e = own_epi(params, &l.name, p, exp_of(src));
                let (xin, out) = split_src_dst(act, n, src, dst);
                run_conv(
                    reg, l, &plan.dims[*layer], p, &e, n, src.h, src.w, xin, cols, acc, None,
                    None, out, profile, *layer,
                );
            }
            ExecStep::ConvSkip { layer, src, dst } => {
                // the residual join, fused: the prepared i64 lane rides the
                // epilogue with its per-row maxima for the vector gate
                let l = &net.layers[*layer];
                let d = &plan.dims[*layer];
                let p = &params.convs[&l.name];
                let e = own_epi(params, &l.name, p, exp_of(src));
                let m = n * d.m;
                let (xin, out) = split_src_dst(act, n, src, dst);
                run_conv(
                    reg,
                    l,
                    d,
                    p,
                    &e,
                    n,
                    src.h,
                    src.w,
                    xin,
                    cols,
                    acc,
                    Some(&skip[..m * d.f]),
                    Some(&skip_max[..m]),
                    out,
                    profile,
                    *layer,
                );
            }
            ExecStep::ConvToSkip { layer, src, target } => {
                // projection conv straight onto the i64 lane, requantized
                // to the consuming layer's activation grid
                let l = &net.layers[*layer];
                let d = &plan.dims[*layer];
                let p = &params.convs[&l.name];
                let tgt_exp = params.convs[&net.layers[*target].name].act_exp;
                let e = proj_epi(params, &l.name, p, exp_of(src), tgt_exp);
                let m = n * d.m;
                run_conv_skip(
                    reg,
                    l,
                    d,
                    p,
                    &e,
                    n,
                    src.h,
                    src.w,
                    slot(act, n, src),
                    cols,
                    acc,
                    &mut skip[..m * d.f],
                    &mut skip_max[..m],
                    profile,
                    *layer,
                );
            }
            ExecStep::IdentitySkip { src, target } => {
                let t = Instant::now();
                let tgt_exp = params.convs[&net.layers[*target].name].act_exp;
                let rows = n * src.h * src.w;
                dequant_to_skip_into(
                    slot(act, n, src),
                    exp_of(src),
                    tgt_exp,
                    src.c,
                    &mut skip[..rows * src.c],
                    &mut skip_max[..rows],
                );
                profile.skip_ns += t.elapsed().as_nanos() as u64;
            }
            ExecStep::Pool { k, stride, pad, src, dst } => {
                // exact on i8 codes: max commutes with the monotone requant
                let t = Instant::now();
                let (xin, out) = split_src_dst(act, n, src, dst);
                maxpool2d_into(xin, n, src.h, src.w, src.c, *k, *stride, *pad, out);
                profile.maxpool_ns += t.elapsed().as_nanos() as u64;
            }
        }
    }

    // integer global average pool: i64 code sums requantized to feat_exp
    // through a scalar fixed-point multiplier (no f32 feature tensor)
    let t = Instant::now();
    let fin = &plan.final_act;
    let exp_h = exp_of(fin);
    let (cur_h, cur_w, c) = (fin.h, fin.w, fin.c);
    assert_eq!(c, params.fc_wq.dim(0), "final activation channels != fc_in");
    let hq = slot(act, n, fin);
    let sums = &mut sums[..n * c];
    sums.fill(0);
    for b in 0..n {
        for y in 0..cur_h {
            for xx in 0..cur_w {
                let base = ((b * cur_h + y) * cur_w + xx) * c;
                for ch in 0..c {
                    sums[b * c + ch] += i64::from(hq[base + ch]);
                }
            }
        }
    }
    let gap = Requantizer::from_scale(2f64.powi(exp_h - params.feat_exp) / ((cur_h * cur_w) as f64))
        .expect("GAP requant scale representable");
    let fq = &mut fq[..n * c];
    for (q, &s) in fq.iter_mut().zip(sums.iter()) {
        *q = fx_rescale(s * i64::from(gap.mult), gap.shift).clamp(-127, 127) as i8;
    }
    profile.gap_ns = t.elapsed().as_nanos() as u64;

    // integer FC; logits are the pipeline output, produced in f32
    let t = Instant::now();
    let fc_acc = &mut fc_acc[..n * ncls];
    reg.gemm_into(fq, n, c, ncls, &params.fc_packed, params.fc_wq.data(), fc_acc);
    let fs = 2f32.powi(params.feat_exp);
    for b in 0..n {
        for k in 0..ncls {
            logits[b * ncls + k] =
                fc_acc[b * ncls + k] as f32 * (params.fc_scale[k] * fs) + params.fc_b[k];
        }
    }
    profile.fc_ns = t.elapsed().as_nanos() as u64;
    profile.total_ns = t_total.elapsed().as_nanos() as u64;
    // end-of-forward drain into the global counters: a fixed number of
    // relaxed adds, allocation-free (always on — see telemetry module doc)
    telemetry::engine().drain(profile);
}

// ---------------------------------------------------------------------------
// f32 reference path (python-sim mirror; validation only)
// ---------------------------------------------------------------------------

struct ConvOut {
    /// int8 requantized activations (next layer input)
    q: Tensor<i8>,
    /// f32 pre-requant activations (residual path), only kept when needed
    z: Option<Tensor<f32>>,
}

#[allow(clippy::too_many_arguments)]
fn qconv_ref(
    x: &Tensor<i8>,
    exp_in: i32,
    l: &ConvLayer,
    p: &QConvParams,
    relu: bool,
    skip: Option<&Tensor<f32>>,
    keep_f32: bool,
    reg: &KernelRegistry,
) -> ConvOut {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    let acc = reg.gemm(&cols, &p.wq, &p.packed);
    let cout = l.cout;
    let exp_scale = 2f32.powi(exp_in);
    let mut z = vec![0.0f32; acc.len()];
    let accd = acc.data();
    let skipd = skip.map(Tensor::data);
    for row in 0..n * ho * wo {
        for c in 0..cout {
            let i = row * cout + c;
            let y = accd[i] as f32 * (p.w_scale[c] * exp_scale);
            let mut v = y * p.bn_scale[c] + p.bn_shift[c];
            if let Some(s) = skipd {
                v += s[i];
            }
            if relu {
                v = v.max(0.0);
            }
            z[i] = v;
        }
    }
    let q = Tensor::new(&[n, ho, wo, cout], requant(&z, p.act_exp)).expect("requant shape");
    let zt = keep_f32.then(|| Tensor::new(&[n, ho, wo, cout], z).expect("z shape"));
    ConvOut { q, z: zt }
}

/// Store a planned tensor's reference activations under its plan id.
fn put_ref(ts: &mut Vec<Option<Tensor<i8>>>, t: usize, v: Tensor<i8>) {
    if ts.len() <= t {
        ts.resize(t + 1, None);
    }
    ts[t] = Some(v);
}

/// Plan for the reference/divergence interpreters, which have no silent
/// fallback: an unplannable table is a caller error.
fn ref_plan(net: &Network, x: &Tensor<f32>) -> ForwardPlan {
    ForwardPlan::build_for(net, x.dim(1), x.dim(2)).unwrap_or_else(|e| {
        panic!("forward_quant_ref: cannot plan network '{}': {e}", net.name)
    })
}

/// [`forward_quant_ref_with`] with the default (auto, single-thread)
/// registry.
pub fn forward_quant_ref(params: &QModelParams, net: &Network, x: &Tensor<f32>) -> Tensor<f32> {
    forward_quant_ref_with(params, net, x, &KernelRegistry::auto())
}

/// The f32-epilogue reference pipeline: identical op order to
/// `python/compile/model.py::forward_quant(engine="sim")`, materializing
/// f32 pre-activations between layers. Kept for cross-validation of the
/// fused integer path ([`paths_divergence`]) and the python cross-check
/// tests — serving uses [`forward_quant_with`].
pub fn forward_quant_ref_with(
    params: &QModelParams,
    net: &Network,
    x: &Tensor<f32>,
    reg: &KernelRegistry,
) -> Tensor<f32> {
    let plan = ref_plan(net, x);
    let exp_of = |t: &TensorRef| -> i32 {
        match t.exp_from {
            None => params.in_exp,
            Some(li) => params.convs[&net.layers[li].name].act_exp,
        }
    };
    let mut ts: Vec<Option<Tensor<i8>>> = Vec::new();
    put_ref(
        &mut ts,
        plan.input.t,
        Tensor::new(x.shape(), requant(x.data(), params.in_exp)).expect("input shape"),
    );
    // pending f32 skip value (mirrors the python sim's residual exactly)
    let mut skip_f: Option<Tensor<f32>> = None;
    for step in &plan.steps {
        match step {
            ExecStep::Conv { layer, src, dst } => {
                let l = &net.layers[*layer];
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let q =
                    qconv_ref(xin, exp_of(src), l, &params.convs[&l.name], true, None, false, reg)
                        .q;
                put_ref(&mut ts, dst.t, q);
            }
            ExecStep::ConvSkip { layer, src, dst } => {
                let l = &net.layers[*layer];
                let s = skip_f.take().expect("plan prepares the lane before the join");
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let q = qconv_ref(
                    xin,
                    exp_of(src),
                    l,
                    &params.convs[&l.name],
                    true,
                    Some(&s),
                    false,
                    reg,
                )
                .q;
                put_ref(&mut ts, dst.t, q);
            }
            ExecStep::ConvToSkip { layer, src, .. } => {
                let l = &net.layers[*layer];
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let z =
                    qconv_ref(xin, exp_of(src), l, &params.convs[&l.name], false, None, true, reg)
                        .z
                        .expect("proj keeps f32");
                skip_f = Some(z);
            }
            ExecStep::IdentitySkip { src, .. } => {
                let s = 2f32.powi(exp_of(src));
                let xin = ts[src.t].as_ref().expect("planned tensor");
                skip_f = Some(xin.map(|v| f32::from(v) * s));
            }
            ExecStep::Pool { k, stride, pad, src, dst } => {
                let xin = ts[src.t].as_ref().expect("planned tensor");
                put_ref(&mut ts, dst.t, maxpool2d(xin, *k, *stride, *pad));
            }
        }
    }
    let hq = ts[plan.final_act.t].take().expect("planned final activation");
    let exp_h = exp_of(&plan.final_act);

    // global average pool (dequantized), requant features, integer FC
    let (n, ho, wo, c) = (hq.dim(0), hq.dim(1), hq.dim(2), hq.dim(3));
    let s = 2f32.powi(exp_h);
    let mut feat = vec![0.0f32; n * c];
    {
        let hd = hq.data();
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        feat[b * c + ch] += f32::from(hd[base + ch]);
                    }
                }
            }
        }
        let inv = s / (ho * wo) as f32;
        for v in feat.iter_mut() {
            *v *= inv;
        }
    }
    let fq = Tensor::new(&[n, c], requant(&feat, params.feat_exp)).expect("feat shape");
    let acc = reg.gemm(&fq, &params.fc_wq, &params.fc_packed);
    let ncls = params.fc_b.len();
    let fs = 2f32.powi(params.feat_exp);
    let mut logits = Tensor::<f32>::zeros(&[n, ncls]);
    {
        let ld = logits.data_mut();
        let ad = acc.data();
        for b in 0..n {
            for k in 0..ncls {
                ld[b * ncls + k] =
                    ad[b * ncls + k] as f32 * (params.fc_scale[k] * fs) + params.fc_b[k];
            }
        }
    }
    logits
}

// ---------------------------------------------------------------------------
// fused-vs-reference divergence harness
// ---------------------------------------------------------------------------

/// Result of [`paths_divergence`]: how far the fused integer path strays
/// from the f32 reference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PathsDivergence {
    /// max |fused - ref| over every requantized activation code, measured
    /// in per-layer lockstep (both paths fed the same reference input at
    /// each layer). The documented bound is 1: the fused multiplier is
    /// exact to 2^-31, so codes can only differ when the real value sits
    /// within a hair of a rounding boundary (DESIGN.md §requant).
    pub max_code_ulp: i32,
    /// max |fused - ref| over the final logits of the two *free-running*
    /// pipelines (code divergences may cascade here, so this is reported
    /// rather than bounded analytically).
    pub logit_max_abs_diff: f32,
}

fn code_ulp(a: &Tensor<i8>, b: &Tensor<i8>) -> i32 {
    a.data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| (i32::from(x) - i32::from(y)).abs())
        .max()
        .unwrap_or(0)
}

/// Run the fused integer pipeline and the f32 reference in per-layer
/// lockstep (the fused layer consumes the *reference* activations, so
/// divergence cannot cascade) and report the maximum code divergence,
/// plus the free-running logit gap. The validation harness behind
/// `rust/tests/requant_equivalence.rs`.
pub fn paths_divergence(
    params: &QModelParams,
    net: &Network,
    x: &Tensor<f32>,
    reg: &KernelRegistry,
) -> PathsDivergence {
    let plan = ref_plan(net, x);
    let exp_of = |t: &TensorRef| -> i32 {
        match t.exp_from {
            None => params.in_exp,
            Some(li) => params.convs[&net.layers[li].name].act_exp,
        }
    };
    let mut max_ulp = 0i32;
    // reference activations per planned tensor — both paths consume these,
    // so divergence cannot cascade
    let mut ts: Vec<Option<Tensor<i8>>> = Vec::new();
    put_ref(
        &mut ts,
        plan.input.t,
        Tensor::new(x.shape(), requant(x.data(), params.in_exp)).expect("input shape"),
    );
    // the pending skip value in both representations, from the same
    // reference activations
    let mut lane: Option<(Tensor<f32>, Tensor<i64>)> = None;
    for step in &plan.steps {
        match step {
            ExecStep::Conv { layer, src, dst } => {
                let l = &net.layers[*layer];
                let p = &params.convs[&l.name];
                let e_in = exp_of(src);
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let r = qconv_ref(xin, e_in, l, p, true, None, false, reg);
                let e = own_epi(params, &l.name, p, e_in);
                let f = qconv_fused(xin, l, p, &e, None, reg);
                max_ulp = max_ulp.max(code_ulp(&r.q, &f));
                put_ref(&mut ts, dst.t, r.q);
            }
            ExecStep::ConvSkip { layer, src, dst } => {
                let (sf, sx) = lane.take().expect("plan prepares the lane before the join");
                let l = &net.layers[*layer];
                let p = &params.convs[&l.name];
                let e_in = exp_of(src);
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let r = qconv_ref(xin, e_in, l, p, true, Some(&sf), false, reg);
                let e = own_epi(params, &l.name, p, e_in);
                let f = qconv_fused(xin, l, p, &e, Some(&sx), reg);
                max_ulp = max_ulp.max(code_ulp(&r.q, &f));
                put_ref(&mut ts, dst.t, r.q);
            }
            ExecStep::ConvToSkip { layer, src, target } => {
                let l = &net.layers[*layer];
                let p = &params.convs[&l.name];
                let e_in = exp_of(src);
                let tgt_exp = params.convs[&net.layers[*target].name].act_exp;
                let xin = ts[src.t].as_ref().expect("planned tensor");
                let zf = qconv_ref(xin, e_in, l, p, false, None, true, reg)
                    .z
                    .expect("proj keeps f32");
                let pepi = proj_epi(params, &l.name, p, e_in, tgt_exp);
                let fx = qconv_to_skip(xin, l, p, &pepi, reg);
                lane = Some((zf, fx));
            }
            ExecStep::IdentitySkip { src, target } => {
                let e_in = exp_of(src);
                let tgt_exp = params.convs[&net.layers[*target].name].act_exp;
                let s = 2f32.powi(e_in);
                let xin = ts[src.t].as_ref().expect("planned tensor");
                lane =
                    Some((xin.map(|v| f32::from(v) * s), dequant_to_skip(xin, e_in, tgt_exp)));
            }
            ExecStep::Pool { k, stride, pad, src, dst } => {
                // both paths pool the same i8 codes — divergence-free
                let xin = ts[src.t].as_ref().expect("planned tensor");
                put_ref(&mut ts, dst.t, maxpool2d(xin, *k, *stride, *pad));
            }
        }
    }
    let hq = ts[plan.final_act.t].take().expect("planned final activation");
    let exp_h = exp_of(&plan.final_act);

    // GAP lockstep: f32 mean+requant vs integer sum+fixed-point rescale
    let (n, ho, wo, c) = (hq.dim(0), hq.dim(1), hq.dim(2), hq.dim(3));
    let mut sums = vec![0i64; n * c];
    let mut feat = vec![0.0f32; n * c];
    {
        let hd = hq.data();
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        sums[b * c + ch] += i64::from(hd[base + ch]);
                        feat[b * c + ch] += f32::from(hd[base + ch]);
                    }
                }
            }
        }
        let inv = 2f32.powi(exp_h) / (ho * wo) as f32;
        for v in feat.iter_mut() {
            *v *= inv;
        }
    }
    let fq_ref = requant(&feat, params.feat_exp);
    let gap = Requantizer::from_scale(2f64.powi(exp_h - params.feat_exp) / ((ho * wo) as f64))
        .expect("GAP requant scale representable");
    for (s, &r) in sums.iter().zip(&fq_ref) {
        let q = fx_rescale(s * i64::from(gap.mult), gap.shift).clamp(-127, 127) as i8;
        max_ulp = max_ulp.max((i32::from(q) - i32::from(r)).abs());
    }

    let logits_ref = forward_quant_ref_with(params, net, x, reg);
    let logits_fused = forward_quant_with(params, net, x, reg);
    PathsDivergence {
        max_code_ulp: max_ulp,
        logit_max_abs_diff: logits_ref.max_abs_diff(&logits_fused),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::TernaryMode;
    use crate::util::SplitMix64;

    fn scheme(s: &str) -> Scheme {
        Scheme::parse(s).unwrap()
    }

    #[test]
    fn test_gemm_i8_reexport_exact() {
        let a = Tensor::new(&[2, 3], vec![1i8, -2, 3, 0, 5, -6]).unwrap();
        let b = Tensor::new(&[3, 2], vec![1i8, 2, 3, 4, 5, 6]).unwrap();
        let c = gemm_i8(&a, &b);
        assert_eq!(c.data(), &[10, 12, -15, -16]);
    }

    #[test]
    fn test_requant_half_even_and_clip() {
        let q = requant(&[0.5, 1.5, 2.5, -0.5, 1000.0, -1000.0], 0);
        assert_eq!(q, vec![0, 2, 2, 0, 127, -127]);
        let q = requant(&[1.0], -2); // 1.0 * 4 = 4
        assert_eq!(q, vec![4]);
    }

    fn identity_conv() -> (ConvLayer, QConvParams) {
        let l = ConvLayer {
            name: "t".into(),
            kh: 1,
            kw: 1,
            cin: 2,
            cout: 2,
            stride: 1,
            pad: 0,
            out_hw: 2,
            residual: false,
            relu: false,
        };
        let p = QConvParams::new(
            Tensor::new(&[1, 1, 2, 2], vec![1i8, 0, 0, 1]).unwrap(),
            vec![1.0; 2],
            vec![1.0; 2],
            vec![0.0; 2],
            0,
            LayerPolicy::new(WeightCodec::Ternary { mode: TernaryMode::Support }, 2).unwrap(),
        )
        .unwrap();
        (l, p)
    }

    #[test]
    fn test_qconv_1x1_identity_both_paths() {
        // identity 1x1 ternary conv with unit scales: output == clipped input
        let (l, p) = identity_conv();
        assert!(p.packed.ternary.is_some(), "ternary codes must pack");
        let x = Tensor::new(&[1, 2, 2, 2], vec![1i8, -2, 3, -4, 5, -6, 7, -8]).unwrap();
        let reg = KernelRegistry::auto();
        let out_ref = qconv_ref(&x, 0, &l, &p, false, None, false, &reg);
        assert_eq!(out_ref.q.data(), x.data());
        let epi = p.requant.resolve(0, p.act_exp, false);
        let out_fused = qconv_fused(&x, &l, &p, &epi, None, &reg);
        assert_eq!(out_fused.data(), x.data());
    }

    #[test]
    fn test_forward_quant_tiny_net_finite() {
        // build a minimal 1-block net with random ternary weights and run it
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 11, &scheme("8a2w_n4"));
        params.validate(&net).unwrap();
        let mut rng = SplitMix64::new(11);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let logits = forward_quant(&params, &net, &x);
        assert_eq!(logits.shape(), &[2, 3]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn test_forward_quant_invariant_under_kernel_choice() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 5, &scheme("8a2w_n4"));
        let mut rng = SplitMix64::new(6);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let want = forward_quant_with(&params, &net, &x, &KernelRegistry::auto());
        for kind in crate::kernels::ALL_KERNELS {
            let reg = KernelRegistry::new(Some(kind), 2);
            let got = forward_quant_with(&params, &net, &x, &reg);
            assert_eq!(got.data(), want.data(), "kernel {kind}");
        }
    }

    #[test]
    fn test_forward_quant_bottleneck_pool_invariant_and_tracks_reference() {
        // ResNet-50-shaped blocks (1x1-3x3-1x1 + stem maxpool + projection
        // and identity shortcuts) through the planned step interpreter
        let net = crate::model::bottleneck_mini(16, &[4, 8], 3);
        let params = QModelParams::synthetic(&net, 77, &scheme("8a2w_n4@stem=i8"));
        params.validate(&net).unwrap();
        let mut rng = SplitMix64::new(78);
        let x = Tensor::new(&[2, 16, 16, 3], rng.normal(2 * 16 * 16 * 3)).unwrap();
        let want = forward_quant(&params, &net, &x);
        assert!(want.data().iter().all(|v| v.is_finite()));
        for kind in crate::kernels::ALL_KERNELS {
            for threads in [1usize, 2] {
                let reg = KernelRegistry::new(Some(kind), threads);
                let got = forward_quant_with(&params, &net, &x, &reg);
                assert_eq!(got.data(), want.data(), "kernel {kind} threads {threads}");
            }
        }
        let d = paths_divergence(&params, &net, &x, &KernelRegistry::auto());
        assert!(d.max_code_ulp <= 1, "lockstep divergence {} > 1 code", d.max_code_ulp);
    }

    #[test]
    fn test_load_surfaces_unplannable_net_as_typed_error() {
        // satellite: a table the graph builder cannot express must fail the
        // *load* with an error naming the layer — never a silent empty plan
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 13, &scheme("8a2w_n4"));
        let mut map = params.to_tensors();
        let mut bad = net.clone();
        let mut tail = bad.layers[1].clone();
        tail.name = "dangling".into();
        bad.layers.push(tail);
        // give the dangling layer real params so shape validation passes
        // and the failure is the plan build itself
        for suffix in
            ["wq", "w_scale", "bn_scale", "bn_shift", "act_exp", "w_bits", "rq_mult", "rq_shift", "rq_bias"]
        {
            let v = map[&format!("s0b0c1.{suffix}")[..]].clone();
            map.insert(format!("dangling.{suffix}"), v);
        }
        let err = QModelParams::from_tensors(&map, &bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("forward plan"), "{msg}");
        assert!(msg.contains("dangling"), "{msg}");
    }

    #[test]
    fn test_fused_path_tracks_reference_on_synthetic_net() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 17, &scheme("8a2w_n4@stem=i8"));
        let mut rng = SplitMix64::new(18);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let d = paths_divergence(&params, &net, &x, &KernelRegistry::auto());
        assert!(d.max_code_ulp <= 1, "lockstep divergence {} > 1 code", d.max_code_ulp);
        assert!(d.logit_max_abs_diff.is_finite());
    }

    #[test]
    fn test_epilogue_cache_built_at_load_and_equals_fallback() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 51, &scheme("8a2w_n4@stem=i8"));
        // one own-grid entry per non-proj conv, one per projection conv,
        // each keyed by the exponent chain of the residual-block walk
        let n_proj = net.layers.iter().filter(|l| l.name.ends_with("proj")).count();
        assert!(n_proj > 0, "test net must exercise the projection path");
        assert_eq!(params.epilogues.len(), net.layers.len());
        assert!(params.epilogues.own("stem", params.in_exp).is_some());
        let mut exp_h = params.convs["stem"].act_exp;
        let mut i = 1;
        while i + 1 < net.layers.len() {
            let c1 = &net.layers[i];
            let c2 = &net.layers[i + 1];
            let has_proj = net.layers.get(i + 2).map(|l| l.name.ends_with("proj")).unwrap_or(false);
            let exp2 = params.convs[&c2.name].act_exp;
            if has_proj {
                assert!(params.epilogues.proj(&net.layers[i + 2].name, exp_h, exp2).is_some());
            }
            assert!(params.epilogues.own(&c1.name, exp_h).is_some(), "{}", c1.name);
            assert!(params.epilogues.own(&c2.name, params.convs[&c1.name].act_exp).is_some(), "{}", c2.name);
            exp_h = exp2;
            i += if has_proj { 3 } else { 2 };
        }
        // a mismatched exponent misses instead of serving a stale entry
        assert!(params.epilogues.own("stem", params.in_exp + 1).is_none());
        // export -> load rebuilds the cache too
        let back = QModelParams::from_tensors(&params.to_tensors(), &net).unwrap();
        assert_eq!(back.epilogues.len(), net.layers.len());
        // an empty cache (hand-assembled params) resolves on the fly to
        // bit-identical logits
        let mut bare = params.clone();
        bare.epilogues = EpilogueCache::default();
        assert!(bare.epilogues.is_empty());
        let mut rng = SplitMix64::new(52);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let want = forward_quant(&params, &net, &x);
        let got = forward_quant(&bare, &net, &x);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn test_synthetic_packs_expected_encodings() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let tern = QModelParams::synthetic(&net, 1, &scheme("8a2w_n4"));
        assert!(tern.convs.values().all(|p| p.packed.ternary.is_some()));
        assert!(tern.fc_packed.ternary.is_some());
        let i4 = QModelParams::synthetic(&net, 1, &scheme("8a4w_n4"));
        assert!(i4.convs.values().all(|p| p.packed.i4.is_some()));
        let i8m = QModelParams::synthetic(&net, 1, &scheme("8a8w_n4"));
        // full i8 codes fit neither sub-8-bit encoding
        assert!(i8m.convs.values().any(|p| p.packed.ternary.is_none() && p.packed.i4.is_none()));
    }

    #[test]
    fn test_validate_catches_bad_shapes() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams {
            convs: BTreeMap::new(),
            fc_wq: Tensor::<i8>::zeros(&[1, 1]),
            fc_scale: vec![],
            fc_b: vec![],
            in_exp: 0,
            feat_exp: 0,
            scheme: scheme("8a2w_n4"),
            fc_packed: PackedLayer::none(),
            epilogues: EpilogueCache::default(),
            plan: ForwardPlan::default(),
        };
        assert!(params.validate(&net).is_err());
    }

    #[test]
    fn test_mixed_scheme_assigns_per_layer_policies() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let s = scheme("8a2w_n4@stem=i8@fc=i8");
        let params = QModelParams::synthetic(&net, 21, &s);
        params.validate(&net).unwrap();
        assert_eq!(params.convs["stem"].policy.codec, WeightCodec::I8);
        for (name, p) in &params.convs {
            if name != "stem" {
                assert_eq!(p.policy.w_bits(), 2, "{name}");
                assert!(p.packed.ternary.is_some(), "{name} must pack ternary");
            }
        }
        assert_eq!(params.scheme.policy_for("fc").codec, WeightCodec::I8);
    }

    #[test]
    fn test_validate_rejects_codes_outside_declared_codec() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        // weights drawn for an 8-bit model, but declared ternary
        let wide = QModelParams::synthetic(&net, 2, &scheme("8a8w_n4"));
        let lied = QModelParams { scheme: scheme("8a2w_n4"), ..wide };
        let err = lied.validate(&net).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn test_export_roundtrip_preserves_requant_and_logits() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 33, &scheme("8a2w_n4@stem=i8"));
        let map = params.to_tensors();
        assert_eq!(map["meta.requant_version"].as_i32().unwrap().data()[0], REQUANT_VERSION);
        let back = QModelParams::from_tensors(&map, &net).unwrap();
        for (name, p) in &params.convs {
            assert_eq!(p.requant, back.convs[name].requant, "layer {name}");
        }
        assert_eq!(params.scheme, back.scheme);
        let mut rng = SplitMix64::new(34);
        let x = Tensor::new(&[1, 8, 8, 3], rng.normal(8 * 8 * 3)).unwrap();
        let want = forward_quant(&params, &net, &x);
        let got = forward_quant(&back, &net, &x);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn test_legacy_export_falls_back_to_derived_requant() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 35, &scheme("8a2w_n4"));
        let mut map = params.to_tensors();
        // strip the integer-requant tensors: a pre-versioning export
        map.remove("meta.requant_version");
        let names: Vec<String> =
            map.keys().filter(|k| k.contains(".rq_")).cloned().collect();
        for n in names {
            map.remove(&n);
        }
        let back = QModelParams::from_tensors(&map, &net).unwrap();
        // the f32 fallback derives exactly what the export carried
        for (name, p) in &params.convs {
            assert_eq!(p.requant, back.convs[name].requant, "layer {name}");
        }
    }

    #[test]
    fn test_newer_requant_version_rejected() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 36, &scheme("8a2w_n4"));
        let mut map = params.to_tensors();
        map.insert(
            "meta.requant_version".into(),
            AnyTensor::I32(Tensor::new(&[1], vec![REQUANT_VERSION + 1]).unwrap()),
        );
        let err = QModelParams::from_tensors(&map, &net).unwrap_err().to_string();
        assert!(err.contains("requant_version"), "{err}");
    }

    #[test]
    fn test_versioned_export_missing_rq_tensor_is_an_error() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 37, &scheme("8a2w_n4"));
        let mut map = params.to_tensors();
        map.remove("stem.rq_mult");
        let err = QModelParams::from_tensors(&map, &net).unwrap_err().to_string();
        assert!(err.contains("stem.rq_mult"), "{err}");
    }

    /// The pre-plan forward implementation (one tensor allocation per conv,
    /// via the Tensor-based helpers) — kept here as the equivalence oracle
    /// for the workspace rewrite.
    fn forward_quant_legacy(
        params: &QModelParams,
        net: &Network,
        x: &Tensor<f32>,
        reg: &KernelRegistry,
    ) -> Tensor<f32> {
        let layers: BTreeMap<&str, &ConvLayer> =
            net.layers.iter().map(|l| (l.name.as_str(), l)).collect();
        let xq = Tensor::new(x.shape(), requant(x.data(), params.in_exp)).expect("input shape");
        let stem_p = &params.convs["stem"];
        let stem_epi = own_epi(params, "stem", stem_p, params.in_exp);
        let mut hq = qconv_fused(&xq, layers["stem"], stem_p, &stem_epi, None, reg);
        let mut exp_h = stem_p.act_exp;
        let mut i = 1;
        while i < net.layers.len() {
            let c1 = &net.layers[i];
            let c2 = &net.layers[i + 1];
            let has_proj = net
                .layers
                .get(i + 2)
                .map(|l| l.name.ends_with("proj"))
                .unwrap_or(false);
            let exp2 = params.convs[&c2.name].act_exp;
            let skip_fx = if has_proj {
                let proj = &net.layers[i + 2];
                let pp = &params.convs[&proj.name];
                let pepi = proj_epi(params, &proj.name, pp, exp_h, exp2);
                qconv_to_skip(&hq, proj, pp, &pepi, reg)
            } else {
                dequant_to_skip(&hq, exp_h, exp2)
            };
            let p1 = &params.convs[&c1.name];
            let e1 = own_epi(params, &c1.name, p1, exp_h);
            let h1 = qconv_fused(&hq, c1, p1, &e1, None, reg);
            let p2 = &params.convs[&c2.name];
            let e2 = own_epi(params, &c2.name, p2, p1.act_exp);
            hq = qconv_fused(&h1, c2, p2, &e2, Some(&skip_fx), reg);
            exp_h = exp2;
            i += if has_proj { 3 } else { 2 };
        }
        let (n, ho, wo, c) = (hq.dim(0), hq.dim(1), hq.dim(2), hq.dim(3));
        let mut sums = vec![0i64; n * c];
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        sums[b * c + ch] += i64::from(hq.data()[base + ch]);
                    }
                }
            }
        }
        let gap = Requantizer::from_scale(2f64.powi(exp_h - params.feat_exp) / ((ho * wo) as f64))
            .expect("GAP requant scale representable");
        let fq_data: Vec<i8> = sums
            .iter()
            .map(|&s| fx_rescale(s * i64::from(gap.mult), gap.shift).clamp(-127, 127) as i8)
            .collect();
        let fq = Tensor::new(&[n, c], fq_data).expect("feat shape");
        let acc = reg.gemm(&fq, &params.fc_wq, &params.fc_packed);
        let ncls = params.fc_b.len();
        let fs = 2f32.powi(params.feat_exp);
        let mut logits = Tensor::<f32>::zeros(&[n, ncls]);
        for b in 0..n {
            for k in 0..ncls {
                logits.data_mut()[b * ncls + k] =
                    acc.data()[b * ncls + k] as f32 * (params.fc_scale[k] * fs) + params.fc_b[k];
            }
        }
        logits
    }

    /// stem 3×3 + one block whose c1 is 1×1/stride-1/pad-0 — exercises the
    /// im2col-free direct path end to end (resnet-mini's own 1×1 convs are
    /// all strided projections).
    fn pointwise_net() -> Network {
        let conv = |name: &str, k: usize, cin: usize, cout: usize, pad: usize| ConvLayer {
            name: name.into(),
            kh: k,
            kw: k,
            cin,
            cout,
            stride: 1,
            pad,
            out_hw: 8,
            residual: false,
            relu: true,
        };
        let mut c2 = conv("s0b0c2", 3, 6, 6, 1);
        c2.residual = true;
        Network {
            name: "pointwise-mini".into(),
            input_hw: 8,
            layers: vec![conv("stem", 3, 3, 6, 1), conv("s0b0c1", 1, 6, 6, 0), c2],
            fc_in: 6,
            fc_out: 3,
            stem_pool: None,
        }
    }

    #[test]
    fn test_workspace_forward_matches_legacy_tensor_path() {
        for (net, tag) in [
            (crate::model::resnet_mini(8, &[4, 8, 8], 1, 3), "resnet-mini"),
            (pointwise_net(), "pointwise"),
        ] {
            for (seed, s) in [(41u64, "8a2w_n4"), (42, "8a2w_n4@stem=i8"), (43, "8a4w_n4")] {
                let params = QModelParams::synthetic(&net, seed, &scheme(s));
                params.validate(&net).unwrap();
                let mut rng = SplitMix64::new(seed ^ 7);
                let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
                for threads in [1usize, 2] {
                    let reg = KernelRegistry::new(None, threads);
                    let want = forward_quant_legacy(&params, &net, &x, &reg);
                    let got = forward_quant_with(&params, &net, &x, &reg);
                    assert_eq!(got.data(), want.data(), "{tag} scheme={s} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn test_pointwise_conv_skips_im2col_and_stays_bit_exact() {
        let net = pointwise_net();
        let c1 = &net.layers[1];
        assert!(c1.is_pointwise());
        let params = QModelParams::synthetic(&net, 44, &scheme("8a2w_n4"));
        let plan = params.forward_plan();
        assert!(plan.matches(&net, 8, 8));
        assert!(plan.dims[1].direct, "1x1/s1/p0 conv must take the direct path");
        assert!(!plan.dims[0].direct && !plan.dims[2].direct);
        let mut rng = SplitMix64::new(45);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        // the direct path must agree with every kernel/thread combination
        let want = forward_quant(&params, &net, &x);
        for kind in crate::kernels::ALL_KERNELS {
            for threads in [1usize, 2] {
                let reg = KernelRegistry::new(Some(kind), threads);
                let got = forward_quant_with(&params, &net, &x, &reg);
                assert_eq!(got.data(), want.data(), "kernel {kind} threads {threads}");
            }
        }
        // and with the f32 reference within the documented lockstep bound
        let d = paths_divergence(&params, &net, &x, &KernelRegistry::auto());
        assert!(d.max_code_ulp <= 1, "lockstep divergence {} > 1 code", d.max_code_ulp);
    }

    #[test]
    fn test_forward_into_reuses_workspace_across_batches_bit_exact() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 71, &scheme("8a2w_n4@stem=i8"));
        let mut ws = ForwardWorkspace::new();
        let mut rng = SplitMix64::new(72);
        // grow, shrink, grow, steady — the dirty arena must never leak into
        // the logits
        for n in [2usize, 1, 3, 3] {
            let x = Tensor::new(&[n, 8, 8, 3], rng.normal(n * 8 * 8 * 3)).unwrap();
            let auto = KernelRegistry::auto();
            let want = forward_quant_with(&params, &net, &x, &auto);
            let mut logits = vec![0f32; n * 3];
            forward_quant_into(&params, &net, &x, &auto, &mut ws, &mut logits);
            assert_eq!(&logits[..], want.data(), "batch {n}");
            let reg = KernelRegistry::new(None, 3);
            forward_quant_into(&params, &net, &x, &reg, &mut ws, &mut logits);
            assert_eq!(&logits[..], want.data(), "batch {n} threaded");
        }
    }

    #[test]
    fn test_set_conv_invalidates_epilogue_cache_never_stale() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let mut edited = QModelParams::synthetic(&net, 61, &scheme("8a2w_n4"));
        let mut rebuilt = edited.clone();
        assert!(!edited.epilogues.is_empty());
        let name = "s0b0c1";
        let p = edited.conv(name).unwrap();
        let doubled = QConvParams::new(
            p.wq.clone(),
            p.w_scale.clone(),
            p.bn_scale.iter().map(|v| v * 2.0).collect(),
            p.bn_shift.clone(),
            p.act_exp,
            p.policy.clone(),
        )
        .unwrap();
        edited.set_conv(name, doubled.clone());
        // the setter cleared the derived cache — a stale epilogue cannot
        // survive an in-place scale edit
        assert!(edited.epilogues.is_empty());
        rebuilt.set_conv(name, doubled);
        rebuilt.rebuild_epilogues(&net).unwrap();
        assert!(!rebuilt.epilogues.is_empty());
        let mut rng = SplitMix64::new(62);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        // on-the-fly resolution (cleared cache) == freshly rebuilt cache,
        // and both see the *edited* scales
        let got = forward_quant(&edited, &net, &x);
        let want = forward_quant(&rebuilt, &net, &x);
        assert_eq!(got.data(), want.data());
        let unedited = QModelParams::synthetic(&net, 61, &scheme("8a2w_n4"));
        let orig = forward_quant(&unedited, &net, &x);
        assert_ne!(got.data(), orig.data(), "edit must actually change the logits");
    }
}
