//! Low-precision integer inference pipeline — the paper's "full 8-bit
//! compute pipeline" in pure Rust.
//!
//! Replicates `python/compile/model.py::forward_quant(engine="sim")`
//! op-for-op: int8 DFP activations, int8/ternary weights, i32 accumulation,
//! per-filter scale (cluster α̂ · 2^exp_in), folded re-estimated BatchNorm,
//! round-half-even requantization. The integration tests check rust-vs-jax
//! agreement on the exported quantized model; the benches use this pipeline
//! to measure the realizable ternary-vs-fp32 CPU speedup (E5).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dfp::round_half_even;
use crate::io::TensorMap;
use crate::model::{ConvLayer, Network};
use crate::nn::im2col;
use crate::tensor::Tensor;

/// Quantized parameters for one conv layer.
#[derive(Debug, Clone)]
pub struct QConvParams {
    /// int8 codes, HWIO ({-1,0,1} for ternary layers).
    pub wq: Tensor<i8>,
    /// per-output-filter dequantization scale (α̂ or 2^exp).
    pub w_scale: Vec<f32>,
    pub bn_scale: Vec<f32>,
    pub bn_shift: Vec<f32>,
    /// DFP exponent of this layer's output activations.
    pub act_exp: i32,
    pub w_bits: u32,
}

/// Whole quantized model (mirrors the python `QModel` export).
#[derive(Debug, Clone)]
pub struct QModelParams {
    pub convs: BTreeMap<String, QConvParams>,
    pub fc_wq: Tensor<i8>,
    pub fc_scale: Vec<f32>,
    pub fc_b: Vec<f32>,
    pub in_exp: i32,
    pub feat_exp: i32,
    pub cluster: usize,
    pub w_bits: u32,
}

impl QModelParams {
    /// Load from a `qweights_<tag>.dft` produced by `python -m compile.aot`.
    pub fn from_tensors(map: &TensorMap, net: &Network) -> Result<Self> {
        let f32v = |name: &str| -> Result<Vec<f32>> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_f32()?
                .data()
                .to_vec())
        };
        let i32s = |name: &str| -> Result<i32> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_i32()?
                .data()[0])
        };
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let n = &l.name;
            convs.insert(
                n.clone(),
                QConvParams {
                    wq: map
                        .get(&format!("{n}.wq"))
                        .with_context(|| format!("missing {n}.wq"))?
                        .as_i8()?
                        .clone(),
                    w_scale: f32v(&format!("{n}.w_scale"))?,
                    bn_scale: f32v(&format!("{n}.bn_scale"))?,
                    bn_shift: f32v(&format!("{n}.bn_shift"))?,
                    act_exp: i32s(&format!("{n}.act_exp"))?,
                    w_bits: i32s(&format!("{n}.w_bits"))? as u32,
                },
            );
        }
        Ok(Self {
            convs,
            fc_wq: map.get("fc.wq").context("missing fc.wq")?.as_i8()?.clone(),
            fc_scale: f32v("fc.scale")?,
            fc_b: f32v("fc.b")?,
            in_exp: i32s("meta.in_exp")?,
            feat_exp: i32s("meta.feat_exp")?,
            cluster: i32s("meta.cluster")? as usize,
            w_bits: i32s("meta.w_bits")? as u32,
        })
    }

    /// Sanity-check layer shapes against the network description.
    pub fn validate(&self, net: &Network) -> Result<()> {
        for l in &net.layers {
            let p = self.convs.get(&l.name).with_context(|| format!("no params for {}", l.name))?;
            let want = [l.kh, l.kw, l.cin, l.cout];
            if p.wq.shape() != want {
                bail!("{}: weight shape {:?} != {:?}", l.name, p.wq.shape(), want);
            }
            if p.w_scale.len() != l.cout || p.bn_scale.len() != l.cout {
                bail!("{}: scale length mismatch", l.name);
            }
        }
        if self.fc_wq.dim(0) != net.fc_in || self.fc_wq.dim(1) != net.fc_out {
            bail!("fc shape mismatch");
        }
        Ok(())
    }
}

/// int8 x int8 -> i32 GEMM: (M,K) x (K,F) -> (M,F).
///
/// PERF (§Perf L3): the `av == 0` skip exploits post-ReLU activation
/// sparsity (~40-60 % zeros in the real pipeline). For dense operands the
/// branch costs ~15 %; `gemm_i8_dense` below is the branch-free variant —
/// the bench harness quantifies both (EXPERIMENTS.md §Perf).
pub fn gemm_i8(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i32> {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, f) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2);
    let mut out = Tensor::<i32>::zeros(&[m, f]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * f..(i + 1) * f];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = i32::from(av);
            let brow = &bd[kk * f..(kk + 1) * f];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * i32::from(bv);
            }
        }
    }
    out
}

/// Branch-free dense variant of [`gemm_i8`]: widens the activation once
/// per (row, k) and lets LLVM vectorize the inner f-loop.
pub fn gemm_i8_dense(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i32> {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, f) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2);
    let mut out = Tensor::<i32>::zeros(&[m, f]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * f..(i + 1) * f];
        for (kk, &av) in arow.iter().enumerate() {
            let av = i32::from(av);
            let brow = &bd[kk * f..(kk + 1) * f];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * i32::from(bv);
            }
        }
    }
    out
}

/// f32 -> int8 DFP requantization (round-half-even, symmetric clip).
pub fn requant(x: &[f32], exp: i32) -> Vec<i8> {
    let scale = 2f64.powi(-exp);
    x.iter()
        .map(|&v| round_half_even(f64::from(v) * scale).clamp(-127.0, 127.0) as i8)
        .collect()
}

struct ConvOut {
    /// int8 requantized activations (next layer input)
    q: Tensor<i8>,
    /// f32 pre-requant activations (residual path), only kept when needed
    z: Option<Tensor<f32>>,
}

fn qconv(
    x: &Tensor<i8>,
    exp_in: i32,
    l: &ConvLayer,
    p: &QConvParams,
    relu: bool,
    skip: Option<&Tensor<f32>>,
    keep_f32: bool,
) -> ConvOut {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    let wflat = p
        .wq
        .clone()
        .reshape(&[l.kh * l.kw * l.cin, l.cout])
        .expect("weight reshape");
    let acc = gemm_i8(&cols, &wflat);
    let cout = l.cout;
    let exp_scale = 2f32.powi(exp_in);
    let mut z = vec![0.0f32; acc.len()];
    let accd = acc.data();
    let skipd = skip.map(Tensor::data);
    for row in 0..n * ho * wo {
        for c in 0..cout {
            let i = row * cout + c;
            let y = accd[i] as f32 * (p.w_scale[c] * exp_scale);
            let mut v = y * p.bn_scale[c] + p.bn_shift[c];
            if let Some(s) = skipd {
                v += s[i];
            }
            if relu {
                v = v.max(0.0);
            }
            z[i] = v;
        }
    }
    let q = Tensor::new(&[n, ho, wo, cout], requant(&z, p.act_exp)).expect("requant shape");
    let zt = keep_f32.then(|| Tensor::new(&[n, ho, wo, cout], z).expect("z shape"));
    ConvOut { q, z: zt }
}

/// Forward a f32 image batch through the integer pipeline. Returns logits.
pub fn forward_quant(params: &QModelParams, net: &Network, x: &Tensor<f32>) -> Tensor<f32> {
    let layers: BTreeMap<&str, &ConvLayer> =
        net.layers.iter().map(|l| (l.name.as_str(), l)).collect();

    // quantize input image to int8 DFP
    let xq = Tensor::new(x.shape(), requant(x.data(), params.in_exp)).expect("input shape");

    let stem = qconv(&xq, params.in_exp, layers["stem"], &params.convs["stem"], true, None, false);
    let mut hq = stem.q;
    let mut exp_h = params.convs["stem"].act_exp;

    let mut i = 1;
    while i < net.layers.len() {
        let c1 = &net.layers[i];
        let c2 = &net.layers[i + 1];
        let has_proj = net
            .layers
            .get(i + 2)
            .map(|l| l.name.ends_with("proj"))
            .unwrap_or(false);
        // skip path in f32 (mirrors the python sim exactly)
        let skip_f = if has_proj {
            let proj = &net.layers[i + 2];
            qconv(&hq, exp_h, proj, &params.convs[&proj.name], false, None, true)
                .z
                .expect("proj keeps f32")
        } else {
            let s = 2f32.powi(exp_h);
            hq.map(|v| f32::from(v) * s)
        };
        let h1 = qconv(&hq, exp_h, c1, &params.convs[&c1.name], true, None, false);
        let exp1 = params.convs[&c1.name].act_exp;
        let h2 = qconv(&h1.q, exp1, c2, &params.convs[&c2.name], true, Some(&skip_f), false);
        exp_h = params.convs[&c2.name].act_exp;
        hq = h2.q;
        i += if has_proj { 3 } else { 2 };
    }

    // global average pool (dequantized), requant features, integer FC
    let (n, ho, wo, c) = (hq.dim(0), hq.dim(1), hq.dim(2), hq.dim(3));
    let s = 2f32.powi(exp_h);
    let mut feat = vec![0.0f32; n * c];
    {
        let hd = hq.data();
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        feat[b * c + ch] += f32::from(hd[base + ch]);
                    }
                }
            }
        }
        let inv = s / (ho * wo) as f32;
        for v in feat.iter_mut() {
            *v *= inv;
        }
    }
    let fq = Tensor::new(&[n, c], requant(&feat, params.feat_exp)).expect("feat shape");
    let acc = gemm_i8(&fq, &params.fc_wq);
    let ncls = params.fc_b.len();
    let fs = 2f32.powi(params.feat_exp);
    let mut logits = Tensor::<f32>::zeros(&[n, ncls]);
    {
        let ld = logits.data_mut();
        let ad = acc.data();
        for b in 0..n {
            for k in 0..ncls {
                ld[b * ncls + k] =
                    ad[b * ncls + k] as f32 * (params.fc_scale[k] * fs) + params.fc_b[k];
            }
        }
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn test_gemm_i8_exact() {
        let a = Tensor::new(&[2, 3], vec![1i8, -2, 3, 0, 5, -6]).unwrap();
        let b = Tensor::new(&[3, 2], vec![1i8, 2, 3, 4, 5, 6]).unwrap();
        let c = gemm_i8(&a, &b);
        assert_eq!(c.data(), &[10, 12, -15, -16]);
    }

    #[test]
    fn test_gemm_i8_saturation_free() {
        // worst case |acc| = K * 127 * 127 must not overflow i32
        let k = 2048;
        let a = Tensor::new(&[1, k], vec![127i8; k]).unwrap();
        let b = Tensor::new(&[k, 1], vec![127i8; k]).unwrap();
        let c = gemm_i8(&a, &b);
        assert_eq!(c.data()[0], 127 * 127 * k as i32);
    }

    #[test]
    fn test_requant_half_even_and_clip() {
        let q = requant(&[0.5, 1.5, 2.5, -0.5, 1000.0, -1000.0], 0);
        assert_eq!(q, vec![0, 2, 2, 0, 127, -127]);
        let q = requant(&[1.0], -2); // 1.0 * 4 = 4
        assert_eq!(q, vec![4]);
    }

    #[test]
    fn test_qconv_1x1_identity() {
        // identity 1x1 ternary conv with unit scales: output == clipped input
        let l = ConvLayer {
            name: "t".into(),
            kh: 1,
            kw: 1,
            cin: 2,
            cout: 2,
            stride: 1,
            pad: 0,
            out_hw: 2,
            residual: false,
            relu: false,
        };
        let p = QConvParams {
            wq: Tensor::new(&[1, 1, 2, 2], vec![1i8, 0, 0, 1]).unwrap(),
            w_scale: vec![1.0; 2],
            bn_scale: vec![1.0; 2],
            bn_shift: vec![0.0; 2],
            act_exp: 0,
            w_bits: 2,
        };
        let x = Tensor::new(&[1, 2, 2, 2], vec![1i8, -2, 3, -4, 5, -6, 7, -8]).unwrap();
        let out = qconv(&x, 0, &l, &p, false, None, false);
        assert_eq!(out.q.data(), x.data());
    }

    #[test]
    fn test_forward_quant_tiny_net_finite() {
        // build a minimal 1-block net with random ternary weights and run it
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let mut rng = SplitMix64::new(11);
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let n = l.kh * l.kw * l.cin * l.cout;
            let wq: Vec<i8> = (0..n).map(|_| rng.next_below(3) as i8 - 1).collect();
            convs.insert(
                l.name.clone(),
                QConvParams {
                    wq: Tensor::new(&[l.kh, l.kw, l.cin, l.cout], wq).unwrap(),
                    w_scale: vec![0.1; l.cout],
                    bn_scale: vec![1.0; l.cout],
                    bn_shift: vec![0.0; l.cout],
                    act_exp: -4,
                    w_bits: 2,
                },
            );
        }
        let fcn = net.fc_in * net.fc_out;
        let params = QModelParams {
            convs,
            fc_wq: Tensor::new(
                &[net.fc_in, net.fc_out],
                (0..fcn).map(|_| rng.next_below(3) as i8 - 1).collect(),
            )
            .unwrap(),
            fc_scale: vec![0.1; net.fc_out],
            fc_b: vec![0.0; net.fc_out],
            in_exp: -5,
            feat_exp: -5,
            cluster: 4,
            w_bits: 2,
        };
        params.validate(&net).unwrap();
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let logits = forward_quant(&params, &net, &x);
        assert_eq!(logits.shape(), &[2, 3]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn test_validate_catches_bad_shapes() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams {
            convs: BTreeMap::new(),
            fc_wq: Tensor::<i8>::zeros(&[1, 1]),
            fc_scale: vec![],
            fc_b: vec![],
            in_exp: 0,
            feat_exp: 0,
            cluster: 4,
            w_bits: 2,
        };
        assert!(params.validate(&net).is_err());
    }
}
