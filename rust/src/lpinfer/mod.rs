//! Low-precision integer inference pipeline — the paper's "full 8-bit
//! compute pipeline" in pure Rust.
//!
//! Replicates `python/compile/model.py::forward_quant(engine="sim")`
//! op-for-op: int8 DFP activations, int8/ternary weights, i32 accumulation,
//! per-filter scale (cluster α̂ · 2^exp_in), folded re-estimated BatchNorm,
//! round-half-even requantization. Every conv/FC GEMM dispatches through
//! [`crate::kernels::KernelRegistry`], so sub-8-bit layers run on the
//! packed multiply-free engines while staying bit-exact with the dense i8
//! kernels (see `rust/tests/kernels_equivalence.rs`). The integration tests
//! check rust-vs-jax agreement on the exported quantized model; the benches
//! use this pipeline to measure the realizable ternary-vs-fp32 CPU speedup
//! (E5).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dfp::round_half_even;
use crate::io::TensorMap;
use crate::kernels::{KernelRegistry, PackedLayer};
use crate::model::{ConvLayer, Network};
use crate::nn::im2col;
use crate::scheme::{LayerPolicy, Scheme, WeightCodec};
use crate::tensor::Tensor;

pub use crate::kernels::{gemm_i8, gemm_i8_dense};

/// Quantized parameters for one conv layer.
#[derive(Debug, Clone)]
pub struct QConvParams {
    /// int8 codes, HWIO ({-1,0,1} for ternary layers).
    pub wq: Tensor<i8>,
    /// per-output-filter dequantization scale (α̂ or 2^exp).
    pub w_scale: Vec<f32>,
    pub bn_scale: Vec<f32>,
    pub bn_shift: Vec<f32>,
    /// DFP exponent of this layer's output activations.
    pub act_exp: i32,
    /// this layer's precision policy (codec + α̂/exp cluster size).
    pub policy: LayerPolicy,
    /// packed encodings of `wq` for the kernels/ dispatch (built once here,
    /// so the hot path never re-derives or unpacks weights).
    pub packed: PackedLayer,
}

impl QConvParams {
    /// Build layer params, packing `wq` into every encoding it fits; the
    /// policy's cluster size attaches scale metadata to the packed matrices.
    pub fn new(
        wq: Tensor<i8>,
        w_scale: Vec<f32>,
        bn_scale: Vec<f32>,
        bn_shift: Vec<f32>,
        act_exp: i32,
        policy: LayerPolicy,
    ) -> Self {
        let packed = PackedLayer::build(&wq, &w_scale, policy.cluster);
        Self { wq, w_scale, bn_scale, bn_shift, act_exp, policy, packed }
    }
}

/// Whole quantized model (mirrors the python `QModel` export). Precision is
/// carried by `scheme` — one [`LayerPolicy`] per layer instead of global
/// bits/cluster scalars, so mixed models (i8 stem, ternary interior,
/// i4 tail) are first-class.
#[derive(Debug, Clone)]
pub struct QModelParams {
    pub convs: BTreeMap<String, QConvParams>,
    pub fc_wq: Tensor<i8>,
    pub fc_scale: Vec<f32>,
    pub fc_b: Vec<f32>,
    pub in_exp: i32,
    pub feat_exp: i32,
    /// the mixed-precision scheme these params realize (`convs[*].policy`
    /// and the FC policy are resolved from it).
    pub scheme: Scheme,
    /// packed encodings of `fc_wq` (same dispatch as the conv layers).
    pub fc_packed: PackedLayer,
}

impl QModelParams {
    /// Load from a `qweights_<tag>.dft` produced by `python -m compile.aot`.
    pub fn from_tensors(map: &TensorMap, net: &Network) -> Result<Self> {
        let f32v = |name: &str| -> Result<Vec<f32>> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_f32()?
                .data()
                .to_vec())
        };
        let i32s = |name: &str| -> Result<i32> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing {name}"))?
                .as_i32()?
                .data()[0])
        };
        let cluster = i32s("meta.cluster")? as usize;
        let model_bits = i32s("meta.w_bits")? as u32;
        let default_policy = LayerPolicy::new(WeightCodec::from_w_bits(model_bits)?, cluster)?;
        // reconstruct the scheme the export realizes: the model-wide policy
        // plus a named override for every layer whose recorded w_bits differ
        let mut scheme = Scheme::uniform(8, default_policy.clone())?;
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let n = &l.name;
            let layer_bits = i32s(&format!("{n}.w_bits"))? as u32;
            let policy = if layer_bits == model_bits {
                default_policy.clone()
            } else {
                let p = LayerPolicy::new(WeightCodec::from_w_bits(layer_bits)?, cluster)?;
                scheme = scheme.with_override(n, p.clone())?;
                p
            };
            convs.insert(
                n.clone(),
                QConvParams::new(
                    map.get(&format!("{n}.wq"))
                        .with_context(|| format!("missing {n}.wq"))?
                        .as_i8()?
                        .clone(),
                    f32v(&format!("{n}.w_scale"))?,
                    f32v(&format!("{n}.bn_scale"))?,
                    f32v(&format!("{n}.bn_shift"))?,
                    i32s(&format!("{n}.act_exp"))?,
                    policy,
                ),
            );
        }
        // exports may record a distinct FC precision (QuantConfig.fc_bits);
        // without the optional fc.w_bits entry the FC follows the default
        if let Some(t) = map.get("fc.w_bits") {
            let fc_bits = t.as_i32()?.data()[0] as u32;
            if fc_bits != model_bits {
                let p = LayerPolicy::new(WeightCodec::from_w_bits(fc_bits)?, cluster)?;
                scheme = scheme.with_override("fc", p)?;
            }
        }
        let fc_wq = map.get("fc.wq").context("missing fc.wq")?.as_i8()?.clone();
        let fc_scale = f32v("fc.scale")?;
        let fc_packed = PackedLayer::build(&fc_wq, &fc_scale, scheme.policy_for("fc").cluster);
        let out = Self {
            convs,
            fc_wq,
            fc_scale,
            fc_b: f32v("fc.b")?,
            in_exp: i32s("meta.in_exp")?,
            feat_exp: i32s("meta.feat_exp")?,
            scheme,
            fc_packed,
        };
        // loaded codes must actually fit the scheme the export declares
        out.validate(net)?;
        Ok(out)
    }

    /// Deterministic synthetic model (random codes, benign scales) for
    /// tests, benches and the artifact-free serving demo. Every layer's
    /// code range follows its `scheme` policy (ternary -> {-1,0,1},
    /// i4 -> [-7,7], i8 -> [-127,127]), so mixed schemes produce genuinely
    /// mixed models.
    pub fn synthetic(net: &Network, seed: u64, scheme: &Scheme) -> Self {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(seed);
        let mut code = move |n: usize, qmax: i64| -> Vec<i8> {
            (0..n).map(|_| (rng.next_below((2 * qmax + 1) as u64) as i64 - qmax) as i8).collect()
        };
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let policy = scheme.policy_for(&l.name).clone();
            let qmax = crate::dfp::qmax(policy.w_bits()).min(127) as i64;
            convs.insert(
                l.name.clone(),
                QConvParams::new(
                    Tensor::new(&[l.kh, l.kw, l.cin, l.cout], code(l.kh * l.kw * l.cin * l.cout, qmax))
                        .expect("conv shape"),
                    vec![0.1 / qmax as f32; l.cout],
                    vec![1.0; l.cout],
                    vec![0.0; l.cout],
                    -4,
                    policy,
                ),
            );
        }
        let fc_policy = scheme.policy_for("fc").clone();
        let fc_qmax = crate::dfp::qmax(fc_policy.w_bits()).min(127) as i64;
        let fc_wq = Tensor::new(&[net.fc_in, net.fc_out], code(net.fc_in * net.fc_out, fc_qmax))
            .expect("fc shape");
        let fc_scale = vec![0.1 / fc_qmax as f32; net.fc_out];
        let fc_packed = PackedLayer::build(&fc_wq, &fc_scale, fc_policy.cluster);
        Self {
            convs,
            fc_wq,
            fc_scale,
            fc_b: vec![0.0; net.fc_out],
            in_exp: -5,
            feat_exp: -5,
            scheme: scheme.clone(),
            fc_packed,
        }
    }

    /// Sanity-check the params against the network description *and* the
    /// declared scheme: layer shapes must match the net, and every layer's
    /// codes must fit the range its [`LayerPolicy`] codec promises.
    pub fn validate(&self, net: &Network) -> Result<()> {
        let check_codes = |name: &str, codes: &[i8], policy: &LayerPolicy| -> Result<()> {
            let qmax = crate::dfp::qmax(policy.w_bits());
            if let Some(&c) = codes.iter().find(|&&c| i32::from(c).abs() > qmax) {
                bail!(
                    "{name}: code {c} exceeds |code| <= {qmax} declared by codec '{}' of scheme '{}'",
                    policy.codec,
                    self.scheme
                );
            }
            Ok(())
        };
        for l in &net.layers {
            let p = self.convs.get(&l.name).with_context(|| format!("no params for {}", l.name))?;
            let want = [l.kh, l.kw, l.cin, l.cout];
            if p.wq.shape() != want {
                bail!("{}: weight shape {:?} != {:?}", l.name, p.wq.shape(), want);
            }
            if p.w_scale.len() != l.cout || p.bn_scale.len() != l.cout {
                bail!("{}: scale length mismatch", l.name);
            }
            check_codes(&l.name, p.wq.data(), &p.policy)?;
        }
        if self.fc_wq.dim(0) != net.fc_in || self.fc_wq.dim(1) != net.fc_out {
            bail!("fc shape mismatch");
        }
        check_codes("fc", self.fc_wq.data(), self.scheme.policy_for("fc"))?;
        Ok(())
    }
}

/// f32 -> int8 DFP requantization (round-half-even, symmetric clip).
pub fn requant(x: &[f32], exp: i32) -> Vec<i8> {
    let scale = 2f64.powi(-exp);
    x.iter()
        .map(|&v| round_half_even(f64::from(v) * scale).clamp(-127.0, 127.0) as i8)
        .collect()
}

struct ConvOut {
    /// int8 requantized activations (next layer input)
    q: Tensor<i8>,
    /// f32 pre-requant activations (residual path), only kept when needed
    z: Option<Tensor<f32>>,
}

#[allow(clippy::too_many_arguments)]
fn qconv(
    x: &Tensor<i8>,
    exp_in: i32,
    l: &ConvLayer,
    p: &QConvParams,
    relu: bool,
    skip: Option<&Tensor<f32>>,
    keep_f32: bool,
    reg: &KernelRegistry,
) -> ConvOut {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    let acc = reg.gemm_with(&cols, &p.packed, || {
        p.wq.clone().reshape(&[l.kh * l.kw * l.cin, l.cout]).expect("weight reshape")
    });
    let cout = l.cout;
    let exp_scale = 2f32.powi(exp_in);
    let mut z = vec![0.0f32; acc.len()];
    let accd = acc.data();
    let skipd = skip.map(Tensor::data);
    for row in 0..n * ho * wo {
        for c in 0..cout {
            let i = row * cout + c;
            let y = accd[i] as f32 * (p.w_scale[c] * exp_scale);
            let mut v = y * p.bn_scale[c] + p.bn_shift[c];
            if let Some(s) = skipd {
                v += s[i];
            }
            if relu {
                v = v.max(0.0);
            }
            z[i] = v;
        }
    }
    let q = Tensor::new(&[n, ho, wo, cout], requant(&z, p.act_exp)).expect("requant shape");
    let zt = keep_f32.then(|| Tensor::new(&[n, ho, wo, cout], z).expect("z shape"));
    ConvOut { q, z: zt }
}

/// Forward a f32 image batch through the integer pipeline with the default
/// (auto, single-thread) kernel registry. Returns logits.
pub fn forward_quant(params: &QModelParams, net: &Network, x: &Tensor<f32>) -> Tensor<f32> {
    forward_quant_with(params, net, x, &KernelRegistry::auto())
}

/// Forward pass with an explicit kernel registry (kernel choice + threads).
/// Logits are bit-identical for every registry configuration.
pub fn forward_quant_with(
    params: &QModelParams,
    net: &Network,
    x: &Tensor<f32>,
    reg: &KernelRegistry,
) -> Tensor<f32> {
    let layers: BTreeMap<&str, &ConvLayer> =
        net.layers.iter().map(|l| (l.name.as_str(), l)).collect();

    // quantize input image to int8 DFP
    let xq = Tensor::new(x.shape(), requant(x.data(), params.in_exp)).expect("input shape");

    let stem =
        qconv(&xq, params.in_exp, layers["stem"], &params.convs["stem"], true, None, false, reg);
    let mut hq = stem.q;
    let mut exp_h = params.convs["stem"].act_exp;

    let mut i = 1;
    while i < net.layers.len() {
        let c1 = &net.layers[i];
        let c2 = &net.layers[i + 1];
        let has_proj = net
            .layers
            .get(i + 2)
            .map(|l| l.name.ends_with("proj"))
            .unwrap_or(false);
        // skip path in f32 (mirrors the python sim exactly)
        let skip_f = if has_proj {
            let proj = &net.layers[i + 2];
            qconv(&hq, exp_h, proj, &params.convs[&proj.name], false, None, true, reg)
                .z
                .expect("proj keeps f32")
        } else {
            let s = 2f32.powi(exp_h);
            hq.map(|v| f32::from(v) * s)
        };
        let h1 = qconv(&hq, exp_h, c1, &params.convs[&c1.name], true, None, false, reg);
        let exp1 = params.convs[&c1.name].act_exp;
        let h2 = qconv(&h1.q, exp1, c2, &params.convs[&c2.name], true, Some(&skip_f), false, reg);
        exp_h = params.convs[&c2.name].act_exp;
        hq = h2.q;
        i += if has_proj { 3 } else { 2 };
    }

    // global average pool (dequantized), requant features, integer FC
    let (n, ho, wo, c) = (hq.dim(0), hq.dim(1), hq.dim(2), hq.dim(3));
    let s = 2f32.powi(exp_h);
    let mut feat = vec![0.0f32; n * c];
    {
        let hd = hq.data();
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        feat[b * c + ch] += f32::from(hd[base + ch]);
                    }
                }
            }
        }
        let inv = s / (ho * wo) as f32;
        for v in feat.iter_mut() {
            *v *= inv;
        }
    }
    let fq = Tensor::new(&[n, c], requant(&feat, params.feat_exp)).expect("feat shape");
    let acc = reg.gemm(&fq, &params.fc_wq, &params.fc_packed);
    let ncls = params.fc_b.len();
    let fs = 2f32.powi(params.feat_exp);
    let mut logits = Tensor::<f32>::zeros(&[n, ncls]);
    {
        let ld = logits.data_mut();
        let ad = acc.data();
        for b in 0..n {
            for k in 0..ncls {
                ld[b * ncls + k] =
                    ad[b * ncls + k] as f32 * (params.fc_scale[k] * fs) + params.fc_b[k];
            }
        }
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::TernaryMode;
    use crate::util::SplitMix64;

    fn scheme(s: &str) -> Scheme {
        Scheme::parse(s).unwrap()
    }

    #[test]
    fn test_gemm_i8_reexport_exact() {
        let a = Tensor::new(&[2, 3], vec![1i8, -2, 3, 0, 5, -6]).unwrap();
        let b = Tensor::new(&[3, 2], vec![1i8, 2, 3, 4, 5, 6]).unwrap();
        let c = gemm_i8(&a, &b);
        assert_eq!(c.data(), &[10, 12, -15, -16]);
    }

    #[test]
    fn test_requant_half_even_and_clip() {
        let q = requant(&[0.5, 1.5, 2.5, -0.5, 1000.0, -1000.0], 0);
        assert_eq!(q, vec![0, 2, 2, 0, 127, -127]);
        let q = requant(&[1.0], -2); // 1.0 * 4 = 4
        assert_eq!(q, vec![4]);
    }

    #[test]
    fn test_qconv_1x1_identity() {
        // identity 1x1 ternary conv with unit scales: output == clipped input
        let l = ConvLayer {
            name: "t".into(),
            kh: 1,
            kw: 1,
            cin: 2,
            cout: 2,
            stride: 1,
            pad: 0,
            out_hw: 2,
            residual: false,
            relu: false,
        };
        let p = QConvParams::new(
            Tensor::new(&[1, 1, 2, 2], vec![1i8, 0, 0, 1]).unwrap(),
            vec![1.0; 2],
            vec![1.0; 2],
            vec![0.0; 2],
            0,
            LayerPolicy::new(WeightCodec::Ternary { mode: TernaryMode::Support }, 2).unwrap(),
        );
        assert!(p.packed.ternary.is_some(), "ternary codes must pack");
        let x = Tensor::new(&[1, 2, 2, 2], vec![1i8, -2, 3, -4, 5, -6, 7, -8]).unwrap();
        let out = qconv(&x, 0, &l, &p, false, None, false, &KernelRegistry::auto());
        assert_eq!(out.q.data(), x.data());
    }

    #[test]
    fn test_forward_quant_tiny_net_finite() {
        // build a minimal 1-block net with random ternary weights and run it
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams::synthetic(&net, 11, &scheme("8a2w_n4"));
        params.validate(&net).unwrap();
        let mut rng = SplitMix64::new(11);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let logits = forward_quant(&params, &net, &x);
        assert_eq!(logits.shape(), &[2, 3]);
        assert!(logits.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn test_forward_quant_invariant_under_kernel_choice() {
        let net = crate::model::resnet_mini(8, &[4, 8, 8], 1, 3);
        let params = QModelParams::synthetic(&net, 5, &scheme("8a2w_n4"));
        let mut rng = SplitMix64::new(6);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let want = forward_quant_with(&params, &net, &x, &KernelRegistry::auto());
        for kind in crate::kernels::ALL_KERNELS {
            let reg = KernelRegistry::new(Some(kind), 2);
            let got = forward_quant_with(&params, &net, &x, &reg);
            assert_eq!(got.data(), want.data(), "kernel {kind}");
        }
    }

    #[test]
    fn test_synthetic_packs_expected_encodings() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let tern = QModelParams::synthetic(&net, 1, &scheme("8a2w_n4"));
        assert!(tern.convs.values().all(|p| p.packed.ternary.is_some()));
        assert!(tern.fc_packed.ternary.is_some());
        let i4 = QModelParams::synthetic(&net, 1, &scheme("8a4w_n4"));
        assert!(i4.convs.values().all(|p| p.packed.i4.is_some()));
        let i8m = QModelParams::synthetic(&net, 1, &scheme("8a8w_n4"));
        // full i8 codes fit neither sub-8-bit encoding
        assert!(i8m.convs.values().any(|p| p.packed.ternary.is_none() && p.packed.i4.is_none()));
    }

    #[test]
    fn test_validate_catches_bad_shapes() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params = QModelParams {
            convs: BTreeMap::new(),
            fc_wq: Tensor::<i8>::zeros(&[1, 1]),
            fc_scale: vec![],
            fc_b: vec![],
            in_exp: 0,
            feat_exp: 0,
            scheme: scheme("8a2w_n4"),
            fc_packed: PackedLayer::none(),
        };
        assert!(params.validate(&net).is_err());
    }

    #[test]
    fn test_mixed_scheme_assigns_per_layer_policies() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let s = scheme("8a2w_n4@stem=i8@fc=i8");
        let params = QModelParams::synthetic(&net, 21, &s);
        params.validate(&net).unwrap();
        assert_eq!(params.convs["stem"].policy.codec, WeightCodec::I8);
        for (name, p) in &params.convs {
            if name != "stem" {
                assert_eq!(p.policy.w_bits(), 2, "{name}");
                assert!(p.packed.ternary.is_some(), "{name} must pack ternary");
            }
        }
        assert_eq!(params.scheme.policy_for("fc").codec, WeightCodec::I8);
    }

    #[test]
    fn test_validate_rejects_codes_outside_declared_codec() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        // weights drawn for an 8-bit model, but declared ternary
        let wide = QModelParams::synthetic(&net, 2, &scheme("8a8w_n4"));
        let lied = QModelParams { scheme: scheme("8a2w_n4"), ..wide };
        let err = lied.validate(&net).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }
}
