//! Forward planning: everything the integer forward pass will touch,
//! computed **once** at model load instead of per request.
//!
//! [`ForwardPlan::build_for`] builds the layer DAG ([`crate::graph`]),
//! schedules it deterministically, and lowers the schedule to a flat list
//! of [`ExecStep`]s over planned activation buffers:
//!
//! * every intermediate i8 activation (the quantized input included) gets
//!   a live interval in schedule time, and the graph's liveness planner
//!   ([`crate::graph::liveness`]) packs all of them into **one** `act`
//!   arena by greedy interval coloring — the planned peak replaces the old
//!   hand-sized `xq` + ping-pong `act_a`/`act_b` trio and never exceeds
//!   their high-water sizing on the 2-conv block family;
//! * residual adds are fused into the consuming conv ([`ExecStep::ConvSkip`]);
//!   the block's shortcut is prepared on the single i64 `skip` lane by
//!   [`ExecStep::ConvToSkip`] (projection) or [`ExecStep::IdentitySkip`]
//!   (identity), scheduled before the block chain;
//! * `cols` / `acc` — im2col patch scratch (skipped entirely for 1×1
//!   pointwise convs: the NHWC activation buffer *is* the GEMM operand)
//!   and the i32 accumulator arena, sized to their per-layer maxima;
//! * `sums` / `fq` / `fc_acc` — GAP and FC scratch.
//!
//! A [`ForwardWorkspace`] allocates those buffers once and
//! [`super::forward_quant_into`] interprets the step list through them. In
//! steady state (same batch size, model with load-built caches) a forward
//! pass through a reused workspace performs **zero heap allocations** at
//! any registry thread count — multi-threaded GEMMs dispatch row blocks
//! onto the persistent [`crate::kernels::WorkerPool`] from a
//! stack-resident job record, so there is no per-call spawn left to
//! allocate. Asserted for both a single-threaded and a threaded registry
//! (batched, B=4) by `rust/tests/alloc_steady_state.rs`. Buffers grow
//! monotonically: a larger batch resizes them once and later batches
//! reuse the high-water mark.
//!
//! Unplannable layer tables (dangling tails, shape breaks, misplaced
//! projections) are **typed errors** ([`GraphError`]) naming the offending
//! layer — loaders and CLIs surface them instead of silently degrading to
//! an empty plan.

use crate::graph::{color_intervals, Graph, GraphError, Lifetime, NodeId, Op};
use crate::model::Network;
use crate::telemetry::ForwardProfile;

/// GEMM geometry of one conv layer, for a batch of one image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvDims {
    /// output pixels per image (`ho * wo`) — GEMM M is `n * m`
    pub m: usize,
    /// GEMM depth (`kh * kw * cin`)
    pub k: usize,
    /// output channels (GEMM F)
    pub f: usize,
    /// output spatial size
    pub ho: usize,
    /// output spatial size
    pub wo: usize,
    /// 1×1/stride-1/pad-0: the GEMM reads the activation buffer directly,
    /// no im2col (see [`crate::model::ConvLayer::is_pointwise`])
    pub direct: bool,
    // input geometry + structural role, kept so [`ForwardPlan::matches`]
    // can compare a network against the *stored* schedule without
    // re-walking anything
    kh: usize,
    kw: usize,
    cin: usize,
    stride: usize,
    pad: usize,
    residual: bool,
    proj: bool,
}

/// A planned activation: which tensor (`t`), where it lives in the `act`
/// arena (`off`, elements per image — scale by the batch), its geometry,
/// and which layer's activation exponent governs its codes (`None` = the
/// network input exponent).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TensorRef {
    pub(crate) t: usize,
    pub(crate) off: usize,
    pub(crate) h: usize,
    pub(crate) w: usize,
    pub(crate) c: usize,
    pub(crate) exp_from: Option<usize>,
}

impl TensorRef {
    pub(crate) fn elems(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// One step of the scheduled forward. `layer` / `target` index
/// `net.layers`; residual adds are fused into [`ExecStep::ConvSkip`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecStep {
    /// Plain conv (+BN+ReLU folded into the fused requant epilogue).
    Conv { layer: usize, src: TensorRef, dst: TensorRef },
    /// Conv that adds the prepared i64 skip lane before requantizing —
    /// the residual join, fused.
    ConvSkip { layer: usize, src: TensorRef, dst: TensorRef },
    /// Projection conv whose output lands on the skip lane at the
    /// fraction-bit alignment of consuming layer `target`.
    ConvToSkip { layer: usize, src: TensorRef, target: usize },
    /// Identity shortcut: re-align `src`'s codes onto the skip lane for
    /// consuming layer `target`.
    IdentitySkip { src: TensorRef, target: usize },
    /// Stem max pool (exact on i8 codes: max commutes with the monotone
    /// requantization).
    Pool { k: usize, stride: usize, pad: usize, src: TensorRef, dst: TensorRef },
}

/// The load-time forward plan: per-layer GEMM geometry, the scheduled step
/// list over planned arena offsets, and the per-image high-water size of
/// every scratch buffer. Built by [`ForwardPlan::build`] (called from
/// `QModelParams::rebuild_epilogues` at load); the `Default` plan is empty
/// and matches nothing.
#[derive(Debug, Clone, Default)]
pub struct ForwardPlan {
    /// parallel to `net.layers`
    pub(crate) dims: Vec<ConvDims>,
    /// the scheduled forward, between input quantization and GAP
    pub(crate) steps: Vec<ExecStep>,
    /// where the quantized input lives in the arena
    pub(crate) input: TensorRef,
    /// the activation GAP reads
    pub(crate) final_act: TensorRef,
    /// stem max pool spec `(k, stride, pad)`, if the network has one
    pub(crate) pool: Option<(usize, usize, usize)>,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) in_c: usize,
    // per-image element counts of each workspace buffer
    /// planned activation arena total (interval-colored peak)
    pub(crate) act_elems: usize,
    pub(crate) cols_elems: usize,
    pub(crate) acc_elems: usize,
    pub(crate) skip_elems: usize,
    pub(crate) skip_rows: usize,
    pub(crate) feat_c: usize,
    pub(crate) classes: usize,
}

fn conv_dims(l: &crate::model::ConvLayer, h: usize, w: usize) -> ConvDims {
    let ho = (h + 2 * l.pad - l.kh) / l.stride + 1;
    let wo = (w + 2 * l.pad - l.kw) / l.stride + 1;
    ConvDims {
        m: ho * wo,
        k: l.kh * l.kw * l.cin,
        f: l.cout,
        ho,
        wo,
        direct: l.is_pointwise(),
        kh: l.kh,
        kw: l.kw,
        cin: l.cin,
        stride: l.stride,
        pad: l.pad,
        residual: l.residual,
        proj: l.name.ends_with("proj"),
    }
}

impl ForwardPlan {
    /// Plan for `net` at its nominal input size.
    pub fn build(net: &Network) -> Result<Self, GraphError> {
        Self::build_for(net, net.input_hw, net.input_hw)
    }

    /// Plan for `net` fed `h × w` inputs (the forward pass falls back to
    /// this when an input disagrees with the nominal geometry). Returns a
    /// typed error naming the first unsupported layer for tables the graph
    /// builder cannot express.
    pub fn build_for(net: &Network, in_h: usize, in_w: usize) -> Result<Self, GraphError> {
        let g = Graph::from_network(net, in_h, in_w)?;
        let order = g.schedule();
        let consumers = g.consumers();
        let unsupported = |id: NodeId, detail: String| GraphError::Unsupported {
            net: net.name.clone(),
            node: g.label(net, id),
            detail,
        };

        // residual-join roles: which conv feeds an Add as chain (fused
        // requant-with-skip) and which node produces the lane value
        let n_nodes = g.nodes.len();
        let mut chain_add: Vec<Option<NodeId>> = vec![None; n_nodes];
        let mut lane_add: Vec<Option<NodeId>> = vec![None; n_nodes];
        for node in &g.nodes {
            if let Op::Add = node.op {
                chain_add[node.inputs[0]] = Some(node.id);
                lane_add[node.inputs[1]] = Some(node.id);
            }
        }
        // a fused or lane-feeding node's value must not be observable
        // elsewhere: the pre-add chain output never materializes, and the
        // lane holds exactly one pending value
        for id in 0..n_nodes {
            if (chain_add[id].is_some() || lane_add[id].is_some()) && consumers[id].len() != 1 {
                return Err(unsupported(
                    id,
                    format!(
                        "feeds a residual join but has {} consumers; fused residual \
                         values cannot be read elsewhere",
                        consumers[id].len()
                    ),
                ));
            }
        }
        // the layer index whose activation exponent a lane producer must
        // requantize to: the chain conv of its Add
        let lane_target = |id: NodeId| -> Result<usize, GraphError> {
            let add = lane_add[id].expect("caller checked");
            match g.nodes[g.nodes[add].inputs[0]].op {
                Op::Conv { layer } => Ok(layer),
                _ => Err(unsupported(add, "residual chain input is not a conv".into())),
            }
        };

        // --- lower the schedule to steps, recording tensor lifetimes ---
        struct TInfo {
            life: Lifetime,
            h: usize,
            w: usize,
            c: usize,
            exp_from: Option<usize>,
        }
        let mut tensors: Vec<TInfo> = Vec::new();
        let mut tensor_of: Vec<Option<usize>> = vec![None; n_nodes];
        let mut dims: Vec<Option<ConvDims>> = vec![None; net.layers.len()];
        let mut steps: Vec<ExecStep> = Vec::new();
        let mut lane: Option<usize> = None; // pending skip value's target layer
        let mut final_node: Option<NodeId> = None;

        // placeholder refs; arena offsets are patched in after coloring
        let proto = |tensors: &[TInfo], t: usize| TensorRef {
            t,
            off: usize::MAX,
            h: tensors[t].h,
            w: tensors[t].w,
            c: tensors[t].c,
            exp_from: tensors[t].exp_from,
        };

        for &id in &order {
            let node = &g.nodes[id];
            let t_now = steps.len() + 1; // time 0 = input quantization
            // the source activation most ops read
            let src_t = node.inputs.first().and_then(|&s| tensor_of[s]);
            match node.op {
                Op::Input => {
                    tensor_of[id] = Some(tensors.len());
                    tensors.push(TInfo {
                        life: Lifetime { size: node.out_elems(), start: 0, end: 0 },
                        h: node.out_h,
                        w: node.out_w,
                        c: node.out_c,
                        exp_from: None,
                    });
                }
                Op::Conv { layer } => {
                    let src_t = src_t
                        .ok_or_else(|| unsupported(id, "conv reads a non-tensor value".into()))?;
                    tensors[src_t].life.end = t_now;
                    let src = proto(&tensors, src_t);
                    let d = conv_dims(&net.layers[layer], src.h, src.w);
                    if lane_add[id].is_some() {
                        // projection: lands on the skip lane
                        let target = lane_target(id)?;
                        if let Some(prev) = lane {
                            return Err(unsupported(
                                id,
                                format!(
                                    "skip lane already holds a value for layer '{}'",
                                    net.layers[prev].name
                                ),
                            ));
                        }
                        lane = Some(target);
                        steps.push(ExecStep::ConvToSkip { layer, src, target });
                    } else {
                        let dst_t = tensors.len();
                        tensors.push(TInfo {
                            life: Lifetime { size: node.out_elems(), start: t_now, end: t_now },
                            h: node.out_h,
                            w: node.out_w,
                            c: node.out_c,
                            exp_from: Some(layer),
                        });
                        tensor_of[id] = Some(dst_t);
                        let dst = proto(&tensors, dst_t);
                        if chain_add[id].is_some() {
                            if lane != Some(layer) {
                                return Err(unsupported(
                                    id,
                                    "residual conv scheduled before its skip lane was \
                                     prepared"
                                        .into(),
                                ));
                            }
                            lane = None;
                            steps.push(ExecStep::ConvSkip { layer, src, dst });
                        } else {
                            steps.push(ExecStep::Conv { layer, src, dst });
                        }
                    }
                    dims[layer] = Some(d);
                }
                Op::Skip => {
                    let src_t = src_t
                        .ok_or_else(|| unsupported(id, "skip reads a non-tensor value".into()))?;
                    tensors[src_t].life.end = t_now;
                    let target = lane_target(id)?;
                    if let Some(prev) = lane {
                        return Err(unsupported(
                            id,
                            format!(
                                "skip lane already holds a value for layer '{}'",
                                net.layers[prev].name
                            ),
                        ));
                    }
                    lane = Some(target);
                    steps.push(ExecStep::IdentitySkip { src: proto(&tensors, src_t), target });
                }
                Op::Pool { k, stride, pad } => {
                    let src_t = src_t
                        .ok_or_else(|| unsupported(id, "pool reads a non-tensor value".into()))?;
                    tensors[src_t].life.end = t_now;
                    let src = proto(&tensors, src_t);
                    let src_exp = tensors[src_t].exp_from;
                    let dst_t = tensors.len();
                    tensors.push(TInfo {
                        life: Lifetime { size: node.out_elems(), start: t_now, end: t_now },
                        h: node.out_h,
                        w: node.out_w,
                        c: node.out_c,
                        exp_from: src_exp,
                    });
                    tensor_of[id] = Some(dst_t);
                    let dst = proto(&tensors, dst_t);
                    steps.push(ExecStep::Pool { k, stride, pad, src, dst });
                }
                Op::Add => {
                    // fused into the chain conv: the add's value *is* the
                    // ConvSkip's output tensor
                    tensor_of[id] = tensor_of[node.inputs[0]];
                }
                Op::Gap => {
                    let src_t = src_t
                        .ok_or_else(|| unsupported(id, "gap reads a non-tensor value".into()))?;
                    tensors[src_t].life.end = t_now;
                    final_node = Some(node.inputs[0]);
                    if node.out_c != net.fc_in {
                        return Err(unsupported(
                            id,
                            format!(
                                "final activation has {} channels but fc_in is {}",
                                node.out_c, net.fc_in
                            ),
                        ));
                    }
                }
                Op::Fc => {}
            }
        }
        debug_assert!(lane.is_none(), "a prepared skip value was never consumed");
        let Some(dims) = dims.into_iter().collect::<Option<Vec<_>>>() else {
            unreachable!("graph builder visits every layer exactly once");
        };

        // --- pack tensor lifetimes into the activation arena ---
        let reqs: Vec<Lifetime> = tensors.iter().map(|t| t.life).collect();
        let layout = color_intervals(&reqs);
        let patch = |r: &mut TensorRef| r.off = layout.offsets[r.t];
        let mut input = proto(&tensors, tensor_of[order[0]].expect("input is a tensor"));
        for s in &mut steps {
            match s {
                ExecStep::Conv { src, dst, .. }
                | ExecStep::ConvSkip { src, dst, .. }
                | ExecStep::Pool { src, dst, .. } => {
                    patch(src);
                    patch(dst);
                }
                ExecStep::ConvToSkip { src, .. } | ExecStep::IdentitySkip { src, .. } => {
                    patch(src);
                }
            }
        }
        let final_t = final_node
            .and_then(|n| tensor_of[n])
            .expect("every graph ends in GAP over a tensor");
        let mut final_act = proto(&tensors, final_t);
        patch(&mut input);
        patch(&mut final_act);

        // --- scratch high-water marks ---
        let mut plan = ForwardPlan {
            input,
            final_act,
            pool: net.stem_pool.map(|p| (p.k, p.stride, p.pad)),
            in_h,
            in_w,
            in_c: net.layers[0].cin,
            act_elems: layout.total,
            feat_c: net.fc_in,
            classes: net.fc_out,
            ..ForwardPlan::default()
        };
        for d in &dims {
            plan.acc_elems = plan.acc_elems.max(d.m * d.f);
            if !d.direct {
                plan.cols_elems = plan.cols_elems.max(d.m * d.k);
            }
        }
        for s in &steps {
            if let ExecStep::ConvSkip { layer, .. } = s {
                let d = &dims[*layer];
                plan.skip_elems = plan.skip_elems.max(d.m * d.f);
                plan.skip_rows = plan.skip_rows.max(d.m);
            }
        }
        plan.dims = dims;
        plan.steps = steps;
        Ok(plan)
    }

    /// True when nothing was planned (the `Default` plan of hand-built
    /// params).
    pub fn is_empty(&self) -> bool {
        self.dims.is_empty()
    }

    /// Number of scheduled execution steps (introspection / benches).
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Planned activation-arena elements per image — the interval-colored
    /// peak over all simultaneously-live tensors.
    pub fn planned_act_elems(&self) -> usize {
        self.act_elems
    }

    /// What the pre-liveness sizing would have reserved per image: the
    /// quantized input buffer plus two ping-pong buffers of the largest
    /// layer output. The planned arena never exceeds this on 2-conv block
    /// nets (locked by `tests/plan_liveness.rs`).
    pub fn legacy_act_elems(&self) -> usize {
        if self.dims.is_empty() {
            return 0;
        }
        let max_out = self.dims.iter().map(|d| d.m * d.f).max().unwrap_or(0);
        self.in_h * self.in_w * self.in_c + 2 * max_out
    }

    /// Does this plan describe `net` fed `h × w` inputs? A pure,
    /// allocation-free comparison of the **stored** schedule against the
    /// layer table: per-layer geometry and structural role (residual
    /// terminator / projection), the stem pool spec, and the head. The
    /// graph builder is deterministic in exactly these inputs, so agreeing
    /// here means the stored step list is the one `build_for` would
    /// produce — nothing is re-walked.
    pub fn matches(&self, net: &Network, h: usize, w: usize) -> bool {
        if self.is_empty()
            || self.in_h != h
            || self.in_w != w
            || self.dims.len() != net.layers.len()
            || self.feat_c != net.fc_in
            || self.classes != net.fc_out
            || net.layers.first().map(|l| l.cin).unwrap_or(0) != self.in_c
            || self.pool != net.stem_pool.map(|p| (p.k, p.stride, p.pad))
        {
            return false;
        }
        self.dims.iter().zip(&net.layers).all(|(d, l)| {
            (d.kh, d.kw, d.cin, d.stride, d.pad, d.f, d.residual, d.proj)
                == (
                    l.kh,
                    l.kw,
                    l.cin,
                    l.stride,
                    l.pad,
                    l.cout,
                    l.residual,
                    l.name.ends_with("proj"),
                )
        })
    }
}

/// The reusable forward arena: every buffer `forward_quant_into` writes,
/// allocated once and grown only when a larger batch arrives. One workspace
/// per serving worker (see `coordinator::LpExecutor`); borrow it mutably
/// per request.
#[derive(Debug, Default)]
pub struct ForwardWorkspace {
    /// the single planned activation arena (input + every intermediate,
    /// at interval-colored offsets)
    pub(crate) act: Vec<i8>,
    pub(crate) cols: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) skip: Vec<i64>,
    pub(crate) skip_max: Vec<i64>,
    pub(crate) sums: Vec<i64>,
    pub(crate) fq: Vec<i8>,
    pub(crate) fc_acc: Vec<i32>,
    /// per-forward telemetry slots — preallocated with the arena, filled
    /// by plain stores on the hot path (see `telemetry::ForwardProfile`)
    pub(crate) profile: ForwardProfile,
}

fn grow<T: Clone + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() < len {
        v.resize(len, T::default());
    }
}

/// Borrow a step's source and destination slots out of the `act` arena
/// simultaneously. The liveness planner guarantees the ranges are
/// disjoint; asserted here.
pub(crate) fn split_src_dst<'a>(
    act: &'a mut [i8],
    n: usize,
    src: &TensorRef,
    dst: &TensorRef,
) -> (&'a [i8], &'a mut [i8]) {
    let (s0, s1) = (n * src.off, n * (src.off + src.elems()));
    let (d0, d1) = (n * dst.off, n * (dst.off + dst.elems()));
    if s1 <= d0 {
        let (lo, hi) = act.split_at_mut(d0);
        (&lo[s0..s1], &mut hi[..d1 - d0])
    } else {
        assert!(d1 <= s0, "liveness layout produced overlapping src/dst slots");
        let (lo, hi) = act.split_at_mut(s0);
        (&hi[..s1 - s0], &mut lo[d0..d1])
    }
}

/// A tensor's slot in the arena, immutably.
pub(crate) fn slot<'a>(act: &'a [i8], n: usize, t: &TensorRef) -> &'a [i8] {
    &act[n * t.off..n * (t.off + t.elems())]
}

/// A tensor's slot in the arena, mutably.
pub(crate) fn slot_mut<'a>(act: &'a mut [i8], n: usize, t: &TensorRef) -> &'a mut [i8] {
    &mut act[n * t.off..n * (t.off + t.elems())]
}

impl ForwardWorkspace {
    /// An empty workspace; the first `ensure` sizes it.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to what `plan` needs for an `n`-image batch.
    /// Monotonic: shrinking batches keep the high-water allocation, equal
    /// batches allocate nothing.
    pub fn ensure(&mut self, plan: &ForwardPlan, n: usize) {
        grow(&mut self.act, n * plan.act_elems);
        grow(&mut self.cols, n * plan.cols_elems);
        grow(&mut self.acc, n * plan.acc_elems);
        grow(&mut self.skip, n * plan.skip_elems);
        grow(&mut self.skip_max, n * plan.skip_rows);
        grow(&mut self.sums, n * plan.feat_c);
        grow(&mut self.fq, n * plan.feat_c);
        grow(&mut self.fc_acc, n * plan.classes);
        self.profile.begin(plan.dims.len(), n);
    }

    /// The profile of the most recent forward through this workspace.
    pub fn profile(&self) -> &ForwardProfile {
        &self.profile
    }

    /// Total bytes currently held by the arena (introspection / benches).
    pub fn allocated_bytes(&self) -> usize {
        self.act.len()
            + self.cols.len()
            + self.fq.len()
            + 4 * (self.acc.len() + self.fc_acc.len())
            + 8 * (self.skip.len() + self.skip_max.len() + self.sums.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{bottleneck_mini, resnet50, resnet_mini};

    /// No two simultaneously-live tensors of a plan may overlap in the
    /// arena — the invariant the forward pass's split borrows rely on.
    fn assert_steps_disjoint(plan: &ForwardPlan) {
        // rebuild (ref, live interval) per tensor from the step list
        let mut spans: Vec<(TensorRef, usize, usize)> = Vec::new();
        let mut note = |r: &TensorRef, t: usize| {
            if let Some(e) = spans.iter_mut().find(|(s, _, _)| s.t == r.t) {
                e.1 = e.1.min(t);
                e.2 = e.2.max(t);
            } else {
                spans.push((*r, t, t));
            }
        };
        note(&plan.input, 0);
        for (i, s) in plan.steps.iter().enumerate() {
            let t = i + 1;
            match s {
                ExecStep::Conv { src, dst, .. }
                | ExecStep::ConvSkip { src, dst, .. }
                | ExecStep::Pool { src, dst, .. } => {
                    note(src, t);
                    note(dst, t);
                }
                ExecStep::ConvToSkip { src, .. } | ExecStep::IdentitySkip { src, .. } => {
                    note(src, t)
                }
            }
        }
        note(&plan.final_act, plan.steps.len() + 1);
        for a in 0..spans.len() {
            for b in a + 1..spans.len() {
                let (ra, sa, ea) = &spans[a];
                let (rb, sb, eb) = &spans[b];
                if sa <= eb && sb <= ea {
                    let clash =
                        ra.off < rb.off + rb.elems() && rb.off < ra.off + ra.elems();
                    assert!(!clash, "live tensors {} and {} share arena bytes", ra.t, rb.t);
                }
            }
        }
    }

    #[test]
    fn test_plan_walk_and_sizes_on_resnet_mini() {
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let plan = ForwardPlan::build(&net).unwrap();
        assert!(!plan.is_empty());
        assert_eq!(plan.dims.len(), net.layers.len());
        assert!(plan.matches(&net, 8, 8));
        assert!(!plan.matches(&net, 16, 16));
        // stem: 3x3 s1 p1 on 8x8x3 -> 8x8, k = 27
        assert_eq!((plan.dims[0].m, plan.dims[0].k, plan.dims[0].f), (64, 27, 4));
        assert!(!plan.dims[0].direct);
        // every proj in this family is 1x1 but strided -> never direct
        for (d, l) in plan.dims.iter().zip(&net.layers) {
            assert_eq!(d.direct, l.is_pointwise(), "{}", l.name);
            assert_eq!(d.k, l.kh * l.kw * l.cin, "{}", l.name);
        }
        // the step list covers every layer exactly once
        let mut seen = vec![false; net.layers.len()];
        for s in &plan.steps {
            if let ExecStep::Conv { layer, .. }
            | ExecStep::ConvSkip { layer, .. }
            | ExecStep::ConvToSkip { layer, .. } = s
            {
                assert!(!seen[*layer], "layer {layer} stepped twice");
                seen[*layer] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "steps must cover all layers");
        // buffer high-water marks cover every layer; the planned arena
        // holds at least the largest single tensor
        for d in &plan.dims {
            assert!(plan.acc_elems >= d.m * d.f);
            if !d.direct {
                assert!(plan.cols_elems >= d.m * d.k);
            }
        }
        let max_out = plan.dims.iter().map(|d| d.m * d.f).max().unwrap();
        assert!(plan.act_elems >= max_out);
        assert!(plan.planned_act_elems() <= plan.legacy_act_elems());
        assert_eq!(plan.feat_c, net.fc_in);
        assert_eq!(plan.classes, net.fc_out);
        assert_steps_disjoint(&plan);
    }

    #[test]
    fn test_bottleneck_and_pool_plans_schedule_and_stay_disjoint() {
        for net in
            [bottleneck_mini(16, &[4, 8], 3), bottleneck_mini(8, &[2], 2), resnet50()]
        {
            let plan = ForwardPlan::build(&net).unwrap();
            assert!(plan.matches(&net, net.input_hw, net.input_hw), "{}", net.name);
            assert_eq!(plan.dims.len(), net.layers.len(), "{}", net.name);
            // a pool step right after the stem conv
            assert_eq!(plan.pool, Some((3, 2, 1)), "{}", net.name);
            assert!(
                matches!(plan.steps[1], ExecStep::Pool { k: 3, stride: 2, pad: 1, .. }),
                "{}: {:?}",
                net.name,
                plan.steps[1]
            );
            // every block: lane prepared before its ConvSkip consumes it
            let mut lane_ready = false;
            for s in &plan.steps {
                match s {
                    ExecStep::ConvToSkip { .. } | ExecStep::IdentitySkip { .. } => {
                        assert!(!lane_ready, "{}: lane double-armed", net.name);
                        lane_ready = true;
                    }
                    ExecStep::ConvSkip { .. } => {
                        assert!(lane_ready, "{}: join before lane", net.name);
                        lane_ready = false;
                    }
                    _ => {}
                }
            }
            assert!(!lane_ready, "{}: dangling lane value", net.name);
            assert_steps_disjoint(&plan);
            assert!(plan.planned_act_elems() > 0);
        }
    }

    #[test]
    fn test_workspace_grow_only() {
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let plan = ForwardPlan::build(&net).unwrap();
        let mut ws = ForwardWorkspace::new();
        ws.ensure(&plan, 2);
        let bytes2 = ws.allocated_bytes();
        assert!(bytes2 > 0);
        ws.ensure(&plan, 1); // smaller batch keeps the high-water mark
        assert_eq!(ws.allocated_bytes(), bytes2);
        ws.ensure(&plan, 4);
        assert!(ws.allocated_bytes() > bytes2);
    }

    #[test]
    fn test_plan_build_is_a_typed_error_on_dangling_tail_layer() {
        // a layer the graph walk cannot reach must never be silently
        // skipped: the build fails with an error naming the layer, and
        // loaders surface it instead of producing logits that ignore it
        let mut net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let mut tail = net.layers[1].clone();
        tail.name = "dangling".into();
        net.layers.push(tail);
        let err = ForwardPlan::build(&net).unwrap_err();
        assert!(
            matches!(&err, GraphError::DanglingTail { layer, .. } if layer == "dangling"),
            "{err}"
        );
        assert!(err.to_string().contains("dangling"), "{err}");
    }

    #[test]
    fn test_default_plan_is_empty_and_mismatches() {
        let net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let plan = ForwardPlan::default();
        assert!(plan.is_empty());
        assert!(!plan.matches(&net, 8, 8));
    }

    #[test]
    fn test_matches_compares_stored_schedule_not_names_only() {
        // satellite: matches() must be a pure comparison against what the
        // plan stored — structural edits that change the schedule must
        // flip it even when raw conv geometry stays identical
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let plan = ForwardPlan::build(&net).unwrap();
        assert!(plan.matches(&net, 8, 8));

        // renaming a projection re-routes the walk -> mismatch
        let mut renamed = net.clone();
        let pi = renamed.layers.iter().position(|l| l.name.ends_with("proj")).unwrap();
        renamed.layers[pi].name = "s1b0shortcut".into();
        assert!(!plan.matches(&renamed, 8, 8));

        // flipping a residual terminator changes the block structure
        let mut flipped = net.clone();
        let ci = flipped.layers.iter().position(|l| l.residual).unwrap();
        flipped.layers[ci].residual = false;
        assert!(!plan.matches(&flipped, 8, 8));

        // adding a stem pool changes every downstream tensor -> mismatch
        let mut pooled = net.clone();
        pooled.stem_pool = Some(crate::model::PoolLayer { k: 3, stride: 2, pad: 1 });
        assert!(!plan.matches(&pooled, 8, 8));

        // same structure under a different *non-structural* name matches:
        // the schedule does not depend on chain-layer spelling
        let mut respelled = net.clone();
        respelled.layers[1].name = "renamed_c1".into();
        assert!(plan.matches(&respelled, 8, 8));
    }
}
