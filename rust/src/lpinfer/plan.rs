//! Forward planning: everything the integer forward pass will touch,
//! computed **once** at model load instead of per request.
//!
//! [`ForwardPlan`] walks the [`Network`] a single time (alongside the
//! [`super::EpilogueCache`] build) and records, per conv, the GEMM geometry
//! `(m, k, f)`, the output spatial size, and whether the layer is a
//! 1×1/stride-1/pad-0 conv whose im2col is the identity — plus the maximum
//! per-image size of every scratch buffer any layer needs. A
//! [`ForwardWorkspace`] then allocates those buffers once, and
//! [`super::forward_quant_into`] runs the whole network through them:
//!
//! * `xq` — the quantized input image;
//! * `act_a` / `act_b` — ping-pong i8 activation buffers (a residual block
//!   reads the running activation from one, writes `c1` into the other, and
//!   lands `c2` back in the first — two buffers cover any depth);
//! * `cols` — im2col patch scratch (skipped entirely for pointwise convs:
//!   the NHWC activation buffer *is* the GEMM operand);
//! * `acc` — the i32 accumulator arena the fused GEMMs tile per row block;
//! * `skip` / `skip_max` — the i64 residual lane and its per-row max
//!   magnitudes (the SIMD epilogue's overflow gate reads the maxima instead
//!   of re-scanning the lane);
//! * `sums` / `fq` / `fc_acc` — GAP and FC scratch.
//!
//! In steady state (same batch size, model with load-built caches, a
//! single-threaded registry) a forward pass through a reused workspace
//! performs **zero heap allocations** — asserted by
//! `rust/tests/alloc_steady_state.rs`. Multi-threaded registries reuse the
//! same arenas for all tensor data; only the scoped thread spawns
//! themselves allocate. Buffers grow monotonically: a larger batch resizes
//! them once and later batches reuse the high-water mark.

use crate::model::Network;
use crate::telemetry::ForwardProfile;

/// GEMM geometry of one conv layer, for a batch of one image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvDims {
    /// output pixels per image (`ho * wo`) — GEMM M is `n * m`
    pub m: usize,
    /// GEMM depth (`kh * kw * cin`)
    pub k: usize,
    /// output channels (GEMM F)
    pub f: usize,
    /// output spatial size
    pub ho: usize,
    /// output spatial size
    pub wo: usize,
    /// 1×1/stride-1/pad-0: the GEMM reads the activation buffer directly,
    /// no im2col (see [`crate::model::ConvLayer::is_pointwise`])
    pub direct: bool,
    // input geometry, kept so [`ForwardPlan::matches`] can verify a plan
    // against a network without re-walking allocations
    kh: usize,
    kw: usize,
    cin: usize,
    stride: usize,
    pad: usize,
}

/// One residual block of the forward walk: indices into `net.layers`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockStep {
    pub c1: usize,
    pub c2: usize,
    /// projection conv feeding the residual lane (absent = identity skip)
    pub proj: Option<usize>,
}

/// The load-time forward plan: per-layer GEMM geometry, the residual-block
/// walk, and the per-image high-water size of every workspace buffer.
/// Built by [`ForwardPlan::build`] (called from
/// `QModelParams::rebuild_epilogues` at load); an empty default plan makes
/// the forward pass derive one on the fly (hand-assembled params).
#[derive(Debug, Clone, Default)]
pub struct ForwardPlan {
    /// parallel to `net.layers`
    pub(crate) dims: Vec<ConvDims>,
    /// residual blocks after the stem
    pub(crate) steps: Vec<BlockStep>,
    pub(crate) in_h: usize,
    pub(crate) in_w: usize,
    pub(crate) in_c: usize,
    // per-image element counts of each workspace buffer
    pub(crate) xq_elems: usize,
    pub(crate) act_elems: usize,
    pub(crate) cols_elems: usize,
    pub(crate) acc_elems: usize,
    pub(crate) skip_elems: usize,
    pub(crate) skip_rows: usize,
    pub(crate) feat_c: usize,
    pub(crate) classes: usize,
}

fn conv_dims(l: &crate::model::ConvLayer, h: usize, w: usize) -> ConvDims {
    let ho = (h + 2 * l.pad - l.kh) / l.stride + 1;
    let wo = (w + 2 * l.pad - l.kw) / l.stride + 1;
    ConvDims {
        m: ho * wo,
        k: l.kh * l.kw * l.cin,
        f: l.cout,
        ho,
        wo,
        direct: l.is_pointwise(),
        kh: l.kh,
        kw: l.kw,
        cin: l.cin,
        stride: l.stride,
        pad: l.pad,
    }
}

impl ForwardPlan {
    /// Plan for `net` at its nominal input size.
    pub fn build(net: &Network) -> Self {
        Self::build_for(net, net.input_hw, net.input_hw)
    }

    /// Plan for `net` fed `h × w` inputs (the forward pass falls back to
    /// this when an input disagrees with the nominal geometry).
    pub fn build_for(net: &Network, in_h: usize, in_w: usize) -> Self {
        fn note(plan: &mut ForwardPlan, d: &ConvDims) {
            let out = d.m * d.f;
            plan.act_elems = plan.act_elems.max(out);
            plan.acc_elems = plan.acc_elems.max(out);
            if !d.direct {
                plan.cols_elems = plan.cols_elems.max(d.m * d.k);
            }
        }
        let mut plan = ForwardPlan {
            in_h,
            in_w,
            in_c: net.layers.first().map(|l| l.cin).unwrap_or(0),
            feat_c: net.fc_in,
            classes: net.fc_out,
            ..ForwardPlan::default()
        };
        plan.xq_elems = in_h * in_w * plan.in_c;
        if net.layers.is_empty() {
            return plan;
        }
        let stem = conv_dims(&net.layers[0], in_h, in_w);
        note(&mut plan, &stem);
        let (mut h, mut w) = (stem.ho, stem.wo);
        let mut dims = vec![stem];
        let mut steps = Vec::new();
        let mut i = 1;
        while i + 1 < net.layers.len() {
            let has_proj = net
                .layers
                .get(i + 2)
                .map(|l| l.name.ends_with("proj"))
                .unwrap_or(false);
            let d1 = conv_dims(&net.layers[i], h, w);
            let d2 = conv_dims(&net.layers[i + 1], d1.ho, d1.wo);
            note(&mut plan, &d1);
            note(&mut plan, &d2);
            plan.skip_elems = plan.skip_elems.max(d2.m * d2.f);
            plan.skip_rows = plan.skip_rows.max(d2.m);
            let (next_h, next_w) = (d2.ho, d2.wo);
            let d2_f = d2.f;
            dims.push(d1);
            dims.push(d2);
            if has_proj {
                // the projection reads the *pre-block* activation grid
                let dp = conv_dims(&net.layers[i + 2], h, w);
                debug_assert_eq!(
                    (dp.ho, dp.wo, dp.f),
                    (next_h, next_w, d2_f),
                    "projection grid must match the consuming layer"
                );
                note(&mut plan, &dp);
                dims.push(dp);
                steps.push(BlockStep { c1: i, c2: i + 1, proj: Some(i + 2) });
            } else {
                steps.push(BlockStep { c1: i, c2: i + 1, proj: None });
            }
            (h, w) = (next_h, next_w);
            i += if has_proj { 3 } else { 2 };
        }
        // every layer must be visited exactly once; a net with a dangling
        // unpaired tail layer yields the *empty* plan (same degrade rule as
        // EpilogueCache::build, so Result-returning loaders stay Ok), and
        // the forward pass then fails loudly instead of silently skipping
        // the layer — matching the pre-plan loop, which panicked there
        if dims.len() != net.layers.len() {
            return ForwardPlan::default();
        }
        plan.dims = dims;
        plan.steps = steps;
        plan
    }

    /// True when nothing was planned (default plan of hand-built params).
    pub fn is_empty(&self) -> bool {
        self.dims.is_empty()
    }

    /// Does this plan describe `net` fed `h × w` inputs? A pure, allocation-
    /// free comparison: per-layer geometry and the residual-block walk must
    /// both agree.
    pub fn matches(&self, net: &Network, h: usize, w: usize) -> bool {
        if self.in_h != h
            || self.in_w != w
            || self.dims.len() != net.layers.len()
            || self.feat_c != net.fc_in
            || self.classes != net.fc_out
            || net.layers.first().map(|l| l.cin).unwrap_or(0) != self.in_c
        {
            return false;
        }
        for (d, l) in self.dims.iter().zip(&net.layers) {
            if (d.kh, d.kw, d.cin, d.stride, d.pad, d.f)
                != (l.kh, l.kw, l.cin, l.stride, l.pad, l.cout)
            {
                return false;
            }
        }
        // the block walk is keyed on layer *names* (proj detection), which
        // the geometry check above cannot see
        let mut i = 1;
        let mut s = 0;
        while i + 1 < net.layers.len() {
            let has_proj = net
                .layers
                .get(i + 2)
                .map(|l| l.name.ends_with("proj"))
                .unwrap_or(false);
            let Some(step) = self.steps.get(s) else {
                return false;
            };
            let want_proj = if has_proj { Some(i + 2) } else { None };
            if step.c1 != i || step.c2 != i + 1 || step.proj != want_proj {
                return false;
            }
            s += 1;
            i += if has_proj { 3 } else { 2 };
        }
        s == self.steps.len()
    }
}

/// The reusable forward arena: every buffer `forward_quant_into` writes,
/// allocated once and grown only when a larger batch arrives. One workspace
/// per serving worker (see `coordinator::LpExecutor`); borrow it mutably
/// per request.
#[derive(Debug, Default)]
pub struct ForwardWorkspace {
    pub(crate) xq: Vec<i8>,
    pub(crate) act_a: Vec<i8>,
    pub(crate) act_b: Vec<i8>,
    pub(crate) cols: Vec<i8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) skip: Vec<i64>,
    pub(crate) skip_max: Vec<i64>,
    pub(crate) sums: Vec<i64>,
    pub(crate) fq: Vec<i8>,
    pub(crate) fc_acc: Vec<i32>,
    /// per-forward telemetry slots — preallocated with the arena, filled
    /// by plain stores on the hot path (see `telemetry::ForwardProfile`)
    pub(crate) profile: ForwardProfile,
}

fn grow<T: Clone + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() < len {
        v.resize(len, T::default());
    }
}

impl ForwardWorkspace {
    /// An empty workspace; the first `ensure` sizes it.
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow every buffer to what `plan` needs for an `n`-image batch.
    /// Monotonic: shrinking batches keep the high-water allocation, equal
    /// batches allocate nothing.
    pub fn ensure(&mut self, plan: &ForwardPlan, n: usize) {
        grow(&mut self.xq, n * plan.xq_elems);
        grow(&mut self.act_a, n * plan.act_elems);
        grow(&mut self.act_b, n * plan.act_elems);
        grow(&mut self.cols, n * plan.cols_elems);
        grow(&mut self.acc, n * plan.acc_elems);
        grow(&mut self.skip, n * plan.skip_elems);
        grow(&mut self.skip_max, n * plan.skip_rows);
        grow(&mut self.sums, n * plan.feat_c);
        grow(&mut self.fq, n * plan.feat_c);
        grow(&mut self.fc_acc, n * plan.classes);
        self.profile.begin(plan.dims.len(), n);
    }

    /// The profile of the most recent forward through this workspace.
    pub fn profile(&self) -> &ForwardProfile {
        &self.profile
    }

    /// Total bytes currently held by the arena (introspection / benches).
    pub fn allocated_bytes(&self) -> usize {
        self.xq.len()
            + self.act_a.len()
            + self.act_b.len()
            + self.cols.len()
            + self.fq.len()
            + 4 * (self.acc.len() + self.fc_acc.len())
            + 8 * (self.skip.len() + self.skip_max.len() + self.sums.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet_mini;

    #[test]
    fn test_plan_walk_and_sizes_on_resnet_mini() {
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let plan = ForwardPlan::build(&net);
        assert!(!plan.is_empty());
        assert_eq!(plan.dims.len(), net.layers.len());
        assert!(plan.matches(&net, 8, 8));
        assert!(!plan.matches(&net, 16, 16));
        // stem: 3x3 s1 p1 on 8x8x3 -> 8x8, k = 27
        assert_eq!((plan.dims[0].m, plan.dims[0].k, plan.dims[0].f), (64, 27, 4));
        assert!(!plan.dims[0].direct);
        // every proj in this family is 1x1 but strided -> never direct
        for (d, l) in plan.dims.iter().zip(&net.layers) {
            assert_eq!(d.direct, l.is_pointwise(), "{}", l.name);
            assert_eq!(d.k, l.kh * l.kw * l.cin, "{}", l.name);
        }
        // block walk covers every non-stem layer exactly once
        let mut seen = vec![false; net.layers.len()];
        seen[0] = true;
        for s in &plan.steps {
            for idx in [Some(s.c1), Some(s.c2), s.proj].into_iter().flatten() {
                assert!(!seen[idx], "layer {idx} visited twice");
                seen[idx] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "walk must cover all layers");
        // buffer highwater marks cover every layer
        for d in &plan.dims {
            assert!(plan.act_elems >= d.m * d.f);
            assert!(plan.acc_elems >= d.m * d.f);
            if !d.direct {
                assert!(plan.cols_elems >= d.m * d.k);
            }
        }
        assert_eq!(plan.feat_c, net.fc_in);
        assert_eq!(plan.classes, net.fc_out);
    }

    #[test]
    fn test_workspace_grow_only() {
        let net = resnet_mini(8, &[4, 8, 8], 1, 3);
        let plan = ForwardPlan::build(&net);
        let mut ws = ForwardWorkspace::new();
        ws.ensure(&plan, 2);
        let bytes2 = ws.allocated_bytes();
        assert!(bytes2 > 0);
        ws.ensure(&plan, 1); // smaller batch keeps the high-water mark
        assert_eq!(ws.allocated_bytes(), bytes2);
        ws.ensure(&plan, 4);
        assert!(ws.allocated_bytes() > bytes2);
    }

    #[test]
    fn test_plan_build_degrades_to_empty_on_dangling_tail_layer() {
        // a layer the block walk cannot reach must never be silently
        // skipped: the build degrades to the empty plan (loaders stay Ok)
        // and the forward pass then refuses to run (loud assert), instead
        // of producing logits that ignore the layer
        let mut net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let mut tail = net.layers[1].clone();
        tail.name = "dangling".into();
        net.layers.push(tail);
        let plan = ForwardPlan::build(&net);
        assert!(plan.is_empty(), "unwalkable net must yield the empty plan");
        assert!(!plan.matches(&net, 8, 8));
    }

    #[test]
    fn test_default_plan_is_empty_and_mismatches() {
        let net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let plan = ForwardPlan::default();
        assert!(plan.is_empty());
        assert!(!plan.matches(&net, 8, 8));
    }
}
