//! Minimal JSON parser + serializer (serde is not available offline).
//!
//! Supports the full JSON data model; numbers are f64 (adequate for config
//! and results interchange). Parsing is recursive-descent with a depth cap.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path lookup: `j.path(&["variants", "fp32", "files"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ----------------------------------------------------------- serializer

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// -------------------------------------------------------------------- parser

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> anyhow::Error {
        anyhow::anyhow!("JSON parse error at byte {}: {}", self.pos, msg)
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() != Some(c) {
            bail!(self.err(&format!("expected '{}'", c as char)));
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut arr = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    arr.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b']') => break,
                        _ => bail!(self.err("expected ',' or ']'")),
                    }
                }
                Ok(Json::Arr(arr))
            }
            Some(b'{') => {
                self.pos += 1;
                let mut obj = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    obj.insert(key, self.value(depth + 1)?);
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b'}') => break,
                        _ => bail!(self.err("expected ',' or '}'")),
                    }
                }
                Ok(Json::Obj(obj))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => bail!(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => bail!(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-decode multibyte utf-8 in place
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + width;
                    let chunk = self.s.get(start..start + width).ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Json> {
    let mut p = Parser { s: s.as_bytes(), pos: 0 };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.s.len() {
        bail!(p.err("trailing garbage"));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn test_parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn test_roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"he\"llo\n","t":true}}"#;
        let j = parse(src).unwrap();
        let round = parse(&j.to_string()).unwrap();
        assert_eq!(j, round);
        let pretty = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn test_unicode_and_escapes() {
        let j = parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
        let round = parse(&j.to_string()).unwrap();
        assert_eq!(j, round);
    }

    #[test]
    fn test_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"open").is_err());
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn test_integer_formatting() {
        assert_eq!(Json::num(32.0).to_string(), "32");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }

    #[test]
    fn test_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }
}
