//! ShapeSet — Rust mirror of the procedural dataset generator
//! (`python/compile/data.py`). The PRNG stream (SplitMix64 + Box-Muller)
//! is bit-exact; prototype textures use the same formulas evaluated in f64
//! then cast, so images match the python export to ~1e-5 (the integration
//! test checks against `artifacts/eval_data.dft`). Used by the serving
//! load generator and the end-to-end examples.

use crate::tensor::Tensor;
use crate::util::SplitMix64;

pub const IMG: usize = 24;
pub const CH: usize = 3;
pub const CLASSES: usize = 10;
pub const DEFAULT_NOISE: f32 = 1.0;

fn class_texture(cls: usize, xx: &[f64], yy: &[f64]) -> Vec<f64> {
    // (IMG, IMG, CH) row-major
    let mut out = vec![0.0f64; IMG * IMG * CH];
    for c in 0..CH {
        let fx = 1.0 + ((cls * 3 + c * 5) % 7) as f64 * 0.5;
        let fy = 1.0 + ((cls * 5 + c * 3) % 5) as f64 * 0.7;
        let ph = (cls as f64 * 1.7 + c as f64 * 0.9) % (2.0 * std::f64::consts::PI);
        for i in 0..IMG {
            for j in 0..IMG {
                let v = (fx * xx[i * IMG + j] + ph).sin() * (fy * yy[i * IMG + j] - ph).cos();
                out[(i * IMG + j) * CH + c] = v;
            }
        }
    }
    out
}

fn class_mask(cls: usize, xx: &[f64], yy: &[f64]) -> Vec<f64> {
    let k = cls / 5;
    (0..IMG * IMG)
        .map(|i| {
            let (x, y) = (xx[i], yy[i]);
            let r2 = x * x + y * y;
            let m = match cls % 5 {
                0 => r2 < (1.0 + 0.2 * k as f64).powi(2),
                1 => r2 > 0.8 && r2 < 2.2 + 0.4 * k as f64,
                2 => y.abs() < 0.5 + 0.2 * k as f64,
                3 => ((x * (1.5 + k as f64)).floor() + (y * 1.5).floor()).rem_euclid(2.0) == 0.0,
                _ => x > 0.0 && y.abs() < x * (0.8 + 0.3 * k as f64),
            };
            if m {
                1.0
            } else {
                0.0
            }
        })
        .collect()
}

/// All class prototypes, (CLASSES, IMG, IMG, CH) in [-1, 1].
pub fn prototypes() -> Vec<Vec<f32>> {
    // linspace(-pi, pi, IMG), meshgrid(indexing="ij"): yy varies over rows,
    // xx over... python uses meshgrid(lin, lin, indexing="ij") -> (yy, xx)
    // with yy[i,j] = lin[i], xx[i,j] = lin[j].
    let lin: Vec<f64> = (0..IMG)
        .map(|i| -std::f64::consts::PI + 2.0 * std::f64::consts::PI * i as f64 / (IMG - 1) as f64)
        .collect();
    let mut yy = vec![0.0f64; IMG * IMG];
    let mut xx = vec![0.0f64; IMG * IMG];
    for i in 0..IMG {
        for j in 0..IMG {
            yy[i * IMG + j] = lin[i];
            xx[i * IMG + j] = lin[j];
        }
    }
    (0..CLASSES)
        .map(|cls| {
            let tex = class_texture(cls, &xx, &yy);
            let mask = class_mask(cls, &xx, &yy);
            (0..IMG * IMG * CH)
                .map(|i| (tex[i] * (0.4 + 0.6 * mask[i / CH])) as f32)
                .collect()
        })
        .collect()
}

/// Deterministic (image, label) sample — same stream as python `sample()`.
pub fn sample(protos: &[Vec<f32>], seed: u64, index: u64, noise: f32) -> (Tensor<f32>, usize) {
    let mut rng = SplitMix64::for_sample(seed, index);
    let label = rng.next_below(CLASSES as u64) as usize;
    let proto = &protos[label];
    let dx = rng.next_below(9) as isize - 4;
    let dy = rng.next_below(9) as isize - 4;
    // np.roll over (rows, cols) by (dy, dx)
    let mut img = vec![0.0f32; IMG * IMG * CH];
    for i in 0..IMG {
        let si = (i as isize - dy).rem_euclid(IMG as isize) as usize;
        for j in 0..IMG {
            let sj = (j as isize - dx).rem_euclid(IMG as isize) as usize;
            for c in 0..CH {
                img[(i * IMG + j) * CH + c] = proto[(si * IMG + sj) * CH + c];
            }
        }
    }
    if rng.next_below(2) == 1 {
        // horizontal flip (reverse column order)
        for i in 0..IMG {
            for j in 0..IMG / 2 {
                for c in 0..CH {
                    let a = (i * IMG + j) * CH + c;
                    let b = (i * IMG + (IMG - 1 - j)) * CH + c;
                    img.swap(a, b);
                }
            }
        }
    }
    let bright = 0.8 + 0.4 * rng.next_f32();
    for v in img.iter_mut() {
        *v *= bright;
    }
    if noise > 0.0 {
        let g = rng.normal(IMG * IMG * CH);
        for (v, n) in img.iter_mut().zip(g) {
            *v += noise * n;
        }
    }
    (Tensor::new(&[IMG, IMG, CH], img).expect("image shape"), label)
}

/// Batch generation: (images (n,IMG,IMG,CH), labels).
pub fn make_split(n: usize, seed: u64, noise: f32) -> (Tensor<f32>, Vec<usize>) {
    let protos = prototypes();
    let mut xs = Tensor::<f32>::zeros(&[n, IMG, IMG, CH]);
    let mut ys = Vec::with_capacity(n);
    let stride = IMG * IMG * CH;
    for i in 0..n {
        let (img, label) = sample(&protos, seed, i as u64, noise);
        xs.data_mut()[i * stride..(i + 1) * stride].copy_from_slice(img.data());
        ys.push(label);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_deterministic() {
        let protos = prototypes();
        let (a, la) = sample(&protos, 7, 13, DEFAULT_NOISE);
        let (b, lb) = sample(&protos, 7, 13, DEFAULT_NOISE);
        assert_eq!(la, lb);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn test_varies_with_index() {
        let protos = prototypes();
        let (a, _) = sample(&protos, 7, 13, DEFAULT_NOISE);
        let (b, _) = sample(&protos, 7, 14, DEFAULT_NOISE);
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn test_labels_roughly_balanced() {
        let (_, ys) = make_split(500, 0, 0.0);
        let mut counts = [0usize; CLASSES];
        for &y in &ys {
            counts[y] += 1;
        }
        assert!(counts.iter().all(|&c| c > 20), "{counts:?}");
    }

    #[test]
    fn test_clean_sample_bounded() {
        let protos = prototypes();
        let (img, _) = sample(&protos, 1, 2, 0.0);
        assert!(img.max_abs() <= 1.2 * 1.3);
    }

    #[test]
    fn test_prototypes_in_range() {
        for p in prototypes() {
            assert!(p.iter().all(|v| v.abs() <= 1.0 + 1e-6));
        }
    }
}
