//! Serving metrics: per-stage latency summaries + counters, shared between
//! the coordinator threads via a mutex (contention is negligible next to
//! model execution). Besides throughput/latency, the resilience layer
//! tallies its overload state machine here: shed admissions, deadline
//! misses, degraded serves, caught worker panics and quarantined
//! executors — so a saturation sweep can distinguish "slow" from
//! "shedding".

use std::sync::{Mutex, MutexGuard};

use crate::telemetry::{self, EngineSnapshot};
use crate::util::Summary;

/// Snapshot of the metrics at a point in time.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rejected: u64,
    /// admissions shed past the hard overload watermark (typed
    /// `ServeError::Overloaded` replies, not queue-full rejections)
    pub shed: u64,
    /// requests answered `DeadlineExceeded` instead of being executed
    pub deadline_missed: u64,
    /// responses served at a cheaper precision class than requested
    pub degraded: u64,
    /// executor panics caught and converted to `ExecutorFailed` replies
    pub worker_panics: u64,
    /// executors quarantined after consecutive panics
    pub quarantined: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub occupied_slots: u64,
    pub queue_us_p50: f64,
    pub queue_us_p99: f64,
    pub exec_us_p50: f64,
    pub exec_us_p99: f64,
    pub e2e_us_p50: f64,
    pub e2e_us_p95: f64,
    pub e2e_us_p99: f64,
    pub e2e_us_mean: f64,
    /// engine-level counters (global [`telemetry::engine`] image taken with
    /// this snapshot — forwards, kernel dispatch mix, skip/SIMD rates)
    pub engine: EngineSnapshot,
}

impl MetricsSnapshot {
    /// Mean batch occupancy (occupied / (occupied + padding)).
    pub fn occupancy(&self) -> f64 {
        let total = self.occupied_slots + self.padded_slots;
        if total == 0 {
            return 0.0;
        }
        self.occupied_slots as f64 / total as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} rejected={} batches={} occupancy={:.1}%\n\
             shed={} deadline_missed={} degraded={} worker_panics={} quarantined={}\n\
             queue  p50={:.0}us p99={:.0}us\n\
             exec   p50={:.0}us p99={:.0}us\n\
             e2e    mean={:.0}us p50={:.0}us p95={:.0}us p99={:.0}us\n\
             {}",
            self.requests,
            self.rejected,
            self.batches,
            100.0 * self.occupancy(),
            self.shed,
            self.deadline_missed,
            self.degraded,
            self.worker_panics,
            self.quarantined,
            self.queue_us_p50,
            self.queue_us_p99,
            self.exec_us_p50,
            self.exec_us_p99,
            self.e2e_us_mean,
            self.e2e_us_p50,
            self.e2e_us_p95,
            self.e2e_us_p99,
            self.engine.report(),
        )
    }
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    rejected: u64,
    shed: u64,
    deadline_missed: u64,
    degraded: u64,
    worker_panics: u64,
    quarantined: u64,
    batches: u64,
    padded_slots: u64,
    occupied_slots: u64,
    queue_us: Summary,
    exec_us: Summary,
    e2e_us: Summary,
}

/// Thread-safe metrics collector.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the inner state, recovering from poisoning: a worker that
    /// panicked elsewhere must never take serving metrics down with it.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn on_submit(&self) {
        self.lock().requests += 1;
    }

    pub fn on_reject(&self) {
        self.lock().rejected += 1;
    }

    pub fn on_shed(&self) {
        self.lock().shed += 1;
    }

    pub fn on_deadline_miss(&self) {
        self.lock().deadline_missed += 1;
    }

    pub fn on_degraded(&self) {
        self.lock().degraded += 1;
    }

    pub fn on_worker_panic(&self) {
        self.lock().worker_panics += 1;
    }

    pub fn on_quarantine(&self) {
        self.lock().quarantined += 1;
    }

    pub fn on_batch(&self, occupied: usize, padded: usize, exec_us: f64) {
        let mut m = self.lock();
        m.batches += 1;
        m.occupied_slots += occupied as u64;
        m.padded_slots += padded as u64;
        m.exec_us.add(exec_us);
    }

    pub fn on_response(&self, queue_us: f64, e2e_us: f64) {
        let mut m = self.lock();
        m.queue_us.add(queue_us);
        m.e2e_us.add(e2e_us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut m = self.lock();
        MetricsSnapshot {
            requests: m.requests,
            rejected: m.rejected,
            shed: m.shed,
            deadline_missed: m.deadline_missed,
            degraded: m.degraded,
            worker_panics: m.worker_panics,
            quarantined: m.quarantined,
            batches: m.batches,
            padded_slots: m.padded_slots,
            occupied_slots: m.occupied_slots,
            queue_us_p50: m.queue_us.percentile(50.0),
            queue_us_p99: m.queue_us.percentile(99.0),
            exec_us_p50: m.exec_us.percentile(50.0),
            exec_us_p99: m.exec_us.percentile(99.0),
            e2e_us_p50: m.e2e_us.percentile(50.0),
            e2e_us_p95: m.e2e_us.percentile(95.0),
            e2e_us_p99: m.e2e_us.percentile(99.0),
            e2e_us_mean: m.e2e_us.mean(),
            engine: telemetry::engine().snapshot(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_counters_and_occupancy() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_batch(6, 2, 100.0);
        m.on_response(10.0, 150.0);
        m.on_response(30.0, 250.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 1);
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
        assert!(s.e2e_us_p99 >= s.e2e_us_p50);
        assert!(s.report().contains("occupancy=75.0%"));
    }

    #[test]
    fn test_resilience_counters() {
        let m = Metrics::new();
        m.on_shed();
        m.on_shed();
        m.on_deadline_miss();
        m.on_degraded();
        m.on_degraded();
        m.on_degraded();
        m.on_worker_panic();
        m.on_quarantine();
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.deadline_missed, 1);
        assert_eq!(s.degraded, 3);
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.quarantined, 1);
        let r = s.report();
        assert!(r.contains("shed=2"), "{r}");
        assert!(r.contains("deadline_missed=1"), "{r}");
        assert!(r.contains("degraded=3"), "{r}");
        assert!(r.contains("worker_panics=1"), "{r}");
        assert!(r.contains("quarantined=1"), "{r}");
    }

    #[test]
    fn test_empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.occupancy(), 0.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.shed, 0);
        assert_eq!(s.deadline_missed, 0);
    }

    #[test]
    fn test_report_carries_engine_section() {
        // the engine image rides along with every snapshot (global counters,
        // so only the presence of the section is asserted here)
        let s = Metrics::new().snapshot();
        assert!(s.report().contains("engine forwards="), "{}", s.report());
    }

    #[test]
    fn test_thread_safety() {
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        m.on_submit();
                        m.on_response(1.0, 2.0);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().requests, 400);
    }
}
