//! L3 coordinator — the serving system around the paper's quantized models:
//! precision-class routing (§3.3's accuracy/perf trade-off as policy),
//! deadline-bounded dynamic batching onto fixed-batch AOT artifacts,
//! a worker pool over PJRT executables, bounded-queue backpressure and
//! per-stage latency metrics. Python is never on this path.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod router;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

pub use batcher::BatchPolicy;
pub use executor::{Executor, ExecutorFactory, LpExecutor, MockExecutor, PjrtExecutor};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{PrecisionClass, Router};

use crate::tensor::Tensor;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// admission-control bound on in-flight requests (backpressure)
    pub max_queue: usize,
    /// dynamic-batching deadline for the oldest queued request
    pub max_wait_us: u64,
    /// dispatcher poll tick
    pub tick_us: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { max_queue: 1024, max_wait_us: 2_000, tick_us: 200 }
    }
}

/// An inference request.
pub struct Request {
    /// (img, img, 3) f32 image
    pub image: Tensor<f32>,
    pub class: PrecisionClass,
}

/// An inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub predicted: usize,
    pub variant: String,
    pub batch: usize,
    pub queue_us: f64,
    pub e2e_us: f64,
}

struct Pending {
    image: Tensor<f32>,
    reply: Sender<Response>,
    submitted: Instant,
}

struct BatchJob {
    variant: String,
    artifact_batch: usize,
    reqs: Vec<Pending>,
}

enum WorkerMsg {
    Job(BatchJob),
    Stop,
}

/// The running coordinator (owns dispatcher + worker threads).
pub struct Coordinator {
    submit_tx: SyncSender<(Request, Sender<Response>)>,
    metrics: Arc<Metrics>,
    router: Router,
    stopping: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    img: usize,
}

/// Error returned when the admission queue is full.
#[derive(Debug)]
pub struct Busy;

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "coordinator queue full (backpressure)")
    }
}

impl std::error::Error for Busy {}

impl Coordinator {
    /// Start with one executor factory per worker thread. PJRT state is not
    /// `Send`, so each worker *constructs* its executor on its own thread;
    /// the factory (config + paths) is what crosses the thread boundary.
    ///
    /// `sizes` maps each routable variant to its available artifact batch
    /// sizes (from the manifest); `img` is the expected input side length.
    pub fn start(
        factories: Vec<ExecutorFactory>,
        router: Router,
        sizes: &BTreeMap<String, Vec<usize>>,
        img: usize,
        cfg: CoordinatorConfig,
    ) -> Result<Self> {
        if factories.is_empty() {
            bail!("need at least one executor factory");
        }

        // per-variant batch policies from the manifest's artifact set
        let mut policies: BTreeMap<String, BatchPolicy> = BTreeMap::new();
        for v in router.active_variants() {
            let s = sizes.get(v).cloned().unwrap_or_default();
            if s.is_empty() {
                bail!("variant '{v}' has no artifacts");
            }
            policies.insert(v.to_string(), BatchPolicy::new(s, cfg.max_wait_us));
        }

        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let (submit_tx, submit_rx) = mpsc::sync_channel::<(Request, Sender<Response>)>(cfg.max_queue);
        let (job_tx, job_rx) = mpsc::channel::<WorkerMsg>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();

        let mut threads = Vec::new();

        // ---- worker pool --------------------------------------------------
        let n_workers = factories.len();
        for (wid, factory) in factories.into_iter().enumerate() {
            let job_rx = Arc::clone(&job_rx);
            let metrics = Arc::clone(&metrics);
            let init_tx = init_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("dfp-worker-{wid}"))
                    .spawn(move || {
                        let mut exec = match factory() {
                            Ok(e) => {
                                let _ = init_tx.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = init_tx.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&mut *exec, &job_rx, &metrics);
                    })
                    .context("spawning worker")?,
            );
        }
        drop(init_tx);
        for _ in 0..n_workers {
            init_rx
                .recv()
                .context("worker init channel closed")?
                .context("worker executor init failed")?;
        }

        // ---- dispatcher ---------------------------------------------------
        {
            let router = router.clone();
            let metrics = Arc::clone(&metrics);
            let stopping = Arc::clone(&stopping);
            let tick = Duration::from_micros(cfg.tick_us);
            threads.push(
                std::thread::Builder::new()
                    .name("dfp-dispatcher".into())
                    .spawn(move || {
                        dispatcher_loop(
                            &submit_rx, &job_tx, &router, &policies, &metrics, &stopping, tick,
                            n_workers,
                        );
                    })
                    .context("spawning dispatcher")?,
            );
        }

        Ok(Self { submit_tx, metrics, router, stopping, threads, img })
    }

    /// Submit a request; returns a channel that will receive the response.
    /// Fails fast with [`Busy`] when the admission queue is full.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>> {
        if req.image.shape() != [self.img, self.img, 3] {
            bail!("image shape {:?} != ({i}, {i}, 3)", req.image.shape(), i = self.img);
        }
        let (tx, rx) = mpsc::channel();
        self.metrics.on_submit();
        match self.submit_tx.try_send((req, tx)) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.on_reject();
                Err(Busy.into())
            }
            Err(TrySendError::Disconnected(_)) => bail!("coordinator stopped"),
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, image: Tensor<f32>, class: PrecisionClass) -> Result<Response> {
        let rx = self.submit(Request { image, class })?;
        rx.recv().context("coordinator dropped request")
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    submit_rx: &Receiver<(Request, Sender<Response>)>,
    job_tx: &Sender<WorkerMsg>,
    router: &Router,
    policies: &BTreeMap<String, BatchPolicy>,
    _metrics: &Metrics,
    stopping: &AtomicBool,
    tick: Duration,
    n_workers: usize,
) {
    let mut queues: BTreeMap<String, Vec<Pending>> = BTreeMap::new();
    loop {
        // admit up to the tick deadline
        match submit_rx.recv_timeout(tick) {
            Ok((req, reply)) => {
                let variant = router.route(req.class).to_string();
                queues.entry(variant).or_default().push(Pending {
                    image: req.image,
                    reply,
                    submitted: Instant::now(),
                });
                // keep draining whatever is immediately available
                while let Ok((req, reply)) = submit_rx.try_recv() {
                    let variant = router.route(req.class).to_string();
                    queues.entry(variant).or_default().push(Pending {
                        image: req.image,
                        reply,
                        submitted: Instant::now(),
                    });
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }

        // flush per-variant queues per policy
        for (variant, q) in queues.iter_mut() {
            let policy = &policies[variant];
            loop {
                let oldest_us = q
                    .first()
                    .map(|p| p.submitted.elapsed().as_micros() as u64)
                    .unwrap_or(0);
                let Some(bsz) = policy.plan(q.len(), oldest_us) else { break };
                let take = q.len().min(bsz);
                let reqs: Vec<Pending> = q.drain(..take).collect();
                let _ = job_tx.send(WorkerMsg::Job(BatchJob {
                    variant: variant.clone(),
                    artifact_batch: bsz,
                    reqs,
                }));
            }
        }

        if stopping.load(Ordering::SeqCst) {
            // flush leftovers at their best-fit batch, then stop workers
            for (variant, q) in queues.iter_mut() {
                if q.is_empty() {
                    continue;
                }
                let policy = &policies[variant];
                while !q.is_empty() {
                    let bsz = policy.best_fit(q.len());
                    let take = q.len().min(bsz);
                    let reqs: Vec<Pending> = q.drain(..take).collect();
                    let _ = job_tx.send(WorkerMsg::Job(BatchJob {
                        variant: variant.clone(),
                        artifact_batch: bsz,
                        reqs,
                    }));
                }
            }
            for _ in 0..n_workers {
                let _ = job_tx.send(WorkerMsg::Stop);
            }
            break;
        }
    }
}

fn worker_loop(
    exec: &mut dyn Executor,
    job_rx: &Arc<Mutex<Receiver<WorkerMsg>>>,
    metrics: &Metrics,
) {
    let img = exec.img();
    let classes = exec.classes();
    let px = img * img * 3;
    // per-worker logits arena: grows to the largest artifact batch seen,
    // then every further batch runs the executor allocation-free
    let mut logits: Vec<f32> = Vec::new();
    loop {
        let msg = {
            let rx = job_rx.lock().unwrap();
            rx.recv()
        };
        let job = match msg {
            Ok(WorkerMsg::Job(j)) => j,
            Ok(WorkerMsg::Stop) | Err(_) => break,
        };
        let occupied = job.reqs.len();
        let padded = job.artifact_batch - occupied;
        // assemble the (possibly padded) input batch
        let mut x = Tensor::<f32>::zeros(&[job.artifact_batch, img, img, 3]);
        for (i, p) in job.reqs.iter().enumerate() {
            x.data_mut()[i * px..(i + 1) * px].copy_from_slice(p.image.data());
        }
        let want = job.artifact_batch * classes;
        if logits.len() < want {
            logits.resize(want, 0.0);
        }
        let t_exec = Instant::now();
        let result = exec.run_batch_into(&job.variant, job.artifact_batch, &x, &mut logits[..want]);
        let exec_us = t_exec.elapsed().as_micros() as f64;
        metrics.on_batch(occupied, padded, exec_us);
        match result {
            Ok(()) => {
                for (i, p) in job.reqs.into_iter().enumerate() {
                    let row = &logits[i * classes..(i + 1) * classes];
                    let predicted = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    let e2e_us = p.submitted.elapsed().as_micros() as f64;
                    let queue_us = e2e_us - exec_us;
                    metrics.on_response(queue_us.max(0.0), e2e_us);
                    let _ = p.reply.send(Response {
                        logits: row.to_vec(),
                        predicted,
                        variant: job.variant.clone(),
                        batch: job.artifact_batch,
                        queue_us: queue_us.max(0.0),
                        e2e_us,
                    });
                }
            }
            Err(_) => {
                // drop the reply senders: clients see a disconnected channel
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    const MANIFEST: &str = r#"{
      "img": 8, "classes": 4, "batch_sizes": [1, 4],
      "variants": {
        "fp32":    {"files": {"1": "a", "4": "b"}, "eval_acc": 0.9, "w_bits": 32, "cluster": 0},
        "8a2w_n4": {"files": {"1": "c", "4": "d"}, "eval_acc": 0.8, "w_bits": 2,  "cluster": 4}
      }
    }"#;

    fn mock_sizes() -> BTreeMap<String, Vec<usize>> {
        [("fp32".to_string(), vec![1, 4]), ("8a2w_n4".to_string(), vec![1, 4])]
            .into_iter()
            .collect()
    }

    fn start_mock(n_workers: usize, cfg: CoordinatorConfig) -> Coordinator {
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let factories: Vec<ExecutorFactory> = (0..n_workers)
            .map(|_| {
                Box::new(|| {
                    Ok(Box::new(MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a2w_n4", &[1, 4])]))
                        as Box<dyn Executor>)
                }) as ExecutorFactory
            })
            .collect();
        Coordinator::start(factories, router, &mock_sizes(), 8, cfg).unwrap()
    }

    fn image(v: f32) -> Tensor<f32> {
        Tensor::new(&[8, 8, 3], vec![v; 192]).unwrap()
    }

    #[test]
    fn test_single_request_roundtrip() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        // mock logits = mean + class index -> argmax = last class
        assert_eq!(r.predicted, 3);
        assert_eq!(r.variant, "fp32");
        assert!((r.logits[0] - 1.0).abs() < 1e-6);
        c.shutdown();
    }

    #[test]
    fn test_routing_by_class() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        let fast = c.infer(image(0.5), PrecisionClass::Fast).unwrap();
        assert_eq!(fast.variant, "8a2w_n4");
        c.shutdown();
    }

    #[test]
    fn test_batching_aggregates() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 50_000, ..Default::default() });
        // submit 4 concurrently: should form one full batch of 4
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(Request { image: image(i as f32), class: PrecisionClass::Fast }).unwrap())
            .collect();
        let resps: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert!(resps.iter().all(|r| r.batch == 4), "batches: {:?}", resps.iter().map(|r| r.batch).collect::<Vec<_>>());
        let m = c.metrics();
        assert_eq!(m.requests, 4);
        assert_eq!(m.batches, 1);
        assert_eq!(m.padded_slots, 0);
        c.shutdown();
    }

    #[test]
    fn test_deadline_flush_with_padding() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 1_000, ..Default::default() });
        let r = c.infer(image(2.0), PrecisionClass::Fast).unwrap();
        assert_eq!(r.batch, 1); // single request -> best-fit artifact of 1
        c.shutdown();
    }

    #[test]
    fn test_shape_validation() {
        let c = start_mock(1, Default::default());
        let bad = Tensor::<f32>::zeros(&[4, 4, 3]);
        assert!(c.submit(Request { image: bad, class: PrecisionClass::Fast }).is_err());
        c.shutdown();
    }

    #[test]
    fn test_backpressure_rejects() {
        // tiny queue + slow mock => try_send must eventually reject
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let factory: ExecutorFactory = Box::new(|| {
            let mut slow = MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a2w_n4", &[1, 4])]);
            slow.delay_us_per_image = 20_000;
            Ok(Box::new(slow) as Box<dyn Executor>)
        });
        let c = Coordinator::start(
            vec![factory],
            router,
            &mock_sizes(),
            8,
            CoordinatorConfig { max_queue: 2, max_wait_us: 100, tick_us: 100 },
        )
        .unwrap();
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..50 {
            match c.submit(Request { image: image(1.0), class: PrecisionClass::Accurate }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in rxs {
            let _ = rx.recv();
        }
        assert_eq!(c.metrics().rejected, rejected);
        c.shutdown();
    }

    #[test]
    fn test_multi_worker() {
        let c = start_mock(2, CoordinatorConfig { max_wait_us: 200, ..Default::default() });
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                c.submit(Request {
                    image: image(i as f32),
                    class: if i % 2 == 0 { PrecisionClass::Fast } else { PrecisionClass::Accurate },
                })
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.predicted, 3);
        }
        assert_eq!(c.metrics().requests, 16);
        c.shutdown();
    }

    #[test]
    fn test_shutdown_flushes_pending() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 10_000_000, ..Default::default() });
        // these can't hit the deadline before shutdown; shutdown must flush
        let rxs: Vec<_> = (0..2)
            .map(|_| c.submit(Request { image: image(1.0), class: PrecisionClass::Fast }).unwrap())
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        c.shutdown();
        for rx in rxs {
            assert!(rx.recv().is_ok(), "pending request dropped at shutdown");
        }
    }
}
