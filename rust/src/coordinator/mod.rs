//! L3 coordinator — the serving system around the paper's quantized models:
//! precision-class routing (§3.3's accuracy/perf trade-off as policy),
//! deadline-bounded dynamic batching onto fixed-batch AOT artifacts,
//! a worker pool over PJRT executables, bounded-queue backpressure and
//! per-stage latency metrics. Python is never on this path.
//!
//! Overload resilience rides on the same precision ladder: past a
//! configurable queue watermark (or latency target) admissions are
//! *degraded* to the next-cheaper variant instead of queued, past a hard
//! watermark they are *shed* with a typed error, expired per-request
//! deadlines are answered instead of executed, and worker panics are
//! caught and converted into [`ServeError::ExecutorFailed`] replies —
//! the invariant being that **every** admitted request receives exactly
//! one reply: a [`Response`] or a [`ServeError`], never a silently
//! dropped channel.
//!
//! The routing table (router + per-variant batch policies) lives behind an
//! [`ArcCell`] so [`Coordinator::reload`] can hot-swap a fully-validated
//! new artifact generation atomically — see the [`swap`] module for the
//! two-phase commit, drain and rollback semantics.

pub mod batcher;
pub mod degrade;
pub mod executor;
pub mod metrics;
pub mod router;
pub mod swap;

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

pub use batcher::{BatchPolicy, PolicyError};
pub use degrade::{Admission, DegradeConfig, DegradePolicy, LoadTracker, WATERMARK_DISABLED};
pub use executor::{Executor, ExecutorFactory, LpExecutor, MockExecutor, PjrtExecutor};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{PrecisionClass, Router};
pub use swap::{
    ArcCell, PreparedSwap, ReloadHook, RoutingState, SwapError, SwapReport, VariantSet,
    VariantStore,
};

use crate::tensor::Tensor;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// admission-control bound on in-flight requests (backpressure)
    pub max_queue: usize,
    /// dynamic-batching deadline for the oldest queued request
    pub max_wait_us: u64,
    /// dispatcher poll tick
    pub tick_us: u64,
    /// overload watermarks (disabled by default)
    pub degrade: DegradeConfig,
    /// quarantine an executor after this many *consecutive* panics
    pub quarantine_after: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            max_queue: 1024,
            max_wait_us: 2_000,
            tick_us: 200,
            degrade: DegradeConfig::default(),
            quarantine_after: 3,
        }
    }
}

/// Typed serving errors — one of these (or a [`Response`]) is the reply
/// every submitted request is guaranteed to receive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// the request's deadline expired before execution started
    DeadlineExceeded,
    /// admission queue full, or load past the shed watermark
    Overloaded,
    /// the executor returned an error or panicked on this batch
    ExecutorFailed(String),
    /// the coordinator is draining and no longer admits requests
    ShuttingDown,
    /// the request was malformed (wrong image shape, unroutable class)
    InvalidRequest(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded => write!(f, "request deadline exceeded before execution"),
            ServeError::Overloaded => write!(f, "coordinator overloaded (request shed)"),
            ServeError::ExecutorFailed(msg) => write!(f, "executor failed: {msg}"),
            ServeError::ShuttingDown => write!(f, "coordinator is shutting down"),
            ServeError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a submitted request resolves to: exactly one of these arrives on
/// the receiver returned by [`Coordinator::submit`].
pub type ServeResult = std::result::Result<Response, ServeError>;

/// An inference request.
pub struct Request {
    /// (img, img, 3) f32 image
    pub image: Tensor<f32>,
    pub class: PrecisionClass,
    /// optional completion deadline; expired requests are answered
    /// [`ServeError::DeadlineExceeded`] instead of executed
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(image: Tensor<f32>, class: PrecisionClass) -> Self {
        Self { image, class, deadline: None }
    }

    /// Attach a deadline `budget` from now.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline = Some(Instant::now() + budget);
        self
    }
}

/// An inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub predicted: usize,
    pub variant: String,
    /// the precision class actually served (differs from the requested
    /// class when `degraded`)
    pub class: PrecisionClass,
    /// true when overload degraded this request to a cheaper class
    pub degraded: bool,
    pub batch: usize,
    pub queue_us: f64,
    pub e2e_us: f64,
}

/// Single-use reply handle enforcing the no-lost-replies invariant
/// *structurally*: if a `ReplyOnce` is dropped anywhere (a request stuck
/// in a channel at shutdown, a job abandoned by a dying worker) without
/// an explicit reply, its drop glue sends [`ServeError::ShuttingDown`] —
/// so a submitted request can never end up with a silently dropped
/// channel.
struct ReplyOnce {
    tx: Option<Sender<ServeResult>>,
}

impl ReplyOnce {
    fn new(tx: Sender<ServeResult>) -> Self {
        Self { tx: Some(tx) }
    }

    fn send(mut self, r: ServeResult) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(r);
        }
    }
}

impl Drop for ReplyOnce {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Err(ServeError::ShuttingDown));
        }
    }
}

struct Pending {
    image: Tensor<f32>,
    reply: ReplyOnce,
    submitted: Instant,
    deadline: Option<Instant>,
    /// the class actually being served (post-degradation)
    class: PrecisionClass,
    degraded: bool,
}

struct BatchJob {
    variant: String,
    artifact_batch: usize,
    reqs: Vec<Pending>,
}

enum WorkerMsg {
    Job(BatchJob),
    Stop,
}

/// Outcome of a deadline-bounded [`Coordinator::shutdown_within`] drain.
#[derive(Debug, Clone, Copy)]
pub struct DrainReport {
    /// all threads flushed their queues and joined within the deadline
    pub drained: bool,
    /// threads joined before the deadline
    pub joined: usize,
    /// threads still running at the deadline (detached, not blocked on)
    pub leaked: usize,
}

/// The running coordinator (owns dispatcher + worker threads).
pub struct Coordinator {
    submit_tx: SyncSender<(Request, ReplyOnce)>,
    metrics: Arc<Metrics>,
    /// router + batch policies, swapped atomically by [`Self::reload`]
    routing: Arc<ArcCell<RoutingState>>,
    /// prepares a new artifact generation off the hot path; the lock also
    /// serializes concurrent reloads
    reload_hook: Mutex<Option<ReloadHook>>,
    /// generation counter for swapped routing states (0 = startup)
    generation: AtomicU64,
    max_wait_us: u64,
    stopping: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    img: usize,
}

impl Coordinator {
    /// Start with one executor factory per worker thread. PJRT state is not
    /// `Send`, so each worker *constructs* its executor on its own thread;
    /// the factory (config + paths) is what crosses the thread boundary.
    ///
    /// `sizes` maps each routable variant to its available artifact batch
    /// sizes (from the manifest); `img` is the expected input side length.
    /// A routable variant with no artifacts is tolerated as long as at
    /// least one variant has them — requests targeting it fall back down
    /// the precision ladder (and count as degraded).
    pub fn start(
        factories: Vec<ExecutorFactory>,
        router: Router,
        sizes: &BTreeMap<String, Vec<usize>>,
        img: usize,
        cfg: CoordinatorConfig,
    ) -> Result<Self> {
        if factories.is_empty() {
            bail!("need at least one executor factory");
        }

        // per-variant batch policies from the manifest's artifact set;
        // artifact-less variants get no policy and are served by ladder
        // fallback instead
        let mut policies: BTreeMap<String, BatchPolicy> = BTreeMap::new();
        for v in router.active_variants() {
            let s = sizes.get(v).cloned().unwrap_or_default();
            if s.is_empty() {
                continue;
            }
            policies.insert(
                v.to_string(),
                BatchPolicy::new(s, cfg.max_wait_us)
                    .with_context(|| format!("batch policy for variant '{v}'"))?,
            );
        }
        if policies.is_empty() {
            bail!("no routable variant has artifacts");
        }

        let metrics = Arc::new(Metrics::new());
        let tracker = Arc::new(LoadTracker::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let (submit_tx, submit_rx) = mpsc::sync_channel::<(Request, ReplyOnce)>(cfg.max_queue);
        let (job_tx, job_rx) = mpsc::channel::<WorkerMsg>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();

        let mut threads = Vec::new();

        // ---- worker pool --------------------------------------------------
        let n_workers = factories.len();
        for (wid, factory) in factories.into_iter().enumerate() {
            let job_rx = Arc::clone(&job_rx);
            let metrics = Arc::clone(&metrics);
            let tracker = Arc::clone(&tracker);
            let init_tx = init_tx.clone();
            let quarantine_after = cfg.quarantine_after.max(1);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("dfp-worker-{wid}"))
                    .spawn(move || {
                        let mut exec = match factory() {
                            Ok(e) => {
                                let _ = init_tx.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = init_tx.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(&mut *exec, &job_rx, &metrics, &tracker, quarantine_after);
                    })
                    .context("spawning worker")?,
            );
        }
        drop(init_tx);
        for _ in 0..n_workers {
            init_rx
                .recv()
                .context("worker init channel closed")?
                .context("worker executor init failed")?;
        }

        // ---- dispatcher ---------------------------------------------------
        let routing = Arc::new(ArcCell::new(Arc::new(RoutingState {
            router,
            policies,
            generation: 0,
        })));
        {
            let routing = Arc::clone(&routing);
            let metrics = Arc::clone(&metrics);
            let tracker = Arc::clone(&tracker);
            let stopping = Arc::clone(&stopping);
            let degrade = DegradePolicy::new(cfg.degrade.clone());
            let tick = Duration::from_micros(cfg.tick_us);
            threads.push(
                std::thread::Builder::new()
                    .name("dfp-dispatcher".into())
                    .spawn(move || {
                        let ctx = DispatchCtx {
                            routing,
                            degrade,
                            tracker,
                            metrics,
                            tick,
                            n_workers,
                        };
                        dispatcher_loop(&submit_rx, &job_tx, &ctx, &stopping);
                    })
                    .context("spawning dispatcher")?,
            );
        }

        Ok(Self {
            submit_tx,
            metrics,
            routing,
            reload_hook: Mutex::new(None),
            generation: AtomicU64::new(0),
            max_wait_us: cfg.max_wait_us,
            stopping,
            threads: Mutex::new(threads),
            img,
        })
    }

    /// Submit a request; returns a channel that will receive exactly one
    /// [`ServeResult`]. Fails fast (typed) when the request is malformed,
    /// the admission queue is full, or the coordinator is draining.
    pub fn submit(&self, req: Request) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        if req.image.shape() != [self.img, self.img, 3] {
            return Err(ServeError::InvalidRequest(format!(
                "image shape {:?} != ({i}, {i}, 3)",
                req.image.shape(),
                i = self.img
            )));
        }
        if self.stopping.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        let (tx, rx) = mpsc::channel();
        self.metrics.on_submit();
        match self.submit_tx.try_send((req, ReplyOnce::new(tx))) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.on_reject();
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
        }
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, image: Tensor<f32>, class: PrecisionClass) -> Result<Response> {
        let rx = self.submit(Request::new(image, class))?;
        Ok(rx.recv().context("coordinator dropped request")??)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Snapshot of the current routing state (router + batch policies).
    /// The snapshot stays coherent across a concurrent [`Self::reload`].
    pub fn routing(&self) -> Arc<RoutingState> {
        self.routing.load()
    }

    /// The artifact generation currently serving (0 until the first
    /// successful [`Self::reload`]).
    pub fn serving_generation(&self) -> u64 {
        self.routing.load().generation
    }

    /// Install the hook [`Self::reload`] uses to load + validate a new
    /// artifact directory off the hot path (see `LpExecutor::reload_hook`).
    pub fn install_reload_hook(&self, hook: ReloadHook) {
        let mut g = match self.reload_hook.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *g = Some(hook);
    }

    /// Atomically hot-swap serving onto the artifact set in `dir`.
    ///
    /// Two-phase: the hook loads and **fully validates** the new set off
    /// the hot path (any failure returns a typed [`SwapError`] with the old
    /// generation untouched — no partial ladders); then the weights are
    /// published to the shared store and the routing table is swapped in
    /// one pointer store. In-flight batches drain on the `Arc`s they
    /// already hold; queued requests whose variant vanished are re-admitted
    /// by the dispatcher against the new ladder.
    pub fn reload(&self, dir: &Path) -> std::result::Result<SwapReport, SwapError> {
        let guard = match self.reload_hook.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let hook = guard.as_ref().ok_or(SwapError::Unsupported)?;
        let t = Instant::now();
        let prepared = hook(dir)?;
        // batch policies for the new ladder; a failure here is still a
        // clean rollback — nothing has been published yet
        let mut policies: BTreeMap<String, BatchPolicy> = BTreeMap::new();
        for v in prepared.router.active_variants() {
            let s = prepared.sizes.get(v).cloned().unwrap_or_default();
            if s.is_empty() {
                continue;
            }
            let p = BatchPolicy::new(s, self.max_wait_us).map_err(|e| SwapError::Rejected {
                path: dir.to_path_buf(),
                reason: format!("batch policy for variant '{v}': {e}"),
            })?;
            policies.insert(v.to_string(), p);
        }
        if policies.is_empty() {
            return Err(SwapError::Rejected {
                path: dir.to_path_buf(),
                reason: "no routable variant in the new set has batch sizes".into(),
            });
        }
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        // commit order matters: weights first (jobs queued under the old
        // routing still resolve via the store's prev-generation fallback),
        // then routing — from here on new admissions see the new ladder
        (prepared.commit)(generation);
        self.routing.store(Arc::new(RoutingState {
            router: prepared.router,
            policies,
            generation,
        }));
        Ok(SwapReport {
            generation,
            variants: prepared.variants,
            prepare_us: t.elapsed().as_micros() as u64,
        })
    }

    /// Graceful drain with the default 5 s deadline. See
    /// [`Self::shutdown_within`].
    pub fn shutdown(&self) -> DrainReport {
        self.shutdown_within(Duration::from_secs(5))
    }

    /// Deadline-bounded graceful drain: stop admissions, let the dispatcher
    /// flush every pending queue to the workers, and join all threads —
    /// but never block past `deadline`. Threads still running at the
    /// deadline are left to a background reaper (reported as `leaked`,
    /// never blocked on again). Idempotent: later calls see no threads and
    /// return a trivially-drained report.
    pub fn shutdown_within(&self, deadline: Duration) -> DrainReport {
        self.stopping.store(true, Ordering::SeqCst);
        let threads: Vec<JoinHandle<()>> = {
            let mut g = match self.threads.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            g.drain(..).collect()
        };
        let n = threads.len();
        if n == 0 {
            return DrainReport { drained: true, joined: 0, leaked: 0 };
        }
        let (done_tx, done_rx) = mpsc::channel::<()>();
        std::thread::spawn(move || {
            for t in threads {
                let _ = t.join();
                let _ = done_tx.send(());
            }
        });
        let until = Instant::now() + deadline;
        let mut joined = 0usize;
        while joined < n {
            let now = Instant::now();
            if now >= until {
                break;
            }
            match done_rx.recv_timeout(until - now) {
                Ok(()) => joined += 1,
                Err(_) => break,
            }
        }
        DrainReport { drained: joined == n, joined, leaked: n - joined }
    }
}

/// Dispatcher context: shared handles plus the hot-swappable routing slot.
struct DispatchCtx {
    /// router + batch policies; reloaded atomically by [`Coordinator::reload`],
    /// so the dispatcher snapshots it once per tick
    routing: Arc<ArcCell<RoutingState>>,
    degrade: DegradePolicy,
    tracker: Arc<LoadTracker>,
    metrics: Arc<Metrics>,
    tick: Duration,
    n_workers: usize,
}

/// Admit one request into the per-variant queues, applying deadline,
/// shed and degradation policy against the routing snapshot `rs`.
/// Replies immediately (typed) when the request cannot be queued.
fn admit(
    req: Request,
    reply: ReplyOnce,
    queues: &mut BTreeMap<String, Vec<Pending>>,
    rs: &RoutingState,
    ctx: &DispatchCtx,
) {
    let now = Instant::now();
    if req.deadline.is_some_and(|d| d <= now) {
        ctx.metrics.on_deadline_miss();
        reply.send(Err(ServeError::DeadlineExceeded));
        return;
    }
    let queued: usize = queues.values().map(Vec::len).sum();
    let admission = ctx.degrade.admit(queued, ctx.tracker.p99(req.class));
    let target = match admission {
        Admission::Shed => {
            ctx.metrics.on_shed();
            reply.send(Err(ServeError::Overloaded));
            return;
        }
        Admission::Degrade => rs.router.next_cheaper(req.class).unwrap_or(req.class),
        Admission::Serve => req.class,
    };
    let Some((served, variant)) = rs.resolve(target) else {
        reply.send(Err(ServeError::ExecutorFailed(format!(
            "no servable variant at or below class '{target}'"
        ))));
        return;
    };
    let degraded = served != req.class;
    if degraded {
        ctx.metrics.on_degraded();
    }
    queues.entry(variant).or_default().push(Pending {
        image: req.image,
        reply,
        submitted: now,
        deadline: req.deadline,
        class: served,
        degraded,
    });
}

/// Re-admit a request whose queued variant vanished in a hot-swap:
/// re-resolve its class against the new routing state and move it to the
/// surviving queue, or answer it typed when the new ladder cannot serve it.
fn readmit(
    p: Pending,
    queues: &mut BTreeMap<String, Vec<Pending>>,
    rs: &RoutingState,
    ctx: &DispatchCtx,
) {
    match rs.resolve(p.class) {
        Some((served, variant)) => {
            let degraded = p.degraded || served != p.class;
            if degraded && !p.degraded {
                ctx.metrics.on_degraded();
            }
            queues.entry(variant).or_default().push(Pending { class: served, degraded, ..p });
        }
        None => p.reply.send(Err(ServeError::ExecutorFailed(format!(
            "variant for class '{}' removed by artifact reload",
            p.class
        )))),
    }
}

fn dispatcher_loop(
    submit_rx: &Receiver<(Request, ReplyOnce)>,
    job_tx: &Sender<WorkerMsg>,
    ctx: &DispatchCtx,
    stopping: &AtomicBool,
) {
    let mut queues: BTreeMap<String, Vec<Pending>> = BTreeMap::new();
    let mut disconnected = false;
    loop {
        // snapshot the routing state once per tick: admissions, planning
        // and orphan handling within a tick see one coherent ladder even
        // while a reload swaps the slot concurrently
        let rs = ctx.routing.load();

        // a hot-swap may have removed variants whose queues hold requests;
        // re-admit those against the new ladder before anything else
        let orphaned: Vec<String> = queues
            .iter()
            .filter(|(v, q)| !q.is_empty() && !rs.policies.contains_key(*v))
            .map(|(v, _)| v.clone())
            .collect();
        for v in orphaned {
            if let Some(q) = queues.remove(&v) {
                for p in q {
                    readmit(p, &mut queues, &rs, ctx);
                }
            }
        }

        // admit up to the tick deadline
        match submit_rx.recv_timeout(ctx.tick) {
            Ok((req, reply)) => {
                admit(req, reply, &mut queues, &rs, ctx);
                // keep draining whatever is immediately available
                while let Ok((req, reply)) = submit_rx.try_recv() {
                    admit(req, reply, &mut queues, &rs, ctx);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }

        // sweep expired deadlines out of every queue before planning
        let now = Instant::now();
        for q in queues.values_mut() {
            let mut i = 0;
            while i < q.len() {
                if q[i].deadline.is_some_and(|d| d <= now) {
                    let p = q.remove(i);
                    ctx.metrics.on_deadline_miss();
                    p.reply.send(Err(ServeError::DeadlineExceeded));
                } else {
                    i += 1;
                }
            }
        }

        // flush per-variant queues per policy; a queue whose variant has no
        // policy in this snapshot (swapped away mid-tick) waits for the
        // orphan pass at the top of the next iteration
        for (variant, q) in queues.iter_mut() {
            let Some(policy) = rs.policies.get(variant) else { continue };
            loop {
                let oldest_us = q
                    .first()
                    .map(|p| p.submitted.elapsed().as_micros() as u64)
                    .unwrap_or(0);
                // tightest remaining per-request deadline budget in the
                // queue, if any request carries one
                let headroom = q
                    .iter()
                    .filter_map(|p| p.deadline)
                    .map(|d| d.saturating_duration_since(now).as_micros() as u64)
                    .min();
                let Some(bsz) = policy.plan(q.len(), oldest_us, headroom) else { break };
                let take = q.len().min(bsz);
                let reqs: Vec<Pending> = q.drain(..take).collect();
                send_job(job_tx, variant, bsz, reqs);
            }
        }

        if stopping.load(Ordering::SeqCst) || disconnected {
            // stop admitting, but first drain anything already accepted
            // into the channel — those requests hold a reply promise
            while let Ok((req, reply)) = submit_rx.try_recv() {
                admit(req, reply, &mut queues, &rs, ctx);
            }
            // queues orphaned by a mid-drain swap are re-admitted first so
            // every leftover flushes at a real artifact batch size
            let orphaned: Vec<String> = queues
                .iter()
                .filter(|(v, q)| !q.is_empty() && !rs.policies.contains_key(*v))
                .map(|(v, _)| v.clone())
                .collect();
            for v in orphaned {
                if let Some(q) = queues.remove(&v) {
                    for p in q {
                        readmit(p, &mut queues, &rs, ctx);
                    }
                }
            }
            // flush leftovers at their best-fit batch, then stop workers
            for (variant, q) in queues.iter_mut() {
                let Some(policy) = rs.policies.get(variant) else { continue };
                while !q.is_empty() {
                    let bsz = policy.best_fit(q.len());
                    let take = q.len().min(bsz);
                    let reqs: Vec<Pending> = q.drain(..take).collect();
                    send_job(job_tx, variant, bsz, reqs);
                }
            }
            for _ in 0..ctx.n_workers {
                let _ = job_tx.send(WorkerMsg::Stop);
            }
            break;
        }
    }
}

/// Hand a batch to the worker pool; if every worker is gone (all
/// quarantined or crashed), the send fails and each request gets a typed
/// reply instead of a dropped channel.
fn send_job(job_tx: &Sender<WorkerMsg>, variant: &str, artifact_batch: usize, reqs: Vec<Pending>) {
    let job = BatchJob { variant: variant.to_string(), artifact_batch, reqs };
    if let Err(mpsc::SendError(WorkerMsg::Job(job))) = job_tx.send(WorkerMsg::Job(job)) {
        for p in job.reqs {
            p.reply.send(Err(ServeError::ExecutorFailed("no live workers".into())));
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

fn worker_loop(
    exec: &mut dyn Executor,
    job_rx: &Arc<Mutex<Receiver<WorkerMsg>>>,
    metrics: &Metrics,
    tracker: &LoadTracker,
    quarantine_after: usize,
) {
    let img = exec.img();
    let classes = exec.classes();
    let px = img * img * 3;
    // per-worker logits arena: grows to the largest artifact batch seen,
    // then every further batch runs the executor allocation-free
    let mut logits: Vec<f32> = Vec::new();
    let mut consecutive_panics = 0usize;
    loop {
        let msg = {
            let rx = match job_rx.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            rx.recv()
        };
        let job = match msg {
            Ok(WorkerMsg::Job(j)) => j,
            Ok(WorkerMsg::Stop) | Err(_) => break,
        };
        // requests can expire while queued in the job channel under
        // overload — answer them here instead of spending executor time
        let now = Instant::now();
        let mut live: Vec<Pending> = Vec::with_capacity(job.reqs.len());
        for p in job.reqs {
            if p.deadline.is_some_and(|d| d <= now) {
                metrics.on_deadline_miss();
                p.reply.send(Err(ServeError::DeadlineExceeded));
            } else {
                live.push(p);
            }
        }
        if live.is_empty() {
            continue;
        }
        let occupied = live.len();
        let padded = job.artifact_batch - occupied.min(job.artifact_batch);
        // assemble the (possibly padded) input batch
        let mut x = Tensor::<f32>::zeros(&[job.artifact_batch, img, img, 3]);
        for (i, p) in live.iter().enumerate() {
            x.data_mut()[i * px..(i + 1) * px].copy_from_slice(p.image.data());
        }
        let want = job.artifact_batch * classes;
        if logits.len() < want {
            logits.resize(want, 0.0);
        }
        let t_exec = Instant::now();
        // isolate the executor: a panicking batch must fail *its* requests,
        // not the worker (idiom shared with kernels::WorkerPool)
        let result = catch_unwind(AssertUnwindSafe(|| {
            exec.run_batch_into(&job.variant, job.artifact_batch, &x, &mut logits[..want])
        }));
        let exec_us = t_exec.elapsed().as_micros() as f64;
        metrics.on_batch(occupied, padded, exec_us);
        match result {
            Ok(Ok(())) => {
                consecutive_panics = 0;
                for (i, p) in live.into_iter().enumerate() {
                    let row = &logits[i * classes..(i + 1) * classes];
                    let predicted = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    let e2e_us = p.submitted.elapsed().as_micros() as f64;
                    let queue_us = e2e_us - exec_us;
                    metrics.on_response(queue_us.max(0.0), e2e_us);
                    tracker.record(p.class, e2e_us);
                    p.reply.send(Ok(Response {
                        logits: row.to_vec(),
                        predicted,
                        variant: job.variant.clone(),
                        class: p.class,
                        degraded: p.degraded,
                        batch: job.artifact_batch,
                        queue_us: queue_us.max(0.0),
                        e2e_us,
                    }));
                }
            }
            Ok(Err(e)) => {
                consecutive_panics = 0;
                let msg = format!("{e:#}");
                for p in live {
                    p.reply.send(Err(ServeError::ExecutorFailed(msg.clone())));
                }
            }
            Err(payload) => {
                metrics.on_worker_panic();
                consecutive_panics += 1;
                let msg = format!("executor panicked: {}", panic_message(payload.as_ref()));
                for p in live {
                    p.reply.send(Err(ServeError::ExecutorFailed(msg.clone())));
                }
                if consecutive_panics >= quarantine_after {
                    // quarantine: this executor keeps failing back-to-back;
                    // exit so surviving workers (or the dispatcher's
                    // no-live-workers reply path) take over
                    metrics.on_quarantine();
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    const MANIFEST: &str = r#"{
      "img": 8, "classes": 4, "batch_sizes": [1, 4],
      "variants": {
        "fp32":    {"files": {"1": "a", "4": "b"}, "eval_acc": 0.9, "w_bits": 32, "cluster": 0},
        "8a2w_n4": {"files": {"1": "c", "4": "d"}, "eval_acc": 0.8, "w_bits": 2,  "cluster": 4}
      }
    }"#;

    fn mock_sizes() -> BTreeMap<String, Vec<usize>> {
        [("fp32".to_string(), vec![1, 4]), ("8a2w_n4".to_string(), vec![1, 4])]
            .into_iter()
            .collect()
    }

    fn start_mock(n_workers: usize, cfg: CoordinatorConfig) -> Coordinator {
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let factories: Vec<ExecutorFactory> = (0..n_workers)
            .map(|_| {
                Box::new(|| {
                    Ok(Box::new(MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a2w_n4", &[1, 4])]))
                        as Box<dyn Executor>)
                }) as ExecutorFactory
            })
            .collect();
        Coordinator::start(factories, router, &mock_sizes(), 8, cfg).unwrap()
    }

    fn image(v: f32) -> Tensor<f32> {
        Tensor::new(&[8, 8, 3], vec![v; 192]).unwrap()
    }

    #[test]
    fn test_single_request_roundtrip() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        // mock logits = mean + class index -> argmax = last class
        assert_eq!(r.predicted, 3);
        assert_eq!(r.variant, "fp32");
        assert_eq!(r.class, PrecisionClass::Accurate);
        assert!(!r.degraded);
        assert!((r.logits[0] - 1.0).abs() < 1e-6);
        c.shutdown();
    }

    #[test]
    fn test_routing_by_class() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        let fast = c.infer(image(0.5), PrecisionClass::Fast).unwrap();
        assert_eq!(fast.variant, "8a2w_n4");
        c.shutdown();
    }

    #[test]
    fn test_batching_aggregates() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 50_000, ..Default::default() });
        // submit 4 concurrently: should form one full batch of 4
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(Request::new(image(i as f32), PrecisionClass::Fast)).unwrap())
            .collect();
        let resps: Vec<Response> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        assert!(resps.iter().all(|r| r.batch == 4), "batches: {:?}", resps.iter().map(|r| r.batch).collect::<Vec<_>>());
        let m = c.metrics();
        assert_eq!(m.requests, 4);
        assert_eq!(m.batches, 1);
        assert_eq!(m.padded_slots, 0);
        c.shutdown();
    }

    #[test]
    fn test_deadline_flush_with_padding() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 1_000, ..Default::default() });
        let r = c.infer(image(2.0), PrecisionClass::Fast).unwrap();
        assert_eq!(r.batch, 1); // single request -> best-fit artifact of 1
        c.shutdown();
    }

    #[test]
    fn test_shape_validation() {
        let c = start_mock(1, Default::default());
        let bad = Tensor::<f32>::zeros(&[4, 4, 3]);
        match c.submit(Request::new(bad, PrecisionClass::Fast)) {
            Err(ServeError::InvalidRequest(msg)) => assert!(msg.contains("shape"), "{msg}"),
            other => panic!("expected InvalidRequest, got {other:?}"),
        }
        c.shutdown();
    }

    #[test]
    fn test_backpressure_rejects() {
        // tiny queue + slow mock => try_send must eventually reject
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let factory: ExecutorFactory = Box::new(|| {
            let mut slow = MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a2w_n4", &[1, 4])]);
            slow.delay_us_per_image = 20_000;
            Ok(Box::new(slow) as Box<dyn Executor>)
        });
        let c = Coordinator::start(
            vec![factory],
            router,
            &mock_sizes(),
            8,
            CoordinatorConfig { max_queue: 2, max_wait_us: 100, tick_us: 100, ..Default::default() },
        )
        .unwrap();
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..50 {
            match c.submit(Request::new(image(1.0), PrecisionClass::Accurate)) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    assert_eq!(e, ServeError::Overloaded);
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in rxs {
            let _ = rx.recv();
        }
        assert_eq!(c.metrics().rejected, rejected);
        c.shutdown();
    }

    #[test]
    fn test_multi_worker() {
        let c = start_mock(2, CoordinatorConfig { max_wait_us: 200, ..Default::default() });
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                c.submit(Request::new(
                    image(i as f32),
                    if i % 2 == 0 { PrecisionClass::Fast } else { PrecisionClass::Accurate },
                ))
                .unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.predicted, 3);
        }
        assert_eq!(c.metrics().requests, 16);
        c.shutdown();
    }

    #[test]
    fn test_shutdown_flushes_pending() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 10_000_000, ..Default::default() });
        // these can't hit the deadline before shutdown; shutdown must flush
        let rxs: Vec<_> = (0..2)
            .map(|_| c.submit(Request::new(image(1.0), PrecisionClass::Fast)).unwrap())
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        let report = c.shutdown();
        assert!(report.drained, "drain timed out: {report:?}");
        assert_eq!(report.leaked, 0);
        for rx in rxs {
            rx.recv().expect("reply must arrive").expect("pending request dropped at shutdown");
        }
    }

    #[test]
    fn test_shutdown_is_idempotent_and_rejects_new_submits() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        assert!(c.shutdown().drained);
        // second drain is a no-op
        let again = c.shutdown();
        assert!(again.drained);
        assert_eq!(again.joined, 0);
        // admissions are closed
        assert_eq!(
            c.submit(Request::new(image(1.0), PrecisionClass::Fast)).unwrap_err(),
            ServeError::ShuttingDown
        );
    }

    #[test]
    fn test_expired_deadline_gets_typed_reply_without_execution() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        let rx = c
            .submit(Request::new(image(1.0), PrecisionClass::Fast).with_deadline(Duration::ZERO))
            .unwrap();
        assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        let m = c.metrics();
        assert_eq!(m.deadline_missed, 1);
        c.shutdown();
    }

    #[test]
    fn test_degrade_watermark_serves_cheaper_class() {
        // degrade from the first queued request on: accurate traffic must
        // come back served as the cheaper rung, marked degraded
        let cfg = CoordinatorConfig {
            max_wait_us: 100,
            degrade: DegradeConfig { degrade_watermark: 0, ..Default::default() },
            ..Default::default()
        };
        let c = start_mock(1, cfg);
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        assert!(r.degraded);
        assert_eq!(r.class, PrecisionClass::Balanced);
        assert_eq!(r.variant, "8a2w_n4"); // balanced routes to the 2-bit variant here
        assert!(c.metrics().degraded >= 1);
        // fast is already the cheapest rung: served as asked, not degraded
        let f = c.infer(image(1.0), PrecisionClass::Fast).unwrap();
        assert!(!f.degraded);
        c.shutdown();
    }

    #[test]
    fn test_shed_watermark_rejects_with_typed_error() {
        let cfg = CoordinatorConfig {
            max_wait_us: 60_000_000, // never flush on age: force queue buildup
            degrade: DegradeConfig { shed_watermark: 1, ..Default::default() },
            ..Default::default()
        };
        let c = start_mock(1, cfg);
        let rxs: Vec<_> = (0..6)
            .map(|_| c.submit(Request::new(image(1.0), PrecisionClass::Fast)).unwrap())
            .collect();
        let mut shed = 0;
        let mut served = 0;
        // shutdown flushes whatever was admitted below the watermark
        c.shutdown();
        for rx in rxs {
            match rx.recv().expect("every request must get a reply") {
                Ok(_) => served += 1,
                Err(ServeError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(served + shed, 6);
        assert!(shed > 0, "expected sheds past the watermark");
        assert_eq!(c.metrics().shed, shed);
    }

    #[test]
    fn test_variant_without_artifacts_falls_back_down_the_ladder() {
        // fp32 (accurate) has no artifact sizes: accurate requests must be
        // served by the cheaper variant instead of failing at startup
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let sizes: BTreeMap<String, Vec<usize>> =
            [("8a2w_n4".to_string(), vec![1, 4])].into_iter().collect();
        let factory: ExecutorFactory = Box::new(|| {
            Ok(Box::new(MockExecutor::new(8, 4, &[("fp32", &[1, 4]), ("8a2w_n4", &[1, 4])]))
                as Box<dyn Executor>)
        });
        let c = Coordinator::start(
            vec![factory],
            router,
            &sizes,
            8,
            CoordinatorConfig { max_wait_us: 100, ..Default::default() },
        )
        .unwrap();
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        assert_eq!(r.variant, "8a2w_n4");
        assert!(r.degraded, "ladder fallback must be reported as degraded");
        assert_ne!(r.class, PrecisionClass::Accurate);
        c.shutdown();
    }

    #[test]
    fn test_start_fails_only_when_no_variant_has_artifacts() {
        let m = Manifest::from_json_text(MANIFEST).unwrap();
        let router = Router::from_manifest(&m).unwrap();
        let factory: ExecutorFactory = Box::new(|| {
            Ok(Box::new(MockExecutor::new(8, 4, &[("fp32", &[1]), ("8a2w_n4", &[1])]))
                as Box<dyn Executor>)
        });
        let empty: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        assert!(Coordinator::start(
            vec![factory],
            router,
            &empty,
            8,
            CoordinatorConfig::default()
        )
        .is_err());
    }

    #[test]
    fn test_reload_without_hook_is_typed_unsupported() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        match c.reload(std::path::Path::new("/tmp/nowhere")) {
            Err(SwapError::Unsupported) => {}
            other => panic!("expected Unsupported, got {other:?}"),
        }
        assert_eq!(c.serving_generation(), 0);
        c.shutdown();
    }

    /// Hook that swaps routing to a ladder with only the cheap variant.
    fn cheap_only_hook() -> ReloadHook {
        Box::new(|_dir: &std::path::Path| {
            let m = Manifest::from_json_text(
                r#"{
                  "img": 8, "classes": 4, "batch_sizes": [1, 4],
                  "variants": {
                    "8a2w_n4": {"files": {"1": "c", "4": "d"}, "eval_acc": 0.8, "w_bits": 2, "cluster": 4}
                  }
                }"#,
            )
            .unwrap();
            let router = Router::from_manifest(&m).unwrap();
            let sizes: BTreeMap<String, Vec<usize>> =
                [("8a2w_n4".to_string(), vec![1, 4])].into_iter().collect();
            Ok(PreparedSwap {
                router,
                sizes,
                variants: vec!["8a2w_n4".to_string()],
                commit: Box::new(|_generation| {}),
            })
        })
    }

    #[test]
    fn test_reload_swaps_routing_atomically() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        assert_eq!(c.infer(image(1.0), PrecisionClass::Accurate).unwrap().variant, "fp32");
        c.install_reload_hook(cheap_only_hook());
        let report = c.reload(std::path::Path::new("/tmp/gen1")).unwrap();
        assert_eq!(report.generation, 1);
        assert_eq!(report.variants, vec!["8a2w_n4".to_string()]);
        assert_eq!(c.serving_generation(), 1);
        // accurate traffic now ladder-falls to the only remaining variant
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        assert_eq!(r.variant, "8a2w_n4");
        assert!(r.degraded, "ladder fallback after swap must report degraded");
        c.shutdown();
    }

    #[test]
    fn test_failed_reload_rolls_back_and_keeps_serving() {
        let c = start_mock(1, CoordinatorConfig { max_wait_us: 100, ..Default::default() });
        c.install_reload_hook(Box::new(|dir: &std::path::Path| {
            Err(SwapError::Rejected {
                path: dir.to_path_buf(),
                reason: "checksum mismatch in tensor 'c1.wq'".into(),
            })
        }));
        let err = c.reload(std::path::Path::new("/tmp/poisoned")).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        // previous generation untouched: still serving the full ladder
        assert_eq!(c.serving_generation(), 0);
        let r = c.infer(image(1.0), PrecisionClass::Accurate).unwrap();
        assert_eq!(r.variant, "fp32");
        assert!(!r.degraded);
        c.shutdown();
    }
}
