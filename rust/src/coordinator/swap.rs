//! Atomic artifact hot-swap — the trustworthy half of the serving
//! lifecycle. A running [`Coordinator`](super::Coordinator) can be pointed
//! at a *new* artifact directory without dropping a request:
//!
//! 1. the new set is loaded and **fully validated off the hot path**
//!    (checksums, packed-code ranges, requant envelopes, scheme
//!    cross-checks — everything `QModelParams::from_tensors` enforces);
//! 2. the validated set is *published* into the shared [`VariantStore`] —
//!    one pointer swap; workers pick it up at their next batch while
//!    batches already in flight keep the old `Arc` until they drain;
//! 3. the routing table ([`RoutingState`]: router + batch policies) is
//!    swapped through an [`ArcCell`], so the dispatcher plans the next tick
//!    against the new ladder.
//!
//! Any failure in step 1 or 2 returns a typed [`SwapError`] and leaves the
//! previous generation serving untouched — there is no state in which half
//! a ladder is new and half old. The store keeps the previous generation
//! around so jobs queued under the old routing still resolve by name even
//! when the new set dropped a variant (the dispatcher re-admits such
//! queues, but a job already handed to a worker needs the fallback).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use crate::lpinfer::QModelParams;

use super::batcher::BatchPolicy;
use super::router::{PrecisionClass, Router};

// ------------------------------------------------------------------ ArcCell

/// Hand-rolled `arc_swap`: a shared slot holding an `Arc<T>` that readers
/// `load()` (cheap clone under a read lock, never blocked by other readers)
/// and a writer atomically replaces with `store()`. In-flight users keep
/// whatever `Arc` they loaded — the old value lives until the last clone
/// drops, which is exactly the drain semantics hot-swap needs.
#[derive(Debug)]
pub struct ArcCell<T> {
    slot: RwLock<Arc<T>>,
}

impl<T> ArcCell<T> {
    pub fn new(value: Arc<T>) -> Self {
        Self { slot: RwLock::new(value) }
    }

    /// Snapshot the current value. The returned `Arc` stays valid across
    /// any number of later [`ArcCell::store`] calls.
    pub fn load(&self) -> Arc<T> {
        match self.slot.read() {
            Ok(g) => Arc::clone(&g),
            Err(poisoned) => Arc::clone(&poisoned.into_inner()),
        }
    }

    /// Atomically publish a new value; readers that loaded before keep the
    /// old one, readers that load after see the new one.
    pub fn store(&self, value: Arc<T>) {
        match self.slot.write() {
            Ok(mut g) => *g = value,
            Err(poisoned) => *poisoned.into_inner() = value,
        }
    }
}

// -------------------------------------------------------------- VariantSet

/// One immutable generation of loaded model variants. Shared (`Arc` per
/// param set) between every worker's executor, so publishing a new
/// generation is a pointer swap, not a weight copy.
#[derive(Debug, Clone, Default)]
pub struct VariantSet {
    /// generation counter assigned at publish time (0 = the startup set)
    pub generation: u64,
    pub variants: BTreeMap<String, Arc<QModelParams>>,
}

impl VariantSet {
    pub fn new(variants: BTreeMap<String, Arc<QModelParams>>) -> Self {
        Self { generation: 0, variants }
    }
}

/// The shared model-weight slot behind every worker's `LpExecutor`:
/// `current` is the serving generation, `prev` the one before it. Lookups
/// fall back `current -> prev` so a batch routed just before a swap that
/// *removed* its variant still executes against the old weights instead of
/// failing — the only window where two generations serve concurrently.
#[derive(Debug)]
pub struct VariantStore {
    inner: RwLock<Generations>,
}

#[derive(Debug)]
struct Generations {
    current: Arc<VariantSet>,
    prev: Option<Arc<VariantSet>>,
}

impl VariantStore {
    pub fn new(set: VariantSet) -> Self {
        Self { inner: RwLock::new(Generations { current: Arc::new(set), prev: None }) }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Generations> {
        match self.inner.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// The serving generation's set.
    pub fn current(&self) -> Arc<VariantSet> {
        Arc::clone(&self.read().current)
    }

    /// Serving generation number.
    pub fn generation(&self) -> u64 {
        self.read().current.generation
    }

    /// Resolve a variant's params: current generation first, previous as
    /// the drain fallback. The clone is an `Arc` bump — the caller holds
    /// the weights for its batch regardless of later swaps.
    pub fn lookup(&self, variant: &str) -> Option<Arc<QModelParams>> {
        let g = self.read();
        g.current
            .variants
            .get(variant)
            .or_else(|| g.prev.as_ref().and_then(|p| p.variants.get(variant)))
            .map(Arc::clone)
    }

    /// Atomically publish a fully-validated set as generation `generation`;
    /// the old current becomes the drain fallback.
    pub fn publish(&self, mut set: VariantSet, generation: u64) {
        set.generation = generation;
        let mut g = match self.inner.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        g.prev = Some(Arc::clone(&g.current));
        g.current = Arc::new(set);
    }
}

// ------------------------------------------------------------ RoutingState

/// Everything the dispatcher needs to admit and plan a request, swapped as
/// one unit so a reload can never leave the router pointing at a variant
/// the policy table does not know.
#[derive(Debug)]
pub struct RoutingState {
    pub router: Router,
    pub policies: BTreeMap<String, BatchPolicy>,
    /// generation this routing state was published for (0 = startup)
    pub generation: u64,
}

impl RoutingState {
    /// Resolve the class to serve a request at: the routed variant if it
    /// has a batch policy, else walk down the precision ladder to the
    /// first variant that does. `None` when nothing at or below `class`
    /// is servable.
    pub fn resolve(&self, class: PrecisionClass) -> Option<(PrecisionClass, String)> {
        let mut c = class;
        loop {
            if let Some(v) = self.router.try_route(c) {
                if self.policies.contains_key(v) {
                    return Some((c, v.to_string()));
                }
            }
            c = c.cheaper()?;
        }
    }
}

// ------------------------------------------------------------ swap control

/// A new artifact set, loaded and validated off the hot path, ready to
/// commit. Produced by a [`ReloadHook`]; nothing is visible to serving
/// until the coordinator calls `commit`.
pub struct PreparedSwap {
    /// router over the new variant ladder
    pub router: Router,
    /// per-variant artifact batch sizes for the new ladder
    pub sizes: BTreeMap<String, Vec<usize>>,
    /// names of the variants the new set serves (for the report)
    pub variants: Vec<String>,
    /// publishes the validated set into the shared store; called exactly
    /// once, with the generation number the coordinator assigned
    pub commit: Box<dyn FnOnce(u64) + Send>,
}

/// Loads + validates a new artifact directory into a [`PreparedSwap`].
/// Installed on the coordinator by whoever owns the [`VariantStore`]
/// (see `LpExecutor::reload_hook`).
pub type ReloadHook = Box<dyn Fn(&Path) -> Result<PreparedSwap, SwapError> + Send + Sync>;

/// Typed hot-swap failure. Every rejection means the previous generation
/// is still serving, untouched — a failed reload is diagnosable from the
/// error and invisible to traffic.
#[derive(Debug)]
pub enum SwapError {
    /// this coordinator has no reload hook (e.g. PJRT/mock executors)
    Unsupported,
    /// the new artifact set failed to load or validate; `reason` carries
    /// the full typed chain (checksum mismatches name file and tensor)
    Rejected { path: PathBuf, reason: String },
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::Unsupported => {
                write!(f, "hot-swap is not supported by this coordinator's executors")
            }
            SwapError::Rejected { path, reason } => {
                write!(
                    f,
                    "reload from {} rejected (still serving previous generation): {reason}",
                    path.display()
                )
            }
        }
    }
}

impl std::error::Error for SwapError {}

/// Outcome of a successful [`Coordinator::reload`](super::Coordinator::reload).
#[derive(Debug, Clone)]
pub struct SwapReport {
    /// the generation now serving
    pub generation: u64,
    /// variants in the new ladder
    pub variants: Vec<String>,
    /// wall time spent loading + validating off the hot path
    pub prepare_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet_mini;
    use crate::scheme::Scheme;

    #[test]
    fn test_arc_cell_load_store() {
        let cell = ArcCell::new(Arc::new(1u32));
        let before = cell.load();
        cell.store(Arc::new(2));
        assert_eq!(*before, 1, "in-flight snapshot must survive a store");
        assert_eq!(*cell.load(), 2);
    }

    fn tiny_set(seed: u64) -> VariantSet {
        let net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let scheme = Scheme::parse("8a2w_n4").unwrap();
        let mut m = BTreeMap::new();
        m.insert("8a2w_n4".to_string(), Arc::new(QModelParams::synthetic(&net, seed, &scheme)));
        VariantSet::new(m)
    }

    #[test]
    fn test_store_publish_and_prev_fallback() {
        let store = VariantStore::new(tiny_set(1));
        assert_eq!(store.generation(), 0);
        let held = store.lookup("8a2w_n4").expect("startup set");

        // publish a generation that renames the variant
        let net = resnet_mini(8, &[4, 4, 4], 1, 3);
        let scheme = Scheme::parse("8a4w_n4").unwrap();
        let mut m = BTreeMap::new();
        m.insert("8a4w_n4".to_string(), Arc::new(QModelParams::synthetic(&net, 2, &scheme)));
        store.publish(VariantSet::new(m), 1);

        assert_eq!(store.generation(), 1);
        assert!(store.lookup("8a4w_n4").is_some(), "new variant must resolve");
        // the removed name still resolves through the prev generation...
        let fallback = store.lookup("8a2w_n4").expect("prev-generation fallback");
        assert!(Arc::ptr_eq(&held, &fallback));
        // ...and only one generation back: a second publish retires it
        store.publish(tiny_set(3), 2);
        assert!(store.lookup("8a4w_n4").is_some(), "gen-1 variant still in prev");
        store.publish(tiny_set(4), 3);
        assert!(store.lookup("8a4w_n4").is_none(), "two publishes retire a generation");
    }

    #[test]
    fn test_swap_error_display_names_path() {
        let e = SwapError::Rejected {
            path: PathBuf::from("/tmp/bad_artifacts"),
            reason: "checksum mismatch in tensor 'c1.wq'".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("bad_artifacts"), "{msg}");
        assert!(msg.contains("c1.wq"), "{msg}");
        assert!(msg.contains("previous generation"), "{msg}");
        assert!(!SwapError::Unsupported.to_string().is_empty());
    }
}
