//! Dynamic batching policy (pure logic — unit-testable without threads).
//!
//! The serving artifacts are compiled at fixed batch sizes (1/8/32 by
//! default); the batcher decides *when* to flush a variant's pending queue
//! and *which* artifact batch to run: flush when the queue can fill the
//! largest artifact, or when the oldest request has waited `max_wait_us`
//! (deadline-bounded batching, the vLLM-style latency/throughput knob).

/// Batching policy configuration.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// available artifact batch sizes, ascending (e.g. [1, 8, 32])
    pub sizes: Vec<usize>,
    /// flush deadline for the oldest queued request
    pub max_wait_us: u64,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait_us: u64) -> Self {
        sizes.sort_unstable();
        sizes.dedup();
        assert!(!sizes.is_empty(), "need at least one batch size");
        Self { sizes, max_wait_us }
    }

    pub fn max_batch(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    /// Decide whether to flush now. Returns the artifact batch size to run
    /// (taking `min(pending, chosen)` requests, padding the rest).
    ///
    /// * queue can fill the largest artifact -> run it full (throughput);
    /// * oldest request past deadline -> run the smallest artifact that
    ///   covers the whole queue (latency), padding as needed.
    pub fn plan(&self, pending: usize, oldest_age_us: u64) -> Option<usize> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_batch() {
            return Some(self.max_batch());
        }
        if oldest_age_us >= self.max_wait_us {
            return Some(self.best_fit(pending));
        }
        None
    }

    /// Smallest artifact batch >= n (or the largest available).
    pub fn best_fit(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Padding waste if `n` requests run on the chosen artifact.
    pub fn padding(&self, n: usize) -> usize {
        let b = self.best_fit(n);
        b.saturating_sub(n.min(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1, 32], 2_000)
    }

    #[test]
    fn test_sizes_sorted_deduped() {
        let p = BatchPolicy::new(vec![8, 8, 1], 100);
        assert_eq!(p.sizes, vec![1, 8]);
        assert_eq!(p.max_batch(), 8);
    }

    #[test]
    fn test_no_flush_when_empty() {
        assert_eq!(policy().plan(0, 999_999), None);
    }

    #[test]
    fn test_flush_full_batch_immediately() {
        let p = policy();
        assert_eq!(p.plan(32, 0), Some(32));
        assert_eq!(p.plan(100, 0), Some(32));
    }

    #[test]
    fn test_deadline_flush_best_fit() {
        let p = policy();
        assert_eq!(p.plan(3, 1_999), None); // young queue: keep batching
        assert_eq!(p.plan(3, 2_000), Some(8));
        assert_eq!(p.plan(1, 5_000), Some(1));
        assert_eq!(p.plan(9, 2_000), Some(32));
    }

    #[test]
    fn test_best_fit_and_padding() {
        let p = policy();
        assert_eq!(p.best_fit(1), 1);
        assert_eq!(p.best_fit(2), 8);
        assert_eq!(p.best_fit(8), 8);
        assert_eq!(p.best_fit(33), 32);
        assert_eq!(p.padding(3), 5);
        assert_eq!(p.padding(8), 0);
        assert_eq!(p.padding(40), 0);
    }

    #[test]
    #[should_panic]
    fn test_empty_sizes_rejected() {
        BatchPolicy::new(vec![], 1);
    }
}
