//! Dynamic batching policy (pure logic — unit-testable without threads).
//!
//! The serving artifacts are compiled at fixed batch sizes (1/8/32 by
//! default); the batcher decides *when* to flush a variant's pending queue
//! and *which* artifact batch to run: flush when the queue can fill the
//! largest artifact, when the oldest request has waited `max_wait_us`
//! (deadline-bounded batching, the vLLM-style latency/throughput knob), or
//! when the tightest per-request deadline in the queue can no longer
//! absorb another full batching wait.
//!
//! Construction is fallible with a typed [`PolicyError`] — bad config must
//! surface as an error to the caller, never abort the serving process.

/// Typed configuration errors for [`BatchPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyError {
    /// the artifact batch-size list was empty
    EmptySizes,
    /// a batch size of zero was supplied
    ZeroBatchSize,
}

impl std::fmt::Display for PolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PolicyError::EmptySizes => write!(f, "batch policy needs at least one batch size"),
            PolicyError::ZeroBatchSize => write!(f, "batch sizes must be >= 1"),
        }
    }
}

impl std::error::Error for PolicyError {}

/// Batching policy configuration.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// available artifact batch sizes, ascending (e.g. [1, 8, 32]);
    /// validated non-empty and nonzero at construction
    sizes: Vec<usize>,
    /// flush deadline for the oldest queued request
    pub max_wait_us: u64,
}

impl BatchPolicy {
    /// Build a policy over the available artifact batch sizes. Returns a
    /// typed [`PolicyError`] on an empty or zero-containing size list
    /// instead of panicking — the coordinator surfaces it at startup.
    pub fn new(mut sizes: Vec<usize>, max_wait_us: u64) -> Result<Self, PolicyError> {
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.is_empty() {
            return Err(PolicyError::EmptySizes);
        }
        if sizes[0] == 0 {
            return Err(PolicyError::ZeroBatchSize);
        }
        Ok(Self { sizes, max_wait_us })
    }

    /// The validated, ascending artifact batch sizes.
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    pub fn max_batch(&self) -> usize {
        // non-empty by construction; 1 is the safe floor either way
        self.sizes.last().copied().unwrap_or(1)
    }

    /// Decide whether to flush now. Returns the artifact batch size to run
    /// (taking `min(pending, chosen)` requests, padding the rest).
    ///
    /// * queue can fill the largest artifact -> run it full (throughput);
    /// * oldest request past `max_wait_us` -> run the smallest artifact
    ///   that covers the whole queue (latency), padding as needed;
    /// * `min_headroom_us` (tightest per-request deadline budget left in
    ///   the queue, if any request carries a deadline) no longer covers
    ///   another full batching wait -> flush now, for the same best-fit
    ///   artifact, so the request still has its headroom for execution.
    pub fn plan(
        &self,
        pending: usize,
        oldest_age_us: u64,
        min_headroom_us: Option<u64>,
    ) -> Option<usize> {
        if pending == 0 {
            return None;
        }
        if pending >= self.max_batch() {
            return Some(self.max_batch());
        }
        if oldest_age_us >= self.max_wait_us {
            return Some(self.best_fit(pending));
        }
        if let Some(headroom) = min_headroom_us {
            if headroom <= self.max_wait_us {
                return Some(self.best_fit(pending));
            }
        }
        None
    }

    /// Smallest artifact batch >= n (or the largest available).
    pub fn best_fit(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Padding waste if `n` requests run on the chosen artifact.
    pub fn padding(&self, n: usize) -> usize {
        let b = self.best_fit(n);
        b.saturating_sub(n.min(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(vec![8, 1, 32], 2_000).unwrap()
    }

    #[test]
    fn test_sizes_sorted_deduped() {
        let p = BatchPolicy::new(vec![8, 8, 1], 100).unwrap();
        assert_eq!(p.sizes(), &[1, 8]);
        assert_eq!(p.max_batch(), 8);
    }

    #[test]
    fn test_no_flush_when_empty() {
        assert_eq!(policy().plan(0, 999_999, None), None);
    }

    #[test]
    fn test_flush_full_batch_immediately() {
        let p = policy();
        assert_eq!(p.plan(32, 0, None), Some(32));
        assert_eq!(p.plan(100, 0, None), Some(32));
    }

    #[test]
    fn test_deadline_flush_best_fit() {
        let p = policy();
        assert_eq!(p.plan(3, 1_999, None), None); // young queue: keep batching
        assert_eq!(p.plan(3, 2_000, None), Some(8));
        assert_eq!(p.plan(1, 5_000, None), Some(1));
        assert_eq!(p.plan(9, 2_000, None), Some(32));
    }

    #[test]
    fn test_request_deadline_forces_early_flush() {
        let p = policy();
        // young queue, but a request can't absorb another full wait window
        assert_eq!(p.plan(3, 0, Some(1_500)), Some(8));
        assert_eq!(p.plan(3, 0, Some(2_000)), Some(8));
        // plenty of deadline headroom: keep batching
        assert_eq!(p.plan(3, 0, Some(50_000)), None);
        // no deadlines in the queue: unchanged behavior
        assert_eq!(p.plan(3, 0, None), None);
    }

    #[test]
    fn test_best_fit_and_padding() {
        let p = policy();
        assert_eq!(p.best_fit(1), 1);
        assert_eq!(p.best_fit(2), 8);
        assert_eq!(p.best_fit(8), 8);
        assert_eq!(p.best_fit(33), 32);
        assert_eq!(p.padding(3), 5);
        assert_eq!(p.padding(8), 0);
        assert_eq!(p.padding(40), 0);
    }

    #[test]
    fn test_bad_config_is_a_typed_error_not_a_panic() {
        assert_eq!(BatchPolicy::new(vec![], 1).unwrap_err(), PolicyError::EmptySizes);
        assert_eq!(BatchPolicy::new(vec![0, 4], 1).unwrap_err(), PolicyError::ZeroBatchSize);
        assert!(!PolicyError::EmptySizes.to_string().is_empty());
        assert!(!PolicyError::ZeroBatchSize.to_string().is_empty());
    }
}
