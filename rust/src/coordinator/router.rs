//! Precision router — the paper's accuracy/performance trade-off (§3.3 /
//! §5 "tailoring solutions ... based on the accuracy and performance
//! requirements") exposed as a serving policy: a request declares a
//! precision class and the router picks the model variant.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::runtime::Manifest;

/// Client-facing precision classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionClass {
    /// cheapest variant: lowest weight bits, largest cluster (max op replacement)
    Fast,
    /// middle ground (4-bit if available)
    Balanced,
    /// highest available precision
    Accurate,
}

impl std::str::FromStr for PrecisionClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fast" => Ok(Self::Fast),
            "balanced" => Ok(Self::Balanced),
            "accurate" => Ok(Self::Accurate),
            other => bail!("unknown precision class '{other}'"),
        }
    }
}

/// Routing decision table computed once from the manifest.
#[derive(Debug, Clone)]
pub struct Router {
    table: BTreeMap<PrecisionClass, String>,
}

impl Router {
    /// Build from a manifest:
    /// * Accurate -> max w_bits (ties: smallest cluster);
    /// * Fast     -> min w_bits (ties: largest cluster);
    /// * Balanced -> the 4-bit variant if present, else closest-to-middle.
    pub fn from_manifest(m: &Manifest) -> Result<Self> {
        if m.variants.is_empty() {
            bail!("manifest has no variants");
        }
        let mut vs: Vec<(&String, u32, usize)> = m
            .variants
            .iter()
            .map(|(n, v)| (n, v.w_bits, v.cluster))
            .collect();
        vs.sort_by_key(|&(_, bits, cluster)| (bits, std::cmp::Reverse(cluster)));
        let fast = vs.first().unwrap().0.clone();
        let accurate = {
            let mut acc = vs.clone();
            acc.sort_by_key(|&(_, bits, cluster)| (std::cmp::Reverse(bits), cluster));
            acc.first().unwrap().0.clone()
        };
        let balanced = vs
            .iter()
            .find(|&&(_, bits, _)| bits == 4)
            .map(|&(n, _, _)| n.clone())
            .unwrap_or_else(|| {
                // closest to 4 bits
                vs.iter()
                    .min_by_key(|&&(_, bits, _)| (i64::from(bits) - 4).abs())
                    .unwrap()
                    .0
                    .clone()
            });
        let mut table = BTreeMap::new();
        table.insert(PrecisionClass::Fast, fast);
        table.insert(PrecisionClass::Balanced, balanced);
        table.insert(PrecisionClass::Accurate, accurate);
        Ok(Self { table })
    }

    pub fn route(&self, class: PrecisionClass) -> &str {
        &self.table[&class]
    }

    /// All distinct variants the router can send traffic to.
    pub fn active_variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.table.values().map(String::as_str).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

// Ord needed for BTreeMap key
impl PartialOrd for PrecisionClass {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PrecisionClass {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        fn rank(c: &PrecisionClass) -> u8 {
            match c {
                PrecisionClass::Fast => 0,
                PrecisionClass::Balanced => 1,
                PrecisionClass::Accurate => 2,
            }
        }
        rank(self).cmp(&rank(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "img": 24, "classes": 10, "batch_sizes": [1],
      "variants": {
        "fp32":     {"files": {"1": "a"}, "eval_acc": 0.90, "w_bits": 32, "cluster": 0},
        "8a8w_n4":  {"files": {"1": "b"}, "eval_acc": 0.90, "w_bits": 8,  "cluster": 4},
        "8a4w_n4":  {"files": {"1": "c"}, "eval_acc": 0.90, "w_bits": 4,  "cluster": 4},
        "8a2w_n4":  {"files": {"1": "d"}, "eval_acc": 0.85, "w_bits": 2,  "cluster": 4},
        "8a2w_n64": {"files": {"1": "e"}, "eval_acc": 0.84, "w_bits": 2,  "cluster": 64}
      }
    }"#;

    fn router() -> Router {
        Router::from_manifest(&Manifest::from_json_text(SAMPLE).unwrap()).unwrap()
    }

    #[test]
    fn test_routes() {
        let r = router();
        assert_eq!(r.route(PrecisionClass::Fast), "8a2w_n64"); // 2-bit, biggest cluster
        assert_eq!(r.route(PrecisionClass::Balanced), "8a4w_n4");
        assert_eq!(r.route(PrecisionClass::Accurate), "fp32");
    }

    #[test]
    fn test_active_variants_deduped() {
        let r = router();
        assert_eq!(r.active_variants().len(), 3);
    }

    #[test]
    fn test_single_variant_manifest() {
        let one = r#"{"img": 24, "classes": 10, "batch_sizes": [1],
          "variants": {"only": {"files": {"1": "a"}, "eval_acc": 0.5, "w_bits": 8, "cluster": 4}}}"#;
        let r = Router::from_manifest(&Manifest::from_json_text(one).unwrap()).unwrap();
        for c in [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate] {
            assert_eq!(r.route(c), "only");
        }
    }

    #[test]
    fn test_class_parsing() {
        assert_eq!("fast".parse::<PrecisionClass>().unwrap(), PrecisionClass::Fast);
        assert!("turbo".parse::<PrecisionClass>().is_err());
    }
}
