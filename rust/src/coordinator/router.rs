//! Precision router — the paper's accuracy/performance trade-off (§3.3 /
//! §5 "tailoring solutions ... based on the accuracy and performance
//! requirements") exposed as a serving policy: a request declares a
//! precision class and the router picks the model variant.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::runtime::Manifest;

/// Client-facing precision classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrecisionClass {
    /// cheapest variant: lowest weight bits, largest cluster (max op replacement)
    Fast,
    /// middle ground (4-bit if available)
    Balanced,
    /// highest available precision
    Accurate,
}

impl PrecisionClass {
    /// The next-cheaper rung of the paper's §3.3 accuracy/performance
    /// ladder (Accurate -> Balanced -> Fast), or `None` when already at
    /// the cheapest class. This is the axis the overload degradation
    /// policy walks: under pressure a request is served at the cheaper
    /// precision rather than shed.
    pub fn cheaper(self) -> Option<PrecisionClass> {
        match self {
            PrecisionClass::Accurate => Some(PrecisionClass::Balanced),
            PrecisionClass::Balanced => Some(PrecisionClass::Fast),
            PrecisionClass::Fast => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PrecisionClass::Fast => "fast",
            PrecisionClass::Balanced => "balanced",
            PrecisionClass::Accurate => "accurate",
        }
    }
}

impl std::fmt::Display for PrecisionClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for PrecisionClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fast" => Ok(Self::Fast),
            "balanced" => Ok(Self::Balanced),
            "accurate" => Ok(Self::Accurate),
            other => bail!("unknown precision class '{other}' (try fast|balanced|accurate)"),
        }
    }
}

/// Routing decision table computed once from the manifest.
#[derive(Debug, Clone)]
pub struct Router {
    table: BTreeMap<PrecisionClass, String>,
}

impl Router {
    /// Build from a manifest:
    /// * Accurate -> max w_bits (ties: smallest cluster);
    /// * Fast     -> min w_bits (ties: largest cluster);
    /// * Balanced -> the 4-bit variant if present, else closest-to-middle.
    pub fn from_manifest(m: &Manifest) -> Result<Self> {
        if m.variants.is_empty() {
            bail!("manifest has no variants");
        }
        let mut vs: Vec<(&String, u32, usize)> = m
            .variants
            .iter()
            .map(|(n, v)| (n, v.w_bits, v.cluster))
            .collect();
        vs.sort_by_key(|&(_, bits, cluster)| (bits, std::cmp::Reverse(cluster)));
        let fast = vs.first().unwrap().0.clone();
        let accurate = {
            let mut acc = vs.clone();
            acc.sort_by_key(|&(_, bits, cluster)| (std::cmp::Reverse(bits), cluster));
            acc.first().unwrap().0.clone()
        };
        let balanced = vs
            .iter()
            .find(|&&(_, bits, _)| bits == 4)
            .map(|&(n, _, _)| n.clone())
            .unwrap_or_else(|| {
                // closest to 4 bits
                vs.iter()
                    .min_by_key(|&&(_, bits, _)| (i64::from(bits) - 4).abs())
                    .unwrap()
                    .0
                    .clone()
            });
        let mut table = BTreeMap::new();
        table.insert(PrecisionClass::Fast, fast);
        table.insert(PrecisionClass::Balanced, balanced);
        table.insert(PrecisionClass::Accurate, accurate);
        Ok(Self { table })
    }

    pub fn route(&self, class: PrecisionClass) -> &str {
        // the table is total by construction (`from_manifest` fills every
        // class); `try_route` keeps even a malformed table panic-free
        self.try_route(class).expect("router table missing a precision class")
    }

    /// Non-panicking lookup (the table is total by construction, so this
    /// only returns `None` for a corrupted table).
    pub fn try_route(&self, class: PrecisionClass) -> Option<&str> {
        self.table.get(&class).map(String::as_str)
    }

    /// The degradation ladder: the next-cheaper class whose routed variant
    /// actually *differs* from `class`'s (rungs that collapse onto the
    /// same variant buy nothing and are skipped). `None` when `class` is
    /// already served by the cheapest distinct variant.
    pub fn next_cheaper(&self, class: PrecisionClass) -> Option<PrecisionClass> {
        let current = self.try_route(class)?;
        let mut c = class;
        while let Some(n) = c.cheaper() {
            if self.try_route(n).is_some_and(|v| v != current) {
                return Some(n);
            }
            c = n;
        }
        None
    }

    /// All distinct variants the router can send traffic to.
    pub fn active_variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.table.values().map(String::as_str).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

// Ord needed for BTreeMap key
impl PartialOrd for PrecisionClass {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PrecisionClass {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        fn rank(c: &PrecisionClass) -> u8 {
            match c {
                PrecisionClass::Fast => 0,
                PrecisionClass::Balanced => 1,
                PrecisionClass::Accurate => 2,
            }
        }
        rank(self).cmp(&rank(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "img": 24, "classes": 10, "batch_sizes": [1],
      "variants": {
        "fp32":     {"files": {"1": "a"}, "eval_acc": 0.90, "w_bits": 32, "cluster": 0},
        "8a8w_n4":  {"files": {"1": "b"}, "eval_acc": 0.90, "w_bits": 8,  "cluster": 4},
        "8a4w_n4":  {"files": {"1": "c"}, "eval_acc": 0.90, "w_bits": 4,  "cluster": 4},
        "8a2w_n4":  {"files": {"1": "d"}, "eval_acc": 0.85, "w_bits": 2,  "cluster": 4},
        "8a2w_n64": {"files": {"1": "e"}, "eval_acc": 0.84, "w_bits": 2,  "cluster": 64}
      }
    }"#;

    fn router() -> Router {
        Router::from_manifest(&Manifest::from_json_text(SAMPLE).unwrap()).unwrap()
    }

    #[test]
    fn test_routes() {
        let r = router();
        assert_eq!(r.route(PrecisionClass::Fast), "8a2w_n64"); // 2-bit, biggest cluster
        assert_eq!(r.route(PrecisionClass::Balanced), "8a4w_n4");
        assert_eq!(r.route(PrecisionClass::Accurate), "fp32");
    }

    #[test]
    fn test_active_variants_deduped() {
        let r = router();
        assert_eq!(r.active_variants().len(), 3);
    }

    #[test]
    fn test_single_variant_manifest() {
        let one = r#"{"img": 24, "classes": 10, "batch_sizes": [1],
          "variants": {"only": {"files": {"1": "a"}, "eval_acc": 0.5, "w_bits": 8, "cluster": 4}}}"#;
        let r = Router::from_manifest(&Manifest::from_json_text(one).unwrap()).unwrap();
        for c in [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate] {
            assert_eq!(r.route(c), "only");
        }
    }

    #[test]
    fn test_class_parsing() {
        assert_eq!("fast".parse::<PrecisionClass>().unwrap(), PrecisionClass::Fast);
        assert!("turbo".parse::<PrecisionClass>().is_err());
        // the unknown-class error names the valid alternatives
        let err = "turbo".parse::<PrecisionClass>().unwrap_err().to_string();
        assert!(err.contains("fast|balanced|accurate"), "{err}");
    }

    #[test]
    fn test_class_display_roundtrip() {
        for c in [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate] {
            assert_eq!(c.to_string().parse::<PrecisionClass>().unwrap(), c);
        }
    }

    #[test]
    fn test_empty_variant_ladder_is_a_typed_error() {
        let empty = r#"{"img": 24, "classes": 10, "batch_sizes": [1], "variants": {}}"#;
        let err = Router::from_manifest(&Manifest::from_json_text(empty).unwrap());
        assert!(err.is_err(), "empty ladder must not build a router");
    }

    #[test]
    fn test_cheaper_ladder_order() {
        assert_eq!(PrecisionClass::Accurate.cheaper(), Some(PrecisionClass::Balanced));
        assert_eq!(PrecisionClass::Balanced.cheaper(), Some(PrecisionClass::Fast));
        assert_eq!(PrecisionClass::Fast.cheaper(), None);
    }

    #[test]
    fn test_next_cheaper_walks_to_distinct_variants() {
        let r = router();
        // fp32 -> 8a4w_n4 -> 8a2w_n64: every rung is a distinct variant
        assert_eq!(r.next_cheaper(PrecisionClass::Accurate), Some(PrecisionClass::Balanced));
        assert_eq!(r.next_cheaper(PrecisionClass::Balanced), Some(PrecisionClass::Fast));
        assert_eq!(r.next_cheaper(PrecisionClass::Fast), None);
    }

    #[test]
    fn test_next_cheaper_skips_collapsed_rungs() {
        // balanced and fast collapse onto the same variant: degrading
        // accurate must skip straight past the no-op rung, and degrading
        // balanced has nowhere cheaper to go
        let two = r#"{"img": 24, "classes": 10, "batch_sizes": [1],
          "variants": {
            "fp32":    {"files": {"1": "a"}, "eval_acc": 0.9, "w_bits": 32, "cluster": 0},
            "8a4w_n4": {"files": {"1": "b"}, "eval_acc": 0.9, "w_bits": 4,  "cluster": 4}
          }}"#;
        let r = Router::from_manifest(&Manifest::from_json_text(two).unwrap()).unwrap();
        assert_eq!(r.route(PrecisionClass::Balanced), r.route(PrecisionClass::Fast));
        assert_eq!(r.next_cheaper(PrecisionClass::Accurate), Some(PrecisionClass::Balanced));
        assert_eq!(r.next_cheaper(PrecisionClass::Balanced), None);
        assert_eq!(r.next_cheaper(PrecisionClass::Fast), None);
    }

    #[test]
    fn test_single_variant_has_no_degradation_target() {
        let one = r#"{"img": 24, "classes": 10, "batch_sizes": [1],
          "variants": {"only": {"files": {"1": "a"}, "eval_acc": 0.5, "w_bits": 8, "cluster": 4}}}"#;
        let r = Router::from_manifest(&Manifest::from_json_text(one).unwrap()).unwrap();
        for c in [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate] {
            assert_eq!(r.next_cheaper(c), None);
            assert_eq!(r.try_route(c), Some("only"));
        }
    }
}
