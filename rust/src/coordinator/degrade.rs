//! Overload degradation policy — the paper's §3.3 accuracy/performance
//! ladder (ternary N=64 replaces ~98% of multiplications at lower
//! accuracy; 4-bit stays within 2% of FP32) used as a *graceful
//! degradation* axis for serving.
//!
//! Admission control walks a three-state machine per request:
//!
//! * **admit** — queue below the degrade watermark and recent latency
//!   under the target: serve the class the client asked for;
//! * **degrade** — queue past the degrade watermark (or recent per-class
//!   p99 past `p99_target_us`): rewrite the admission to the next-cheaper
//!   rung of the router ladder and mark the response `degraded`;
//! * **shed** — queue past the hard shed watermark: answer immediately
//!   with [`crate::coordinator::ServeError::Overloaded`] instead of
//!   queueing unboundedly.
//!
//! The policy itself is pure (watermark comparisons), so it is trivially
//! unit-testable; the [`LoadTracker`] supplies the "recent p99 per
//! precision class" signal from a fixed ring of completed-request
//! latencies (no allocation after construction, lock held only for the
//! ring write / copy).

use std::sync::Mutex;

use crate::coordinator::PrecisionClass;

/// Watermark configuration for the overload state machine. The defaults
/// disable both mechanisms (`usize::MAX` watermarks), preserving plain
/// bounded-queue backpressure; `dfp-infer serve` exposes them as
/// `--degrade-watermark` / `--shed-watermark`.
#[derive(Debug, Clone)]
pub struct DegradeConfig {
    /// queued requests at or past this: admissions degrade one ladder rung
    pub degrade_watermark: usize,
    /// queued requests at or past this: admissions are shed (`Overloaded`)
    pub shed_watermark: usize,
    /// recent per-class p99 (microseconds) past this also degrades
    /// admissions; `0.0` disables the latency signal
    pub p99_target_us: f64,
}

/// Watermark value meaning "disabled".
pub const WATERMARK_DISABLED: usize = usize::MAX;

impl Default for DegradeConfig {
    fn default() -> Self {
        Self {
            degrade_watermark: WATERMARK_DISABLED,
            shed_watermark: WATERMARK_DISABLED,
            p99_target_us: 0.0,
        }
    }
}

/// The admission decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// serve at the requested class
    Serve,
    /// serve, but degraded to the next-cheaper distinct ladder rung
    Degrade,
    /// answer `Overloaded` now rather than queue
    Shed,
}

/// Pure watermark policy: maps (queue depth, recent p99) to an
/// [`Admission`]. The caller resolves *which* cheaper class via
/// [`crate::coordinator::Router::next_cheaper`].
#[derive(Debug, Clone, Default)]
pub struct DegradePolicy {
    cfg: DegradeConfig,
}

impl DegradePolicy {
    pub fn new(cfg: DegradeConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &DegradeConfig {
        &self.cfg
    }

    /// Decide how to admit a request given the current total queued depth
    /// and the admitted class's recent p99 (microseconds; pass `0.0` when
    /// unknown).
    pub fn admit(&self, queued: usize, recent_p99_us: f64) -> Admission {
        if queued >= self.cfg.shed_watermark {
            return Admission::Shed;
        }
        if queued >= self.cfg.degrade_watermark {
            return Admission::Degrade;
        }
        if self.cfg.p99_target_us > 0.0 && recent_p99_us > self.cfg.p99_target_us {
            return Admission::Degrade;
        }
        Admission::Serve
    }
}

const TRACKER_RING: usize = 128;

/// Fixed-size ring of recent end-to-end latencies per precision class,
/// feeding the degrade policy's p99 signal. Writers (coordinator workers)
/// push one sample per completed request; the dispatcher reads a windowed
/// p99. All storage is allocated at construction.
#[derive(Debug)]
pub struct LoadTracker {
    rings: [Mutex<Ring>; 3],
}

#[derive(Debug)]
struct Ring {
    buf: Vec<f64>,
    next: usize,
    filled: usize,
}

impl Ring {
    fn new() -> Self {
        Self { buf: vec![0.0; TRACKER_RING], next: 0, filled: 0 }
    }

    fn push(&mut self, v: f64) {
        self.buf[self.next] = v;
        self.next = (self.next + 1) % self.buf.len();
        self.filled = (self.filled + 1).min(self.buf.len());
    }

    fn p99(&self) -> f64 {
        if self.filled == 0 {
            return 0.0;
        }
        let mut window: Vec<f64> = self.buf[..self.filled].to_vec();
        window.sort_by(f64::total_cmp);
        let idx = ((self.filled as f64) * 0.99).ceil() as usize;
        window[idx.clamp(1, self.filled) - 1]
    }
}

impl Default for LoadTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl LoadTracker {
    pub fn new() -> Self {
        Self { rings: [Mutex::new(Ring::new()), Mutex::new(Ring::new()), Mutex::new(Ring::new())] }
    }

    fn ring(&self, class: PrecisionClass) -> &Mutex<Ring> {
        let idx = match class {
            PrecisionClass::Fast => 0,
            PrecisionClass::Balanced => 1,
            PrecisionClass::Accurate => 2,
        };
        &self.rings[idx]
    }

    /// Record one completed request's end-to-end latency for `class`.
    pub fn record(&self, class: PrecisionClass, e2e_us: f64) {
        let mut r = match self.ring(class).lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        r.push(e2e_us);
    }

    /// Recent p99 (microseconds) for `class`; `0.0` before any sample.
    pub fn p99(&self, class: PrecisionClass) -> f64 {
        let r = match self.ring(class).lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        r.p99()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_default_policy_never_degrades_or_sheds() {
        let p = DegradePolicy::default();
        assert_eq!(p.admit(0, 0.0), Admission::Serve);
        assert_eq!(p.admit(1_000_000, 1e12), Admission::Serve);
    }

    #[test]
    fn test_watermark_state_machine() {
        let p = DegradePolicy::new(DegradeConfig {
            degrade_watermark: 4,
            shed_watermark: 8,
            p99_target_us: 0.0,
        });
        assert_eq!(p.admit(0, 0.0), Admission::Serve);
        assert_eq!(p.admit(3, 0.0), Admission::Serve);
        assert_eq!(p.admit(4, 0.0), Admission::Degrade);
        assert_eq!(p.admit(7, 0.0), Admission::Degrade);
        assert_eq!(p.admit(8, 0.0), Admission::Shed);
        assert_eq!(p.admit(999, 0.0), Admission::Shed);
    }

    #[test]
    fn test_p99_signal_degrades_admissions() {
        let p = DegradePolicy::new(DegradeConfig {
            degrade_watermark: WATERMARK_DISABLED,
            shed_watermark: WATERMARK_DISABLED,
            p99_target_us: 5_000.0,
        });
        assert_eq!(p.admit(0, 4_999.0), Admission::Serve);
        assert_eq!(p.admit(0, 5_001.0), Admission::Degrade);
        // the latency signal never sheds on its own — only the hard
        // queue watermark does
        assert_eq!(p.admit(0, 1e12), Admission::Degrade);
    }

    #[test]
    fn test_tracker_p99_orders_classes_independently() {
        let t = LoadTracker::new();
        assert_eq!(t.p99(PrecisionClass::Fast), 0.0);
        for i in 0..100 {
            t.record(PrecisionClass::Fast, f64::from(i));
            t.record(PrecisionClass::Accurate, 1_000.0 + f64::from(i));
        }
        let fast = t.p99(PrecisionClass::Fast);
        let acc = t.p99(PrecisionClass::Accurate);
        assert!(fast >= 90.0 && fast <= 99.0, "fast p99 {fast}");
        assert!(acc >= 1_090.0 && acc <= 1_099.0, "accurate p99 {acc}");
        // balanced never saw a sample
        assert_eq!(t.p99(PrecisionClass::Balanced), 0.0);
    }

    #[test]
    fn test_tracker_ring_wraps_to_recent_window() {
        let t = LoadTracker::new();
        // old slow samples fully displaced by fast ones
        for _ in 0..TRACKER_RING {
            t.record(PrecisionClass::Balanced, 1e6);
        }
        for _ in 0..TRACKER_RING {
            t.record(PrecisionClass::Balanced, 10.0);
        }
        assert_eq!(t.p99(PrecisionClass::Balanced), 10.0);
    }

    #[test]
    fn test_single_sample_p99() {
        let t = LoadTracker::new();
        t.record(PrecisionClass::Fast, 42.0);
        assert_eq!(t.p99(PrecisionClass::Fast), 42.0);
    }
}
