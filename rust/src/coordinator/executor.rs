//! Executor abstraction: the coordinator drives anything that can run a
//! fixed-batch forward pass. Production uses [`PjrtExecutor`] (AOT XLA
//! artifacts); tests and benches use [`MockExecutor`] / the pure-Rust
//! lpinfer pipeline so coordinator logic is testable without artifacts.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Factory that builds an executor *on the worker's own thread* — PJRT
/// handles are not `Send`, so only the factory crosses threads.
pub type ExecutorFactory = Box<dyn FnOnce() -> Result<Box<dyn Executor>> + Send>;

/// Anything that can run a (variant, fixed-batch) forward pass. Constructed
/// and used on a single worker thread (see [`ExecutorFactory`]).
pub trait Executor {
    /// x: (batch, img, img, 3) f32 -> logits (batch, classes).
    fn run_batch(&mut self, variant: &str, batch: usize, x: &Tensor<f32>) -> Result<Tensor<f32>>;

    /// Available artifact batch sizes for a variant (ascending).
    fn batch_sizes(&self, variant: &str) -> Vec<usize>;

    fn img(&self) -> usize;
    fn classes(&self) -> usize;
}

/// PJRT-backed executor (the production path).
pub struct PjrtExecutor {
    engine: Engine,
}

impl PjrtExecutor {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self { engine: Engine::new(artifacts_dir)? })
    }

    /// Factory for [`crate::coordinator::Coordinator::start`]: builds the
    /// engine on the worker thread and pre-compiles all artifacts.
    pub fn factory(artifacts_dir: std::path::PathBuf, warmup: bool) -> ExecutorFactory {
        Box::new(move || {
            let mut e = PjrtExecutor::new(&artifacts_dir)?;
            if warmup {
                e.warmup()?;
            }
            Ok(Box::new(e) as Box<dyn Executor>)
        })
    }

    /// Compile all artifacts up front (avoids first-request latency spikes).
    pub fn warmup(&mut self) -> Result<usize> {
        self.engine.load_all()
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.engine.manifest
    }
}

impl Executor for PjrtExecutor {
    fn run_batch(&mut self, variant: &str, batch: usize, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        self.engine.load(variant, batch)?.run(x)
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.engine.batch_sizes(variant)
    }

    fn img(&self) -> usize {
        self.engine.manifest.img
    }

    fn classes(&self) -> usize {
        self.engine.manifest.classes
    }
}

/// Deterministic fake executor for coordinator tests: logits[i][c] =
/// mean(image_i) + c, optionally with a configurable artificial delay.
pub struct MockExecutor {
    pub img: usize,
    pub classes: usize,
    pub sizes: BTreeMap<String, Vec<usize>>,
    pub delay_us_per_image: u64,
    /// (variant, batch) log of executed jobs
    pub executed: Vec<(String, usize)>,
}

impl MockExecutor {
    pub fn new(img: usize, classes: usize, variants: &[(&str, &[usize])]) -> Self {
        Self {
            img,
            classes,
            sizes: variants
                .iter()
                .map(|(v, s)| (v.to_string(), s.to_vec()))
                .collect(),
            delay_us_per_image: 0,
            executed: Vec::new(),
        }
    }
}

impl Executor for MockExecutor {
    fn run_batch(&mut self, variant: &str, batch: usize, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        anyhow::ensure!(x.dim(0) == batch, "batch mismatch");
        self.executed.push((variant.to_string(), batch));
        if self.delay_us_per_image > 0 {
            std::thread::sleep(std::time::Duration::from_micros(
                self.delay_us_per_image * batch as u64,
            ));
        }
        let px = self.img * self.img * 3;
        let mut out = Tensor::<f32>::zeros(&[batch, self.classes]);
        for b in 0..batch {
            let mean: f32 =
                x.data()[b * px..(b + 1) * px].iter().sum::<f32>() / px as f32;
            for c in 0..self.classes {
                out.data_mut()[b * self.classes + c] = mean + c as f32;
            }
        }
        Ok(out)
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.sizes.get(variant).cloned().unwrap_or_default()
    }

    fn img(&self) -> usize {
        self.img
    }

    fn classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mock_executor_deterministic() {
        let mut m = MockExecutor::new(4, 3, &[("v", &[1, 2])]);
        let x = Tensor::new(&[1, 4, 4, 3], vec![2.0; 48]).unwrap();
        let y = m.run_batch("v", 1, &x).unwrap();
        assert_eq!(y.data(), &[2.0, 3.0, 4.0]);
        assert_eq!(m.executed, vec![("v".to_string(), 1)]);
        assert_eq!(m.batch_sizes("v"), vec![1, 2]);
        assert!(m.batch_sizes("nope").is_empty());
    }

    #[test]
    fn test_mock_rejects_bad_batch() {
        let mut m = MockExecutor::new(4, 3, &[("v", &[1])]);
        let x = Tensor::new(&[2, 4, 4, 3], vec![0.0; 96]).unwrap();
        assert!(m.run_batch("v", 1, &x).is_err());
    }
}
