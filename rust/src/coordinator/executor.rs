//! Executor abstraction: the coordinator drives anything that can run a
//! fixed-batch forward pass. Production paths are [`PjrtExecutor`] (AOT XLA
//! artifacts, `pjrt` feature) and [`LpExecutor`] — the pure-Rust quantized
//! pipeline over the `kernels/` packed GEMMs, which needs only a
//! `qweights_*.dft` export (no HLO artifacts, no PJRT). Tests and benches
//! also use [`MockExecutor`] so coordinator logic is testable standalone.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::kernels::KernelRegistry;
use crate::lpinfer::{forward_quant_into, ForwardWorkspace, QModelParams};
use crate::model::Network;
use crate::runtime::Engine;
use crate::tensor::Tensor;

use super::router::Router;
use super::swap::{PreparedSwap, ReloadHook, SwapError, VariantSet, VariantStore};

/// Factory that builds an executor *on the worker's own thread* — PJRT
/// handles are not `Send`, so only the factory crosses threads.
pub type ExecutorFactory = Box<dyn FnOnce() -> Result<Box<dyn Executor>> + Send>;

/// Anything that can run a (variant, fixed-batch) forward pass. Constructed
/// and used on a single worker thread (see [`ExecutorFactory`]).
pub trait Executor {
    /// Borrowed-output forward: x (batch, img, img, 3) f32 -> `logits`
    /// (batch × classes, row-major, fully overwritten). The serving hot
    /// path — the coordinator's workers call this with a reusable
    /// per-worker logits arena, so a steady-state request allocates no
    /// logits tensor.
    fn run_batch_into(
        &mut self,
        variant: &str,
        batch: usize,
        x: &Tensor<f32>,
        logits: &mut [f32],
    ) -> Result<()>;

    /// Allocating convenience wrapper over [`Self::run_batch_into`]:
    /// x (batch, img, img, 3) f32 -> logits (batch, classes).
    fn run_batch(&mut self, variant: &str, batch: usize, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        let mut logits = Tensor::<f32>::zeros(&[batch, self.classes()]);
        self.run_batch_into(variant, batch, x, logits.data_mut())?;
        Ok(logits)
    }

    /// Available artifact batch sizes for a variant (ascending).
    fn batch_sizes(&self, variant: &str) -> Vec<usize>;

    fn img(&self) -> usize;
    fn classes(&self) -> usize;
}

/// PJRT-backed executor (the production path).
pub struct PjrtExecutor {
    engine: Engine,
}

impl PjrtExecutor {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self { engine: Engine::new(artifacts_dir)? })
    }

    /// Factory for [`crate::coordinator::Coordinator::start`]: builds the
    /// engine on the worker thread and pre-compiles all artifacts.
    pub fn factory(artifacts_dir: std::path::PathBuf, warmup: bool) -> ExecutorFactory {
        Box::new(move || {
            let mut e = PjrtExecutor::new(&artifacts_dir)?;
            if warmup {
                e.warmup()?;
            }
            Ok(Box::new(e) as Box<dyn Executor>)
        })
    }

    /// Compile all artifacts up front (avoids first-request latency spikes).
    pub fn warmup(&mut self) -> Result<usize> {
        self.engine.load_all()
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.engine.manifest
    }
}

impl Executor for PjrtExecutor {
    fn run_batch_into(
        &mut self,
        variant: &str,
        batch: usize,
        x: &Tensor<f32>,
        logits: &mut [f32],
    ) -> Result<()> {
        // PJRT owns its output buffers, so this path copies once; the
        // tensor-returning override below stays copy-free
        let out = self.engine.load(variant, batch)?.run(x)?;
        anyhow::ensure!(
            out.data().len() == logits.len(),
            "PJRT returned {} logits for a {} slot buffer",
            out.data().len(),
            logits.len()
        );
        logits.copy_from_slice(out.data());
        Ok(())
    }

    fn run_batch(&mut self, variant: &str, batch: usize, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        self.engine.load(variant, batch)?.run(x)
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.engine.batch_sizes(variant)
    }

    fn img(&self) -> usize {
        self.engine.manifest.img
    }

    fn classes(&self) -> usize {
        self.engine.manifest.classes
    }
}

/// Pure-Rust quantized executor: runs `lpinfer::forward_quant` through the
/// `kernels/` registry for every variant it holds. Unlike [`PjrtExecutor`]
/// it accepts any batch size, so the advertised `batch_sizes` are purely a
/// batching-policy knob.
///
/// Each executor owns one [`ForwardWorkspace`] arena, and the coordinator
/// builds one executor per worker thread — so concurrent serving reuses a
/// per-worker arena instead of allocating activation/im2col/accumulator
/// tensors per request. After warm-up, a steady-state batch through
/// [`Executor::run_batch_into`] runs with zero heap allocations at any
/// registry thread count — the GEMMs dispatch onto the persistent
/// [`crate::kernels::WorkerPool`], which registry clones share (see
/// `lpinfer::forward_quant_into`).
pub struct LpExecutor {
    net: Network,
    /// shared hot-swappable weight slot — every worker's executor holds the
    /// same store, so a published generation is visible to all of them at
    /// their next batch without copying a single weight
    store: Arc<VariantStore>,
    registry: KernelRegistry,
    workspace: ForwardWorkspace,
    sizes: Vec<usize>,
    img: usize,
    classes: usize,
}

impl LpExecutor {
    /// Build from in-memory params (tests, synthetic serving). The params
    /// are wrapped into a private [`VariantStore`]; use [`Self::with_store`]
    /// to share one store (and hot-swap it) across executors.
    pub fn new(
        net: Network,
        variants: BTreeMap<String, QModelParams>,
        registry: KernelRegistry,
        sizes: Vec<usize>,
    ) -> Result<Self> {
        let variants: BTreeMap<String, Arc<QModelParams>> =
            variants.into_iter().map(|(name, p)| (name, Arc::new(p))).collect();
        let store = Arc::new(VariantStore::new(VariantSet::new(variants)));
        Self::with_store(net, store, registry, sizes)
    }

    /// Build over a shared [`VariantStore`]: the coordinator's per-worker
    /// executors all hold the same store, and [`Self::reload_hook`]
    /// publishes new generations into it. The store's *current* set is
    /// validated against `net` here; later generations are validated by
    /// whoever publishes them (the reload hook validates fully before
    /// commit).
    pub fn with_store(
        net: Network,
        store: Arc<VariantStore>,
        registry: KernelRegistry,
        mut sizes: Vec<usize>,
    ) -> Result<Self> {
        let current = store.current();
        if current.variants.is_empty() {
            bail!("LpExecutor needs at least one variant");
        }
        for (name, p) in &current.variants {
            p.validate(&net).with_context(|| format!("variant '{name}'"))?;
        }
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.is_empty() {
            sizes = vec![1, 8, 32];
        }
        let (img, classes) = (net.input_hw, net.fc_out);
        Ok(Self { net, store, registry, workspace: ForwardWorkspace::new(), sizes, img, classes })
    }

    /// The shared weight slot this executor serves from.
    pub fn store(&self) -> Arc<VariantStore> {
        Arc::clone(&self.store)
    }

    /// The manifest variants this executor could serve from `dir`: sub-8-bit
    /// weights with a `qweights_<variant>.dft` export present. The single
    /// source of the lp-eligibility rule — `from_artifacts` and the CLI
    /// executor selection both consult it (fp32 needs the f32 pipeline /
    /// PJRT, so it is never lp-servable).
    pub fn servable(dir: &Path, manifest: &crate::runtime::Manifest) -> Vec<String> {
        manifest
            .variants
            .iter()
            .filter(|(name, info)| {
                info.w_bits < 32 && dir.join(format!("qweights_{name}.dft")).exists()
            })
            .map(|(name, _)| name.to_string())
            .collect()
    }

    /// Load + deep-validate every lp-servable variant in `dir`: manifest
    /// (typed parse errors naming the file), geometry cross-check, DFT
    /// checksums, packed-code ranges, requant envelopes and scheme
    /// consistency — everything that must hold before a set may serve.
    /// The single load path shared by [`Self::from_artifacts`],
    /// [`Self::reload_hook`] and the `verify-artifact` CLI.
    pub fn load_variant_set(
        dir: &Path,
    ) -> Result<(crate::runtime::Manifest, BTreeMap<String, Arc<QModelParams>>)> {
        let manifest = crate::runtime::Manifest::load(&dir.join("manifest.json"))?;
        let net = crate::model::resnet_mini_default();
        if manifest.img != net.input_hw || manifest.classes != net.fc_out {
            bail!(
                "manifest geometry {}x{} (c={}) != resnet-mini {}x{} (c={})",
                manifest.img,
                manifest.img,
                manifest.classes,
                net.input_hw,
                net.input_hw,
                net.fc_out
            );
        }
        let mut variants = BTreeMap::new();
        for name in Self::servable(dir, &manifest) {
            let path = dir.join(format!("qweights_{name}.dft"));
            let map = crate::io::read_dft(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let params = QModelParams::from_tensors(&map, &net)
                .with_context(|| format!("validating {}", path.display()))?;
            // a scheme-named variant must be consistent end to end: the
            // manifest metadata must agree with the name, and the qweights
            // export must realize the same default policy
            if let Ok(declared) = crate::scheme::Scheme::parse(&name) {
                anyhow::ensure!(
                    manifest.scheme_of(&name).is_some(),
                    "variant '{name}': manifest w_bits/cluster disagree with the scheme its name declares"
                );
                let got = params.scheme.default_policy();
                let want = declared.default_policy();
                anyhow::ensure!(
                    got.w_bits() == want.w_bits() && got.cluster == want.cluster,
                    "variant '{name}': qweights export realizes scheme '{}' but the manifest declares '{declared}'",
                    params.scheme
                );
            }
            variants.insert(name.clone(), Arc::new(params));
        }
        if variants.is_empty() {
            bail!("no qweights_<variant>.dft exports found in {}", dir.display());
        }
        Ok((manifest, variants))
    }

    /// Load every quantized variant the manifest lists for which a
    /// `qweights_<variant>.dft` export exists next to it.
    pub fn from_artifacts(dir: &Path, registry: KernelRegistry) -> Result<Self> {
        let (manifest, variants) = Self::load_variant_set(dir)?;
        let store = Arc::new(VariantStore::new(VariantSet::new(variants)));
        Self::with_store(
            crate::model::resnet_mini_default(),
            store,
            registry,
            manifest.batch_sizes.clone(),
        )
    }

    /// Factory for [`crate::coordinator::Coordinator::start`].
    pub fn factory(dir: std::path::PathBuf, registry: KernelRegistry) -> ExecutorFactory {
        Box::new(move || {
            Ok(Box::new(LpExecutor::from_artifacts(&dir, registry)?) as Box<dyn Executor>)
        })
    }

    /// Load `dir` once into a shared store for a multi-worker coordinator;
    /// returns the manifest alongside so the caller can build the router.
    pub fn shared_store_from_artifacts(
        dir: &Path,
    ) -> Result<(crate::runtime::Manifest, Arc<VariantStore>)> {
        let (manifest, variants) = Self::load_variant_set(dir)?;
        Ok((manifest, Arc::new(VariantStore::new(VariantSet::new(variants)))))
    }

    /// Factory over a shared [`VariantStore`]: all workers serve the same
    /// weight slot, which is what makes [`Self::reload_hook`] hot-swaps
    /// visible to the whole pool at once.
    pub fn store_factory(
        net: Network,
        store: Arc<VariantStore>,
        registry: KernelRegistry,
        sizes: Vec<usize>,
    ) -> ExecutorFactory {
        Box::new(move || {
            Ok(Box::new(LpExecutor::with_store(net, store, registry, sizes)?) as Box<dyn Executor>)
        })
    }

    /// [`ReloadHook`] for [`crate::coordinator::Coordinator::reload`] over a
    /// shared store: loads + deep-validates the new artifact directory off
    /// the hot path ([`Self::load_variant_set`] — checksums, packed codes,
    /// requant envelopes, scheme cross-checks), and on success hands back a
    /// commit that publishes the set into `store`. Any failure is a typed
    /// [`SwapError::Rejected`] naming the directory, with nothing published.
    pub fn reload_hook(store: Arc<VariantStore>) -> ReloadHook {
        Box::new(move |dir: &Path| {
            let reject = |reason: String| SwapError::Rejected { path: dir.to_path_buf(), reason };
            let (manifest, variants) =
                Self::load_variant_set(dir).map_err(|e| reject(format!("{e:#}")))?;
            let router = Router::from_manifest(&manifest).map_err(|e| reject(format!("{e:#}")))?;
            let names: Vec<String> = variants.keys().cloned().collect();
            let sizes: BTreeMap<String, Vec<usize>> = names
                .iter()
                .map(|n| (n.clone(), manifest.batch_sizes.clone()))
                .collect();
            let store = Arc::clone(&store);
            Ok(PreparedSwap {
                router,
                sizes,
                variants: names,
                commit: Box::new(move |generation| {
                    store.publish(VariantSet::new(variants), generation);
                }),
            })
        })
    }

    /// Names of the variants in the serving generation.
    pub fn variants(&self) -> Vec<String> {
        self.store.current().variants.keys().cloned().collect()
    }

    /// The synthetic serving ladder: the paper's §3.3 accuracy/performance
    /// rungs as (scheme name, w_bits, cluster) — ternary N=64 for Fast,
    /// 4-bit for Balanced, full i8 for Accurate. Shared by `bench_serving`,
    /// `serve --synthetic` and the resilience CI smoke so they all route
    /// over the same three-variant ladder.
    pub const SYNTHETIC_LADDER: [(&'static str, u32, usize); 3] =
        [("8a2w_n64@stem=i8", 2, 64), ("8a4w_n4@stem=i8", 4, 4), ("8a8w_n4", 8, 4)];

    /// Batch sizes advertised by the synthetic ladder.
    pub const SYNTHETIC_BATCH_SIZES: [usize; 2] = [1, 8];

    /// Manifest describing [`Self::SYNTHETIC_LADDER`] on the default
    /// resnet-mini geometry (no artifact files — only the lp pipeline can
    /// serve it).
    pub fn synthetic_manifest() -> crate::runtime::Manifest {
        let vs: Vec<String> = Self::SYNTHETIC_LADDER
            .iter()
            .map(|(name, bits, cluster)| {
                format!(
                    r#""{name}": {{"files": {{"1": "-", "8": "-"}}, "eval_acc": 0.0, "w_bits": {bits}, "cluster": {cluster}}}"#
                )
            })
            .collect();
        let net = crate::model::resnet_mini_default();
        let text = format!(
            r#"{{"img": {}, "classes": {}, "batch_sizes": [1, 8], "variants": {{{}}}}}"#,
            net.input_hw,
            net.fc_out,
            vs.join(", ")
        );
        crate::runtime::Manifest::from_json_text(&text)
            .expect("synthetic manifest is valid by construction")
    }

    /// Shared store holding [`Self::SYNTHETIC_LADDER`] from seeded synthetic
    /// weights — hand it to [`Self::store_factory`] per worker (plus
    /// [`Self::reload_hook`] on the coordinator for hot-swap coverage).
    pub fn synthetic_store(seed: u64) -> Arc<VariantStore> {
        let net = crate::model::resnet_mini_default();
        let mut variants = BTreeMap::new();
        for (name, _, _) in Self::SYNTHETIC_LADDER {
            let scheme = crate::scheme::Scheme::parse(name).expect("ladder scheme parses");
            variants
                .insert(name.to_string(), Arc::new(QModelParams::synthetic(&net, seed, &scheme)));
        }
        Arc::new(VariantStore::new(VariantSet::new(variants)))
    }

    /// Factory serving [`Self::SYNTHETIC_LADDER`] from seeded synthetic
    /// weights — runs anywhere, no artifacts on disk. Each call builds its
    /// own store; use [`Self::synthetic_store`] + [`Self::store_factory`]
    /// when the pool must share (and hot-swap) one slot.
    pub fn synthetic_factory(seed: u64, registry: KernelRegistry) -> ExecutorFactory {
        Self::store_factory(
            crate::model::resnet_mini_default(),
            Self::synthetic_store(seed),
            registry,
            Self::SYNTHETIC_BATCH_SIZES.to_vec(),
        )
    }

    /// Write [`Self::SYNTHETIC_LADDER`] to `dir` as a real artifact set —
    /// checksummed DFT v2 `qweights_<variant>.dft` exports plus a
    /// `manifest.json` — loadable by [`Self::from_artifacts`] and
    /// [`Self::reload_hook`]. The fixture generator for the CI round-trip
    /// (export → verify → corrupt → reject) and hot-swap tests.
    pub fn export_synthetic_artifacts(dir: &Path, seed: u64) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let net = crate::model::resnet_mini_default();
        let mut vs = Vec::new();
        for (name, bits, cluster) in Self::SYNTHETIC_LADDER {
            let scheme = crate::scheme::Scheme::parse(name)?;
            let params = QModelParams::synthetic(&net, seed, &scheme);
            crate::io::write_dft(&dir.join(format!("qweights_{name}.dft")), &params.to_tensors())?;
            vs.push(format!(
                r#""{name}": {{"files": {{"1": "-", "8": "-"}}, "eval_acc": 0.0, "w_bits": {bits}, "cluster": {cluster}, "requant_version": {}}}"#,
                crate::dfp::REQUANT_VERSION
            ));
        }
        let manifest = format!(
            r#"{{"img": {}, "classes": {}, "batch_sizes": [1, 8], "variants": {{{}}}}}"#,
            net.input_hw,
            net.fc_out,
            vs.join(", ")
        );
        std::fs::write(dir.join("manifest.json"), manifest)
            .with_context(|| format!("writing manifest to {}", dir.display()))?;
        Ok(())
    }
}

impl Executor for LpExecutor {
    fn run_batch_into(
        &mut self,
        variant: &str,
        batch: usize,
        x: &Tensor<f32>,
        logits: &mut [f32],
    ) -> Result<()> {
        // the Arc pins this batch's weights: a concurrent hot-swap retires
        // the generation, but these params live until the batch drains
        let params = self
            .store
            .lookup(variant)
            .with_context(|| format!("LpExecutor has no variant '{variant}'"))?;
        anyhow::ensure!(
            x.shape() == [batch, self.img, self.img, 3],
            "batch shape {:?} != ({batch}, {i}, {i}, 3)",
            x.shape(),
            i = self.img
        );
        anyhow::ensure!(
            logits.len() == batch * self.classes,
            "logits buffer has {} slots for a {batch}x{} result",
            logits.len(),
            self.classes
        );
        // per-worker workspace arena + caller-owned logits: a warm
        // steady-state batch runs this with zero heap allocations
        forward_quant_into(&params, &self.net, x, &self.registry, &mut self.workspace, logits);
        Ok(())
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        if self.store.lookup(variant).is_some() {
            self.sizes.clone()
        } else {
            Vec::new()
        }
    }

    fn img(&self) -> usize {
        self.img
    }

    fn classes(&self) -> usize {
        self.classes
    }
}

/// Deterministic fake executor for coordinator tests: logits[i][c] =
/// mean(image_i) + c, optionally with a configurable artificial delay.
pub struct MockExecutor {
    pub img: usize,
    pub classes: usize,
    pub sizes: BTreeMap<String, Vec<usize>>,
    pub delay_us_per_image: u64,
    /// (variant, batch) log of executed jobs
    pub executed: Vec<(String, usize)>,
}

impl MockExecutor {
    pub fn new(img: usize, classes: usize, variants: &[(&str, &[usize])]) -> Self {
        Self {
            img,
            classes,
            sizes: variants
                .iter()
                .map(|(v, s)| (v.to_string(), s.to_vec()))
                .collect(),
            delay_us_per_image: 0,
            executed: Vec::new(),
        }
    }
}

impl Executor for MockExecutor {
    fn run_batch_into(
        &mut self,
        variant: &str,
        batch: usize,
        x: &Tensor<f32>,
        logits: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(x.dim(0) == batch, "batch mismatch");
        anyhow::ensure!(logits.len() == batch * self.classes, "logits buffer mismatch");
        self.executed.push((variant.to_string(), batch));
        if self.delay_us_per_image > 0 {
            std::thread::sleep(std::time::Duration::from_micros(
                self.delay_us_per_image * batch as u64,
            ));
        }
        let px = self.img * self.img * 3;
        for b in 0..batch {
            let mean: f32 =
                x.data()[b * px..(b + 1) * px].iter().sum::<f32>() / px as f32;
            for c in 0..self.classes {
                logits[b * self.classes + c] = mean + c as f32;
            }
        }
        Ok(())
    }

    fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.sizes.get(variant).cloned().unwrap_or_default()
    }

    fn img(&self) -> usize {
        self.img
    }

    fn classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mock_executor_deterministic() {
        let mut m = MockExecutor::new(4, 3, &[("v", &[1, 2])]);
        let x = Tensor::new(&[1, 4, 4, 3], vec![2.0; 48]).unwrap();
        let y = m.run_batch("v", 1, &x).unwrap();
        assert_eq!(y.data(), &[2.0, 3.0, 4.0]);
        assert_eq!(m.executed, vec![("v".to_string(), 1)]);
        assert_eq!(m.batch_sizes("v"), vec![1, 2]);
        assert!(m.batch_sizes("nope").is_empty());
    }

    #[test]
    fn test_mock_rejects_bad_batch() {
        let mut m = MockExecutor::new(4, 3, &[("v", &[1])]);
        let x = Tensor::new(&[2, 4, 4, 3], vec![0.0; 96]).unwrap();
        assert!(m.run_batch("v", 1, &x).is_err());
    }

    fn lp_executor() -> LpExecutor {
        use crate::scheme::Scheme;
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let variants: BTreeMap<String, QModelParams> = [
            ("8a2w_n4", QModelParams::synthetic(&net, 3, &Scheme::parse("8a2w_n4").unwrap())),
            ("8a4w_n4", QModelParams::synthetic(&net, 4, &Scheme::parse("8a4w_n4").unwrap())),
        ]
        .into_iter()
        .map(|(n, p)| (n.to_string(), p))
        .collect();
        LpExecutor::new(net, variants, KernelRegistry::auto(), vec![1, 4]).unwrap()
    }

    #[test]
    fn test_lp_executor_serves_without_artifacts() {
        let mut e = lp_executor();
        assert_eq!(e.img(), 8);
        assert_eq!(e.classes(), 3);
        assert_eq!(e.batch_sizes("8a2w_n4"), vec![1, 4]);
        assert!(e.batch_sizes("nope").is_empty());
        assert_eq!(e.variants().len(), 2);
        let mut rng = crate::util::SplitMix64::new(9);
        let x = Tensor::new(&[2, 8, 8, 3], rng.normal(2 * 8 * 8 * 3)).unwrap();
        let y = e.run_batch("8a2w_n4", 2, &x).unwrap();
        assert_eq!(y.shape(), &[2, 3]);
        assert!(y.data().iter().all(|v| v.is_finite()));
        assert!(e.run_batch("missing", 2, &x).is_err());
        assert!(e.run_batch("8a2w_n4", 4, &x).is_err()); // batch mismatch
    }

    #[test]
    fn test_lp_executor_workspace_reuse_is_bit_exact_across_requests() {
        // repeated and size-varying batches through the same executor (and
        // therefore the same ForwardWorkspace arena) must match the
        // allocating forward exactly — a dirty arena can never leak
        let mut e = lp_executor();
        let mut rng = crate::util::SplitMix64::new(77);
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        for (variant, batch) in [("8a2w_n4", 2usize), ("8a4w_n4", 1), ("8a2w_n4", 4), ("8a2w_n4", 4)] {
            let x = Tensor::new(&[batch, 8, 8, 3], rng.normal(batch * 8 * 8 * 3)).unwrap();
            let scheme = crate::scheme::Scheme::parse(variant).unwrap();
            let seed = if variant == "8a2w_n4" { 3 } else { 4 };
            let params = QModelParams::synthetic(&net, seed, &scheme);
            let want = crate::lpinfer::forward_quant(&params, &net, &x);
            let got = e.run_batch(variant, batch, &x).unwrap();
            assert_eq!(got.data(), want.data(), "variant {variant} batch {batch}");
        }
    }

    #[test]
    fn test_synthetic_ladder_manifest_routes_three_distinct_variants() {
        let m = LpExecutor::synthetic_manifest();
        assert_eq!(m.variants.len(), 3);
        let r = crate::coordinator::Router::from_manifest(&m).unwrap();
        assert_eq!(r.active_variants().len(), 3);
        let exec = (LpExecutor::synthetic_factory(7, KernelRegistry::new(None, 1)))().unwrap();
        assert_eq!(exec.img(), m.img);
        assert_eq!(exec.classes(), m.classes);
        for (name, _, _) in LpExecutor::SYNTHETIC_LADDER {
            assert_eq!(exec.batch_sizes(name), LpExecutor::SYNTHETIC_BATCH_SIZES.to_vec());
        }
    }

    #[test]
    fn test_lp_executor_matches_direct_forward_for_all_kernels() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        let params =
            QModelParams::synthetic(&net, 3, &crate::scheme::Scheme::parse("8a2w_n4").unwrap());
        let mut rng = crate::util::SplitMix64::new(10);
        let x = Tensor::new(&[1, 8, 8, 3], rng.normal(8 * 8 * 3)).unwrap();
        let want = crate::lpinfer::forward_quant(&params, &net, &x);
        for kind in crate::kernels::ALL_KERNELS {
            let reg = KernelRegistry::new(Some(kind), 2);
            let mut e = LpExecutor::new(
                net.clone(),
                [("v".to_string(), params.clone())].into_iter().collect(),
                reg,
                vec![1],
            )
            .unwrap();
            let y = e.run_batch("v", 1, &x).unwrap();
            assert_eq!(y.data(), want.data(), "kernel {kind}");
        }
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("dfp_exec_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn test_export_synthetic_artifacts_round_trip() {
        let dir = temp_dir("roundtrip");
        LpExecutor::export_synthetic_artifacts(&dir, 7).unwrap();
        // every ladder rung exported + loadable through the checksummed path
        let (manifest, variants) = LpExecutor::load_variant_set(&dir).unwrap();
        assert_eq!(variants.len(), LpExecutor::SYNTHETIC_LADDER.len());
        assert_eq!(manifest.batch_sizes, vec![1, 8]);
        for (name, _, _) in LpExecutor::SYNTHETIC_LADDER {
            assert_eq!(manifest.variants[name].requant_version, crate::dfp::REQUANT_VERSION);
        }
        // and the loaded executor matches the in-memory synthetic weights
        let mut from_disk =
            LpExecutor::from_artifacts(&dir, KernelRegistry::new(None, 1)).unwrap();
        let factory = LpExecutor::synthetic_factory(7, KernelRegistry::new(None, 1));
        let mut from_mem = factory().unwrap();
        let net = crate::model::resnet_mini_default();
        let mut rng = crate::util::SplitMix64::new(5);
        let x = Tensor::new(
            &[1, net.input_hw, net.input_hw, 3],
            rng.normal(net.input_hw * net.input_hw * 3),
        )
        .unwrap();
        let (name, _, _) = LpExecutor::SYNTHETIC_LADDER[0];
        let a = from_disk.run_batch(name, 1, &x).unwrap();
        let b = from_mem.run_batch(name, 1, &x).unwrap();
        assert_eq!(a.data(), b.data(), "disk round-trip must be bit-exact");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn test_reload_hook_publishes_into_shared_store() {
        let dir = temp_dir("reload");
        LpExecutor::export_synthetic_artifacts(&dir, 99).unwrap();
        let store = LpExecutor::synthetic_store(1);
        let net = crate::model::resnet_mini_default();
        let mut exec = LpExecutor::with_store(
            net.clone(),
            Arc::clone(&store),
            KernelRegistry::new(None, 1),
            vec![1, 8],
        )
        .unwrap();
        let mut rng = crate::util::SplitMix64::new(5);
        let x = Tensor::new(
            &[1, net.input_hw, net.input_hw, 3],
            rng.normal(net.input_hw * net.input_hw * 3),
        )
        .unwrap();
        let (name, _, _) = LpExecutor::SYNTHETIC_LADDER[0];
        let before = exec.run_batch(name, 1, &x).unwrap();

        let hook = LpExecutor::reload_hook(Arc::clone(&store));
        let prepared = hook(&dir).unwrap();
        assert_eq!(prepared.variants.len(), LpExecutor::SYNTHETIC_LADDER.len());
        (prepared.commit)(1);
        assert_eq!(store.generation(), 1);
        // the *same* executor now serves the swapped-in weights
        let after = exec.run_batch(name, 1, &x).unwrap();
        assert_ne!(before.data(), after.data(), "swap must change served weights");

        // a poisoned directory is rejected with a typed error naming it,
        // and nothing is published
        let missing = dir.join("nope");
        let err = hook(&missing).unwrap_err();
        assert!(matches!(err, SwapError::Rejected { .. }), "{err}");
        assert!(err.to_string().contains("nope"), "{err}");
        assert_eq!(store.generation(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
