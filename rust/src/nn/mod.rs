//! Pure-Rust f32 reference inference pipeline (the FP32 baseline the paper
//! compares against), plus the shared im2col / max pool used by the
//! integer pipeline.
//!
//! [`forward_fp`] interprets the layer DAG from [`crate::graph`], so it
//! runs any plannable network — the resnet-mini family (with weights
//! loaded from a DFT file produced by `python -m compile.train`) and the
//! bottleneck/pooled ImageNet ResNets alike.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::graph::{Graph, Op};
use crate::io::TensorMap;
use crate::kernels::ThreadPool;
use crate::model::{ConvLayer, Network};
use crate::tensor::{Element, Tensor};

pub const BN_EPS: f32 = 1e-5;

/// Don't split an im2col across threads below this many patch rows per
/// block: a patch row is a handful of `memcpy`s, far cheaper than a GEMM
/// row, so blocks must be larger before spawn cost amortizes.
const IM2COL_MIN_ROWS_PER_BLOCK: usize = 64;

/// im2col: NHWC input -> (N*Ho*Wo, kh*kw*C) patch matrix (zero padded).
/// Patch index varies (kh, kw, C) fastest-last — matches the python
/// `kernels/ref.py::im2col` layout so GEMM operands line up.
pub fn im2col<T: Element>(
    x: &Tensor<T>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Tensor<T>, (usize, usize, usize)) {
    let (n, h, w, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w + 2 * pad - kw) / stride + 1;
    let k = kh * kw * c;
    let mut out = Tensor::<T>::zeros(&[n * ho * wo, k]);
    let pool = ThreadPool::new(1);
    im2col_into(x.data(), n, h, w, c, kh, kw, stride, pad, out.data_mut(), &pool);
    (out, (n, ho, wo))
}

/// Borrowed-output [`im2col`]: build the (N·Ho·Wo, kh·kw·C) patch matrix of
/// an NHWC buffer into the caller's `out` slice, parallelized over patch-row
/// blocks on `pool` (each output row depends only on the input, so rows
/// split freely; small maps stay single-threaded and run inline with zero
/// allocations). `out` may hold stale data from a previous call — every row
/// is fully rewritten, with padding positions explicitly zeroed. Returns
/// `(ho, wo)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into<T: Element>(
    xd: &[T],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [T],
    pool: &ThreadPool,
) -> (usize, usize) {
    assert_eq!(xd.len(), n * h * w * c, "im2col: input is not (N,{h},{w},{c})");
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (w + 2 * pad - kw) / stride + 1;
    let k = kh * kw * c;
    let rows = n * ho * wo;
    assert_eq!(out.len(), rows * k, "im2col: out has {} slots for {rows}x{k}", out.len());
    pool.run_row_blocks(out, rows, k, IM2COL_MIN_ROWS_PER_BLOCK, |row0, nrows, block| {
        for r in 0..nrows {
            let row = row0 + r;
            let ox = row % wo;
            let oy = (row / wo) % ho;
            let b = row / (ho * wo);
            let orow = &mut block[r * k..(r + 1) * k];
            for ky in 0..kh {
                let iy = (oy * stride + ky) as isize - pad as isize;
                for kx in 0..kw {
                    let dst = (ky * kw + kx) * c;
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                        orow[dst..dst + c].fill(T::default()); // zero padding
                    } else {
                        let src = ((b * h + iy as usize) * w + ix as usize) * c;
                        orow[dst..dst + c].copy_from_slice(&xd[src..src + c]);
                    }
                }
            }
        }
    });
    (ho, wo)
}

/// Borrowed-output 2-D max pool over an NHWC buffer: `k`×`k` window,
/// `stride`, symmetric `pad`. Out-of-bounds window positions are
/// **ignored** (the max runs over the in-bounds window only — the
/// "-inf padding" convention), so the result on quantized i8 codes equals
/// requantizing the f32 pool output: max commutes with the monotone
/// per-tensor requantization. `out` may hold stale data; every output
/// element is rewritten. No allocation — safe on the zero-alloc forward
/// path. Returns `(ho, wo)`.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_into<T: Copy + PartialOrd>(
    x: &[T],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut [T],
) -> (usize, usize) {
    assert!(k >= 1 && stride >= 1 && pad < k, "maxpool: degenerate window");
    assert!(h + 2 * pad >= k && w + 2 * pad >= k, "maxpool: window does not fit");
    assert_eq!(x.len(), n * h * w * c, "maxpool: input is not (N,{h},{w},{c})");
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    assert!(out.len() >= n * ho * wo * c, "maxpool: out buffer too small");
    for b in 0..n {
        for oy in 0..ho {
            let ys = (oy * stride).saturating_sub(pad);
            let ye = (oy * stride + k - pad).min(h);
            for ox in 0..wo {
                let xs = (ox * stride).saturating_sub(pad);
                let xe = (ox * stride + k - pad).min(w);
                let orow = &mut out[((b * ho + oy) * wo + ox) * c..][..c];
                let mut first = true;
                for y in ys..ye {
                    for xx in xs..xe {
                        let src = &x[((b * h + y) * w + xx) * c..][..c];
                        if first {
                            orow.copy_from_slice(src);
                            first = false;
                        } else {
                            for (o, &s) in orow.iter_mut().zip(src) {
                                if s > *o {
                                    *o = s;
                                }
                            }
                        }
                    }
                }
                debug_assert!(!first, "window covered no input element");
            }
        }
    }
    (ho, wo)
}

/// Allocating [`maxpool2d_into`] over an NHWC tensor (reference paths).
pub fn maxpool2d<T: Element + PartialOrd>(x: &Tensor<T>, k: usize, stride: usize, pad: usize) -> Tensor<T> {
    let (n, h, w, c) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::<T>::zeros(&[n, ho, wo, c]);
    maxpool2d_into(x.data(), n, h, w, c, k, stride, pad, out.data_mut());
    out
}

/// f32 GEMM: (M,K) x (K,F) -> (M,F). Row-major, k-inner loop ordered for
/// cache-friendly access on the (K,F) weight matrix.
pub fn gemm_f32(a: &Tensor<f32>, b: &Tensor<f32>) -> Tensor<f32> {
    let (m, k) = (a.dim(0), a.dim(1));
    let (k2, f) = (b.dim(0), b.dim(1));
    assert_eq!(k, k2);
    let mut out = Tensor::<f32>::zeros(&[m, f]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut od[i * f..(i + 1) * f];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * f..(kk + 1) * f];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// FP32 parameters for one conv layer (weights HWIO + BN).
#[derive(Debug, Clone)]
pub struct ConvParams {
    pub w: Tensor<f32>,
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

/// Whole-model FP32 parameters keyed by layer name.
#[derive(Debug, Clone)]
pub struct FpParams {
    pub convs: BTreeMap<String, ConvParams>,
    pub fc_w: Tensor<f32>,
    pub fc_b: Vec<f32>,
}

impl FpParams {
    /// Load from a DFT map using the python naming convention
    /// (`{layer}.w`, `{layer}.gamma`, ..., `fc.w`, `fc.b`).
    pub fn from_tensors(map: &TensorMap, net: &Network) -> Result<Self> {
        let get_f32 = |name: &str| -> Result<Tensor<f32>> {
            Ok(map
                .get(name)
                .with_context(|| format!("missing tensor {name}"))?
                .as_f32()?
                .clone())
        };
        let mut convs = BTreeMap::new();
        for l in &net.layers {
            let n = &l.name;
            convs.insert(
                n.clone(),
                ConvParams {
                    w: get_f32(&format!("{n}.w"))?,
                    gamma: get_f32(&format!("{n}.gamma"))?.into_data(),
                    beta: get_f32(&format!("{n}.beta"))?.into_data(),
                    mean: get_f32(&format!("{n}.mean"))?.into_data(),
                    var: get_f32(&format!("{n}.var"))?.into_data(),
                },
            );
        }
        Ok(Self { convs, fc_w: get_f32("fc.w")?, fc_b: get_f32("fc.b")?.into_data() })
    }
}

fn conv_bn(x: &Tensor<f32>, l: &ConvLayer, p: &ConvParams, relu: bool) -> Tensor<f32> {
    let (cols, (n, ho, wo)) = im2col(x, l.kh, l.kw, l.stride, l.pad);
    let wflat = p
        .w
        .clone()
        .reshape(&[l.kh * l.kw * l.cin, l.cout])
        .expect("weight reshape");
    let mut y = gemm_f32(&cols, &wflat);
    let cout = l.cout;
    let yd = y.data_mut();
    for row in 0..n * ho * wo {
        for c in 0..cout {
            let inv = 1.0 / (p.var[c] + BN_EPS).sqrt();
            let mut v = (yd[row * cout + c] - p.mean[c]) * inv * p.gamma[c] + p.beta[c];
            if relu {
                v = v.max(0.0);
            }
            yd[row * cout + c] = v;
        }
    }
    y.reshape(&[n, ho, wo, cout]).expect("conv output reshape")
}

/// Forward a batch (NHWC f32) through the fp32 network. Returns logits.
///
/// Interprets the layer DAG ([`crate::graph::Graph`]) in its deterministic
/// schedule, so the same code runs the 2-conv mini family and the
/// bottleneck/pooled ImageNet ResNets. Residual semantics: a conv feeding
/// a residual add runs without ReLU; the add applies ReLU (He et al.
/// post-activation).
pub fn forward_fp(params: &FpParams, net: &Network, x: &Tensor<f32>) -> Tensor<f32> {
    let g = Graph::from_network(net, x.dim(1), x.dim(2))
        .unwrap_or_else(|e| panic!("forward_fp: cannot plan network '{}': {e}", net.name));
    let consumers = g.consumers();
    let mut vals: Vec<Option<Tensor<f32>>> = vec![None; g.nodes.len()];
    let mut h: Option<Tensor<f32>> = None; // the GAP input
    for id in g.schedule() {
        let node = &g.nodes[id];
        let out = match node.op {
            Op::Input => x.clone(),
            Op::Conv { layer } => {
                let l = &net.layers[layer];
                let feeds_add =
                    consumers[id].iter().any(|&cid| matches!(g.nodes[cid].op, Op::Add));
                let src = vals[node.inputs[0]].as_ref().expect("producer scheduled first");
                conv_bn(src, l, &params.convs[&l.name], l.relu && !feeds_add)
            }
            Op::Pool { k, stride, pad } => {
                let src = vals[node.inputs[0]].as_ref().expect("producer scheduled first");
                maxpool2d(src, k, stride, pad)
            }
            Op::Skip => vals[node.inputs[0]].clone().expect("producer scheduled first"),
            Op::Add => {
                let mut chain =
                    vals[node.inputs[0]].clone().expect("producer scheduled first");
                let skip = vals[node.inputs[1]].as_ref().expect("producer scheduled first");
                let cd = chain.data_mut();
                for (v, &s) in cd.iter_mut().zip(skip.data()) {
                    *v = (*v + s).max(0.0);
                }
                chain
            }
            Op::Gap => {
                h = vals[node.inputs[0]].clone();
                continue;
            }
            Op::Fc => continue,
        };
        vals[id] = Some(out);
    }
    let h = h.expect("every graph ends in GAP");

    // global average pool + fc
    let (n, ho, wo, c) = (h.dim(0), h.dim(1), h.dim(2), h.dim(3));
    let mut feat = Tensor::<f32>::zeros(&[n, c]);
    {
        let hd = h.data();
        let fd = feat.data_mut();
        for b in 0..n {
            for y in 0..ho {
                for xx in 0..wo {
                    let base = ((b * ho + y) * wo + xx) * c;
                    for ch in 0..c {
                        fd[b * c + ch] += hd[base + ch];
                    }
                }
            }
        }
        let inv = 1.0 / (ho * wo) as f32;
        for v in fd.iter_mut() {
            *v *= inv;
        }
    }
    let mut logits = gemm_f32(&feat, &params.fc_w);
    let ld = logits.data_mut();
    let ncls = params.fc_b.len();
    for b in 0..n {
        for k in 0..ncls {
            ld[b * ncls + k] += params.fc_b[k];
        }
    }
    logits
}

/// Argmax per row.
pub fn argmax_rows(logits: &Tensor<f32>) -> Vec<usize> {
    let (n, c) = (logits.dim(0), logits.dim(1));
    let d = logits.data();
    (0..n)
        .map(|i| {
            let row = &d[i * c..(i + 1) * c];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn rand_tensor(shape: &[usize], seed: u64) -> Tensor<f32> {
        let mut rng = SplitMix64::new(seed);
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal(n)).unwrap()
    }

    #[test]
    fn test_im2col_identity_1x1() {
        let x = rand_tensor(&[2, 4, 4, 3], 1);
        let (cols, (n, ho, wo)) = im2col(&x, 1, 1, 1, 0);
        assert_eq!((n, ho, wo), (2, 4, 4));
        assert_eq!(cols.shape(), &[32, 3]);
        assert_eq!(cols.data(), x.data()); // 1x1/s1/p0 is a reshape
    }

    #[test]
    fn test_im2col_3x3_padding_zeroes() {
        let x = Tensor::new(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let (cols, (_, ho, wo)) = im2col(&x, 3, 3, 1, 1);
        assert_eq!((ho, wo), (2, 2));
        // top-left output pixel: only the bottom-right 2x2 of the kernel hits data
        let row0 = &cols.data()[0..9];
        assert_eq!(row0, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn test_im2col_stride2() {
        let x = rand_tensor(&[1, 4, 4, 2], 2);
        let (_, (_, ho, wo)) = im2col(&x, 3, 3, 2, 1);
        assert_eq!((ho, wo), (2, 2));
    }

    #[test]
    fn test_im2col_into_matches_alloc_reuses_dirty_buffer_and_threads() {
        use crate::kernels::ThreadPool;
        for (nb, h, w, c, kh, kw, stride, pad) in
            [(2, 5, 5, 3, 3, 3, 1, 1), (1, 8, 8, 2, 3, 3, 2, 1), (2, 4, 6, 3, 1, 1, 1, 0)]
        {
            let x = rand_tensor(&[nb, h, w, c], (h * 10 + w) as u64);
            let (want, (_, ho, wo)) = im2col(&x, kh, kw, stride, pad);
            let k = kh * kw * c;
            for threads in [1usize, 3] {
                let pool = ThreadPool::new(threads);
                // dirty buffer: padding zeros must be rewritten, not assumed
                let mut out = vec![7.5f32; nb * ho * wo * k];
                let got_hw =
                    im2col_into(x.data(), nb, h, w, c, kh, kw, stride, pad, &mut out, &pool);
                assert_eq!(got_hw, (ho, wo));
                assert_eq!(&out[..], want.data(), "threads={threads} kh={kh} stride={stride}");
            }
        }
    }

    #[test]
    fn test_maxpool_3x3_s2_p1_imagenet_stem_geometry() {
        // 4x4 single-channel ramp; 3x3/s2/p1 -> 2x2, padding ignored
        let x = Tensor::new(
            &[1, 4, 4, 1],
            (0..16).map(|v| v as f32).collect::<Vec<f32>>(),
        )
        .unwrap();
        let y = maxpool2d(&x, 3, 2, 1);
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        // windows (in-bounds): rows/cols {0,1},{1,2,3} etc.
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn test_maxpool_i8_into_reuses_dirty_buffer_and_ignores_padding() {
        // all-negative codes: a zero-padded pool would wrongly clamp to 0
        let x: Vec<i8> = vec![-5, -3, -9, -1, -7, -2, -8, -6, -4];
        let mut out = vec![127i8; 2 * 2];
        let (ho, wo) = maxpool2d_into(&x, 1, 3, 3, 1, 3, 2, 1, &mut out);
        assert_eq!((ho, wo), (2, 2));
        // windows: {(-5,-3,-1,-7)}, {(-3,-9,-7,-2)}, {(-1,-7,-8,-6)}, {(-7,-2,-6,-4)}
        assert_eq!(&out[..], &[-1, -2, -1, -2]);
    }

    #[test]
    fn test_maxpool_channels_independent() {
        let x = Tensor::new(&[1, 2, 2, 2], vec![1.0, 8.0, 2.0, 7.0, 3.0, 6.0, 4.0, 5.0])
            .unwrap();
        let y = maxpool2d(&x, 2, 1, 0);
        assert_eq!(y.shape(), &[1, 1, 1, 2]);
        assert_eq!(y.data(), &[4.0, 8.0]);
    }

    #[test]
    fn test_gemm_small_exact() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let b = Tensor::new(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]).unwrap();
        let c = gemm_f32(&a, &b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn test_conv_equals_direct_computation() {
        // 1x1 conv with identity BN == per-pixel matmul
        let x = rand_tensor(&[1, 3, 3, 2], 3);
        let l = ConvLayer {
            name: "t".into(),
            kh: 1,
            kw: 1,
            cin: 2,
            cout: 2,
            stride: 1,
            pad: 0,
            out_hw: 3,
            residual: false,
            relu: false,
        };
        let p = ConvParams {
            w: Tensor::new(&[1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
            gamma: vec![1.0; 2],
            beta: vec![0.0; 2],
            mean: vec![0.0; 2],
            var: vec![1.0 - BN_EPS; 2],
        };
        let y = conv_bn(&x, &l, &p, false);
        for (a, b) in x.data().iter().zip(y.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn test_argmax() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 0.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }
}
