//! Network architecture descriptions.
//!
//! Two families:
//! * [`resnet_mini`] — the trained substitute model (mirrors
//!   `python/compile/model.py::ModelSpec`), used by the nn / lpinfer
//!   pipelines and the serving artifacts.
//! * [`resnet18`] / [`resnet50`] / [`resnet101`] — exact layer tables of
//!   the paper's evaluation networks. The §3.3 op-count claims (85 % of
//!   multiplies replaced at N=4, ≈98 % at N=64) are *analytic* facts about
//!   these shapes, so we reproduce them on the real architectures.

/// One convolution (or FC, as a 1x1 conv over a 1x1 map) layer.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvLayer {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
    /// Output spatial size (square) — input map is derived as out*stride.
    pub out_hw: usize,
    /// Residual-add into this layer's output (before ReLU)?
    pub residual: bool,
    /// ReLU after BN?
    pub relu: bool,
}

impl ConvLayer {
    /// Multiply-accumulates for one inference of this layer.
    pub fn macs(&self) -> u64 {
        (self.kh * self.kw * self.cin * self.cout * self.out_hw * self.out_hw) as u64
    }

    /// True for 1×1 / stride-1 / pad-0 convolutions, whose im2col patch
    /// matrix is element-for-element the NHWC input itself — the forward
    /// pass feeds the activation buffer straight to the GEMM and skips the
    /// im2col copy entirely (the bulk of ResNet bottleneck convs).
    pub fn is_pointwise(&self) -> bool {
        self.kh == 1 && self.kw == 1 && self.stride == 1 && self.pad == 0
    }

    /// Weights in this layer.
    pub fn n_weights(&self) -> u64 {
        (self.kh * self.kw * self.cin * self.cout) as u64
    }
}

/// A max-pooling layer. The ImageNet ResNets put one 3×3/stride-2 max pool
/// between the 7×7 stem and the first residual stage (112² → 56²); the
/// paper's networks are not executable without it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolLayer {
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

/// A network: ordered conv layers + a final FC.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub input_hw: usize,
    pub layers: Vec<ConvLayer>,
    /// Max pool between `layers[0]` (the stem) and the residual stages.
    /// `None` for the mini family (whose stem keeps the input resolution).
    pub stem_pool: Option<PoolLayer>,
    pub fc_in: usize,
    pub fc_out: usize,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(ConvLayer::macs).sum::<u64>() + (self.fc_in * self.fc_out) as u64
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(ConvLayer::n_weights).sum::<u64>()
            + (self.fc_in * self.fc_out) as u64
    }

    /// Fraction of conv MACs that live in KxK (K>1) layers.
    pub fn frac_macs_3x3(&self) -> f64 {
        let k3: u64 = self.layers.iter().filter(|l| l.kh > 1).map(ConvLayer::macs).sum();
        let total: u64 = self.layers.iter().map(ConvLayer::macs).sum();
        k3 as f64 / total as f64
    }
}

fn conv(name: &str, k: usize, cin: usize, cout: usize, stride: usize, out_hw: usize) -> ConvLayer {
    ConvLayer {
        name: name.into(),
        kh: k,
        kw: k,
        cin,
        cout,
        stride,
        pad: k / 2,
        out_hw,
        residual: false,
        relu: true,
    }
}

/// The trained substitute model (must match `python/compile/model.py`).
pub fn resnet_mini(img: usize, channels: &[usize], blocks_per_stage: usize, classes: usize) -> Network {
    let mut layers = vec![conv("stem", 3, 3, channels[0], 1, img)];
    let mut cin = channels[0];
    let mut hw = img;
    for (s, &ch) in channels.iter().enumerate() {
        for b in 0..blocks_per_stage {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            if stride == 2 {
                hw /= 2;
            }
            let pre = format!("s{s}b{b}");
            layers.push(conv(&format!("{pre}c1"), 3, cin, ch, stride, hw));
            let mut c2 = conv(&format!("{pre}c2"), 3, ch, ch, 1, hw);
            c2.residual = true;
            layers.push(c2);
            if stride != 1 || cin != ch {
                let mut p = conv(&format!("{pre}proj"), 1, cin, ch, stride, hw);
                p.relu = false;
                layers.push(p);
            }
            cin = ch;
        }
    }
    Network {
        name: "resnet-mini".into(),
        input_hw: img,
        layers,
        stem_pool: None,
        fc_in: *channels.last().unwrap(),
        fc_out: classes,
    }
}

/// A miniature bottleneck (1×1-3×3-1×1) ResNet with the ImageNet stem
/// max pool — the ResNet-50/101 block structure at test scale, so the
/// graph planner's bottleneck and pool paths are exercised by fast tests.
/// One block per stage; `widths` are the per-stage bottleneck widths
/// (output channels are 4×).
pub fn bottleneck_mini(img: usize, widths: &[usize], classes: usize) -> Network {
    let mut layers = vec![conv("stem", 3, 3, widths[0], 1, img)];
    let mut cin = widths[0];
    let mut hw = img / 2; // after the 3x3/s2 stem pool
    for (s, &width) in widths.iter().enumerate() {
        let cout = width * 4;
        let stride = if s > 0 { 2 } else { 1 };
        if stride == 2 {
            hw /= 2;
        }
        let pre = format!("s{s}b0");
        layers.push(conv(&format!("{pre}a"), 1, cin, width, stride, hw));
        layers.push(conv(&format!("{pre}b"), 3, width, width, 1, hw));
        let mut c = conv(&format!("{pre}c"), 1, width, cout, 1, hw);
        c.residual = true;
        layers.push(c);
        if cin != cout || stride != 1 {
            let mut p = conv(&format!("{pre}proj"), 1, cin, cout, stride, hw);
            p.relu = false;
            layers.push(p);
        }
        cin = cout;
    }
    Network {
        name: "bottleneck-mini".into(),
        input_hw: img,
        layers,
        stem_pool: Some(PoolLayer { k: 3, stride: 2, pad: 1 }),
        fc_in: *widths.last().unwrap() * 4,
        fc_out: classes,
    }
}

/// Default resnet-mini matching the python `ModelSpec()` defaults.
pub fn resnet_mini_default() -> Network {
    resnet_mini(24, &[32, 64, 128], 1, 10)
}

// ---------------------------------------------------------------------------
// Exact ImageNet ResNets (He et al. 2015 layer tables, 224x224 input)
// ---------------------------------------------------------------------------

/// Basic-block ResNet-18.
pub fn resnet18() -> Network {
    let mut layers = vec![conv("conv1", 7, 3, 64, 2, 112)];
    let cfg: &[(usize, usize, usize)] = &[(64, 2, 56), (128, 2, 28), (256, 2, 14), (512, 2, 7)];
    let mut cin = 64;
    for (si, &(ch, blocks, hw)) in cfg.iter().enumerate() {
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let pre = format!("s{si}b{b}");
            layers.push(conv(&format!("{pre}c1"), 3, cin, ch, stride, hw));
            let mut c2 = conv(&format!("{pre}c2"), 3, ch, ch, 1, hw);
            c2.residual = true;
            layers.push(c2);
            if stride != 1 || cin != ch {
                let mut p = conv(&format!("{pre}proj"), 1, cin, ch, stride, hw);
                p.relu = false;
                layers.push(p);
            }
            cin = ch;
        }
    }
    Network {
        name: "resnet-18".into(),
        input_hw: 224,
        layers,
        stem_pool: Some(PoolLayer { k: 3, stride: 2, pad: 1 }),
        fc_in: 512,
        fc_out: 1000,
    }
}

/// Bottleneck ResNet: blocks of (1x1 reduce, 3x3, 1x1 expand).
fn resnet_bottleneck(name: &str, stage_blocks: [usize; 4]) -> Network {
    let mut layers = vec![conv("conv1", 7, 3, 64, 2, 112)];
    let stage_cfg: [(usize, usize); 4] = [(64, 56), (128, 28), (256, 14), (512, 7)];
    let mut cin = 64; // after maxpool
    for (si, (&nblocks, &(width, hw))) in stage_blocks.iter().zip(stage_cfg.iter()).enumerate() {
        let cout = width * 4;
        for b in 0..nblocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let pre = format!("s{si}b{b}");
            layers.push(conv(&format!("{pre}a"), 1, cin, width, stride, hw));
            layers.push(conv(&format!("{pre}b"), 3, width, width, 1, hw));
            let mut c = conv(&format!("{pre}c"), 1, width, cout, 1, hw);
            c.residual = true;
            c.relu = true;
            layers.push(c);
            if cin != cout || stride != 1 {
                let mut p = conv(&format!("{pre}proj"), 1, cin, cout, stride, hw);
                p.relu = false;
                layers.push(p);
            }
            cin = cout;
        }
    }
    Network {
        name: name.into(),
        input_hw: 224,
        layers,
        stem_pool: Some(PoolLayer { k: 3, stride: 2, pad: 1 }),
        fc_in: 2048,
        fc_out: 1000,
    }
}

/// ResNet-50 (3-4-6-3 bottleneck blocks).
pub fn resnet50() -> Network {
    resnet_bottleneck("resnet-50", [3, 4, 6, 3])
}

/// ResNet-101 (3-4-23-3 bottleneck blocks) — the paper's headline network.
pub fn resnet101() -> Network {
    resnet_bottleneck("resnet-101", [3, 4, 23, 3])
}

pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "resnet-mini" | "mini" => Some(resnet_mini_default()),
        "resnet-18" | "resnet18" => Some(resnet18()),
        "resnet-50" | "resnet50" => Some(resnet50()),
        "resnet-101" | "resnet101" => Some(resnet101()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_resnet50_shape_facts() {
        let n = resnet50();
        // 1 stem + 16 blocks * 3 convs + 4 projections = 53 convs; +fc = "50" trainable main path
        assert_eq!(n.layers.len(), 1 + 16 * 3 + 4);
        // ~25.5M params (conv ~23.5M + fc 2M); MACs ~4.1 GMACs (3.8G conv + pool/fc)
        let w = n.total_weights();
        assert!((23_000_000..27_000_000).contains(&w), "{w}");
        let m = n.total_macs();
        assert!((3_600_000_000..4_300_000_000).contains(&m), "{m}");
    }

    #[test]
    fn test_resnet101_shape_facts() {
        let n = resnet101();
        assert_eq!(n.layers.len(), 1 + 33 * 3 + 4);
        let w = n.total_weights();
        assert!((42_000_000..46_500_000).contains(&w), "{w}"); // ~44.5M
        let m = n.total_macs();
        assert!((7_000_000_000..8_200_000_000).contains(&m), "{m}"); // ~7.8 GMACs
    }

    #[test]
    fn test_resnet18_macs() {
        let m = resnet18().total_macs();
        assert!((1_600_000_000..1_950_000_000).contains(&m), "{m}"); // ~1.8 GMACs
    }

    #[test]
    fn test_resnet101_op_mix_roughly_half_3x3() {
        // §3.3: "roughly 50% of the convolutions are 3x3 and the rest 1x1"
        let f = resnet101().frac_macs_3x3();
        assert!((0.35..0.75).contains(&f), "{f}");
    }

    #[test]
    fn test_mini_matches_python_spec() {
        let n = resnet_mini_default();
        let names: Vec<&str> = n.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["stem", "s0b0c1", "s0b0c2", "s1b0c1", "s1b0c2", "s1b0proj", "s2b0c1", "s2b0c2", "s2b0proj"]
        );
        assert_eq!(n.layers[3].out_hw, 12); // stride-2 stage
        assert_eq!(n.layers[6].out_hw, 6);
        assert_eq!(n.fc_in, 128);
    }

    #[test]
    fn test_by_name() {
        assert!(by_name("resnet-101").is_some());
        assert!(by_name("vgg").is_none());
    }

    #[test]
    fn test_imagenet_nets_carry_stem_pool_geometry() {
        // 224 -> conv1/s2 -> 112 -> 3x3/s2 pool -> 56 = stage-0 resolution;
        // without the pool the declared layer table is not executable.
        for net in [resnet18(), resnet50(), resnet101()] {
            let p = net.stem_pool.expect("ImageNet ResNets have a stem max pool");
            assert_eq!((p.k, p.stride, p.pad), (3, 2, 1), "{}", net.name);
            assert_eq!(net.layers[0].out_hw, 112);
            // pool output feeds the first stage at its input resolution
            let pooled = (112 + 2 * p.pad - p.k) / p.stride + 1;
            assert_eq!(pooled, net.layers[1].out_hw * net.layers[1].stride);
        }
        assert!(resnet_mini_default().stem_pool.is_none());
    }

    #[test]
    fn test_bottleneck_mini_structure() {
        let n = bottleneck_mini(16, &[4, 8], 3);
        let names: Vec<&str> = n.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["stem", "s0b0a", "s0b0b", "s0b0c", "s0b0proj", "s1b0a", "s1b0b", "s1b0c", "s1b0proj"]
        );
        // stem keeps 16², pool halves to 8², stage 1 strides to 4²
        assert_eq!(n.layers[0].out_hw, 16);
        assert_eq!(n.layers[1].out_hw, 8);
        assert_eq!(n.layers[5].out_hw, 4);
        assert!(n.layers[3].residual && !n.layers[4].relu);
        assert_eq!(n.fc_in, 32);
        assert_eq!(n.stem_pool, Some(PoolLayer { k: 3, stride: 2, pad: 1 }));
    }
}
