//! Minimal dense tensor library (row-major, CPU) — ndarray is not available
//! offline, and the inference pipelines only need contiguous NHWC/HWIO
//! buffers with cheap indexing.

use anyhow::{bail, Result};

/// Element types storable in a [`Tensor`] / DFT container. `Send + Sync`
/// so generic buffers can be filled in parallel over the kernels'
/// [`crate::kernels::ThreadPool`] (every implementor is a primitive).
pub trait Element: Copy + Default + std::fmt::Debug + Send + Sync + 'static {
    const DTYPE: DType;
}

/// On-disk / wire dtype tags (shared with `python/compile/dft.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    I32 = 2,
    U8 = 3,
    I64 = 4,
}

impl DType {
    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            3 => DType::U8,
            4 => DType::I64,
            _ => bail!("unknown dtype tag {tag}"),
        })
    }

    pub fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
            DType::I64 => 8,
        }
    }
}

impl Element for f32 {
    const DTYPE: DType = DType::F32;
}
impl Element for i8 {
    const DTYPE: DType = DType::I8;
}
impl Element for i32 {
    const DTYPE: DType = DType::I32;
}
impl Element for u8 {
    const DTYPE: DType = DType::U8;
}
impl Element for i64 {
    const DTYPE: DType = DType::I64;
}

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T: Element> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Element> Tensor<T> {
    pub fn new(shape: &[usize], data: Vec<T>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn scalar(v: T) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Dimension i, or 1 if the axis doesn't exist (broadcast-friendly).
    pub fn dim(&self, i: usize) -> usize {
        self.shape.get(i).copied().unwrap_or(1)
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flat offset of a multi-index (debug-checked).
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        let mut stride = 1;
        for i in (0..self.shape.len()).rev() {
            debug_assert!(idx[i] < self.shape[i], "index {idx:?} out of {:?}", self.shape);
            off += idx[i] * stride;
            stride *= self.shape[i];
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: T) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    pub fn map<U: Element>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }
}

impl Tensor<f32> {
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| f64::from(x) * f64::from(x)).sum::<f64>().sqrt() as f32
    }

    /// Max |a - b| between two equal-shaped tensors.
    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_new_checks_shape() {
        assert!(Tensor::<f32>::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::<f32>::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn test_indexing_row_major() {
        let t = Tensor::new(&[2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
        assert_eq!(t.strides(), vec![3, 1]);
    }

    #[test]
    fn test_reshape() {
        let t = Tensor::new(&[2, 3], vec![1i32; 6]).unwrap();
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert!(r.reshape(&[7]).is_err());
    }

    #[test]
    fn test_set_get_4d() {
        let mut t = Tensor::<i8>::zeros(&[2, 4, 4, 3]);
        t.set(&[1, 2, 3, 1], 42);
        assert_eq!(t.at(&[1, 2, 3, 1]), 42);
        assert_eq!(t.at(&[1, 2, 3, 0]), 0);
    }

    #[test]
    fn test_map_and_norms() {
        let t = Tensor::new(&[3], vec![3.0f32, -4.0, 0.0]).unwrap();
        assert_eq!(t.max_abs(), 4.0);
        assert!((t.norm() - 5.0).abs() < 1e-6);
        let q = t.map(|x| x as i32);
        assert_eq!(q.data(), &[3, -4, 0]);
    }

    #[test]
    fn test_scalar_and_dim() {
        let s = Tensor::scalar(7i32);
        assert_eq!(s.ndim(), 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.dim(3), 1);
    }

    #[test]
    fn test_max_abs_diff() {
        let a = Tensor::new(&[2], vec![1.0f32, 2.0]).unwrap();
        let b = Tensor::new(&[2], vec![1.5f32, 1.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn test_dtype_tags_roundtrip() {
        for d in [DType::F32, DType::I8, DType::I32, DType::U8, DType::I64] {
            assert_eq!(DType::from_tag(d as u8).unwrap(), d);
        }
        assert!(DType::from_tag(99).is_err());
    }
}
