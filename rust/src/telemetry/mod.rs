//! Engine telemetry: per-forward profile slots + global atomic counters.
//!
//! Two layers, designed so the PR 5 zero-allocation steady state survives
//! (asserted in `rust/tests/alloc_steady_state.rs`):
//!
//! * [`ForwardProfile`] — per-layer/per-stage slots owned by a
//!   `ForwardWorkspace`. Preallocated when the workspace is sized
//!   (`begin` grows monotonically, exactly like the arena buffers) and
//!   filled by plain stores on the hot path; nothing here is shared or
//!   atomic. After each forward the profile is **drained** into the
//!   global [`EngineMetrics`] (a fixed number of relaxed `fetch_add`s —
//!   no allocation, no locks).
//! * [`EngineMetrics`] — a struct of `AtomicU64` counters. One global
//!   instance ([`engine`]) aggregates across every forward and every
//!   thread; unit tests construct local instances for exact accounting.
//!   [`EngineSnapshot`] is a plain `Copy` image of the counters —
//!   taking one never allocates, so tests can snapshot *inside* a
//!   counted region; `report()`/`to_json()` (which do allocate) run
//!   outside.
//!
//! Kernel-level hooks (row-skip tallies, GEMM dispatch, epilogue block
//! classification, thread-pool fan-out) go through the gated free
//! functions below: [`set_enabled`]`(false)` turns them into an early
//! return so `bench_kernels` can measure the instrumentation overhead
//! (`profiling_overhead` in `BENCH_kernels.json`). The per-workspace
//! profile stores and the end-of-forward drain are *not* gated — they
//! are a handful of operations per forward, far below measurement
//! noise. Hot loops never call these per element: callers tally into
//! locals and publish **one** `fetch_add` per row block / call.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::json::Json;
use crate::kernels::KernelKind;

// ---------------------------------------------------------------------------
// Per-forward profile (workspace-owned, no atomics)
// ---------------------------------------------------------------------------

/// Per-stage / per-layer timing and skip slots for one forward pass.
///
/// One row per `ForwardPlan` conv step (network layer order), plus
/// scalar slots for the non-conv stages. All times are wall-clock
/// nanoseconds for the whole batch.
#[derive(Debug, Clone, Default)]
pub struct ForwardProfile {
    /// conv steps recorded this forward (rows `0..layers` are live)
    pub layers: usize,
    /// batch size of the profiled forward
    pub batch: usize,
    /// input f32 -> i8 quantization
    pub quantize_ns: u64,
    /// identity skip-lane rescale (blocks without a projection conv)
    pub skip_ns: u64,
    /// stem max pool over i8 codes (0 for nets without one); distinct
    /// from the engine's `pool_*` counters, which track the thread pool
    pub maxpool_ns: u64,
    /// integer global average pool
    pub gap_ns: u64,
    /// FC GEMM + f32 logits
    pub fc_ns: u64,
    /// whole forward, entry to exit
    pub total_ns: u64,
    /// per conv: im2col time (0 for direct 1×1 layers)
    pub im2col_ns: Vec<u64>,
    /// per conv: fused GEMM + epilogue time
    pub gemm_ns: Vec<u64>,
    /// per conv: activation rows probed by the i8 zero-skip kernel
    pub rows_probed: Vec<u64>,
    /// per conv: rows the probe routed to the zero-skipping loop
    pub rows_skipped: Vec<u64>,
}

impl ForwardProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size for a forward of `layers` conv steps at batch `batch` and
    /// zero the live slots. Growth is monotonic (high-water, like the
    /// workspace arena), so the steady state performs no allocation.
    pub fn begin(&mut self, layers: usize, batch: usize) {
        if self.im2col_ns.len() < layers {
            self.im2col_ns.resize(layers, 0);
            self.gemm_ns.resize(layers, 0);
            self.rows_probed.resize(layers, 0);
            self.rows_skipped.resize(layers, 0);
        }
        self.layers = layers;
        self.batch = batch;
        self.quantize_ns = 0;
        self.skip_ns = 0;
        self.maxpool_ns = 0;
        self.gap_ns = 0;
        self.fc_ns = 0;
        self.total_ns = 0;
        for v in [
            &mut self.im2col_ns,
            &mut self.gemm_ns,
            &mut self.rows_probed,
            &mut self.rows_skipped,
        ] {
            v[..layers].fill(0);
        }
    }

    /// Total conv time (im2col + fused GEMM) over the live rows.
    pub fn conv_ns(&self) -> u64 {
        let l = self.layers;
        self.im2col_ns[..l].iter().sum::<u64>() + self.gemm_ns[..l].iter().sum::<u64>()
    }

    /// Element-wise add of another profile's live slots (profiling CLI
    /// aggregation across runs — not a hot-path operation).
    pub fn accumulate(&mut self, other: &ForwardProfile) {
        if self.im2col_ns.len() < other.layers {
            self.im2col_ns.resize(other.layers, 0);
            self.gemm_ns.resize(other.layers, 0);
            self.rows_probed.resize(other.layers, 0);
            self.rows_skipped.resize(other.layers, 0);
        }
        self.layers = self.layers.max(other.layers);
        self.batch = other.batch;
        self.quantize_ns += other.quantize_ns;
        self.skip_ns += other.skip_ns;
        self.maxpool_ns += other.maxpool_ns;
        self.gap_ns += other.gap_ns;
        self.fc_ns += other.fc_ns;
        self.total_ns += other.total_ns;
        for i in 0..other.layers {
            self.im2col_ns[i] += other.im2col_ns[i];
            self.gemm_ns[i] += other.gemm_ns[i];
            self.rows_probed[i] += other.rows_probed[i];
            self.rows_skipped[i] += other.rows_skipped[i];
        }
    }
}

// ---------------------------------------------------------------------------
// Engine counters (atomic, global or per-test instance)
// ---------------------------------------------------------------------------

/// How a fused-epilogue row block was ultimately executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpilogueBlock {
    /// vector lane path taken
    Simd,
    /// registry tier is scalar — no vector path to take
    ScalarTier,
    /// `ResolvedEpilogue` envelope miss (`SimdLanes` absent for this
    /// layer, or the lane set cannot produce this output kind)
    EnvelopeMiss,
    /// per-block skip magnitude exceeded the overflow-safe limit
    SkipLimit,
}

/// Monotonic engine counters. All operations are relaxed atomics — the
/// counters are statistics, not synchronization.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    forwards: AtomicU64,
    images: AtomicU64,
    forward_ns: AtomicU64,
    quantize_ns: AtomicU64,
    im2col_ns: AtomicU64,
    gemm_ns: AtomicU64,
    skip_ns: AtomicU64,
    gap_ns: AtomicU64,
    fc_ns: AtomicU64,
    rows_probed: AtomicU64,
    rows_skipped: AtomicU64,
    gemm_ternary: AtomicU64,
    gemm_i4: AtomicU64,
    gemm_i8_skip: AtomicU64,
    gemm_i8_dense: AtomicU64,
    epi_simd_blocks: AtomicU64,
    epi_scalar_tier_blocks: AtomicU64,
    epi_envelope_miss_blocks: AtomicU64,
    epi_skip_limit_blocks: AtomicU64,
    pool_runs: AtomicU64,
    pool_blocks: AtomicU64,
}

impl EngineMetrics {
    pub const fn new() -> Self {
        Self {
            forwards: AtomicU64::new(0),
            images: AtomicU64::new(0),
            forward_ns: AtomicU64::new(0),
            quantize_ns: AtomicU64::new(0),
            im2col_ns: AtomicU64::new(0),
            gemm_ns: AtomicU64::new(0),
            skip_ns: AtomicU64::new(0),
            gap_ns: AtomicU64::new(0),
            fc_ns: AtomicU64::new(0),
            rows_probed: AtomicU64::new(0),
            rows_skipped: AtomicU64::new(0),
            gemm_ternary: AtomicU64::new(0),
            gemm_i4: AtomicU64::new(0),
            gemm_i8_skip: AtomicU64::new(0),
            gemm_i8_dense: AtomicU64::new(0),
            epi_simd_blocks: AtomicU64::new(0),
            epi_scalar_tier_blocks: AtomicU64::new(0),
            epi_envelope_miss_blocks: AtomicU64::new(0),
            epi_skip_limit_blocks: AtomicU64::new(0),
            pool_runs: AtomicU64::new(0),
            pool_blocks: AtomicU64::new(0),
        }
    }

    pub fn on_gemm(&self, kind: KernelKind) {
        let c = match kind {
            KernelKind::PackedTernary => &self.gemm_ternary,
            KernelKind::PackedI4 => &self.gemm_i4,
            KernelKind::I8ZeroSkip => &self.gemm_i8_skip,
            KernelKind::I8Dense => &self.gemm_i8_dense,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// One call per `i8_row_block` invocation with the block's tallies.
    pub fn on_rows(&self, probed: u64, skipped: u64) {
        self.rows_probed.fetch_add(probed, Ordering::Relaxed);
        self.rows_skipped.fetch_add(skipped, Ordering::Relaxed);
    }

    pub fn on_epilogue_block(&self, how: EpilogueBlock) {
        let c = match how {
            EpilogueBlock::Simd => &self.epi_simd_blocks,
            EpilogueBlock::ScalarTier => &self.epi_scalar_tier_blocks,
            EpilogueBlock::EnvelopeMiss => &self.epi_envelope_miss_blocks,
            EpilogueBlock::SkipLimit => &self.epi_skip_limit_blocks,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// One call per `run_row_blocks2` with the block count it fanned to.
    pub fn on_pool_run(&self, blocks: u64) {
        self.pool_runs.fetch_add(1, Ordering::Relaxed);
        self.pool_blocks.fetch_add(blocks, Ordering::Relaxed);
    }

    /// Fold one forward's profile into the counters (end-of-forward
    /// drain: a fixed number of relaxed adds, no allocation).
    pub fn drain(&self, p: &ForwardProfile) {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(p.batch as u64, Ordering::Relaxed);
        self.forward_ns.fetch_add(p.total_ns, Ordering::Relaxed);
        self.quantize_ns.fetch_add(p.quantize_ns, Ordering::Relaxed);
        self.skip_ns.fetch_add(p.skip_ns, Ordering::Relaxed);
        self.gap_ns.fetch_add(p.gap_ns, Ordering::Relaxed);
        self.fc_ns.fetch_add(p.fc_ns, Ordering::Relaxed);
        let l = p.layers;
        self.im2col_ns.fetch_add(p.im2col_ns[..l].iter().sum(), Ordering::Relaxed);
        self.gemm_ns.fetch_add(p.gemm_ns[..l].iter().sum(), Ordering::Relaxed);
    }

    /// Copy out every counter. Never allocates.
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            forwards: self.forwards.load(Ordering::Relaxed),
            images: self.images.load(Ordering::Relaxed),
            forward_ns: self.forward_ns.load(Ordering::Relaxed),
            quantize_ns: self.quantize_ns.load(Ordering::Relaxed),
            im2col_ns: self.im2col_ns.load(Ordering::Relaxed),
            gemm_ns: self.gemm_ns.load(Ordering::Relaxed),
            skip_ns: self.skip_ns.load(Ordering::Relaxed),
            gap_ns: self.gap_ns.load(Ordering::Relaxed),
            fc_ns: self.fc_ns.load(Ordering::Relaxed),
            rows_probed: self.rows_probed.load(Ordering::Relaxed),
            rows_skipped: self.rows_skipped.load(Ordering::Relaxed),
            gemm_ternary: self.gemm_ternary.load(Ordering::Relaxed),
            gemm_i4: self.gemm_i4.load(Ordering::Relaxed),
            gemm_i8_skip: self.gemm_i8_skip.load(Ordering::Relaxed),
            gemm_i8_dense: self.gemm_i8_dense.load(Ordering::Relaxed),
            epi_simd_blocks: self.epi_simd_blocks.load(Ordering::Relaxed),
            epi_scalar_tier_blocks: self.epi_scalar_tier_blocks.load(Ordering::Relaxed),
            epi_envelope_miss_blocks: self.epi_envelope_miss_blocks.load(Ordering::Relaxed),
            epi_skip_limit_blocks: self.epi_skip_limit_blocks.load(Ordering::Relaxed),
            pool_runs: self.pool_runs.load(Ordering::Relaxed),
            pool_blocks: self.pool_blocks.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter (`profile` CLI run boundaries, tests).
    pub fn reset(&self) {
        for c in [
            &self.forwards,
            &self.images,
            &self.forward_ns,
            &self.quantize_ns,
            &self.im2col_ns,
            &self.gemm_ns,
            &self.skip_ns,
            &self.gap_ns,
            &self.fc_ns,
            &self.rows_probed,
            &self.rows_skipped,
            &self.gemm_ternary,
            &self.gemm_i4,
            &self.gemm_i8_skip,
            &self.gemm_i8_dense,
            &self.epi_simd_blocks,
            &self.epi_scalar_tier_blocks,
            &self.epi_envelope_miss_blocks,
            &self.epi_skip_limit_blocks,
            &self.pool_runs,
            &self.pool_blocks,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// Plain-value image of [`EngineMetrics`] at a point in time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineSnapshot {
    pub forwards: u64,
    /// images summed across drained forwards (batch sizes accumulate,
    /// so throughput is measured per image, not per batch call)
    pub images: u64,
    pub forward_ns: u64,
    pub quantize_ns: u64,
    pub im2col_ns: u64,
    pub gemm_ns: u64,
    pub skip_ns: u64,
    pub gap_ns: u64,
    pub fc_ns: u64,
    pub rows_probed: u64,
    pub rows_skipped: u64,
    pub gemm_ternary: u64,
    pub gemm_i4: u64,
    pub gemm_i8_skip: u64,
    pub gemm_i8_dense: u64,
    pub epi_simd_blocks: u64,
    pub epi_scalar_tier_blocks: u64,
    pub epi_envelope_miss_blocks: u64,
    pub epi_skip_limit_blocks: u64,
    pub pool_runs: u64,
    pub pool_blocks: u64,
}

impl EngineSnapshot {
    /// Counter-wise `self - earlier` (both from the same monotonic
    /// source, so saturating keeps racy reads sane).
    pub fn since(&self, earlier: &EngineSnapshot) -> EngineSnapshot {
        EngineSnapshot {
            forwards: self.forwards.saturating_sub(earlier.forwards),
            images: self.images.saturating_sub(earlier.images),
            forward_ns: self.forward_ns.saturating_sub(earlier.forward_ns),
            quantize_ns: self.quantize_ns.saturating_sub(earlier.quantize_ns),
            im2col_ns: self.im2col_ns.saturating_sub(earlier.im2col_ns),
            gemm_ns: self.gemm_ns.saturating_sub(earlier.gemm_ns),
            skip_ns: self.skip_ns.saturating_sub(earlier.skip_ns),
            gap_ns: self.gap_ns.saturating_sub(earlier.gap_ns),
            fc_ns: self.fc_ns.saturating_sub(earlier.fc_ns),
            rows_probed: self.rows_probed.saturating_sub(earlier.rows_probed),
            rows_skipped: self.rows_skipped.saturating_sub(earlier.rows_skipped),
            gemm_ternary: self.gemm_ternary.saturating_sub(earlier.gemm_ternary),
            gemm_i4: self.gemm_i4.saturating_sub(earlier.gemm_i4),
            gemm_i8_skip: self.gemm_i8_skip.saturating_sub(earlier.gemm_i8_skip),
            gemm_i8_dense: self.gemm_i8_dense.saturating_sub(earlier.gemm_i8_dense),
            epi_simd_blocks: self.epi_simd_blocks.saturating_sub(earlier.epi_simd_blocks),
            epi_scalar_tier_blocks: self
                .epi_scalar_tier_blocks
                .saturating_sub(earlier.epi_scalar_tier_blocks),
            epi_envelope_miss_blocks: self
                .epi_envelope_miss_blocks
                .saturating_sub(earlier.epi_envelope_miss_blocks),
            epi_skip_limit_blocks: self
                .epi_skip_limit_blocks
                .saturating_sub(earlier.epi_skip_limit_blocks),
            pool_runs: self.pool_runs.saturating_sub(earlier.pool_runs),
            pool_blocks: self.pool_blocks.saturating_sub(earlier.pool_blocks),
        }
    }

    /// Total GEMM dispatches, all encodings.
    pub fn gemm_dispatches(&self) -> u64 {
        self.gemm_ternary + self.gemm_i4 + self.gemm_i8_skip + self.gemm_i8_dense
    }

    /// Fraction of probed i8 rows that took the zero-skipping loop.
    pub fn skip_row_frac(&self) -> f64 {
        if self.rows_probed == 0 {
            return 0.0;
        }
        self.rows_skipped as f64 / self.rows_probed as f64
    }

    /// Fraction of fused-epilogue row blocks that ran the vector path.
    pub fn epi_simd_frac(&self) -> f64 {
        let total = self.epi_simd_blocks
            + self.epi_scalar_tier_blocks
            + self.epi_envelope_miss_blocks
            + self.epi_skip_limit_blocks;
        if total == 0 {
            return 0.0;
        }
        self.epi_simd_blocks as f64 / total as f64
    }

    /// Mean forward latency in milliseconds.
    pub fn mean_forward_ms(&self) -> f64 {
        if self.forwards == 0 {
            return 0.0;
        }
        self.forward_ns as f64 / self.forwards as f64 / 1e6
    }

    /// Mean images per drained forward (the served batch size).
    pub fn mean_batch(&self) -> f64 {
        if self.forwards == 0 {
            return 0.0;
        }
        self.images as f64 / self.forwards as f64
    }

    /// Two-line human report (appended to the serving metrics report).
    pub fn report(&self) -> String {
        format!(
            "engine forwards={} images={} mean={:.2}ms gemm={}t/{}i4/{}i8s/{}i8d \
             rows_skip={:.1}% epi_simd={:.1}% pool_blocks={}",
            self.forwards,
            self.images,
            self.mean_forward_ms(),
            self.gemm_ternary,
            self.gemm_i4,
            self.gemm_i8_skip,
            self.gemm_i8_dense,
            100.0 * self.skip_row_frac(),
            100.0 * self.epi_simd_frac(),
            self.pool_blocks,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("forwards", Json::num(self.forwards as f64)),
            ("images", Json::num(self.images as f64)),
            ("forward_ns", Json::num(self.forward_ns as f64)),
            ("quantize_ns", Json::num(self.quantize_ns as f64)),
            ("im2col_ns", Json::num(self.im2col_ns as f64)),
            ("gemm_ns", Json::num(self.gemm_ns as f64)),
            ("skip_ns", Json::num(self.skip_ns as f64)),
            ("gap_ns", Json::num(self.gap_ns as f64)),
            ("fc_ns", Json::num(self.fc_ns as f64)),
            ("rows_probed", Json::num(self.rows_probed as f64)),
            ("rows_skipped", Json::num(self.rows_skipped as f64)),
            ("gemm_ternary", Json::num(self.gemm_ternary as f64)),
            ("gemm_i4", Json::num(self.gemm_i4 as f64)),
            ("gemm_i8_skip", Json::num(self.gemm_i8_skip as f64)),
            ("gemm_i8_dense", Json::num(self.gemm_i8_dense as f64)),
            ("epi_simd_blocks", Json::num(self.epi_simd_blocks as f64)),
            ("epi_scalar_tier_blocks", Json::num(self.epi_scalar_tier_blocks as f64)),
            ("epi_envelope_miss_blocks", Json::num(self.epi_envelope_miss_blocks as f64)),
            ("epi_skip_limit_blocks", Json::num(self.epi_skip_limit_blocks as f64)),
            ("pool_runs", Json::num(self.pool_runs as f64)),
            ("pool_blocks", Json::num(self.pool_blocks as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Global instance + gated hooks
// ---------------------------------------------------------------------------

static ENGINE: EngineMetrics = EngineMetrics::new();
static ENABLED: AtomicBool = AtomicBool::new(true);

/// The process-wide engine counters.
pub fn engine() -> &'static EngineMetrics {
    &ENGINE
}

/// Whether the kernel-level hooks are live (default: yes).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle the kernel-level hooks. Per-workspace profile slots and the
/// end-of-forward drain stay live either way — only the in-kernel
/// counters (row tallies, dispatch/epilogue/pool counts) are gated, so
/// benches can measure exactly the overhead the gate controls.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub(crate) fn record_gemm(kind: KernelKind) {
    if enabled() {
        ENGINE.on_gemm(kind);
    }
}

#[inline]
pub(crate) fn record_rows(probed: u64, skipped: u64) {
    if enabled() {
        ENGINE.on_rows(probed, skipped);
    }
}

#[inline]
pub(crate) fn record_epilogue_block(how: EpilogueBlock) {
    if enabled() {
        ENGINE.on_epilogue_block(how);
    }
}

#[inline]
pub(crate) fn record_pool_run(blocks: u64) {
    if enabled() {
        ENGINE.on_pool_run(blocks);
    }
}

/// Current global `(rows_probed, rows_skipped)`. The forward pass reads
/// deltas around each conv to attribute skip counts to profile rows —
/// exact single-threaded; attribution between layers is approximate when
/// forwards run concurrently (the totals stay exact).
#[inline]
pub(crate) fn rows_now() -> (u64, u64) {
    (
        ENGINE.rows_probed.load(Ordering::Relaxed),
        ENGINE.rows_skipped.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(layers: usize) -> ForwardProfile {
        let mut p = ForwardProfile::new();
        p.begin(layers, 2);
        p
    }

    #[test]
    fn test_profile_begin_zeroes_and_grows_monotonically() {
        let mut p = profile(3);
        p.gemm_ns[1] = 42;
        p.quantize_ns = 7;
        let cap = p.gemm_ns.capacity();
        p.begin(3, 1);
        assert_eq!(p.gemm_ns[1], 0);
        assert_eq!(p.quantize_ns, 0);
        assert_eq!(p.batch, 1);
        assert_eq!(p.gemm_ns.capacity(), cap, "same layer count must not reallocate");
        // shrinking keeps the high-water buffers
        p.begin(2, 1);
        assert_eq!(p.layers, 2);
        assert_eq!(p.gemm_ns.len(), 3);
        // growing resizes
        p.begin(5, 1);
        assert_eq!(p.gemm_ns.len(), 5);
    }

    #[test]
    fn test_profile_accumulate_sums_live_rows() {
        let mut a = profile(2);
        a.gemm_ns[0] = 10;
        a.im2col_ns[1] = 5;
        a.fc_ns = 3;
        a.total_ns = 20;
        let mut agg = ForwardProfile::new();
        agg.accumulate(&a);
        agg.accumulate(&a);
        assert_eq!(agg.layers, 2);
        assert_eq!(agg.gemm_ns[0], 20);
        assert_eq!(agg.im2col_ns[1], 10);
        assert_eq!(agg.fc_ns, 6);
        assert_eq!(agg.total_ns, 40);
        assert_eq!(agg.conv_ns(), 30);
    }

    #[test]
    fn test_engine_accumulation_exact_on_local_instance() {
        let m = EngineMetrics::new();
        m.on_gemm(KernelKind::PackedTernary);
        m.on_gemm(KernelKind::PackedTernary);
        m.on_gemm(KernelKind::I8ZeroSkip);
        m.on_rows(16, 5);
        m.on_rows(4, 0);
        m.on_epilogue_block(EpilogueBlock::Simd);
        m.on_epilogue_block(EpilogueBlock::SkipLimit);
        m.on_pool_run(4);
        let s = m.snapshot();
        assert_eq!(s.gemm_ternary, 2);
        assert_eq!(s.gemm_i8_skip, 1);
        assert_eq!(s.gemm_dispatches(), 3);
        assert_eq!(s.rows_probed, 20);
        assert_eq!(s.rows_skipped, 5);
        assert!((s.skip_row_frac() - 0.25).abs() < 1e-12);
        assert_eq!(s.epi_simd_blocks, 1);
        assert_eq!(s.epi_skip_limit_blocks, 1);
        assert!((s.epi_simd_frac() - 0.5).abs() < 1e-12);
        assert_eq!((s.pool_runs, s.pool_blocks), (1, 4));
    }

    #[test]
    fn test_drain_and_reset_semantics() {
        let m = EngineMetrics::new();
        let mut p = profile(2);
        p.total_ns = 1_000_000;
        p.quantize_ns = 100;
        p.gemm_ns[0] = 300;
        p.gemm_ns[1] = 200;
        p.im2col_ns[0] = 50;
        m.drain(&p);
        m.drain(&p);
        let s = m.snapshot();
        assert_eq!(s.forwards, 2);
        assert_eq!(s.forward_ns, 2_000_000);
        assert_eq!(s.quantize_ns, 200);
        assert_eq!(s.gemm_ns, 1000);
        assert_eq!(s.im2col_ns, 100);
        assert!((s.mean_forward_ms() - 1.0).abs() < 1e-12);
        m.reset();
        assert_eq!(m.snapshot(), EngineSnapshot::default());
    }

    #[test]
    fn test_concurrent_counting_is_exact() {
        let m = EngineMetrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let mut p = ForwardProfile::new();
                    p.begin(1, 1);
                    p.total_ns = 10;
                    p.gemm_ns[0] = 1;
                    for _ in 0..250 {
                        m.on_rows(8, 3);
                        m.on_gemm(KernelKind::I8Dense);
                        m.drain(&p);
                    }
                });
            }
        });
        let s = m.snapshot();
        assert_eq!(s.rows_probed, 4 * 250 * 8);
        assert_eq!(s.rows_skipped, 4 * 250 * 3);
        assert_eq!(s.gemm_i8_dense, 1000);
        assert_eq!(s.forwards, 1000);
        assert_eq!(s.forward_ns, 10_000);
        assert_eq!(s.gemm_ns, 1000);
    }

    #[test]
    fn test_snapshot_since_delta() {
        let m = EngineMetrics::new();
        m.on_rows(10, 2);
        let a = m.snapshot();
        m.on_rows(5, 5);
        m.on_pool_run(3);
        let d = m.snapshot().since(&a);
        assert_eq!(d.rows_probed, 5);
        assert_eq!(d.rows_skipped, 5);
        assert_eq!(d.pool_runs, 1);
        assert_eq!(d.pool_blocks, 3);
        assert_eq!(d.forwards, 0);
    }

    #[test]
    fn test_report_and_json_surface() {
        let m = EngineMetrics::new();
        m.on_gemm(KernelKind::PackedTernary);
        m.on_rows(10, 4);
        let mut p = profile(1);
        p.total_ns = 2_000_000;
        m.drain(&p);
        let s = m.snapshot();
        let r = s.report();
        assert!(r.contains("forwards=1"), "{r}");
        assert!(r.contains("rows_skip=40.0%"), "{r}");
        let j = s.to_json();
        assert_eq!(j.get("forwards").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("gemm_ternary").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("rows_skipped").and_then(Json::as_f64), Some(4.0));
    }
}
