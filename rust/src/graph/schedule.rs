//! Deterministic topological scheduling.
//!
//! Kahn's algorithm with a fixed tie-break: among ready nodes, always pick
//! the smallest [`NodeId`]. Node ids are assignment order in the builder,
//! and block builders emit the skip-lane producer before the chain convs,
//! so the schedule (a) is reproducible run-to-run, (b) executes each
//! block's shortcut before its chain — freeing the block input as early as
//! possible and matching the legacy fixed-walk execution order — and
//! (c) is a plain `0..n` identity permutation for today's chain-of-blocks
//! builders, while staying correct for any future multi-branch graph.

use super::ir::{Graph, NodeId};

/// Deterministic topological order of `g` (smallest ready id first).
///
/// Panics if the graph contains a cycle — [`Graph::from_network`] cannot
/// build one, so a cycle is a programming error, not an input error.
pub fn topo_order(g: &Graph) -> Vec<NodeId> {
    let n = g.nodes.len();
    let consumers = g.consumers();
    let mut indeg: Vec<usize> = g.nodes.iter().map(|nd| nd.inputs.len()).collect();
    let mut ready: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while !ready.is_empty() {
        // smallest-id tie-break; `ready` stays small (graph width), so a
        // linear scan beats a heap here
        let (slot, &id) =
            ready.iter().enumerate().min_by_key(|&(_, &id)| id).expect("non-empty");
        ready.swap_remove(slot);
        order.push(id);
        for &c in &consumers[id] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push(c);
            }
        }
    }
    assert_eq!(order.len(), n, "layer graph contains a cycle — builder invariant violated");
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ir::{Graph, Node, Op};

    fn node(id: usize, inputs: Vec<usize>) -> Node {
        Node { id, op: Op::Skip, inputs, out_h: 1, out_w: 1, out_c: 1 }
    }

    #[test]
    fn test_topo_order_is_deterministic_and_respects_edges() {
        // diamond with ids deliberately out of dependency order:
        //   3 -> {0, 2} -> 1
        let g = Graph {
            nodes: vec![
                node(0, vec![3]),
                node(1, vec![0, 2]),
                node(2, vec![3]),
                node(3, vec![]),
            ],
        };
        let order = topo_order(&g);
        assert_eq!(order, vec![3, 0, 2, 1]); // smallest ready id first
    }

    #[test]
    fn test_schedule_covers_every_node_once() {
        let net = crate::model::resnet101();
        let g = Graph::from_network(&net, 224, 224).unwrap();
        let order = g.schedule();
        let mut seen = vec![false; g.nodes.len()];
        for &id in &order {
            assert!(!seen[id], "node {id} scheduled twice");
            seen[id] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
