//! Tensor lifetime analysis and arena interval coloring.
//!
//! The forward plan gives every intermediate tensor a live interval in
//! schedule time — `[start, end]` inclusive, from the step that defines it
//! to its last read — and asks this module to pack all tensors into one
//! flat activation arena. Greedy first-fit interval coloring: place
//! tensors in order of definition; each goes at the lowest offset whose
//! byte range is free of every already-placed tensor with an overlapping
//! lifetime. Two tensors may share bytes **iff** their intervals are
//! disjoint — the invariant the planner's property tests check directly.
//!
//! Offsets are in elements per image; batched forwards scale every offset
//! and size by the same batch factor, which preserves disjointness.
//!
//! ```
//! use dfp_infer::graph::{color_intervals, Lifetime};
//!
//! // ping-pong pair + one long-lived skip source
//! let reqs = [
//!     Lifetime { size: 64, start: 0, end: 2 },  // A: defined, read by B and C
//!     Lifetime { size: 64, start: 2, end: 3 },  // B: overlaps A at step 2
//!     Lifetime { size: 64, start: 3, end: 4 },  // C: may reuse A's bytes
//! ];
//! let layout = color_intervals(&reqs);
//! assert_eq!(layout.offsets, vec![0, 64, 0]);
//! assert_eq!(layout.total, 128);
//! ```

/// One tensor's arena request: `size` elements, live over the inclusive
/// step interval `[start, end]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lifetime {
    pub size: usize,
    pub start: usize,
    pub end: usize,
}

impl Lifetime {
    /// Do two live intervals share any step?
    pub fn overlaps(&self, other: &Lifetime) -> bool {
        self.start <= other.end && other.start <= self.end
    }
}

/// The packed arena: one offset per input [`Lifetime`], plus the arena's
/// total element count (the planned peak).
#[derive(Debug, Clone, Default)]
pub struct ArenaLayout {
    pub offsets: Vec<usize>,
    pub total: usize,
}

/// Greedy first-fit interval coloring (see module docs). Deterministic:
/// tensors are placed in order of `(start, index)`.
pub fn color_intervals(reqs: &[Lifetime]) -> ArenaLayout {
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by_key(|&i| (reqs[i].start, i));
    let mut offsets = vec![0usize; reqs.len()];
    let mut placed: Vec<usize> = Vec::with_capacity(reqs.len());
    let mut total = 0usize;
    for &i in &order {
        let r = &reqs[i];
        // already-placed tensors alive at the same time, by offset
        let mut busy: Vec<(usize, usize)> = placed
            .iter()
            .filter(|&&j| r.overlaps(&reqs[j]))
            .map(|&j| (offsets[j], reqs[j].size))
            .collect();
        busy.sort_unstable();
        let mut off = 0usize;
        for (o, sz) in busy {
            if off + r.size <= o {
                break; // fits in the gap before this block
            }
            off = off.max(o + sz);
        }
        offsets[i] = off;
        total = total.max(off + r.size);
        placed.push(i);
    }
    ArenaLayout { offsets, total }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The invariant, checked exhaustively over a layout.
    pub fn assert_disjoint(reqs: &[Lifetime], layout: &ArenaLayout) {
        for a in 0..reqs.len() {
            for b in a + 1..reqs.len() {
                if !reqs[a].overlaps(&reqs[b]) {
                    continue;
                }
                let (ao, bo) = (layout.offsets[a], layout.offsets[b]);
                let clash = ao < bo + reqs[b].size && bo < ao + reqs[a].size;
                assert!(
                    !clash || reqs[a].size == 0 || reqs[b].size == 0,
                    "live tensors {a} and {b} overlap in the arena"
                );
            }
        }
    }

    #[test]
    fn test_disjoint_lifetimes_share_bytes() {
        let reqs = [
            Lifetime { size: 100, start: 0, end: 1 },
            Lifetime { size: 50, start: 1, end: 2 },
            Lifetime { size: 100, start: 2, end: 3 },
        ];
        let l = color_intervals(&reqs);
        assert_disjoint(&reqs, &l);
        assert_eq!(l.offsets[0], 0);
        assert_eq!(l.offsets[1], 100);
        assert_eq!(l.offsets[2], 0, "tensor 2 reuses tensor 0's bytes");
        assert_eq!(l.total, 150);
    }

    #[test]
    fn test_long_lived_tensor_blocks_reuse() {
        let reqs = [
            Lifetime { size: 10, start: 0, end: 5 }, // alive throughout
            Lifetime { size: 10, start: 1, end: 2 },
            Lifetime { size: 10, start: 3, end: 4 },
        ];
        let l = color_intervals(&reqs);
        assert_disjoint(&reqs, &l);
        assert_eq!(l.offsets[1], 10);
        assert_eq!(l.offsets[2], 10, "disjoint from 1, so it reuses its slot");
        assert_eq!(l.total, 20);
    }

    #[test]
    fn test_first_fit_takes_gaps() {
        let reqs = [
            Lifetime { size: 10, start: 0, end: 10 },
            Lifetime { size: 20, start: 0, end: 2 },
            Lifetime { size: 15, start: 3, end: 10 }, // fits where 1 was
            Lifetime { size: 30, start: 4, end: 10 },
        ];
        let l = color_intervals(&reqs);
        assert_disjoint(&reqs, &l);
        assert_eq!(l.offsets[2], 10);
        assert_eq!(l.total, 55);
    }

    #[test]
    fn test_zero_sized_requests_are_harmless() {
        let reqs = [
            Lifetime { size: 0, start: 0, end: 9 },
            Lifetime { size: 8, start: 0, end: 9 },
        ];
        let l = color_intervals(&reqs);
        assert_eq!(l.total, 8);
    }
}
