//! The layer DAG: typed nodes, producer edges, and the (single) home of
//! the residual-walk rule that turns a `model::Network` layer table into
//! a graph.

use std::fmt;

use crate::model::Network;

/// Index of a node in [`Graph::nodes`].
pub type NodeId = usize;

/// What a node computes. Conv nodes index into `net.layers`; everything
/// else is structural.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// The network input (one per graph, no producers).
    Input,
    /// Convolution of `net.layers[layer]` (stem, chain, or `*proj` shortcut).
    Conv { layer: usize },
    /// Max pool (the ImageNet stem pool). Exact on quantized codes: max
    /// commutes with the monotone requantization.
    Pool { k: usize, stride: usize, pad: usize },
    /// Identity shortcut: re-aligns `inputs[0]` onto the residual lane of
    /// a block that has no projection conv.
    Skip,
    /// Residual join: `inputs[0]` is the block's last chain conv,
    /// `inputs[1]` the lane producer ([`Op::Skip`] or a `*proj` conv).
    /// Semantics: add, then ReLU (He et al. post-activation ordering).
    Add,
    /// Global average pool to a (N, C) feature matrix.
    Gap,
    /// The final fully-connected classifier.
    Fc,
}

/// One graph node with its producers and output geometry.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    /// Producer nodes, in operand order (see [`Op`] variants).
    pub inputs: Vec<NodeId>,
    pub out_h: usize,
    pub out_w: usize,
    pub out_c: usize,
}

impl Node {
    /// Output elements per image.
    pub fn out_elems(&self) -> usize {
        self.out_h * self.out_w * self.out_c
    }
}

/// Why a layer table cannot be turned into a runnable graph. Every variant
/// names the first offending layer so loaders and CLIs can surface it —
/// plan building never silently degrades to an empty plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// The network has no conv layers at all.
    EmptyNetwork { net: String },
    /// A trailing run of convs never reaches a `residual = true` layer, so
    /// the block (and everything after it) is unreachable by the walk.
    DanglingTail { net: String, layer: String, index: usize },
    /// A `*proj` layer appears inside a block's chain instead of directly
    /// after its `residual = true` terminator.
    ProjOutOfPlace { net: String, layer: String, index: usize },
    /// A conv whose declared shape cannot consume its producer's output.
    BadConv { net: String, layer: String, detail: String },
    /// Computed output size disagrees with the layer table's declared
    /// `out_hw` at the network's nominal input resolution.
    GeometryMismatch { net: String, layer: String, declared: usize, computed: (usize, usize) },
    /// The two inputs of a residual add have different shapes.
    AddShapeMismatch {
        net: String,
        layer: String,
        chain: (usize, usize, usize),
        skip: (usize, usize, usize),
    },
    /// The stem pool's window does not fit its input.
    BadPool { net: String, detail: String },
    /// A structurally valid graph the lowering cannot execute (e.g. a node
    /// whose output would have to live on the single skip lane twice).
    Unsupported { net: String, node: String, detail: String },
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::EmptyNetwork { net } => {
                write!(f, "network '{net}' has no conv layers")
            }
            GraphError::DanglingTail { net, layer, index } => write!(
                f,
                "network '{net}': layer {index} '{layer}' starts a conv run that never reaches \
                 a residual join (no `residual = true` terminator)"
            ),
            GraphError::ProjOutOfPlace { net, layer, index } => write!(
                f,
                "network '{net}': projection layer {index} '{layer}' sits inside a block chain; \
                 '*proj' convs must directly follow their block's residual layer"
            ),
            GraphError::BadConv { net, layer, detail } => {
                write!(f, "network '{net}': conv '{layer}': {detail}")
            }
            GraphError::GeometryMismatch { net, layer, declared, computed } => write!(
                f,
                "network '{net}': conv '{layer}' declares out_hw = {declared} but computes \
                 {}x{} at the nominal input resolution",
                computed.0, computed.1
            ),
            GraphError::AddShapeMismatch { net, layer, chain, skip } => write!(
                f,
                "network '{net}': residual add at '{layer}': chain output {}x{}x{} vs skip \
                 {}x{}x{}",
                chain.0, chain.1, chain.2, skip.0, skip.1, skip.2
            ),
            GraphError::BadPool { net, detail } => {
                write!(f, "network '{net}': stem pool: {detail}")
            }
            GraphError::Unsupported { net, node, detail } => {
                write!(f, "network '{net}': node '{node}': {detail}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// An explicit layer DAG over a network's conv table.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
}

impl Graph {
    fn push(&mut self, op: Op, inputs: Vec<NodeId>, out_h: usize, out_w: usize, out_c: usize) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { id, op, inputs, out_h, out_w, out_c });
        id
    }

    /// Append a conv node for `net.layers[layer]` consuming `src`,
    /// validating channel agreement, window fit, and (at the nominal input
    /// resolution) the declared `out_hw`.
    fn push_conv(
        &mut self,
        net: &Network,
        layer: usize,
        src: NodeId,
        nominal: bool,
    ) -> Result<NodeId, GraphError> {
        let l = &net.layers[layer];
        let (h, w, c) = {
            let s = &self.nodes[src];
            (s.out_h, s.out_w, s.out_c)
        };
        let err = |detail: String| GraphError::BadConv {
            net: net.name.clone(),
            layer: l.name.clone(),
            detail,
        };
        if l.cin != c {
            return Err(err(format!("expects {} input channels, producer has {c}", l.cin)));
        }
        if l.stride == 0 {
            return Err(err("stride must be >= 1".into()));
        }
        if h + 2 * l.pad < l.kh || w + 2 * l.pad < l.kw {
            return Err(err(format!(
                "{}x{} window does not fit {h}x{w} input with pad {}",
                l.kh, l.kw, l.pad
            )));
        }
        let ho = (h + 2 * l.pad - l.kh) / l.stride + 1;
        let wo = (w + 2 * l.pad - l.kw) / l.stride + 1;
        if nominal && (ho != l.out_hw || wo != l.out_hw) {
            return Err(GraphError::GeometryMismatch {
                net: net.name.clone(),
                layer: l.name.clone(),
                declared: l.out_hw,
                computed: (ho, wo),
            });
        }
        Ok(self.push(Op::Conv { layer }, vec![src], ho, wo, l.cout))
    }

    /// Build the DAG for `net` at input resolution `in_h`×`in_w` (the
    /// nominal `net.input_hw` or any other size the conv windows fit).
    ///
    /// The walk: `layers[0]` is the stem, optionally followed by
    /// `net.stem_pool`; after that, each **block** is a maximal run of
    /// non-`proj` convs ending at the first `residual = true` layer,
    /// optionally followed by one `*proj` conv that computes the block's
    /// shortcut from the block input. The lane producer (projection conv,
    /// or an identity [`Op::Skip`]) is emitted *before* the chain so the
    /// deterministic scheduler prepares the lane first.
    pub fn from_network(net: &Network, in_h: usize, in_w: usize) -> Result<Graph, GraphError> {
        if net.layers.is_empty() {
            return Err(GraphError::EmptyNetwork { net: net.name.clone() });
        }
        let mut g = Graph::default();
        let in_c = net.layers[0].cin;
        let input = g.push(Op::Input, vec![], in_h, in_w, in_c);
        let nominal = in_h == net.input_hw && in_w == net.input_hw;

        let mut cur = g.push_conv(net, 0, input, nominal)?;
        if let Some(p) = &net.stem_pool {
            let (h, w, c) = {
                let s = &g.nodes[cur];
                (s.out_h, s.out_w, s.out_c)
            };
            if p.k == 0 || p.stride == 0 || p.pad >= p.k {
                return Err(GraphError::BadPool {
                    net: net.name.clone(),
                    detail: format!("degenerate {}x{} stride {} pad {}", p.k, p.k, p.stride, p.pad),
                });
            }
            if h + 2 * p.pad < p.k || w + 2 * p.pad < p.k {
                return Err(GraphError::BadPool {
                    net: net.name.clone(),
                    detail: format!("{}x{} window does not fit {h}x{w} stem output", p.k, p.k),
                });
            }
            let ho = (h + 2 * p.pad - p.k) / p.stride + 1;
            let wo = (w + 2 * p.pad - p.k) / p.stride + 1;
            cur = g.push(Op::Pool { k: p.k, stride: p.stride, pad: p.pad }, vec![cur], ho, wo, c);
        }

        let mut i = 1;
        while i < net.layers.len() {
            // find the block terminator (first residual = true layer)
            let mut end = None;
            for (j, l) in net.layers.iter().enumerate().skip(i) {
                if l.name.ends_with("proj") {
                    return Err(GraphError::ProjOutOfPlace {
                        net: net.name.clone(),
                        layer: l.name.clone(),
                        index: j,
                    });
                }
                if l.residual {
                    end = Some(j);
                    break;
                }
            }
            let Some(end) = end else {
                return Err(GraphError::DanglingTail {
                    net: net.name.clone(),
                    layer: net.layers[i].name.clone(),
                    index: i,
                });
            };
            let has_proj =
                net.layers.get(end + 1).map(|l| l.name.ends_with("proj")).unwrap_or(false);

            let block_in = cur;
            // lane producer first (see module docs)
            let skip = if has_proj {
                g.push_conv(net, end + 1, block_in, nominal)?
            } else {
                let (h, w, c) = {
                    let s = &g.nodes[block_in];
                    (s.out_h, s.out_w, s.out_c)
                };
                g.push(Op::Skip, vec![block_in], h, w, c)
            };
            let mut chain = block_in;
            for j in i..=end {
                chain = g.push_conv(net, j, chain, nominal)?;
            }
            let (ch, cw, cc) = {
                let s = &g.nodes[chain];
                (s.out_h, s.out_w, s.out_c)
            };
            let (sh, sw, sc) = {
                let s = &g.nodes[skip];
                (s.out_h, s.out_w, s.out_c)
            };
            if (ch, cw, cc) != (sh, sw, sc) {
                return Err(GraphError::AddShapeMismatch {
                    net: net.name.clone(),
                    layer: net.layers[end].name.clone(),
                    chain: (ch, cw, cc),
                    skip: (sh, sw, sc),
                });
            }
            cur = g.push(Op::Add, vec![chain, skip], ch, cw, cc);
            i = end + 1 + usize::from(has_proj);
        }

        let feat_c = g.nodes[cur].out_c;
        let gap = g.push(Op::Gap, vec![cur], 1, 1, feat_c);
        g.push(Op::Fc, vec![gap], 1, 1, net.fc_out);
        Ok(g)
    }

    /// Consumer lists: `consumers()[p]` holds every node that reads `p`,
    /// in operand order of discovery.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &src in &n.inputs {
                out[src].push(n.id);
            }
        }
        out
    }

    /// Deterministic topological execution order (see [`super::schedule`]).
    pub fn schedule(&self) -> Vec<NodeId> {
        super::schedule::topo_order(self)
    }

    /// Short human label for a node (error messages, bench rows).
    pub fn label(&self, net: &Network, id: NodeId) -> String {
        match &self.nodes[id].op {
            Op::Input => "input".into(),
            Op::Conv { layer } => net.layers[*layer].name.clone(),
            Op::Pool { k, stride, .. } => format!("maxpool{k}x{k}s{stride}"),
            Op::Skip => "skip".into(),
            Op::Add => "add".into(),
            Op::Gap => "gap".into(),
            Op::Fc => "fc".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{bottleneck_mini, resnet101, resnet18, resnet50, resnet_mini_default};

    #[test]
    fn test_mini_graph_shape_and_order() {
        let net = resnet_mini_default();
        let g = Graph::from_network(&net, 24, 24).unwrap();
        // input + 9 convs + 1 identity skip + 3 adds + gap + fc
        assert_eq!(g.nodes.len(), 1 + 9 + 1 + 3 + 1 + 1);
        assert!(matches!(g.nodes[0].op, Op::Input));
        // s0 block: identity skip is created before its chain convs
        let skip_id =
            g.nodes.iter().find(|n| matches!(n.op, Op::Skip)).map(|n| n.id).unwrap();
        let s0c1 = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::Conv { layer } if net.layers[layer].name == "s0b0c1"))
            .map(|n| n.id)
            .unwrap();
        assert!(skip_id < s0c1);
        let order = g.schedule();
        assert_eq!(order.len(), g.nodes.len());
        // smallest-id tie-break makes the schedule the identity permutation
        // for chain-structured builders
        let pos: Vec<usize> = {
            let mut p = vec![0; order.len()];
            for (t, &id) in order.iter().enumerate() {
                p[id] = t;
            }
            p
        };
        for n in &g.nodes {
            for &src in &n.inputs {
                assert!(pos[src] < pos[n.id], "producer must schedule first");
            }
        }
    }

    #[test]
    fn test_bottleneck_nets_build_with_pool() {
        for (net, convs, blocks, projs) in [
            (resnet50(), 53, 16, 4),
            (resnet101(), 104, 33, 4),
            (resnet18(), 20, 8, 3),
            (bottleneck_mini(16, &[4, 8], 3), 9, 2, 2),
        ] {
            let g = Graph::from_network(&net, net.input_hw, net.input_hw).unwrap();
            let n_conv = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv { .. })).count();
            let n_add = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
            let n_skip = g.nodes.iter().filter(|n| matches!(n.op, Op::Skip)).count();
            let n_pool = g.nodes.iter().filter(|n| matches!(n.op, Op::Pool { .. })).count();
            assert_eq!(n_conv, convs, "{}", net.name);
            assert_eq!(n_add, blocks, "{}", net.name);
            assert_eq!(n_skip, blocks - projs, "{}", net.name);
            assert_eq!(n_pool, 1, "{}", net.name);
            // final feature resolution of the He nets is 7x7
            let gap = g.nodes.iter().find(|n| matches!(n.op, Op::Gap)).unwrap();
            let last = &g.nodes[gap.inputs[0]];
            if net.name.starts_with("resnet-") {
                assert_eq!((last.out_h, last.out_w), (7, 7), "{}", net.name);
            }
        }
    }

    #[test]
    fn test_dangling_tail_is_a_typed_error() {
        let mut net = resnet_mini_default();
        net.layers.push(crate::model::ConvLayer {
            name: "tail".into(),
            kh: 3,
            kw: 3,
            cin: 128,
            cout: 128,
            stride: 1,
            pad: 1,
            out_hw: 6,
            residual: false,
            relu: true,
        });
        let err = Graph::from_network(&net, 24, 24).unwrap_err();
        assert!(
            matches!(&err, GraphError::DanglingTail { layer, .. } if layer == "tail"),
            "{err}"
        );
        assert!(err.to_string().contains("tail"), "{err}");
    }

    #[test]
    fn test_channel_mismatch_is_a_typed_error() {
        let mut net = resnet_mini_default();
        net.layers[1].cin = 7; // s0b0c1 no longer matches the stem's 32
        let err = Graph::from_network(&net, 24, 24).unwrap_err();
        assert!(matches!(&err, GraphError::BadConv { layer, .. } if layer == "s0b0c1"), "{err}");
    }

    #[test]
    fn test_declared_geometry_is_checked_at_nominal_resolution() {
        let mut net = resnet_mini_default();
        net.layers[1].out_hw = 23;
        let err = Graph::from_network(&net, 24, 24).unwrap_err();
        assert!(matches!(err, GraphError::GeometryMismatch { declared: 23, .. }), "{err}");
        // off-nominal inputs skip the declared-shape check (the walk still
        // computes real geometry)
        assert!(Graph::from_network(&net, 16, 16).is_ok());
    }

    #[test]
    fn test_empty_network_is_a_typed_error() {
        let mut net = resnet_mini_default();
        net.layers.clear();
        assert!(matches!(
            Graph::from_network(&net, 24, 24),
            Err(GraphError::EmptyNetwork { .. })
        ));
    }
}
