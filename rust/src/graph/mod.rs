//! Layer-graph IR, deterministic scheduling, and buffer liveness planning.
//!
//! [`ir`] turns a [`crate::model::Network`] layer table into an explicit
//! DAG of typed nodes (conv / pool / residual add / skip / GAP / FC) with
//! producer→consumer edges, rejecting unwalkable tables with a typed
//! [`GraphError`] that names the first unsupported layer. The residual-walk
//! rule (a block is a run of convs ending at `residual = true`, optionally
//! followed by a `*proj` shortcut conv) lives **only here** — the forward
//! plan, the epilogue cache and the reference interpreters all consume the
//! graph instead of re-walking the layer table.
//!
//! [`schedule`] is a deterministic Kahn topological sort (smallest node id
//! first among ready nodes). Because block builders emit the skip-lane
//! producer before the chain convs, the schedule prepares each residual
//! lane as early as possible, which both matches the legacy execution
//! order bit-for-bit and minimizes tensor lifetimes.
//!
//! [`liveness`] does interval analysis over tensor lifetimes and packs
//! them into one activation arena by greedy first-fit interval coloring:
//! two tensors may share bytes iff their live step-intervals are disjoint.
//! [`crate::lpinfer::ForwardPlan`] lowers the scheduled graph onto these
//! planned offsets, which is what keeps the steady-state forward at zero
//! heap allocations on arbitrary (bottleneck, pooled) residual nets.
//!
//! ```
//! use dfp_infer::graph::Graph;
//! use dfp_infer::model::resnet50;
//!
//! let net = resnet50();
//! let g = Graph::from_network(&net, 224, 224).unwrap();
//! // 53 convs + input + stem pool + 16 residual adds + 12 identity skips
//! // + GAP + FC
//! let order = g.schedule();
//! assert_eq!(order.len(), g.nodes.len());
//! ```

pub mod ir;
pub mod liveness;
pub mod schedule;

pub use ir::{Graph, GraphError, Node, NodeId, Op};
pub use liveness::{color_intervals, ArenaLayout, Lifetime};
pub use schedule::topo_order;
