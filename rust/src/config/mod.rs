//! Typed configuration for the launcher: defaults <- JSON file <- CLI flags.
//!
//! Precision and kernel knobs are *typed* at the edge: `kernel` resolves to
//! a [`KernelChoice`] and `scheme` to a parsed [`Scheme`] while the config
//! is built, so invalid names fail in `Config::resolve` (with the valid
//! alternatives in the error) instead of deep inside serving.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::cli::Args;
use crate::json::{parse, Json};
use crate::kernels::KernelChoice;
use crate::scheme::Scheme;

/// Top-level server / tool configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// directory holding manifest.json + *.hlo.txt
    pub artifacts_dir: PathBuf,
    /// worker threads (each owns a PJRT engine)
    pub workers: usize,
    /// admission queue bound (backpressure)
    pub max_queue: usize,
    /// dynamic batching deadline (us)
    pub max_wait_us: u64,
    /// load generator: requests to issue / concurrency / noise
    pub requests: usize,
    pub seed: u64,
    pub noise: f32,
    /// GEMM threads per executor (kernels/ thread pool; 0 = all cores)
    pub threads: usize,
    /// kernel selection for the registry
    /// (`--kernel auto|i8|i8-dense|ternary|i4`, optionally suffixed with a
    /// SIMD tier: `+scalar|+simd|+avx2|+neon`, e.g. `ternary+scalar`;
    /// the default tier is the best the CPU supports)
    pub kernel: KernelChoice,
    /// precision scheme to serve/eval/quantize (`--scheme 8a2w_n4@stem=i8`);
    /// `None` means "all exported variants"
    pub scheme: Option<Scheme>,
    /// queued requests at which admissions degrade to the next-cheaper
    /// precision class (0 = disabled)
    pub degrade_watermark: usize,
    /// queued requests at which admissions are shed with a typed
    /// `Overloaded` error (0 = disabled)
    pub shed_watermark: usize,
    /// per-request completion deadline the load generator attaches
    /// (`--deadline-ms`, 0 = none)
    pub deadline_ms: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            workers: 1,
            max_queue: 1024,
            max_wait_us: 2_000,
            requests: 256,
            seed: 0,
            noise: crate::data::DEFAULT_NOISE,
            threads: 1,
            kernel: KernelChoice::auto(),
            scheme: None,
            degrade_watermark: 0,
            shed_watermark: 0,
            deadline_ms: 0,
        }
    }
}

impl Config {
    /// Merge a JSON config file over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let j = parse(&text)?;
        let mut c = Self::default();
        c.apply_json(&j)?;
        Ok(c)
    }

    fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("workers").and_then(Json::as_i64) {
            self.workers = v as usize;
        }
        if let Some(v) = j.get("max_queue").and_then(Json::as_i64) {
            self.max_queue = v as usize;
        }
        if let Some(v) = j.get("max_wait_us").and_then(Json::as_i64) {
            self.max_wait_us = v as u64;
        }
        if let Some(v) = j.get("requests").and_then(Json::as_i64) {
            self.requests = v as usize;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_i64) {
            self.seed = v as u64;
        }
        if let Some(v) = j.get("noise").and_then(Json::as_f64) {
            self.noise = v as f32;
        }
        if let Some(v) = j.get("threads").and_then(Json::as_i64) {
            self.threads = v as usize;
        }
        if let Some(v) = j.get("kernel").and_then(Json::as_str) {
            self.kernel = v.parse().context("config: kernel")?;
        }
        if let Some(v) = j.get("scheme") {
            // accept both the compact string and the full object form
            self.scheme = Some(match v.as_str() {
                Some(s) => Scheme::parse(s).context("config: scheme")?,
                None => Scheme::from_json(v).context("config: scheme")?,
            });
        }
        if let Some(v) = j.get("degrade_watermark").and_then(Json::as_i64) {
            self.degrade_watermark = v as usize;
        }
        if let Some(v) = j.get("shed_watermark").and_then(Json::as_i64) {
            self.shed_watermark = v as usize;
        }
        if let Some(v) = j.get("deadline_ms").and_then(Json::as_i64) {
            self.deadline_ms = v as u64;
        }
        Ok(())
    }

    /// Apply CLI overrides (flags win over file values).
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(v) = a.get_str("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        self.workers = a.get_or("workers", self.workers)?;
        self.max_queue = a.get_or("max-queue", self.max_queue)?;
        self.max_wait_us = a.get_or("max-wait-us", self.max_wait_us)?;
        self.requests = a.get_or("requests", self.requests)?;
        self.seed = a.get_or("seed", self.seed)?;
        self.noise = a.get_or("noise", self.noise)?;
        self.threads = a.get_or("threads", self.threads)?;
        if let Some(v) = a.get_str("kernel") {
            self.kernel = v.parse()?;
        }
        if let Some(v) = a.get_str("scheme") {
            self.scheme = Some(Scheme::parse(v)?);
        }
        self.degrade_watermark = a.get_or("degrade-watermark", self.degrade_watermark)?;
        self.shed_watermark = a.get_or("shed-watermark", self.shed_watermark)?;
        self.deadline_ms = a.get_or("deadline-ms", self.deadline_ms)?;
        Ok(())
    }

    /// Resolve from optional `--config <file>` plus flag overrides.
    pub fn resolve(a: &Args) -> Result<Self> {
        let mut c = match a.get_str("config") {
            Some(p) => Self::from_file(Path::new(p))?,
            None => Self::default(),
        };
        c.apply_args(a)?;
        Ok(c)
    }

    /// Build the kernel registry this config describes (`kernel` choice +
    /// `threads`-wide pool). Infallible: the kernel name was validated when
    /// the config was resolved.
    pub fn kernel_registry(&self) -> crate::kernels::KernelRegistry {
        crate::kernels::KernelRegistry::with_choice(self.kernel, self.threads)
    }

    pub fn to_coordinator(&self) -> crate::coordinator::CoordinatorConfig {
        use crate::coordinator::{DegradeConfig, WATERMARK_DISABLED};
        // CLI convention: watermark 0 means "off"
        let mark = |v: usize| if v == 0 { WATERMARK_DISABLED } else { v };
        crate::coordinator::CoordinatorConfig {
            max_queue: self.max_queue,
            max_wait_us: self.max_wait_us,
            tick_us: 200,
            degrade: DegradeConfig {
                degrade_watermark: mark(self.degrade_watermark),
                shed_watermark: mark(self.shed_watermark),
                p99_target_us: 0.0,
            },
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_defaults() {
        let c = Config::default();
        assert_eq!(c.workers, 1);
        assert_eq!(c.max_wait_us, 2_000);
        assert_eq!(c.kernel, KernelChoice::auto());
        assert!(c.scheme.is_none());
    }

    #[test]
    fn test_file_merge() {
        let p = std::env::temp_dir().join(format!("dfp_cfg_{}.json", std::process::id()));
        std::fs::write(&p, r#"{"workers": 3, "max_wait_us": 500, "artifacts_dir": "/x"}"#).unwrap();
        let c = Config::from_file(&p).unwrap();
        assert_eq!(c.workers, 3);
        assert_eq!(c.max_wait_us, 500);
        assert_eq!(c.artifacts_dir, PathBuf::from("/x"));
        assert_eq!(c.max_queue, 1024); // default preserved
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_cli_overrides() {
        let a = Args::parse_from(
            ["--workers", "2", "--max-wait-us", "99"].iter().map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.workers, 2);
        assert_eq!(c.max_wait_us, 99);
    }

    #[test]
    fn test_resilience_knobs_resolve_and_map_to_watermarks() {
        use crate::coordinator::WATERMARK_DISABLED;
        // defaults: everything off
        let d = Config::default();
        assert_eq!(d.degrade_watermark, 0);
        assert_eq!(d.shed_watermark, 0);
        assert_eq!(d.deadline_ms, 0);
        let cc = d.to_coordinator();
        assert_eq!(cc.degrade.degrade_watermark, WATERMARK_DISABLED);
        assert_eq!(cc.degrade.shed_watermark, WATERMARK_DISABLED);

        // CLI flags flow through to the coordinator config
        let a = Args::parse_from(
            ["--degrade-watermark", "8", "--shed-watermark", "32", "--deadline-ms", "50"]
                .iter()
                .map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.deadline_ms, 50);
        let cc = c.to_coordinator();
        assert_eq!(cc.degrade.degrade_watermark, 8);
        assert_eq!(cc.degrade.shed_watermark, 32);

        // JSON file form
        let p = std::env::temp_dir().join(format!("dfp_cfg_res_{}.json", std::process::id()));
        std::fs::write(&p, r#"{"degrade_watermark": 4, "shed_watermark": 9, "deadline_ms": 7}"#)
            .unwrap();
        let f = Config::from_file(&p).unwrap();
        assert_eq!((f.degrade_watermark, f.shed_watermark, f.deadline_ms), (4, 9, 7));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_bad_file() {
        assert!(Config::from_file(Path::new("/nonexistent/x.json")).is_err());
    }

    #[test]
    fn test_kernel_and_threads_resolution() {
        let a = Args::parse_from(
            ["--kernel", "ternary", "--threads", "4"].iter().map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.kernel, KernelChoice::forced(crate::kernels::KernelKind::PackedTernary));
        assert_eq!(c.threads, 4);
        let reg = c.kernel_registry();
        assert_eq!(reg.choice(), Some(crate::kernels::KernelKind::PackedTernary));
        assert_eq!(reg.pool().threads(), 4);

        // defaults: auto kernel, single thread
        let d = Config::default();
        assert!(d.kernel_registry().choice().is_none());
        assert_eq!(d.kernel_registry().pool().threads(), 1);
    }

    #[test]
    fn test_kernel_tier_suffix_resolution() {
        use crate::kernels::{SimdTier, TierChoice};
        let a = Args::parse_from(
            ["--kernel", "ternary+scalar", "--threads", "2"].iter().map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.kernel.enc, Some(crate::kernels::KernelKind::PackedTernary));
        assert_eq!(c.kernel.tier, TierChoice::Forced(SimdTier::Scalar));
        assert_eq!(c.kernel_registry().tier(), SimdTier::Scalar);

        // bad tier names fail at resolve time, like bad kernel names
        let bad =
            Args::parse_from(["--kernel", "auto+sse9"].iter().map(|s| s.to_string()), false)
                .unwrap();
        let err = Config::resolve(&bad).unwrap_err().to_string();
        assert!(err.contains("auto|scalar|simd|avx2|neon"), "{err}");
    }

    #[test]
    fn test_bad_kernel_name_fails_at_resolve() {
        let a = Args::parse_from(["--kernel", "warp"].iter().map(|s| s.to_string()), false).unwrap();
        let err = Config::resolve(&a).unwrap_err().to_string();
        assert!(err.contains("auto|i8|i8-dense|ternary|i4"), "{err}");
    }

    #[test]
    fn test_scheme_resolution_file_and_cli() {
        let p = std::env::temp_dir().join(format!("dfp_cfg_scheme_{}.json", std::process::id()));
        std::fs::write(&p, r#"{"scheme": "8a2w_n4@stem=i8", "kernel": "i4"}"#).unwrap();
        let a = Args::parse_from(
            ["--config", p.to_str().unwrap()].iter().map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.scheme.as_ref().unwrap().to_string(), "8a2w_n4@stem=i8");
        assert_eq!(c.kernel, KernelChoice::forced(crate::kernels::KernelKind::PackedI4));

        // CLI wins over the file
        let a = Args::parse_from(
            ["--config", p.to_str().unwrap(), "--scheme", "8a4w_n16"].iter().map(|s| s.to_string()),
            false,
        )
        .unwrap();
        let c = Config::resolve(&a).unwrap();
        assert_eq!(c.scheme.as_ref().unwrap().to_string(), "8a4w_n16");
        std::fs::remove_file(&p).ok();

        let bad = Args::parse_from(["--scheme", "fp32"].iter().map(|s| s.to_string()), false).unwrap();
        assert!(Config::resolve(&bad).is_err());
    }
}
