//! Wall-clock timing helper.

use std::time::Instant;

/// Simple scope timer reporting elapsed milliseconds.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_timer_advances() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
        assert!(t.elapsed_us() >= 4000.0);
    }

    #[test]
    fn test_reset() {
        let mut t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(3));
        t.reset();
        assert!(t.elapsed_ms() < 3.0);
    }
}
