//! SplitMix64 PRNG — bit-exact mirror of `python/compile/data.py`.
//!
//! The serving-side load generator must reproduce the exact sample stream
//! the python training/eval pipeline produced, so both sides implement the
//! same SplitMix64 + Box-Muller construction (pinned by reference vectors
//! in the tests below and in `python/tests/test_data_dft.py`).

/// SplitMix64 (Steele et al.) — tiny, fast, good-enough statistical quality
/// for synthetic data and property-test generation.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(state: u64) -> Self {
        Self { state }
    }

    /// Seed identical to the python sample stream: `(seed << 32) ^ (index * GAMMA)`.
    pub fn for_sample(seed: u64, index: u64) -> Self {
        Self::new((seed << 32) ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. Matches python's `next_u64() % n`
    /// (modulo bias is irrelevant for our n << 2^64 and must match python).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of mantissa (matches python).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// `n` standard normals via Box-Muller over `next_f32` pairs — the exact
    /// sequence `python/compile/data.py::_SplitMix64.normal` produces.
    pub fn normal(&mut self, n: usize) -> Vec<f32> {
        let m = n.div_ceil(2);
        let mut u1 = Vec::with_capacity(m);
        let mut u2 = Vec::with_capacity(m);
        for _ in 0..m {
            u1.push(f64::from(self.next_f32()).max(1e-7));
        }
        for _ in 0..m {
            u2.push(f64::from(self.next_f32()));
        }
        let mut out = Vec::with_capacity(2 * m);
        // python: concat(r*cos(2πu2), r*sin(2πu2)) then truncate
        for i in 0..m {
            let r = (-2.0 * u1[i].ln()).sqrt();
            out.push((r * (2.0 * std::f64::consts::PI * u2[i]).cos()) as f32);
        }
        for i in 0..m {
            let r = (-2.0 * u1[i].ln()).sqrt();
            out.push((r * (2.0 * std::f64::consts::PI * u2[i]).sin()) as f32);
        }
        out.truncate(n);
        out
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_reference_vector() {
        // Pinned against python/tests/test_data_dft.py::test_splitmix64_reference_vector
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn test_next_below_in_range() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn test_f32_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn test_normal_moments() {
        let mut r = SplitMix64::new(3);
        let xs = r.normal(20_000);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn test_normal_odd_count() {
        let mut r = SplitMix64::new(5);
        assert_eq!(r.normal(7).len(), 7);
    }

    #[test]
    fn test_shuffle_permutes() {
        let mut r = SplitMix64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn test_deterministic_for_sample() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::for_sample(3, 17);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::for_sample(3, 17);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
