//! Streaming summary statistics (count/mean/min/max/percentiles).
//!
//! Used by the coordinator's metrics and the bench harness. Counts and
//! moments (`len`/`mean`/`min`/`max`/`stddev`) are exact running scalars
//! over every sample ever added; percentiles come from a bounded
//! reservoir of [`MAX_SAMPLES`] raw values — exact while the stream fits
//! the reservoir (which covers the bench harness and the reported
//! few-thousand-request windows), an unbiased uniform sample beyond it.
//! Memory is therefore O(1) no matter how long a `serve` process runs.

/// Reservoir capacity: percentiles are exact up to this many samples.
pub const MAX_SAMPLES: usize = 4096;

/// Collects f64 samples and reports summary statistics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
    /// samples ever added (>= samples.len() once the reservoir is full)
    seen: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
    /// splitmix64 state for reservoir eviction (deterministic, seeded by
    /// the first overflowing add)
    rng: u64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        if self.seen == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.seen += 1;
        self.sum += v;
        self.sum_sq += v * v;
        if self.samples.len() < MAX_SAMPLES {
            self.samples.push(v);
            self.sorted = false;
            return;
        }
        // Vitter's algorithm R: keep each of the `seen` samples in the
        // reservoir with probability MAX_SAMPLES/seen
        self.rng = self.rng.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.rng;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        let slot = (z % self.seen) as usize;
        if slot < MAX_SAMPLES {
            self.samples[slot] = v;
            self.sorted = false;
        }
    }

    /// Samples ever added (the reservoir itself holds at most
    /// [`MAX_SAMPLES`] of them).
    pub fn len(&self) -> usize {
        self.seen as usize
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.sum / self.seen as f64
    }

    pub fn min(&self) -> f64 {
        if self.seen == 0 {
            return f64::INFINITY;
        }
        self.min
    }

    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            return f64::NEG_INFINITY;
        }
        self.max
    }

    pub fn stddev(&self) -> f64 {
        if self.seen < 2 {
            return 0.0;
        }
        let n = self.seen as f64;
        let var = (self.sum_sq - self.sum * self.sum / n) / (n - 1.0);
        var.max(0.0).sqrt()
    }

    /// Percentile by nearest-rank (q in [0, 100]) over the reservoir —
    /// exact for streams up to [`MAX_SAMPLES`] samples.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((q / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    /// One-line report: `n=.. mean=.. p50=.. p95=.. p99=.. max=..`.
    pub fn report(&mut self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_empty() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn test_basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn test_percentiles_monotone() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(f64::from(i));
        }
        let mut last = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let p = s.percentile(q);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn test_add_after_percentile_resorts() {
        let mut s = Summary::new();
        s.add(10.0);
        assert_eq!(s.percentile(50.0), 10.0);
        s.add(0.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }

    #[test]
    fn test_reservoir_bounds_memory_with_exact_moments() {
        let mut s = Summary::new();
        let n = 10 * MAX_SAMPLES;
        for i in 0..n {
            s.add(i as f64);
        }
        assert_eq!(s.len(), n, "len counts every sample ever added");
        assert_eq!(s.samples.len(), MAX_SAMPLES, "reservoir stays capped");
        // moments are running scalars — exact regardless of eviction
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), (n - 1) as f64);
        assert!((s.mean() - (n - 1) as f64 / 2.0).abs() < 1e-6);
        // percentiles come from a uniform sample of [0, n): p50 within a
        // loose tolerance, report shape unchanged
        let p50 = s.percentile(50.0);
        assert!((p50 / (n as f64) - 0.5).abs() < 0.1, "p50={p50}");
        let r = s.report("us");
        assert!(r.starts_with(&format!("n={n} ")), "{r}");
    }

    #[test]
    fn test_exact_percentiles_up_to_capacity() {
        let mut s = Summary::new();
        for i in (0..MAX_SAMPLES).rev() {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), (MAX_SAMPLES - 1) as f64);
        assert_eq!(s.percentile(50.0), (((MAX_SAMPLES - 1) as f64) / 2.0).round());
    }
}
