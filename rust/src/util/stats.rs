//! Streaming summary statistics (count/mean/min/max/percentiles).
//!
//! Used by the coordinator's metrics and the bench harness — we keep raw
//! samples (bounded) so percentiles are exact, which matters when reporting
//! p99 latency over a few thousand requests.

/// Collects f64 samples and reports summary statistics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Exact percentile by nearest-rank (q in [0, 100]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((q / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    /// One-line report: `n=.. mean=.. p50=.. p95=.. p99=.. max=..`.
    pub fn report(&mut self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_empty() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn test_basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn test_percentiles_monotone() {
        let mut s = Summary::new();
        for i in 0..1000 {
            s.add(f64::from(i));
        }
        let mut last = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let p = s.percentile(q);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn test_add_after_percentile_resorts() {
        let mut s = Summary::new();
        s.add(10.0);
        assert_eq!(s.percentile(50.0), 10.0);
        s.add(0.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }
}
