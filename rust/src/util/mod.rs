//! Small shared utilities: PRNG, statistics, timing.

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::SplitMix64;
pub use stats::Summary;
pub use timer::Timer;
