//! Micro-benchmark harness (criterion is not available offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, does a
//! warmup phase, and reports mean / p50 / p95 with throughput derivation.
//! Benches live in `rust/benches/*.rs` with `harness = false`.

use std::time::Instant;

use crate::json::Json;
use crate::util::Summary;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// user-supplied work units per iteration (elements, MACs, requests...)
    pub units_per_iter: f64,
}

impl BenchResult {
    /// Work units per second.
    pub fn throughput(&self) -> f64 {
        self.units_per_iter / (self.mean_ns * 1e-9)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10.3} us/iter  (p50 {:>8.3}, p95 {:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.iters,
        );
        if self.units_per_iter > 0.0 {
            let t = self.throughput();
            if t > 1e9 {
                s.push_str(&format!("  {:.2} G/s", t / 1e9));
            } else if t > 1e6 {
                s.push_str(&format!("  {:.2} M/s", t / 1e6));
            } else {
                s.push_str(&format!("  {:.1} /s", t));
            }
        }
        s
    }
}

/// Benchmark runner with calibrated iteration counts.
pub struct Bencher {
    /// target total measurement time per case (seconds)
    pub target_s: f64,
    /// number of measured batches (percentile resolution)
    pub batches: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // honor a quick mode for CI-style runs
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Self { target_s: if quick { 0.2 } else { 1.0 }, batches: 10, results: Vec::new() }
    }

    /// Run one case: `f()` is a single iteration returning a value that must
    /// not be optimized away (its result is black-boxed here).
    pub fn bench<R>(&mut self, name: &str, units_per_iter: f64, mut f: impl FnMut() -> R) -> &BenchResult {
        // warmup + calibration: find iters such that one batch ~ target/batches
        let mut iters_per_batch = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            let dt = t.elapsed().as_secs_f64();
            if dt > self.target_s / self.batches as f64 || iters_per_batch > 1 << 30 {
                break;
            }
            let scale = ((self.target_s / self.batches as f64) / dt.max(1e-9)).min(16.0);
            iters_per_batch = ((iters_per_batch as f64 * scale).ceil() as u64).max(iters_per_batch + 1);
        }
        // measurement
        let mut per_iter = Summary::new();
        let mut total_iters = 0u64;
        for _ in 0..self.batches {
            let t = Instant::now();
            for _ in 0..iters_per_batch {
                std::hint::black_box(f());
            }
            let ns = t.elapsed().as_nanos() as f64 / iters_per_batch as f64;
            per_iter.add(ns);
            total_iters += iters_per_batch;
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: per_iter.mean(),
            p50_ns: per_iter.percentile(50.0),
            p95_ns: per_iter.percentile(95.0),
            units_per_iter,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Ratio of two completed cases' mean times (a/b).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fa.mean_ns / fb.mean_ns)
    }

    /// Machine-readable dump of all completed cases plus caller-supplied
    /// summary fields — the perf-trajectory baseline subsequent PRs diff
    /// against (e.g. `BENCH_kernels.json`).
    pub fn to_json(&self, extra: Vec<(&str, Json)>) -> Json {
        let cases = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("iters", Json::num(r.iters as f64)),
                        ("mean_ns", Json::num(r.mean_ns)),
                        ("p50_ns", Json::num(r.p50_ns)),
                        ("p95_ns", Json::num(r.p95_ns)),
                        ("units_per_iter", Json::num(r.units_per_iter)),
                        ("throughput_per_s", Json::num(r.throughput())),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![("cases", cases)];
        fields.extend(extra);
        Json::obj(fields)
    }

    /// Write [`Self::to_json`] to a file (pretty-printed).
    pub fn write_json(&self, path: &std::path::Path, extra: Vec<(&str, Json)>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(extra).to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_bench_runs_and_reports() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.target_s = 0.02;
        let r = b.bench("noop-ish", 10.0, || std::hint::black_box(1 + 1)).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn test_ratio() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.target_s = 0.02;
        b.bench("fast", 0.0, || 1);
        b.bench("slow", 0.0, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(std::hint::black_box(i));
            }
            s
        });
        let r = b.ratio("slow", "fast").unwrap();
        assert!(r > 1.0, "slow/fast = {r}");
        assert!(b.ratio("nope", "fast").is_none());
    }

    #[test]
    fn test_json_dump_roundtrips() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.target_s = 0.02;
        b.bench("case-a", 100.0, || 1);
        let j = b.to_json(vec![("speedup", Json::num(2.5))]);
        let back = crate::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("speedup").and_then(Json::as_f64), Some(2.5));
        let cases = back.get("cases").and_then(Json::as_arr).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").and_then(Json::as_str), Some("case-a"));
        assert!(cases[0].get("mean_ns").and_then(Json::as_f64).is_some());
    }
}
