//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `binary <subcommand> [--key value] [--flag] [positional...]`,
//! typed getters with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `expect_subcommand` controls whether the first bare word is treated
    /// as a subcommand or as a positional argument.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I, expect_subcommand: bool) -> Result<Self> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` separator: rest is positional
                    out.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), iter.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if expect_subcommand && out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env(expect_subcommand: bool) -> Result<Self> {
        Self::parse_from(std::env::args().skip(1), expect_subcommand)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get_str(name).unwrap_or(default)
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("--{name}={v}: {e}"),
            },
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(name)?.unwrap_or(default))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get_str(name).with_context(|| format!("missing required option --{name}"))
    }

    /// Comma-separated list option, e.g. `--variants a,b,c`.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get_str(name)
            .map(|s| s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], sub: bool) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()), sub).unwrap()
    }

    #[test]
    fn test_subcommand_and_options() {
        // NB: a bare word right after `--name` becomes its value; boolean
        // flags go last or before `--` (documented parser semantics).
        let a = parse(&["serve", "--port", "8080", "file.txt", "--verbose"], true);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get::<u16>("port").unwrap(), Some(8080));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn test_equals_syntax() {
        let a = parse(&["--batch=32", "--mode=fast"], false);
        assert_eq!(a.get_or("batch", 0usize).unwrap(), 32);
        assert_eq!(a.str_or("mode", "slow"), "fast");
    }

    #[test]
    fn test_flag_followed_by_flag() {
        let a = parse(&["--dry-run", "--force"], false);
        assert!(a.has_flag("dry-run") && a.has_flag("force"));
    }

    #[test]
    fn test_double_dash_separator() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"], false);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn test_typed_errors_and_defaults() {
        let a = parse(&["--n", "abc"], false);
        assert!(a.get::<u32>("n").is_err());
        assert_eq!(a.get_or("missing", 7u32).unwrap(), 7);
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn test_list_option() {
        let a = parse(&["--variants", "fp32, 8a2w_n4,,8a4w_n4"], false);
        assert_eq!(a.get_list("variants"), vec!["fp32", "8a2w_n4", "8a4w_n4"]);
        assert!(a.get_list("nothing").is_empty());
    }

    #[test]
    fn test_no_subcommand_mode() {
        let a = parse(&["input.dft", "--out", "x"], false);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["input.dft"]);
    }
}
