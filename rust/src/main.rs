//! dfp-infer — launcher CLI.
//!
//! Subcommands:
//!   serve      run the serving coordinator against AOT artifacts and a
//!              synthetic ShapeSet load, reporting latency/throughput.
//!              `--executor auto|lp|pjrt` picks the backend: `lp` is the
//!              pure-Rust quantized pipeline (kernels/ packed GEMMs, needs
//!              only qweights exports), `pjrt` the XLA artifacts; `auto`
//!              prefers lp when qweights are present. `--kernel` forces a
//!              GEMM implementation and/or SIMD tier
//!              (`<encoding>[+<tier>]`, e.g. `ternary+scalar`; the default
//!              tier is the best the CPU supports), `--threads` sizes its
//!              pool. `--stats-every <secs>` prints a periodic one-line
//!              serving + engine-counter report while the load runs.
//!   eval       evaluate artifact variants on the exported eval set
//!              (same --executor/--kernel/--threads knobs as serve)
//!   profile    run N forwards of the pure-Rust pipeline against a synthetic
//!              model (`--network`/`--scheme`) or an artifact qweights
//!              export (`--artifacts`/`--variant`) and report per-layer
//!              time, rows skipped and *measured* multiply-elimination,
//!              cross-checked against the analytic `opcount` census
//!              (`--runs`, `--batch`, `--json <path>` for the JSON report)
//!   opcount    print the §3.3 op-replacement table for a network
//!   quantize   quantize a DFT weight file under a precision scheme
//!              (rust-native Algorithms 1 & 2 + k-bit DFP)
//!   info       show the artifact manifest
//!   verify-artifact  deep-validate an artifact set before it serves:
//!              container checksums per tensor (DFT v2), manifest
//!              consistency, packed-code ranges, requant envelopes and
//!              scheme cross-checks — exits nonzero on the first typed
//!              failure. `--file <x.dft>` checks a single container instead
//!   export-synthetic  write the seeded §3.3 synthetic ladder to `--out`
//!              as a real checksummed artifact set (fixture for the CI
//!              round-trip and for trying verify/reload without a trainer)
//!
//! `serve` can hot-swap artifacts while under load: `--reload-from <dir>`
//! atomically reloads the coordinator from `<dir>` after `--reload-after
//! <n>` requests (default: halfway) — a rejected reload (corrupt or
//! inconsistent set) rolls back and the previous generation keeps serving.
//!
//! Precision is selected with typed schemes (see `scheme::Scheme` and
//! DESIGN.md §scheme): `--scheme 8a2w_n4` is the legacy ternary-N4 variant,
//! `--scheme 8a2w_n4@stem=i8@fc=i8` the paper's mixed configuration with
//! 8-bit boundary layers. serve/eval treat a scheme as the variant to run;
//! quantize uses it to pick each layer's codec; opcount accepts a list via
//! `--schemes` (or the legacy `--clusters` sweep).
//!
//! Examples:
//!   dfp-infer opcount --network resnet-101
//!   dfp-infer opcount --network resnet-101 --schemes 8a2w_n4@conv1=i8,8a4w_n4
//!   dfp-infer quantize --weights models/weights_fp32.dft --scheme 8a2w_n4@stem=i8@fc=i8
//!   dfp-infer serve --artifacts artifacts --requests 512 --workers 1
//!   dfp-infer serve --executor lp --kernel ternary --threads 4 --scheme 8a2w_n4
//!   dfp-infer serve --artifacts artifacts --stats-every 5
//!   dfp-infer profile --network resnet-mini --runs 20 --json profile.json
//!   dfp-infer eval --artifacts artifacts --variants fp32,8a2w_n4

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dfp_infer::cli::Args;
use dfp_infer::config::Config;
use dfp_infer::coordinator::{
    Coordinator, Executor, ExecutorFactory, LpExecutor, PjrtExecutor, PrecisionClass, ReloadHook,
    Request, Router, ServeError,
};
use dfp_infer::io::{read_dft, verify_dft, DftReport};
use dfp_infer::json::Json;
use dfp_infer::kernels::KernelKind;
use dfp_infer::lpinfer::{forward_quant_into, ForwardPlan, ForwardWorkspace, QModelParams};
use dfp_infer::model;
use dfp_infer::opcount;
use dfp_infer::quant::{self, TernaryMode};
use dfp_infer::scheme::{LayerPolicy, Scheme, WeightCodec};
use dfp_infer::telemetry::{self, ForwardProfile};
use dfp_infer::tensor::Tensor;
use dfp_infer::util::{SplitMix64, Timer};
use dfp_infer::{data, runtime};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(true)?;
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("profile") => cmd_profile(&args),
        Some("opcount") => cmd_opcount(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("info") => cmd_info(&args),
        Some("verify-artifact") => cmd_verify_artifact(&args),
        Some("export-synthetic") => cmd_export_synthetic(&args),
        Some(other) => {
            bail!(
                "unknown subcommand '{other}' \
                 (try serve|eval|profile|opcount|quantize|info|verify-artifact|export-synthetic)"
            )
        }
        None => {
            println!(
                "dfp-infer — mixed low-precision inference with dynamic fixed point\n\
                 usage: dfp-infer <serve|eval|profile|opcount|quantize|info|verify-artifact|export-synthetic> [options]"
            );
            Ok(())
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let m = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
    println!("image: {0}x{0}x3, classes: {1}", m.img, m.classes);
    println!("batch sizes: {:?}", m.batch_sizes);
    println!(
        "{:<12} {:>6} {:>8} {:>10} {:>3}  {}",
        "variant", "bits", "cluster", "eval_acc", "rq", "scheme"
    );
    for (name, v) in &m.variants {
        let scheme = m.scheme_of(name).map(|s| s.to_string()).unwrap_or_else(|| "-".into());
        println!(
            "{:<12} {:>6} {:>8} {:>10.4} {:>3}  {}",
            name, v.w_bits, v.cluster, v.eval_acc, v.requant_version, scheme
        );
    }
    Ok(())
}

/// Per-tensor integrity table of a [`DftReport`].
fn print_tensor_table(report: &DftReport) {
    println!(
        "  {:<28} {:>6} {:>18} {:>12} {:>18}",
        "tensor", "dtype", "shape", "bytes", "fnv1a"
    );
    for t in &report.tensors {
        let sum = t.checksum.map(|c| format!("{c:016x}")).unwrap_or_else(|| "- (v1)".into());
        println!(
            "  {:<28} {:>6} {:>18} {:>12} {:>18}",
            t.name,
            format!("{:?}", t.dtype).to_lowercase(),
            format!("{:?}", t.shape),
            t.payload_bytes,
            sum
        );
    }
}

/// `verify-artifact`: the offline twin of the serve/reload load gate.
/// Walks the same typed decode + deep-validation path the server enforces
/// and exits nonzero on the first failure, so a deploy pipeline can reject
/// a corrupt artifact set before it ever reaches a coordinator.
fn cmd_verify_artifact(args: &Args) -> Result<()> {
    // --file: verify a single DFT container (any tensor file, not just
    // qweights) — container-level checks only
    if let Some(file) = args.get_str("file") {
        let path = Path::new(file);
        let report = verify_dft(path)?;
        println!(
            "{} — DFT v{}, {} tensors, {} bytes",
            path.display(),
            report.version,
            report.tensors.len(),
            report.file_bytes
        );
        print_tensor_table(&report);
        println!("OK: every stored checksum verified");
        return Ok(());
    }
    let cfg = Config::resolve(args)?;
    let dir = &cfg.artifacts_dir;
    let manifest = runtime::Manifest::load(&dir.join("manifest.json"))?;
    println!(
        "manifest OK: {} variant(s), img {img}x{img}, classes {}",
        manifest.variants.len(),
        manifest.classes,
        img = manifest.img
    );
    let mut verified = 0usize;
    for name in manifest.variants.keys() {
        let path = dir.join(format!("qweights_{name}.dft"));
        if !path.exists() {
            continue;
        }
        let report = verify_dft(&path)
            .with_context(|| format!("integrity check failed for variant '{name}'"))?;
        println!(
            "\nvariant '{name}' — DFT v{}, {} tensors, {} bytes",
            report.version,
            report.tensors.len(),
            report.file_bytes
        );
        print_tensor_table(&report);
        verified += 1;
    }
    anyhow::ensure!(
        verified > 0,
        "no qweights_<variant>.dft exports found in {}",
        dir.display()
    );
    // deep semantic validation: packed-code ranges, requant envelopes,
    // scheme/manifest cross-checks — the same gate `serve` and a hot
    // reload enforce before a set may serve
    let (_, variants) = LpExecutor::load_variant_set(dir)?;
    println!(
        "\ndeep validation OK: {} servable variant(s) {:?}",
        variants.len(),
        variants.keys().collect::<Vec<_>>()
    );
    Ok(())
}

/// `export-synthetic`: write the seeded §3.3 ladder as a real artifact set.
fn cmd_export_synthetic(args: &Args) -> Result<()> {
    let out = args.str_or("out", "artifacts-synthetic");
    let seed: u64 = args.get_or("seed", 7)?;
    let dir = Path::new(out);
    LpExecutor::export_synthetic_artifacts(dir, seed)?;
    println!(
        "wrote synthetic ladder ({} variants, seed {seed}) to {}",
        LpExecutor::SYNTHETIC_LADDER.len(),
        dir.display()
    );
    Ok(())
}

fn cmd_opcount(args: &Args) -> Result<()> {
    let name = args.str_or("network", "resnet-101");
    let net = model::by_name(name).with_context(|| format!("unknown network '{name}'"))?;
    // --schemes takes arbitrary mixed schemes; --clusters sweeps the
    // paper's ternary-N configuration (8-bit first conv, ternary rest)
    let schemes: Vec<Scheme> = {
        let named = args.get_list("schemes");
        if named.is_empty() {
            let clusters: Vec<usize> = {
                let l = args.get_list("clusters");
                if l.is_empty() {
                    vec![1, 2, 4, 8, 16, 32, 64]
                } else {
                    l.iter().map(|s| s.parse()).collect::<Result<_, _>>()?
                }
            };
            anyhow::ensure!(
                clusters.iter().all(|&n| n >= 1),
                "--clusters: cluster sizes must be >= 1 (got {clusters:?})"
            );
            clusters.iter().map(|&n| opcount::ternary_scheme(&net, n)).collect()
        } else {
            let parsed: Vec<Scheme> = named.iter().map(|s| Scheme::parse(s)).collect::<Result<_>>()?;
            for s in &parsed {
                s.validate_for(&net)?;
            }
            parsed
        }
    };
    println!(
        "{} — {:.2} GMACs, {:.1} M weights, {:.0}% of conv MACs in 3x3+ layers",
        net.name,
        net.total_macs() as f64 / 1e9,
        net.total_weights() as f64 / 1e6,
        100.0 * net.frac_macs_3x3()
    );
    println!("{}", opcount::table_3_3(&net, &schemes));
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let input = args.require("weights")?;
    // --scheme drives per-layer codecs; the legacy --cluster/--mode pair
    // builds the equivalent uniform ternary scheme
    let scheme = match args.get_str("scheme") {
        Some(s) => Scheme::parse(s)?,
        None => {
            let cluster: usize = args.get_or("cluster", 4)?;
            let mode: TernaryMode = args.str_or("mode", "support").parse()?;
            Scheme::uniform(8, LayerPolicy::new(WeightCodec::Ternary { mode }, cluster)?)?
        }
    };
    let map = read_dft(Path::new(input))?;
    let mut layers: Vec<(&str, &[f32], usize, usize)> = Vec::new();
    for (name, t) in &map {
        let Some(layer) = name.strip_suffix(".w") else { continue };
        let Ok(f32t) = t.as_f32() else { continue };
        if f32t.shape().len() < 2 {
            continue;
        }
        let n_filters = *f32t.shape().last().unwrap();
        layers.push((layer, f32t.data(), f32t.len() / n_filters, n_filters));
    }
    // fail on typo'd override patterns before touching any weights
    scheme.validate_layers(layers.iter().map(|&(n, ..)| n))?;
    let quantized = quant::quantize_model(&scheme, layers.iter().copied())?;
    println!("scheme: {scheme}");
    println!(
        "{:<12} {:>6} {:>10} {:>10} {:>9} {:>9}",
        "layer", "codec", "elems", "sqnr(dB)", "sparsity", "clusters"
    );
    for ((name, q), &(_, w, _, _)) in quantized.iter().zip(&layers) {
        let back = q.dequantize();
        let codec = scheme.policy_for(name).codec.to_string();
        println!(
            "{:<12} {:>6} {:>10} {:>10.2} {:>8.1}% {:>9}",
            name,
            codec,
            w.len(),
            quant::sqnr_db(w, &back),
            100.0 * q.sparsity(),
            q.n_scales()
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let registry = cfg.kernel_registry();
    let manifest = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
    // auto mirrors cmd_serve: pjrt-enabled builds keep evaluating every
    // variant (incl. the fp32 baseline); the offline build uses lp
    let use_lp = match args.str_or("executor", "auto") {
        "lp" => true,
        "pjrt" => false,
        "auto" => {
            !cfg!(feature = "pjrt") && !LpExecutor::servable(&cfg.artifacts_dir, &manifest).is_empty()
        }
        other => bail!("unknown executor '{other}' (try auto|lp|pjrt)"),
    };
    let mut exec: Box<dyn Executor> = if use_lp {
        println!(
            "executor: lpinfer (kernel {}, simd tier {}, {} GEMM threads)",
            cfg.kernel,
            registry.tier(),
            registry.pool().threads()
        );
        Box::new(LpExecutor::from_artifacts(&cfg.artifacts_dir, registry)?)
    } else {
        let engine = PjrtExecutor::new(&cfg.artifacts_dir)?;
        println!("executor: pjrt");
        Box::new(engine)
    };

    let eval = read_dft(&cfg.artifacts_dir.join("eval_data.dft"))?;
    let images = eval.get("images").context("eval images")?.as_f32()?.clone();
    let labels = eval.get("labels").context("eval labels")?.as_i32()?.clone();
    let n = images.dim(0);
    let img = images.dim(1);
    let px = img * img * 3;
    let ncls = manifest.classes;

    // --variants wins; otherwise --scheme selects its variant; otherwise all
    let mut variants = args.get_list("variants");
    if variants.is_empty() {
        variants = match &cfg.scheme {
            Some(s) => {
                let name = s.name();
                anyhow::ensure!(
                    manifest.variants.contains_key(&name),
                    "scheme '{name}' is not an exported variant (have {:?})",
                    manifest.variants.keys().collect::<Vec<_>>()
                );
                vec![name]
            }
            None => manifest.variants.keys().cloned().collect(),
        };
    }
    let batch = *manifest.batch_sizes.iter().max().context("no batch sizes")?;

    for variant in &variants {
        if exec.batch_sizes(variant).is_empty() {
            println!("{variant:<12} SKIP (executor cannot serve this variant)");
            continue;
        }
        let mut correct = 0usize;
        let mut seen = 0usize;
        let t = Timer::new();
        for chunk in (0..n).step_by(batch) {
            let take = batch.min(n - chunk);
            let mut x = Tensor::<f32>::zeros(&[batch, img, img, 3]);
            x.data_mut()[..take * px]
                .copy_from_slice(&images.data()[chunk * px..(chunk + take) * px]);
            let logits = exec.run_batch(variant, batch, &x)?;
            for i in 0..take {
                let row = &logits.data()[i * ncls..(i + 1) * ncls];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred == labels.data()[chunk + i] as usize {
                    correct += 1;
                }
                seen += 1;
            }
        }
        let dt = t.elapsed_s();
        println!(
            "{:<12} acc {:.4} ({}/{})  exec {:.1} img/s",
            variant,
            correct as f64 / seen as f64,
            correct,
            seen,
            seen as f64 / dt
        );
    }
    Ok(())
}

/// `profile`: N instrumented forwards of the pure-Rust pipeline, reported
/// per layer (time, % of total, zero-skip rows, measured multiplies) and
/// cross-checked against the analytic [`opcount::census`]. The measured
/// multiply count reflects the kernel the registry *actually dispatches*
/// per layer (`--kernel` shows what a forced encoding costs), amortizing
/// one 8-bit scale multiply per N·K² weight block on the packed-ternary
/// engine — exactly the census accounting, so `auto` dispatch reproduces
/// the census fraction and any gap means dispatch diverged from the scheme.
fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let registry = cfg.kernel_registry();
    let runs: usize = args.get_or("runs", 10)?;
    anyhow::ensure!(runs >= 1, "--runs must be >= 1");
    let batch: usize = args.get_or("batch", 1)?;
    anyhow::ensure!(batch >= 1, "--batch must be >= 1");

    // model source: an artifact qweights export when --artifacts is given,
    // otherwise a synthetic quantization of --network under --scheme
    let (net, params, source) = if args.get_str("artifacts").is_some() {
        let manifest = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        let net = model::resnet_mini_default();
        let variant = match args.get_str("variant") {
            Some(v) => v.to_string(),
            None => match &cfg.scheme {
                Some(s) => s.name(),
                None => {
                    let mut servable = LpExecutor::servable(&cfg.artifacts_dir, &manifest);
                    servable.sort();
                    servable.into_iter().next().context(
                        "no lp-servable variant in the artifacts \
                         (need a qweights_<variant>.dft, or pass --variant)",
                    )?
                }
            },
        };
        let path = cfg.artifacts_dir.join(format!("qweights_{variant}.dft"));
        let map = read_dft(&path).with_context(|| format!("reading {}", path.display()))?;
        let params = QModelParams::from_tensors(&map, &net)?;
        (net, params, format!("artifact variant '{variant}'"))
    } else {
        let name = args.str_or("network", "resnet-mini");
        let net = model::by_name(name).with_context(|| format!("unknown network '{name}'"))?;
        let scheme = match &cfg.scheme {
            Some(s) => s.clone(),
            None => Scheme::parse("8a2w_n4@stem=i8")?,
        };
        scheme.validate_for(&net)?;
        // surface an unplannable layer table as the typed graph error (the
        // artifact path gets this for free from QModelParams::from_tensors)
        ForwardPlan::build(&net)
            .with_context(|| format!("cannot build a forward plan for network '{}'", net.name))?;
        let params = QModelParams::synthetic(&net, cfg.seed, &scheme);
        (net, params, format!("synthetic {name}"))
    };
    println!(
        "profiling {source} — scheme {}, kernel {} (tier {}), {} GEMM threads, batch {batch}, {runs} runs",
        params.scheme,
        cfg.kernel,
        registry.tier(),
        registry.pool().threads(),
    );

    let img = net.input_hw;
    let mut rng = SplitMix64::new(cfg.seed ^ 0xD1F);
    let x = Tensor::new(&[batch, img, img, 3], rng.normal(batch * img * img * 3))?;
    let mut ws = ForwardWorkspace::new();
    let mut logits = vec![0f32; batch * net.fc_out];
    // warm-up: sizes the workspace arena and faults the buffers in, so the
    // measured runs are the zero-allocation steady state
    forward_quant_into(&params, &net, &x, &registry, &mut ws, &mut logits);

    telemetry::engine().reset();
    let mut agg = ForwardProfile::new();
    for _ in 0..runs {
        forward_quant_into(&params, &net, &x, &registry, &mut ws, &mut logits);
        agg.accumulate(ws.profile());
    }
    let engine = telemetry::engine().snapshot();
    let rf = runs as f64;
    let ms_of = |ns: u64| ns as f64 / rf / 1e6;
    let total_ms = ms_of(agg.total_ns);

    // per-layer rows: measured time + skip tallies from the profile slots,
    // measured multiplies from the kernel the registry actually dispatches
    let mut rows: Vec<(String, KernelKind, f64, f64, u64, u64, u64, u64)> = Vec::new();
    let mut measured_mults = 0u64;
    for (i, l) in net.layers.iter().enumerate() {
        let p = params.conv(&l.name).with_context(|| format!("missing conv '{}'", l.name))?;
        let kind = registry.select(&p.packed);
        let macs = l.macs();
        let mults = match kind {
            KernelKind::PackedTernary => macs.div_ceil((p.policy.cluster * l.kh * l.kw) as u64),
            _ => macs,
        };
        measured_mults += mults;
        rows.push((
            l.name.clone(),
            kind,
            ms_of(agg.im2col_ns[i] + agg.gemm_ns[i]),
            ms_of(agg.im2col_ns[i]),
            agg.rows_probed[i] / runs as u64,
            agg.rows_skipped[i] / runs as u64,
            macs,
            mults,
        ));
    }
    // FC as its own row (K=1 in the census accounting; the profile has no
    // per-layer skip slot for it — its rows land in the engine totals)
    let fc_macs = (net.fc_in * net.fc_out) as u64;
    let fc_kind = registry.select(&params.fc_packed);
    let fc_mults = match fc_kind {
        KernelKind::PackedTernary => {
            fc_macs.div_ceil(params.scheme.policy_for("fc").cluster as u64)
        }
        _ => fc_macs,
    };
    measured_mults += fc_mults;
    rows.push(("fc".into(), fc_kind, ms_of(agg.fc_ns), 0.0, 0, 0, fc_macs, fc_mults));

    let census = opcount::census(&net, &params.scheme);
    let measured_elim = 1.0 - measured_mults as f64 / census.total_macs as f64;
    let census_elim = census.replaced_frac();
    let delta = (measured_elim - census_elim).abs();

    println!(
        "\n{:<12} {:>9} {:>10} {:>10} {:>6} {:>11} {:>11} {:>6} {:>13} {:>13}",
        "layer", "kernel", "ms", "im2col_ms", "%tot", "rows_probed", "rows_skip", "skip%", "macs", "mults"
    );
    for (name, kind, ms, col_ms, probed, skipped, macs, mults) in &rows {
        let pct = if total_ms > 0.0 { 100.0 * ms / total_ms } else { 0.0 };
        let skipf =
            if *probed > 0 { 100.0 * *skipped as f64 / *probed as f64 } else { 0.0 };
        println!(
            "{name:<12} {:>9} {ms:>10.3} {col_ms:>10.3} {pct:>5.1}% {probed:>11} {skipped:>11} {skipf:>5.1}% {macs:>13} {mults:>13}",
            kind.to_string(),
        );
    }
    let sum_im2col: u64 = agg.im2col_ns[..agg.layers].iter().sum();
    let sum_gemm: u64 = agg.gemm_ns[..agg.layers].iter().sum();
    println!(
        "\nstages (mean per forward): total {total_ms:.3}ms | quantize {:.3} | im2col {:.3} | \
         gemm {:.3} | maxpool {:.3} | skip-lane {:.3} | gap {:.3} | fc {:.3}",
        ms_of(agg.quantize_ns),
        ms_of(sum_im2col),
        ms_of(sum_gemm),
        ms_of(agg.maxpool_ns),
        ms_of(agg.skip_ns),
        ms_of(agg.gap_ns),
        ms_of(agg.fc_ns),
    );
    println!(
        "measured multiply-elimination {:.2}% vs census {:.2}% (delta {:.3}pp) — {} multiplies left of {} MACs",
        100.0 * measured_elim,
        100.0 * census_elim,
        100.0 * delta,
        measured_mults,
        census.total_macs,
    );
    println!("{}", engine.report());

    if let Some(path) = args.get_str("json") {
        let layers_json: Vec<Json> = rows
            .iter()
            .map(|(name, kind, ms, col_ms, probed, skipped, macs, mults)| {
                Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("kernel", Json::str(kind.to_string())),
                    ("ms", Json::num(*ms)),
                    ("im2col_ms", Json::num(*col_ms)),
                    (
                        "pct_of_total",
                        Json::num(if total_ms > 0.0 { 100.0 * ms / total_ms } else { 0.0 }),
                    ),
                    ("rows_probed", Json::num(*probed as f64)),
                    ("rows_skipped", Json::num(*skipped as f64)),
                    ("macs", Json::num(*macs as f64)),
                    ("mults", Json::num(*mults as f64)),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("network", Json::str(net.name.clone())),
            ("scheme", Json::str(params.scheme.to_string())),
            ("kernel", Json::str(cfg.kernel.to_string())),
            ("simd_tier", Json::str(registry.tier().to_string())),
            ("threads", Json::num(registry.pool().threads() as f64)),
            ("runs", Json::num(runs as f64)),
            ("batch", Json::num(batch as f64)),
            ("total_ms", Json::num(total_ms)),
            (
                "stages_ms",
                Json::obj(vec![
                    ("quantize", Json::num(ms_of(agg.quantize_ns))),
                    ("im2col", Json::num(ms_of(sum_im2col))),
                    ("gemm", Json::num(ms_of(sum_gemm))),
                    ("maxpool", Json::num(ms_of(agg.maxpool_ns))),
                    ("skip_lane", Json::num(ms_of(agg.skip_ns))),
                    ("gap", Json::num(ms_of(agg.gap_ns))),
                    ("fc", Json::num(ms_of(agg.fc_ns))),
                ]),
            ),
            ("layers", Json::arr(layers_json)),
            ("measured_mult_elimination", Json::num(measured_elim)),
            ("census_mult_elimination", Json::num(census_elim)),
            ("elimination_delta", Json::num(delta)),
            ("engine", engine.to_json()),
        ]);
        std::fs::write(path, j.to_string_pretty()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let registry = cfg.kernel_registry();
    let t = Timer::new();
    let (router, sizes, factories, img, reload_hook): (
        Router,
        std::collections::BTreeMap<String, Vec<usize>>,
        Vec<ExecutorFactory>,
        usize,
        Option<ReloadHook>,
    ) = if args.has_flag("synthetic") {
        // --synthetic: artifact-free serving over the seeded §3.3 ladder
        // (ternary N=64 / 4-bit / full i8) — used by the resilience CI
        // smoke and for trying the overload knobs without exports
        let m = LpExecutor::synthetic_manifest();
        println!(
            "executor: lpinfer synthetic ladder (kernel {}, simd tier {}, {} GEMM threads) over {:?}",
            cfg.kernel,
            registry.tier(),
            registry.pool().threads(),
            m.variants.keys().collect::<Vec<_>>()
        );
        let router = Router::from_manifest(&m)?;
        let sizes = m.variants.keys().map(|v| (v.clone(), m.batch_sizes.clone())).collect();
        // one shared weight store across the pool, so a hot reload swaps
        // every worker at once
        let store = LpExecutor::synthetic_store(cfg.seed);
        let net = model::resnet_mini_default();
        let factories = (0..cfg.workers.max(1))
            .map(|_| {
                LpExecutor::store_factory(
                    net.clone(),
                    Arc::clone(&store),
                    registry.clone(),
                    m.batch_sizes.clone(),
                )
            })
            .collect();
        let hook = Some(LpExecutor::reload_hook(store));
        (router, sizes, factories, m.img, hook)
    } else {
        println!("loading artifacts from {} ...", cfg.artifacts_dir.display());
        let mut manifest = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        // --scheme pins serving to one precision scheme (all routes collapse)
        if let Some(s) = &cfg.scheme {
            let name = s.name();
            anyhow::ensure!(
                manifest.variants.contains_key(&name),
                "scheme '{name}' is not an exported variant (have {:?})",
                manifest.variants.keys().collect::<Vec<_>>()
            );
            println!("pinned to scheme {name}");
            manifest.variants.retain(|n, _| *n == name);
        }
        let servable = LpExecutor::servable(&cfg.artifacts_dir, &manifest);
        // auto: a pjrt-enabled build keeps the old (full-variant) behavior;
        // the offline build falls back to lp whenever it can serve anything
        let use_lp = match args.str_or("executor", "auto") {
            "lp" => true,
            "pjrt" => false,
            "auto" => !cfg!(feature = "pjrt") && !servable.is_empty(),
            other => bail!("unknown executor '{other}' (try auto|lp|pjrt)"),
        };
        if use_lp {
            // pure-Rust path: serve the variants with a qweights export
            let mut m = manifest.clone();
            m.variants.retain(|n, _| servable.contains(n));
            println!(
                "executor: lpinfer (kernel {}, simd tier {}, {} GEMM threads) over {:?}",
                cfg.kernel,
                registry.tier(),
                registry.pool().threads(),
                m.variants.keys().collect::<Vec<_>>()
            );
            let router = Router::from_manifest(&m)?;
            let sizes = m
                .variants
                .keys()
                .map(|v| (v.clone(), m.batch_sizes.clone()))
                .collect();
            // load once into a shared store (deep-validated: checksums,
            // packed codes, requant envelopes) instead of once per worker
            let (_, store) = LpExecutor::shared_store_from_artifacts(&cfg.artifacts_dir)?;
            let net = model::resnet_mini_default();
            let factories = (0..cfg.workers.max(1))
                .map(|_| {
                    LpExecutor::store_factory(
                        net.clone(),
                        Arc::clone(&store),
                        registry.clone(),
                        m.batch_sizes.clone(),
                    )
                })
                .collect();
            let hook = Some(LpExecutor::reload_hook(store));
            (router, sizes, factories, manifest.img, hook)
        } else {
            println!("executor: pjrt");
            let router = Router::from_manifest(&manifest)?;
            let sizes = manifest
                .variants
                .iter()
                .map(|(v, i)| (v.clone(), i.files.keys().copied().collect()))
                .collect();
            let factories = (0..cfg.workers.max(1))
                .map(|_| PjrtExecutor::factory(cfg.artifacts_dir.clone(), true))
                .collect();
            (router, sizes, factories, manifest.img, None)
        }
    };
    println!(
        "routes: fast->{} balanced->{} accurate->{}",
        router.route(PrecisionClass::Fast),
        router.route(PrecisionClass::Balanced),
        router.route(PrecisionClass::Accurate)
    );
    let coord = Coordinator::start(factories, router.clone(), &sizes, img, cfg.to_coordinator())?;
    if let Some(hook) = reload_hook {
        coord.install_reload_hook(hook);
    }
    println!("coordinator up ({} workers, warmup {:.1}s)", cfg.workers.max(1), t.elapsed_s());

    // synthetic closed-loop load: round-robin precision classes
    let n = cfg.requests;
    // --stats-every <secs>: periodic one-line serving + engine report
    // (engine counters are printed as deltas since the previous line)
    let stats_every: f64 = args.get_or("stats-every", 0.0)?;
    // --reload-from <dir>: hot-swap the serving artifacts mid-run, after
    // --reload-after requests (default halfway) — exercises the atomic
    // swap + rollback path under real load
    let reload_from = args.get_str("reload-from").map(str::to_string);
    let reload_after: usize = args.get_or("reload-after", (n / 2).max(1))?;
    println!("issuing {n} requests (ShapeSet noise={}) ...", cfg.noise);
    let protos = data::prototypes();
    let classes = [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate];
    let deadline = (cfg.deadline_ms > 0)
        .then(|| std::time::Duration::from_millis(cfg.deadline_ms));
    let t = Timer::new();
    let mut stats_t = Timer::new();
    let mut last_engine = telemetry::engine().snapshot();
    let mut inflight = Vec::new();
    let mut correct = 0usize;
    let mut done = 0usize;
    let mut degraded = 0usize;
    let mut errors = 0usize;
    // one reply per request, served or typed-failed — tally both
    let settle = |reply: Result<dfp_infer::coordinator::ServeResult, _>,
                      lab: usize,
                      correct: &mut usize,
                      done: &mut usize,
                      degraded: &mut usize,
                      errors: &mut usize| {
        match reply {
            Ok(Ok(r)) => {
                *correct += usize::from(r.predicted == lab);
                *degraded += usize::from(r.degraded);
                *done += 1;
            }
            Ok(Err(_)) | Err(_) => *errors += 1,
        }
    };
    for i in 0..n {
        let (img, label) = data::sample(&protos, cfg.seed, i as u64, cfg.noise);
        let class = classes[i % classes.len()];
        loop {
            let mut req = Request::new(img.clone(), class);
            if let Some(d) = deadline {
                req = req.with_deadline(d);
            }
            match coord.submit(req) {
                Ok(rx) => {
                    inflight.push((rx, label));
                    break;
                }
                Err(ServeError::Overloaded) => {
                    // backpressure: drain one response and retry
                    match inflight.pop() {
                        Some((rx, lab)) => settle(
                            rx.recv(),
                            lab,
                            &mut correct,
                            &mut done,
                            &mut degraded,
                            &mut errors,
                        ),
                        None => std::thread::sleep(std::time::Duration::from_micros(100)),
                    }
                }
                Err(e) => bail!("submit failed: {e}"),
            }
        }
        if i + 1 == reload_after {
            if let Some(dir) = &reload_from {
                match coord.reload(Path::new(dir)) {
                    Ok(r) => println!(
                        "[reload] now serving generation {} ({} variants, prepared in {:.1}ms)",
                        r.generation,
                        r.variants.len(),
                        r.prepare_us as f64 / 1e3
                    ),
                    Err(e) => {
                        println!("[reload] rejected, previous generation keeps serving: {e}")
                    }
                }
            }
        }
        if stats_every > 0.0 && stats_t.elapsed_s() >= stats_every {
            let m = coord.metrics();
            println!(
                "[stats {:>6}/{n} submitted] e2e p50={:.0}us p99={:.0}us occupancy={:.1}% \
                 shed={} degraded={} dl_miss={} panics={} | {}",
                i + 1,
                m.e2e_us_p50,
                m.e2e_us_p99,
                100.0 * m.occupancy(),
                m.shed,
                m.degraded,
                m.deadline_missed,
                m.worker_panics,
                m.engine.since(&last_engine).report(),
            );
            last_engine = m.engine;
            stats_t.reset();
        }
    }
    for (rx, lab) in inflight {
        settle(rx.recv(), lab, &mut correct, &mut done, &mut degraded, &mut errors);
    }
    let wall = t.elapsed_s();
    let m = coord.metrics();
    println!("\n== serving summary ==");
    println!("{}", m.report());
    println!(
        "completed {}/{} ({} correct, acc {:.3}, {} degraded, {} typed errors)  wall {:.2}s  throughput {:.1} req/s",
        done,
        n,
        correct,
        correct as f64 / done.max(1) as f64,
        degraded,
        errors,
        wall,
        done as f64 / wall
    );
    let report = coord.shutdown();
    if !report.drained {
        eprintln!("warning: shutdown drain timed out ({} threads leaked)", report.leaked);
    }
    Ok(())
}
