//! dfp-infer — launcher CLI.
//!
//! Subcommands:
//!   serve      run the serving coordinator against AOT artifacts and a
//!              synthetic ShapeSet load, reporting latency/throughput.
//!              `--executor auto|lp|pjrt` picks the backend: `lp` is the
//!              pure-Rust quantized pipeline (kernels/ packed GEMMs, needs
//!              only qweights exports), `pjrt` the XLA artifacts; `auto`
//!              prefers lp when qweights are present. `--kernel` forces a
//!              GEMM implementation and/or SIMD tier
//!              (`<encoding>[+<tier>]`, e.g. `ternary+scalar`; the default
//!              tier is the best the CPU supports), `--threads` sizes its
//!              pool.
//!   eval       evaluate artifact variants on the exported eval set
//!              (same --executor/--kernel/--threads knobs as serve)
//!   opcount    print the §3.3 op-replacement table for a network
//!   quantize   quantize a DFT weight file under a precision scheme
//!              (rust-native Algorithms 1 & 2 + k-bit DFP)
//!   info       show the artifact manifest
//!
//! Precision is selected with typed schemes (see `scheme::Scheme` and
//! DESIGN.md §scheme): `--scheme 8a2w_n4` is the legacy ternary-N4 variant,
//! `--scheme 8a2w_n4@stem=i8@fc=i8` the paper's mixed configuration with
//! 8-bit boundary layers. serve/eval treat a scheme as the variant to run;
//! quantize uses it to pick each layer's codec; opcount accepts a list via
//! `--schemes` (or the legacy `--clusters` sweep).
//!
//! Examples:
//!   dfp-infer opcount --network resnet-101
//!   dfp-infer opcount --network resnet-101 --schemes 8a2w_n4@conv1=i8,8a4w_n4
//!   dfp-infer quantize --weights models/weights_fp32.dft --scheme 8a2w_n4@stem=i8@fc=i8
//!   dfp-infer serve --artifacts artifacts --requests 512 --workers 1
//!   dfp-infer serve --executor lp --kernel ternary --threads 4 --scheme 8a2w_n4
//!   dfp-infer eval --artifacts artifacts --variants fp32,8a2w_n4

use std::path::Path;

use anyhow::{bail, Context, Result};

use dfp_infer::cli::Args;
use dfp_infer::config::Config;
use dfp_infer::coordinator::{
    Coordinator, Executor, ExecutorFactory, LpExecutor, PjrtExecutor, PrecisionClass, Request, Router,
};
use dfp_infer::io::read_dft;
use dfp_infer::model;
use dfp_infer::opcount;
use dfp_infer::quant::{self, TernaryMode};
use dfp_infer::scheme::{LayerPolicy, Scheme, WeightCodec};
use dfp_infer::tensor::Tensor;
use dfp_infer::util::Timer;
use dfp_infer::{data, runtime};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(true)?;
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("opcount") => cmd_opcount(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("info") => cmd_info(&args),
        Some(other) => bail!("unknown subcommand '{other}' (try serve|eval|opcount|quantize|info)"),
        None => {
            println!(
                "dfp-infer — mixed low-precision inference with dynamic fixed point\n\
                 usage: dfp-infer <serve|eval|opcount|quantize|info> [options]"
            );
            Ok(())
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let m = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
    println!("image: {0}x{0}x3, classes: {1}", m.img, m.classes);
    println!("batch sizes: {:?}", m.batch_sizes);
    println!(
        "{:<12} {:>6} {:>8} {:>10} {:>3}  {}",
        "variant", "bits", "cluster", "eval_acc", "rq", "scheme"
    );
    for (name, v) in &m.variants {
        let scheme = m.scheme_of(name).map(|s| s.to_string()).unwrap_or_else(|| "-".into());
        println!(
            "{:<12} {:>6} {:>8} {:>10.4} {:>3}  {}",
            name, v.w_bits, v.cluster, v.eval_acc, v.requant_version, scheme
        );
    }
    Ok(())
}

fn cmd_opcount(args: &Args) -> Result<()> {
    let name = args.str_or("network", "resnet-101");
    let net = model::by_name(name).with_context(|| format!("unknown network '{name}'"))?;
    // --schemes takes arbitrary mixed schemes; --clusters sweeps the
    // paper's ternary-N configuration (8-bit first conv, ternary rest)
    let schemes: Vec<Scheme> = {
        let named = args.get_list("schemes");
        if named.is_empty() {
            let clusters: Vec<usize> = {
                let l = args.get_list("clusters");
                if l.is_empty() {
                    vec![1, 2, 4, 8, 16, 32, 64]
                } else {
                    l.iter().map(|s| s.parse()).collect::<Result<_, _>>()?
                }
            };
            anyhow::ensure!(
                clusters.iter().all(|&n| n >= 1),
                "--clusters: cluster sizes must be >= 1 (got {clusters:?})"
            );
            clusters.iter().map(|&n| opcount::ternary_scheme(&net, n)).collect()
        } else {
            let parsed: Vec<Scheme> = named.iter().map(|s| Scheme::parse(s)).collect::<Result<_>>()?;
            for s in &parsed {
                s.validate_for(&net)?;
            }
            parsed
        }
    };
    println!(
        "{} — {:.2} GMACs, {:.1} M weights, {:.0}% of conv MACs in 3x3+ layers",
        net.name,
        net.total_macs() as f64 / 1e9,
        net.total_weights() as f64 / 1e6,
        100.0 * net.frac_macs_3x3()
    );
    println!("{}", opcount::table_3_3(&net, &schemes));
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let input = args.require("weights")?;
    // --scheme drives per-layer codecs; the legacy --cluster/--mode pair
    // builds the equivalent uniform ternary scheme
    let scheme = match args.get_str("scheme") {
        Some(s) => Scheme::parse(s)?,
        None => {
            let cluster: usize = args.get_or("cluster", 4)?;
            let mode: TernaryMode = args.str_or("mode", "support").parse()?;
            Scheme::uniform(8, LayerPolicy::new(WeightCodec::Ternary { mode }, cluster)?)?
        }
    };
    let map = read_dft(Path::new(input))?;
    let mut layers: Vec<(&str, &[f32], usize, usize)> = Vec::new();
    for (name, t) in &map {
        let Some(layer) = name.strip_suffix(".w") else { continue };
        let Ok(f32t) = t.as_f32() else { continue };
        if f32t.shape().len() < 2 {
            continue;
        }
        let n_filters = *f32t.shape().last().unwrap();
        layers.push((layer, f32t.data(), f32t.len() / n_filters, n_filters));
    }
    // fail on typo'd override patterns before touching any weights
    scheme.validate_layers(layers.iter().map(|&(n, ..)| n))?;
    let quantized = quant::quantize_model(&scheme, layers.iter().copied())?;
    println!("scheme: {scheme}");
    println!(
        "{:<12} {:>6} {:>10} {:>10} {:>9} {:>9}",
        "layer", "codec", "elems", "sqnr(dB)", "sparsity", "clusters"
    );
    for ((name, q), &(_, w, _, _)) in quantized.iter().zip(&layers) {
        let back = q.dequantize();
        let codec = scheme.policy_for(name).codec.to_string();
        println!(
            "{:<12} {:>6} {:>10} {:>10.2} {:>8.1}% {:>9}",
            name,
            codec,
            w.len(),
            quant::sqnr_db(w, &back),
            100.0 * q.sparsity(),
            q.n_scales()
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    let registry = cfg.kernel_registry();
    let manifest = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
    // auto mirrors cmd_serve: pjrt-enabled builds keep evaluating every
    // variant (incl. the fp32 baseline); the offline build uses lp
    let use_lp = match args.str_or("executor", "auto") {
        "lp" => true,
        "pjrt" => false,
        "auto" => {
            !cfg!(feature = "pjrt") && !LpExecutor::servable(&cfg.artifacts_dir, &manifest).is_empty()
        }
        other => bail!("unknown executor '{other}' (try auto|lp|pjrt)"),
    };
    let mut exec: Box<dyn Executor> = if use_lp {
        println!(
            "executor: lpinfer (kernel {}, simd tier {}, {} GEMM threads)",
            cfg.kernel,
            registry.tier(),
            registry.pool().threads()
        );
        Box::new(LpExecutor::from_artifacts(&cfg.artifacts_dir, registry)?)
    } else {
        let engine = PjrtExecutor::new(&cfg.artifacts_dir)?;
        println!("executor: pjrt");
        Box::new(engine)
    };

    let eval = read_dft(&cfg.artifacts_dir.join("eval_data.dft"))?;
    let images = eval.get("images").context("eval images")?.as_f32()?.clone();
    let labels = eval.get("labels").context("eval labels")?.as_i32()?.clone();
    let n = images.dim(0);
    let img = images.dim(1);
    let px = img * img * 3;
    let ncls = manifest.classes;

    // --variants wins; otherwise --scheme selects its variant; otherwise all
    let mut variants = args.get_list("variants");
    if variants.is_empty() {
        variants = match &cfg.scheme {
            Some(s) => {
                let name = s.name();
                anyhow::ensure!(
                    manifest.variants.contains_key(&name),
                    "scheme '{name}' is not an exported variant (have {:?})",
                    manifest.variants.keys().collect::<Vec<_>>()
                );
                vec![name]
            }
            None => manifest.variants.keys().cloned().collect(),
        };
    }
    let batch = *manifest.batch_sizes.iter().max().context("no batch sizes")?;

    for variant in &variants {
        if exec.batch_sizes(variant).is_empty() {
            println!("{variant:<12} SKIP (executor cannot serve this variant)");
            continue;
        }
        let mut correct = 0usize;
        let mut seen = 0usize;
        let t = Timer::new();
        for chunk in (0..n).step_by(batch) {
            let take = batch.min(n - chunk);
            let mut x = Tensor::<f32>::zeros(&[batch, img, img, 3]);
            x.data_mut()[..take * px]
                .copy_from_slice(&images.data()[chunk * px..(chunk + take) * px]);
            let logits = exec.run_batch(variant, batch, &x)?;
            for i in 0..take {
                let row = &logits.data()[i * ncls..(i + 1) * ncls];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred == labels.data()[chunk + i] as usize {
                    correct += 1;
                }
                seen += 1;
            }
        }
        let dt = t.elapsed_s();
        println!(
            "{:<12} acc {:.4} ({}/{})  exec {:.1} img/s",
            variant,
            correct as f64 / seen as f64,
            correct,
            seen,
            seen as f64 / dt
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = Config::resolve(args)?;
    println!("loading artifacts from {} ...", cfg.artifacts_dir.display());
    let mut manifest = runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
    // --scheme pins serving to one precision scheme (all routes collapse)
    if let Some(s) = &cfg.scheme {
        let name = s.name();
        anyhow::ensure!(
            manifest.variants.contains_key(&name),
            "scheme '{name}' is not an exported variant (have {:?})",
            manifest.variants.keys().collect::<Vec<_>>()
        );
        println!("pinned to scheme {name}");
        manifest.variants.retain(|n, _| *n == name);
    }
    let servable = LpExecutor::servable(&cfg.artifacts_dir, &manifest);
    // auto: a pjrt-enabled build keeps the old (full-variant) behavior;
    // the offline build falls back to lp whenever it can serve anything
    let use_lp = match args.str_or("executor", "auto") {
        "lp" => true,
        "pjrt" => false,
        "auto" => !cfg!(feature = "pjrt") && !servable.is_empty(),
        other => bail!("unknown executor '{other}' (try auto|lp|pjrt)"),
    };
    let registry = cfg.kernel_registry();
    let t = Timer::new();
    let (router, sizes, factories): (
        Router,
        std::collections::BTreeMap<String, Vec<usize>>,
        Vec<ExecutorFactory>,
    ) = if use_lp {
        // pure-Rust path: serve the variants with a qweights export
        let mut m = manifest.clone();
        m.variants.retain(|n, _| servable.contains(n));
        println!(
            "executor: lpinfer (kernel {}, simd tier {}, {} GEMM threads) over {:?}",
            cfg.kernel,
            registry.tier(),
            registry.pool().threads(),
            m.variants.keys().collect::<Vec<_>>()
        );
        let router = Router::from_manifest(&m)?;
        let sizes = m
            .variants
            .keys()
            .map(|v| (v.clone(), m.batch_sizes.clone()))
            .collect();
        let factories = (0..cfg.workers.max(1))
            .map(|_| LpExecutor::factory(cfg.artifacts_dir.clone(), registry.clone()))
            .collect();
        (router, sizes, factories)
    } else {
        println!("executor: pjrt");
        let router = Router::from_manifest(&manifest)?;
        let sizes = manifest
            .variants
            .iter()
            .map(|(v, i)| (v.clone(), i.files.keys().copied().collect()))
            .collect();
        let factories = (0..cfg.workers.max(1))
            .map(|_| PjrtExecutor::factory(cfg.artifacts_dir.clone(), true))
            .collect();
        (router, sizes, factories)
    };
    println!(
        "routes: fast->{} balanced->{} accurate->{}",
        router.route(PrecisionClass::Fast),
        router.route(PrecisionClass::Balanced),
        router.route(PrecisionClass::Accurate)
    );
    let coord = Coordinator::start(factories, router.clone(), &sizes, manifest.img, cfg.to_coordinator())?;
    println!("coordinator up ({} workers, warmup {:.1}s)", cfg.workers.max(1), t.elapsed_s());

    // synthetic closed-loop load: round-robin precision classes
    let n = cfg.requests;
    println!("issuing {n} requests (ShapeSet noise={}) ...", cfg.noise);
    let protos = data::prototypes();
    let classes = [PrecisionClass::Fast, PrecisionClass::Balanced, PrecisionClass::Accurate];
    let t = Timer::new();
    let mut inflight = Vec::new();
    let mut correct = 0usize;
    let mut done = 0usize;
    for i in 0..n {
        let (img, label) = data::sample(&protos, cfg.seed, i as u64, cfg.noise);
        let class = classes[i % classes.len()];
        loop {
            match coord.submit(Request { image: img.clone(), class }) {
                Ok(rx) => {
                    inflight.push((rx, label));
                    break;
                }
                Err(_) => {
                    // backpressure: drain one response and retry
                    if let Some((rx, lab)) = inflight.pop() {
                        if let Ok(r) = rx.recv() {
                            correct += usize::from(r.predicted == lab);
                            done += 1;
                        }
                    }
                }
            }
        }
    }
    for (rx, lab) in inflight {
        if let Ok(r) = rx.recv() {
            correct += usize::from(r.predicted == lab);
            done += 1;
        }
    }
    let wall = t.elapsed_s();
    let m = coord.metrics();
    println!("\n== serving summary ==");
    println!("{}", m.report());
    println!(
        "completed {}/{} ({} correct, acc {:.3})  wall {:.2}s  throughput {:.1} req/s",
        done,
        n,
        correct,
        correct as f64 / done.max(1) as f64,
        wall,
        done as f64 / wall
    );
    coord.shutdown();
    Ok(())
}
