//! Paper Algorithms 1 & 2 — cluster-based ternary quantization (Rust side).
//!
//! Bit-for-bit mirror of `python/compile/quantize.py` (checked by
//! `rust/tests/integration_quant.rs` on weights exported from the trained
//! model): the serving artifacts are produced by the python pipeline, and
//! this implementation powers the rust-native analysis tools, the lpinfer
//! cross-check pipeline and the quantizer benches.

use crate::dfp::{self, ScaleU8};

/// Ternarization search mode (see DESIGN.md §2 and python docstring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TernaryMode {
    /// Algorithm 1 verbatim: the RMS scale doubles as the pruning threshold.
    Paper,
    /// Decoupled: support chosen by count (cluster-level Algorithm 2),
    /// alpha = RMS over the support.
    Support,
}

impl std::str::FromStr for TernaryMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "paper" => Ok(Self::Paper),
            "support" => Ok(Self::Support),
            other => anyhow::bail!("unknown ternary mode '{other}'"),
        }
    }
}

/// Result of ternarizing one layer: codes in {-1,0,1} plus per-cluster α̂.
#[derive(Debug, Clone)]
pub struct TernaryLayer {
    /// Flattened (elems_per_filter, n_filters) codes, filter-major columns.
    pub codes: Vec<i8>,
    pub elems_per_filter: usize,
    pub n_filters: usize,
    /// Dequantized α̂ per filter (cluster value broadcast).
    pub alpha: Vec<f32>,
    /// 8-bit quantized scale per cluster.
    pub scales: Vec<ScaleU8>,
    pub cluster_size: usize,
}

impl TernaryLayer {
    /// Fraction of zero weights.
    pub fn sparsity(&self) -> f64 {
        self.codes.iter().filter(|&&c| c == 0).count() as f64 / self.codes.len() as f64
    }

    /// Dequantize back to f32 (same flattened layout).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codes.len()];
        for f in 0..self.n_filters {
            let a = self.alpha[f];
            for e in 0..self.elems_per_filter {
                out[e * self.n_filters + f] = f32::from(self.codes[e * self.n_filters + f]) * a;
            }
        }
        out
    }
}

/// Paper Algorithm 2: best RMS alpha over sorted-magnitude prefixes.
///
/// For support = top-t |w|, alpha_t = sqrt(sum w^2 / t); the error with
/// sign weights on that support is `E(t) = Σw² − 2·α_t·S1(t) + α_t²·t`.
pub fn threshold_select(w: &[f32]) -> f64 {
    let mut mags: Vec<f64> = w.iter().map(|&x| f64::from(x).abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    if mags.is_empty() || mags[0] == 0.0 {
        return 0.0;
    }
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    let total: f64 = mags.iter().map(|m| m * m).sum();
    let mut best = (f64::INFINITY, 0.0f64);
    for (i, &m) in mags.iter().enumerate() {
        s1 += m;
        s2 += m * m;
        let t = (i + 1) as f64;
        let alpha = (s2 / t).sqrt();
        let err = total - 2.0 * alpha * s1 + alpha * alpha * t;
        if err < best.0 {
            best = (err, alpha);
        }
    }
    best.1
}

/// Ternarize one cluster (columns `filters` of the flattened layer).
///
/// `wc` is (elems_per_filter x n) column-major-by-filter slice view packed
/// as row-major (elem, filter). Returns (codes, alpha) pre-quantization.
fn ternarize_cluster(wc: &[f32], n: usize, mode: TernaryMode) -> (Vec<i8>, f64) {
    let total: f64 = wc.iter().map(|&x| f64::from(x) * f64::from(x)).sum();
    let alpha = match mode {
        TernaryMode::Support => {
            let mut mags: Vec<f64> = wc.iter().map(|&x| f64::from(x).abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            if mags.is_empty() || mags[0] == 0.0 {
                return (vec![0; wc.len()], 0.0);
            }
            let (mut s1, mut s2) = (0.0, 0.0);
            let mut best = (f64::INFINITY, 0.0, 0.0); // (err, alpha, thresh)
            for (i, &m) in mags.iter().enumerate() {
                s1 += m;
                s2 += m * m;
                let t = (i + 1) as f64;
                let a = (s2 / t).sqrt();
                let err = total - 2.0 * a * s1 + a * a * t;
                if err < best.0 {
                    best = (err, a, m);
                }
            }
            // support = |w| >= threshold (by count in python; >= matches)
            let thresh = best.2;
            let codes = wc
                .iter()
                .map(|&x| {
                    if f64::from(x).abs() >= thresh {
                        if x > 0.0 {
                            1
                        } else {
                            -1
                        }
                    } else {
                        0
                    }
                })
                .collect();
            return (codes, best.1);
        }
        TernaryMode::Paper => {
            // per-filter Algorithm 2 thresholds
            let epf = wc.len() / n;
            let mut alphas: Vec<f64> = (0..n)
                .map(|f| {
                    let col: Vec<f32> = (0..epf).map(|e| wc[e * n + f]).collect();
                    threshold_select(&col)
                })
                .collect();
            alphas.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut best = (f64::INFINITY, 0.0f64);
            let mut acc = 0.0;
            for (t, &a_t) in alphas.iter().enumerate() {
                acc += a_t * a_t;
                let alpha = (acc / (t + 1) as f64).sqrt();
                let (mut s1, mut cnt) = (0.0f64, 0.0f64);
                for &x in wc {
                    let m = f64::from(x).abs();
                    if m >= alpha {
                        s1 += m;
                        cnt += 1.0;
                    }
                }
                let err = total - 2.0 * alpha * s1 + alpha * alpha * cnt;
                if err < best.0 {
                    best = (err, alpha);
                }
            }
            best.1
        }
    };
    let codes = wc
        .iter()
        .map(|&x| {
            if f64::from(x).abs() >= alpha {
                if x > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            }
        })
        .collect();
    (codes, alpha)
}

/// Paper Algorithm 1 over a flattened (elems_per_filter, n_filters) layer.
///
/// Output filters are grouped into static clusters of `cluster_size`
/// consecutive filters; each cluster gets one α̂ (8-bit re-quantized).
pub fn ternarize_layer(
    w: &[f32],
    elems_per_filter: usize,
    n_filters: usize,
    cluster_size: usize,
    mode: TernaryMode,
) -> TernaryLayer {
    assert_eq!(w.len(), elems_per_filter * n_filters);
    let n_clusters = n_filters.div_ceil(cluster_size);
    let mut codes = vec![0i8; w.len()];
    let mut alpha = vec![0.0f32; n_filters];
    let mut scales = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let lo = c * cluster_size;
        let hi = ((c + 1) * cluster_size).min(n_filters);
        let width = hi - lo;
        // gather the cluster as (elem, filter-within-cluster)
        let mut wc = Vec::with_capacity(elems_per_filter * width);
        for e in 0..elems_per_filter {
            for f in lo..hi {
                wc.push(w[e * n_filters + f]);
            }
        }
        let (cc, a) = ternarize_cluster(&wc, width, mode);
        let s = ScaleU8::quantize(a);
        let a_hat = s.dequantize() as f32;
        scales.push(s);
        for e in 0..elems_per_filter {
            for (j, f) in (lo..hi).enumerate() {
                codes[e * n_filters + f] = cc[e * width + j];
                alpha[f] = a_hat;
            }
        }
    }
    TernaryLayer { codes, elems_per_filter, n_filters, alpha, scales, cluster_size }
}

/// TWN baseline (Li et al. [7]): Δ = 0.7·E|w|, α = mean|w| over support.
pub fn ternarize_twn(w: &[f32]) -> (Vec<i8>, f64) {
    let n = w.len() as f64;
    let mean_abs: f64 = w.iter().map(|&x| f64::from(x).abs()).sum::<f64>() / n;
    let delta = 0.7 * mean_abs;
    let mut s = 0.0f64;
    let mut cnt = 0.0f64;
    let codes: Vec<i8> = w
        .iter()
        .map(|&x| {
            let m = f64::from(x).abs();
            if m > delta {
                s += m;
                cnt += 1.0;
                if x > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            }
        })
        .collect();
    let alpha = if cnt > 0.0 { s / cnt } else { 0.0 };
    (codes, alpha)
}

/// k-bit clustered DFP weights: one power-of-two exponent per cluster.
#[derive(Debug, Clone)]
pub struct DfpLayer {
    pub codes: Vec<i8>,
    pub elems_per_filter: usize,
    pub n_filters: usize,
    pub exps: Vec<i32>,
    pub bits: u32,
    pub cluster_size: usize,
}

impl DfpLayer {
    pub fn scale_of_filter(&self, f: usize) -> f32 {
        2f32.powi(self.exps[f / self.cluster_size])
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codes.len()];
        for f in 0..self.n_filters {
            let s = self.scale_of_filter(f);
            for e in 0..self.elems_per_filter {
                out[e * self.n_filters + f] = f32::from(self.codes[e * self.n_filters + f]) * s;
            }
        }
        out
    }
}

/// Quantize a flattened layer to `bits`-bit DFP with per-cluster exponents.
pub fn quantize_layer_dfp(
    w: &[f32],
    elems_per_filter: usize,
    n_filters: usize,
    bits: u32,
    cluster_size: usize,
) -> DfpLayer {
    assert_eq!(w.len(), elems_per_filter * n_filters);
    let n_clusters = n_filters.div_ceil(cluster_size);
    let mut codes = vec![0i8; w.len()];
    let mut exps = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let lo = c * cluster_size;
        let hi = ((c + 1) * cluster_size).min(n_filters);
        let mut max_abs = 0.0f32;
        for e in 0..elems_per_filter {
            for f in lo..hi {
                max_abs = max_abs.max(w[e * n_filters + f].abs());
            }
        }
        let exp = dfp::choose_exp(max_abs, bits);
        let scale = 2f64.powi(-exp);
        let q = f64::from(dfp::qmax(bits));
        for e in 0..elems_per_filter {
            for f in lo..hi {
                let v = dfp::round_half_even(f64::from(w[e * n_filters + f]) * scale).clamp(-q, q);
                codes[e * n_filters + f] = v as i8;
            }
        }
        exps.push(exp);
    }
    DfpLayer { codes, elems_per_filter, n_filters, exps, bits, cluster_size }
}

/// Signal-to-quantization-noise ratio in dB between `w` and `w_hat`.
pub fn sqnr_db(w: &[f32], w_hat: &[f32]) -> f64 {
    let sig: f64 = w.iter().map(|&x| f64::from(x) * f64::from(x)).sum();
    let noise: f64 = w
        .iter()
        .zip(w_hat)
        .map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2))
        .sum();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn gaussian(n: usize, seed: u64, sigma: f32) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        rng.normal(n).into_iter().map(|x| x * sigma).collect()
    }

    #[test]
    fn test_threshold_select_is_prefix_rms() {
        let w = gaussian(200, 1, 0.1);
        let a = threshold_select(&w);
        let mut mags: Vec<f64> = w.iter().map(|&x| f64::from(x).abs()).collect();
        mags.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let mut s2 = 0.0;
        let mut found = false;
        for (i, &m) in mags.iter().enumerate() {
            s2 += m * m;
            if ((s2 / (i + 1) as f64).sqrt() - a).abs() < 1e-12 {
                found = true;
            }
        }
        assert!(found);
    }

    #[test]
    fn test_exact_ternary_recovery_both_modes() {
        let mut rng = SplitMix64::new(3);
        let codes: Vec<i8> = (0..16 * 9).map(|_| rng.next_below(3) as i8 - 1).collect();
        let w: Vec<f32> = codes.iter().map(|&c| f32::from(c) * 0.37).collect();
        for mode in [TernaryMode::Paper, TernaryMode::Support] {
            let t = ternarize_layer(&w, 9, 16, 4, mode);
            let back = t.dequantize();
            let rel = {
                let num: f64 = w.iter().zip(&back).map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2)).sum::<f64>();
                let den: f64 = w.iter().map(|&a| f64::from(a).powi(2)).sum::<f64>();
                (num / den).sqrt()
            };
            assert!(rel < 0.01, "{mode:?}: rel err {rel}");
        }
    }

    #[test]
    fn test_ternary_codes_are_ternary_and_cluster_shared() {
        let w = gaussian(9 * 24, 5, 0.1);
        let t = ternarize_layer(&w, 9, 24, 8, TernaryMode::Support);
        assert!(t.codes.iter().all(|&c| (-1..=1).contains(&c)));
        assert_eq!(t.scales.len(), 3);
        for f in 0..24 {
            assert_eq!(t.alpha[f], t.alpha[(f / 8) * 8]);
        }
    }

    #[test]
    fn test_paper_mode_sparser_than_support() {
        let w = gaussian(9 * 32 * 32, 6, 0.1);
        let p = ternarize_layer(&w, 9 * 32, 32, 4, TernaryMode::Paper);
        let s = ternarize_layer(&w, 9 * 32, 32, 4, TernaryMode::Support);
        assert!(p.sparsity() > s.sparsity(), "{} vs {}", p.sparsity(), s.sparsity());
    }

    #[test]
    fn test_smaller_clusters_not_worse() {
        let w = gaussian(9 * 16 * 64, 7, 0.1);
        let mut errs = Vec::new();
        for n in [1usize, 4, 16, 64] {
            let t = ternarize_layer(&w, 9 * 16, 64, n, TernaryMode::Support);
            let back = t.dequantize();
            let e: f64 = w.iter().zip(&back).map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2)).sum();
            errs.push(e);
        }
        for win in errs.windows(2) {
            assert!(win[0] <= win[1] * 1.02, "{errs:?}");
        }
    }

    #[test]
    fn test_twn_properties() {
        let w = gaussian(1000, 8, 0.1);
        let (codes, alpha) = ternarize_twn(&w);
        assert!(alpha > 0.0);
        let kept: Vec<f64> = w
            .iter()
            .zip(&codes)
            .filter(|(_, &c)| c != 0)
            .map(|(&x, _)| f64::from(x).abs())
            .collect();
        let mean = kept.iter().sum::<f64>() / kept.len() as f64;
        assert!((mean - alpha).abs() < 1e-9);
    }

    #[test]
    fn test_dfp_layer_range_and_error() {
        let w = gaussian(9 * 16, 9, 0.2);
        for bits in [4u32, 8] {
            let d = quantize_layer_dfp(&w, 9, 16, bits, 4);
            assert!(d.codes.iter().all(|&c| i32::from(c).abs() <= dfp::qmax(bits)));
            let back = d.dequantize();
            for f in 0..16 {
                let ulp = 2f32.powi(d.exps[f / 4]);
                for e in 0..9 {
                    let i = e * 16 + f;
                    assert!((w[i] - back[i]).abs() <= ulp / 2.0 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn test_sqnr() {
        let w = vec![1.0f32, 2.0, 3.0];
        assert_eq!(sqnr_db(&w, &w), f64::INFINITY);
        assert!((sqnr_db(&w, &[0.0, 0.0, 0.0]) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn test_uneven_last_cluster() {
        let w = gaussian(9 * 10, 10, 0.1);
        let t = ternarize_layer(&w, 9, 10, 4, TernaryMode::Support);
        assert_eq!(t.scales.len(), 3); // 4 + 4 + 2
        let d = quantize_layer_dfp(&w, 9, 10, 4, 4);
        assert_eq!(d.exps.len(), 3);
    }

    #[test]
    fn test_zero_weights() {
        let w = vec![0.0f32; 9 * 4];
        let t = ternarize_layer(&w, 9, 4, 4, TernaryMode::Support);
        assert!(t.codes.iter().all(|&c| c == 0));
        assert_eq!(threshold_select(&w), 0.0);
    }
}
