//! Paper Algorithms 1 & 2 — cluster-based ternary quantization (Rust side).
//!
//! Bit-for-bit mirror of `python/compile/quantize.py` (checked by
//! `rust/tests/integration_quant.rs` on weights exported from the trained
//! model): the serving artifacts are produced by the python pipeline, and
//! this implementation powers the rust-native analysis tools, the lpinfer
//! cross-check pipeline and the quantizer benches.

use anyhow::{ensure, Context, Result};

use crate::dfp::{self, ScaleU8};
use crate::scheme::{LayerPolicy, Scheme, WeightCodec};

/// Ternarization search mode (see DESIGN.md §2 and python docstring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TernaryMode {
    /// Algorithm 1 verbatim: the RMS scale doubles as the pruning threshold.
    Paper,
    /// Decoupled: support chosen by count (cluster-level Algorithm 2),
    /// alpha = RMS over the support.
    Support,
}

impl std::str::FromStr for TernaryMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "paper" => Ok(Self::Paper),
            "support" => Ok(Self::Support),
            other => anyhow::bail!("unknown ternary mode '{other}'"),
        }
    }
}

/// Result of ternarizing one layer: codes in {-1,0,1} plus per-cluster α̂.
#[derive(Debug, Clone)]
pub struct TernaryLayer {
    /// Flattened (elems_per_filter, n_filters) codes, filter-major columns.
    pub codes: Vec<i8>,
    pub elems_per_filter: usize,
    pub n_filters: usize,
    /// Dequantized α̂ per filter (cluster value broadcast).
    pub alpha: Vec<f32>,
    /// 8-bit quantized scale per cluster.
    pub scales: Vec<ScaleU8>,
    pub cluster_size: usize,
}

impl TernaryLayer {
    /// Fraction of zero weights.
    pub fn sparsity(&self) -> f64 {
        self.codes.iter().filter(|&&c| c == 0).count() as f64 / self.codes.len() as f64
    }

    /// Dequantize back to f32 (same flattened layout).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codes.len()];
        for f in 0..self.n_filters {
            let a = self.alpha[f];
            for e in 0..self.elems_per_filter {
                out[e * self.n_filters + f] = f32::from(self.codes[e * self.n_filters + f]) * a;
            }
        }
        out
    }
}

/// Paper Algorithm 2: best RMS alpha over sorted-magnitude prefixes.
///
/// For support = top-t |w|, alpha_t = sqrt(sum w^2 / t); the error with
/// sign weights on that support is `E(t) = Σw² − 2·α_t·S1(t) + α_t²·t`.
pub fn threshold_select(w: &[f32]) -> f64 {
    let mut mags: Vec<f64> = w.iter().map(|&x| f64::from(x).abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    if mags.is_empty() || mags[0] == 0.0 {
        return 0.0;
    }
    let (mut s1, mut s2) = (0.0f64, 0.0f64);
    let total: f64 = mags.iter().map(|m| m * m).sum();
    let mut best = (f64::INFINITY, 0.0f64);
    for (i, &m) in mags.iter().enumerate() {
        s1 += m;
        s2 += m * m;
        let t = (i + 1) as f64;
        let alpha = (s2 / t).sqrt();
        let err = total - 2.0 * alpha * s1 + alpha * alpha * t;
        if err < best.0 {
            best = (err, alpha);
        }
    }
    best.1
}

/// Ternarize one cluster (columns `filters` of the flattened layer).
///
/// `wc` is (elems_per_filter x n) column-major-by-filter slice view packed
/// as row-major (elem, filter). Returns (codes, alpha) pre-quantization.
fn ternarize_cluster(wc: &[f32], n: usize, mode: TernaryMode) -> (Vec<i8>, f64) {
    let total: f64 = wc.iter().map(|&x| f64::from(x) * f64::from(x)).sum();
    let alpha = match mode {
        TernaryMode::Support => {
            let mut mags: Vec<f64> = wc.iter().map(|&x| f64::from(x).abs()).collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            if mags.is_empty() || mags[0] == 0.0 {
                return (vec![0; wc.len()], 0.0);
            }
            let (mut s1, mut s2) = (0.0, 0.0);
            let mut best = (f64::INFINITY, 0.0, 0.0); // (err, alpha, thresh)
            for (i, &m) in mags.iter().enumerate() {
                s1 += m;
                s2 += m * m;
                let t = (i + 1) as f64;
                let a = (s2 / t).sqrt();
                let err = total - 2.0 * a * s1 + a * a * t;
                if err < best.0 {
                    best = (err, a, m);
                }
            }
            // support = |w| >= threshold (by count in python; >= matches)
            let thresh = best.2;
            let codes = wc
                .iter()
                .map(|&x| {
                    if f64::from(x).abs() >= thresh {
                        if x > 0.0 {
                            1
                        } else {
                            -1
                        }
                    } else {
                        0
                    }
                })
                .collect();
            return (codes, best.1);
        }
        TernaryMode::Paper => {
            // per-filter Algorithm 2 thresholds
            let epf = wc.len() / n;
            let mut alphas: Vec<f64> = (0..n)
                .map(|f| {
                    let col: Vec<f32> = (0..epf).map(|e| wc[e * n + f]).collect();
                    threshold_select(&col)
                })
                .collect();
            alphas.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut best = (f64::INFINITY, 0.0f64);
            let mut acc = 0.0;
            for (t, &a_t) in alphas.iter().enumerate() {
                acc += a_t * a_t;
                let alpha = (acc / (t + 1) as f64).sqrt();
                let (mut s1, mut cnt) = (0.0f64, 0.0f64);
                for &x in wc {
                    let m = f64::from(x).abs();
                    if m >= alpha {
                        s1 += m;
                        cnt += 1.0;
                    }
                }
                let err = total - 2.0 * alpha * s1 + alpha * alpha * cnt;
                if err < best.0 {
                    best = (err, alpha);
                }
            }
            best.1
        }
    };
    let codes = wc
        .iter()
        .map(|&x| {
            if f64::from(x).abs() >= alpha {
                if x > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            }
        })
        .collect();
    (codes, alpha)
}

/// Paper Algorithm 1 over a flattened (elems_per_filter, n_filters) layer.
///
/// Output filters are grouped into static clusters of `cluster_size`
/// consecutive filters; each cluster gets one α̂ (8-bit re-quantized).
pub fn ternarize_layer(
    w: &[f32],
    elems_per_filter: usize,
    n_filters: usize,
    cluster_size: usize,
    mode: TernaryMode,
) -> Result<TernaryLayer> {
    ensure!(cluster_size >= 1, "ternarize_layer: cluster size must be >= 1 (got 0)");
    ensure!(
        w.len() == elems_per_filter * n_filters,
        "ternarize_layer: {} weights != {elems_per_filter}x{n_filters}",
        w.len()
    );
    let n_clusters = n_filters.div_ceil(cluster_size);
    let mut codes = vec![0i8; w.len()];
    let mut alpha = vec![0.0f32; n_filters];
    let mut scales = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let lo = c * cluster_size;
        let hi = ((c + 1) * cluster_size).min(n_filters);
        let width = hi - lo;
        // gather the cluster as (elem, filter-within-cluster)
        let mut wc = Vec::with_capacity(elems_per_filter * width);
        for e in 0..elems_per_filter {
            for f in lo..hi {
                wc.push(w[e * n_filters + f]);
            }
        }
        let (cc, a) = ternarize_cluster(&wc, width, mode);
        let s = ScaleU8::quantize(a);
        let a_hat = s.dequantize() as f32;
        scales.push(s);
        for e in 0..elems_per_filter {
            for (j, f) in (lo..hi).enumerate() {
                codes[e * n_filters + f] = cc[e * width + j];
                alpha[f] = a_hat;
            }
        }
    }
    Ok(TernaryLayer { codes, elems_per_filter, n_filters, alpha, scales, cluster_size })
}

/// TWN baseline (Li et al. [7]): Δ = 0.7·E|w|, α = mean|w| over support.
pub fn ternarize_twn(w: &[f32]) -> (Vec<i8>, f64) {
    let n = w.len() as f64;
    let mean_abs: f64 = w.iter().map(|&x| f64::from(x).abs()).sum::<f64>() / n;
    let delta = 0.7 * mean_abs;
    let mut s = 0.0f64;
    let mut cnt = 0.0f64;
    let codes: Vec<i8> = w
        .iter()
        .map(|&x| {
            let m = f64::from(x).abs();
            if m > delta {
                s += m;
                cnt += 1.0;
                if x > 0.0 {
                    1
                } else {
                    -1
                }
            } else {
                0
            }
        })
        .collect();
    let alpha = if cnt > 0.0 { s / cnt } else { 0.0 };
    (codes, alpha)
}

/// k-bit clustered DFP weights: one power-of-two exponent per cluster.
#[derive(Debug, Clone)]
pub struct DfpLayer {
    pub codes: Vec<i8>,
    pub elems_per_filter: usize,
    pub n_filters: usize,
    pub exps: Vec<i32>,
    pub bits: u32,
    pub cluster_size: usize,
}

impl DfpLayer {
    pub fn scale_of_filter(&self, f: usize) -> f32 {
        2f32.powi(self.exps[f / self.cluster_size])
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.codes.len()];
        for f in 0..self.n_filters {
            let s = self.scale_of_filter(f);
            for e in 0..self.elems_per_filter {
                out[e * self.n_filters + f] = f32::from(self.codes[e * self.n_filters + f]) * s;
            }
        }
        out
    }
}

/// Quantize a flattened layer to `bits`-bit DFP with per-cluster exponents.
pub fn quantize_layer_dfp(
    w: &[f32],
    elems_per_filter: usize,
    n_filters: usize,
    bits: u32,
    cluster_size: usize,
) -> Result<DfpLayer> {
    ensure!(cluster_size >= 1, "quantize_layer_dfp: cluster size must be >= 1 (got 0)");
    ensure!((2..=8).contains(&bits), "quantize_layer_dfp: bits must be in 2..=8 (got {bits})");
    ensure!(
        w.len() == elems_per_filter * n_filters,
        "quantize_layer_dfp: {} weights != {elems_per_filter}x{n_filters}",
        w.len()
    );
    let n_clusters = n_filters.div_ceil(cluster_size);
    let mut codes = vec![0i8; w.len()];
    let mut exps = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let lo = c * cluster_size;
        let hi = ((c + 1) * cluster_size).min(n_filters);
        let mut max_abs = 0.0f32;
        for e in 0..elems_per_filter {
            for f in lo..hi {
                max_abs = max_abs.max(w[e * n_filters + f].abs());
            }
        }
        let exp = dfp::choose_exp(max_abs, bits);
        let scale = 2f64.powi(-exp);
        let q = f64::from(dfp::qmax(bits));
        for e in 0..elems_per_filter {
            for f in lo..hi {
                let v = dfp::round_half_even(f64::from(w[e * n_filters + f]) * scale).clamp(-q, q);
                codes[e * n_filters + f] = v as i8;
            }
        }
        exps.push(exp);
    }
    Ok(DfpLayer { codes, elems_per_filter, n_filters, exps, bits, cluster_size })
}

// ---------------------------------------------------------------------------
// Scheme-driven model quantization (the typed mixed-precision entry point)
// ---------------------------------------------------------------------------

/// One layer quantized under some [`LayerPolicy`].
#[derive(Debug, Clone)]
pub enum QuantizedLayer {
    Ternary(TernaryLayer),
    Dfp(DfpLayer),
}

impl QuantizedLayer {
    /// Integer codes, flattened (elems_per_filter, n_filters) filter-major.
    pub fn codes(&self) -> &[i8] {
        match self {
            QuantizedLayer::Ternary(t) => &t.codes,
            QuantizedLayer::Dfp(d) => &d.codes,
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        match self {
            QuantizedLayer::Ternary(t) => t.dequantize(),
            QuantizedLayer::Dfp(d) => d.dequantize(),
        }
    }

    /// Fraction of zero codes.
    pub fn sparsity(&self) -> f64 {
        let codes = self.codes();
        codes.iter().filter(|&&c| c == 0).count() as f64 / codes.len() as f64
    }

    /// Number of per-cluster scales (α̂ or exponents).
    pub fn n_scales(&self) -> usize {
        match self {
            QuantizedLayer::Ternary(t) => t.scales.len(),
            QuantizedLayer::Dfp(d) => d.exps.len(),
        }
    }

    /// Storage bits per weight.
    pub fn w_bits(&self) -> u32 {
        match self {
            QuantizedLayer::Ternary(_) => 2,
            QuantizedLayer::Dfp(d) => d.bits,
        }
    }
}

/// Quantize one flattened layer under `policy` — the codec picks the
/// algorithm (cluster ternary vs k-bit DFP), the policy's cluster the scale
/// granularity.
pub fn quantize_layer(
    w: &[f32],
    elems_per_filter: usize,
    n_filters: usize,
    policy: &LayerPolicy,
) -> Result<QuantizedLayer> {
    Ok(match policy.codec {
        WeightCodec::Ternary { mode } => {
            QuantizedLayer::Ternary(ternarize_layer(w, elems_per_filter, n_filters, policy.cluster, mode)?)
        }
        WeightCodec::Dfp { bits } => {
            QuantizedLayer::Dfp(quantize_layer_dfp(w, elems_per_filter, n_filters, bits, policy.cluster)?)
        }
        WeightCodec::I8 => QuantizedLayer::Dfp(quantize_layer_dfp(w, elems_per_filter, n_filters, 8, policy.cluster)?),
    })
}

/// Quantize a whole model under `scheme`: each `(name, weights,
/// elems_per_filter, n_filters)` layer gets the codec + cluster its
/// (glob-resolved) policy declares — 8-bit stem, ternary interior, 4-bit
/// tail all in one pass. Returns the layers in input order.
pub fn quantize_model<'a>(
    scheme: &Scheme,
    layers: impl IntoIterator<Item = (&'a str, &'a [f32], usize, usize)>,
) -> Result<Vec<(String, QuantizedLayer)>> {
    layers
        .into_iter()
        .map(|(name, w, elems_per_filter, n_filters)| {
            let policy = scheme.policy_for(name);
            let q = quantize_layer(w, elems_per_filter, n_filters, policy)
                .with_context(|| format!("quantizing layer '{name}' under scheme '{scheme}'"))?;
            Ok((name.to_string(), q))
        })
        .collect()
}

/// Signal-to-quantization-noise ratio in dB between `w` and `w_hat`.
pub fn sqnr_db(w: &[f32], w_hat: &[f32]) -> f64 {
    let sig: f64 = w.iter().map(|&x| f64::from(x) * f64::from(x)).sum();
    let noise: f64 = w
        .iter()
        .zip(w_hat)
        .map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2))
        .sum();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn gaussian(n: usize, seed: u64, sigma: f32) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        rng.normal(n).into_iter().map(|x| x * sigma).collect()
    }

    #[test]
    fn test_threshold_select_is_prefix_rms() {
        let w = gaussian(200, 1, 0.1);
        let a = threshold_select(&w);
        let mut mags: Vec<f64> = w.iter().map(|&x| f64::from(x).abs()).collect();
        mags.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let mut s2 = 0.0;
        let mut found = false;
        for (i, &m) in mags.iter().enumerate() {
            s2 += m * m;
            if ((s2 / (i + 1) as f64).sqrt() - a).abs() < 1e-12 {
                found = true;
            }
        }
        assert!(found);
    }

    #[test]
    fn test_exact_ternary_recovery_both_modes() {
        let mut rng = SplitMix64::new(3);
        let codes: Vec<i8> = (0..16 * 9).map(|_| rng.next_below(3) as i8 - 1).collect();
        let w: Vec<f32> = codes.iter().map(|&c| f32::from(c) * 0.37).collect();
        for mode in [TernaryMode::Paper, TernaryMode::Support] {
            let t = ternarize_layer(&w, 9, 16, 4, mode).unwrap();
            let back = t.dequantize();
            let rel = {
                let num: f64 = w.iter().zip(&back).map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2)).sum::<f64>();
                let den: f64 = w.iter().map(|&a| f64::from(a).powi(2)).sum::<f64>();
                (num / den).sqrt()
            };
            assert!(rel < 0.01, "{mode:?}: rel err {rel}");
        }
    }

    #[test]
    fn test_ternary_codes_are_ternary_and_cluster_shared() {
        let w = gaussian(9 * 24, 5, 0.1);
        let t = ternarize_layer(&w, 9, 24, 8, TernaryMode::Support).unwrap();
        assert!(t.codes.iter().all(|&c| (-1..=1).contains(&c)));
        assert_eq!(t.scales.len(), 3);
        for f in 0..24 {
            assert_eq!(t.alpha[f], t.alpha[(f / 8) * 8]);
        }
    }

    #[test]
    fn test_paper_mode_sparser_than_support() {
        let w = gaussian(9 * 32 * 32, 6, 0.1);
        let p = ternarize_layer(&w, 9 * 32, 32, 4, TernaryMode::Paper).unwrap();
        let s = ternarize_layer(&w, 9 * 32, 32, 4, TernaryMode::Support).unwrap();
        assert!(p.sparsity() > s.sparsity(), "{} vs {}", p.sparsity(), s.sparsity());
    }

    #[test]
    fn test_smaller_clusters_not_worse() {
        let w = gaussian(9 * 16 * 64, 7, 0.1);
        let mut errs = Vec::new();
        for n in [1usize, 4, 16, 64] {
            let t = ternarize_layer(&w, 9 * 16, 64, n, TernaryMode::Support).unwrap();
            let back = t.dequantize();
            let e: f64 = w.iter().zip(&back).map(|(&a, &b)| (f64::from(a) - f64::from(b)).powi(2)).sum();
            errs.push(e);
        }
        for win in errs.windows(2) {
            assert!(win[0] <= win[1] * 1.02, "{errs:?}");
        }
    }

    #[test]
    fn test_twn_properties() {
        let w = gaussian(1000, 8, 0.1);
        let (codes, alpha) = ternarize_twn(&w);
        assert!(alpha > 0.0);
        let kept: Vec<f64> = w
            .iter()
            .zip(&codes)
            .filter(|(_, &c)| c != 0)
            .map(|(&x, _)| f64::from(x).abs())
            .collect();
        let mean = kept.iter().sum::<f64>() / kept.len() as f64;
        assert!((mean - alpha).abs() < 1e-9);
    }

    #[test]
    fn test_dfp_layer_range_and_error() {
        let w = gaussian(9 * 16, 9, 0.2);
        for bits in [4u32, 8] {
            let d = quantize_layer_dfp(&w, 9, 16, bits, 4).unwrap();
            assert!(d.codes.iter().all(|&c| i32::from(c).abs() <= dfp::qmax(bits)));
            let back = d.dequantize();
            for f in 0..16 {
                let ulp = 2f32.powi(d.exps[f / 4]);
                for e in 0..9 {
                    let i = e * 16 + f;
                    assert!((w[i] - back[i]).abs() <= ulp / 2.0 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn test_sqnr() {
        let w = vec![1.0f32, 2.0, 3.0];
        assert_eq!(sqnr_db(&w, &w), f64::INFINITY);
        assert!((sqnr_db(&w, &[0.0, 0.0, 0.0]) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn test_uneven_last_cluster() {
        let w = gaussian(9 * 10, 10, 0.1);
        let t = ternarize_layer(&w, 9, 10, 4, TernaryMode::Support).unwrap();
        assert_eq!(t.scales.len(), 3); // 4 + 4 + 2
        let d = quantize_layer_dfp(&w, 9, 10, 4, 4).unwrap();
        assert_eq!(d.exps.len(), 3);
    }

    #[test]
    fn test_zero_weights() {
        let w = vec![0.0f32; 9 * 4];
        let t = ternarize_layer(&w, 9, 4, 4, TernaryMode::Support).unwrap();
        assert!(t.codes.iter().all(|&c| c == 0));
        assert_eq!(threshold_select(&w), 0.0);
    }

    #[test]
    fn test_cluster_zero_is_typed_error_not_panic() {
        let w = gaussian(9 * 4, 12, 0.1);
        for mode in [TernaryMode::Paper, TernaryMode::Support] {
            let err = ternarize_layer(&w, 9, 4, 0, mode).unwrap_err().to_string();
            assert!(err.contains("cluster"), "{err}");
        }
        assert!(quantize_layer_dfp(&w, 9, 4, 4, 0).is_err());
        assert!(quantize_layer_dfp(&w, 9, 4, 9, 4).is_err()); // bad bits
        assert!(ternarize_layer(&w, 9, 5, 4, TernaryMode::Support).is_err()); // len mismatch
    }

    #[test]
    fn test_quantize_model_dispatches_per_layer_policy() {
        use crate::scheme::Scheme;
        let stem = gaussian(27 * 8, 13, 0.1);
        let mid = gaussian(72 * 8, 14, 0.1);
        let tail = gaussian(72 * 8, 15, 0.1);
        let scheme = Scheme::parse("8a2w_n4@stem=i8@s1*=i4").unwrap();
        let q = quantize_model(
            &scheme,
            [
                ("stem", stem.as_slice(), 27usize, 8usize),
                ("s0b0c1", mid.as_slice(), 72, 8),
                ("s1b0c1", tail.as_slice(), 72, 8),
            ],
        )
        .unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q[0].0, "stem");
        assert_eq!(q[0].1.w_bits(), 8);
        assert!(matches!(q[1].1, QuantizedLayer::Ternary(_)));
        assert!(q[1].1.codes().iter().all(|&c| (-1..=1).contains(&c)));
        assert_eq!(q[2].1.w_bits(), 4);
        assert!(q[2].1.codes().iter().all(|&c| (-7..=7).contains(&c)));
        // every layer: 8 filters, N=4 -> 2 scale clusters
        assert!(q.iter().all(|(_, l)| l.n_scales() == 2));
        // a failing layer reports its name and scheme
        let err = quantize_model(&scheme, [("stem", stem.as_slice(), 27usize, 9usize)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("stem"), "{err}");
    }
}
