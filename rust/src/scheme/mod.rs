//! Typed per-layer precision schemes — the single vocabulary for "how is
//! this model quantized".
//!
//! The paper's core result is *mixed* precision: 8-bit first/last layers,
//! ternary or 4-bit interior convs, accuracy traded against cluster size N
//! (§3.2–3.3; TTQ and FGQ keep the boundary layers high-precision for the
//! same reason). A [`Scheme`] makes that a first-class value instead of a
//! `(w_bits, cluster, mode)` flag soup:
//!
//! * [`WeightCodec`] — how one layer's weights are encoded
//!   (`Ternary { mode } | Dfp { bits } | I8`);
//! * [`LayerPolicy`] — codec + scale-cluster size for one layer;
//! * [`Scheme`] — a default policy plus ordered name/glob overrides
//!   (`policy_for` resolves a layer name; the **last** matching override
//!   wins, the default applies otherwise).
//!
//! The compact grammar round-trips the legacy variant names and extends
//! them with per-layer exceptions (see DESIGN.md §scheme):
//!
//! ```text
//! scheme   := <act>'a' <wspec> '_n' <N> ('@' pattern '=' codec (':n' N)?)*
//! wspec    := '2w' | '2wp' | '3w'..'7w' | '8w'      (2wp = paper-mode ternary)
//! codec    := 't' | 'tp' | 'i3'..'i7' | 'i8'
//! ```
//!
//! `"8a2w_n4"` is the legacy ternary-N4 variant; `"8a2w_n4@stem=i8@fc=i8"`
//! is the paper's mixed configuration with 8-bit boundary layers. A scheme
//! flows quantizer ([`crate::quant::quantize_model`]) → packing/loading
//! ([`crate::lpinfer::QModelParams`]) → kernel dispatch → op counting
//! ([`crate::opcount`]) → serving, and (de)serializes as JSON for configs.

use std::fmt;

use anyhow::{bail, ensure, Context, Result};

use crate::json::Json;
use crate::quant::TernaryMode;

/// How one layer's weights are encoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightCodec {
    /// Cluster-ternary codes in {-1, 0, +1} with per-cluster α̂ (Algorithms 1 & 2).
    Ternary { mode: TernaryMode },
    /// k-bit dynamic fixed point with per-cluster power-of-two exponents, k in 3..=7.
    Dfp { bits: u32 },
    /// Full 8-bit DFP (the paper's first/last-layer precision).
    I8,
}

impl WeightCodec {
    /// Storage bits per weight under this codec.
    pub fn w_bits(self) -> u32 {
        match self {
            WeightCodec::Ternary { .. } => 2,
            WeightCodec::Dfp { bits } => bits,
            WeightCodec::I8 => 8,
        }
    }

    /// Map an exported `w_bits` scalar onto its canonical codec
    /// (2 → support-mode ternary, 3..=7 → DFP, 8 → i8).
    pub fn from_w_bits(bits: u32) -> Result<Self> {
        Ok(match bits {
            2 => WeightCodec::Ternary { mode: TernaryMode::Support },
            b @ 3..=7 => WeightCodec::Dfp { bits: b },
            8 => WeightCodec::I8,
            other => bail!("no weight codec for w_bits={other} (valid: 2..=8)"),
        })
    }
}

impl fmt::Display for WeightCodec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WeightCodec::Ternary { mode: TernaryMode::Support } => f.write_str("t"),
            WeightCodec::Ternary { mode: TernaryMode::Paper } => f.write_str("tp"),
            WeightCodec::Dfp { bits } => write!(f, "i{bits}"),
            WeightCodec::I8 => f.write_str("i8"),
        }
    }
}

impl std::str::FromStr for WeightCodec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "t" | "ternary" => WeightCodec::Ternary { mode: TernaryMode::Support },
            "tp" | "ternary-paper" => WeightCodec::Ternary { mode: TernaryMode::Paper },
            "i8" => WeightCodec::I8,
            other => {
                let bits: u32 = other
                    .strip_prefix('i')
                    .and_then(|b| b.parse().ok())
                    .with_context(|| format!("unknown weight codec '{other}' (valid: t|tp|i3..i7|i8)"))?;
                ensure!((3..=7).contains(&bits), "dfp codec bits must be in 3..=7 (got i{bits})");
                WeightCodec::Dfp { bits }
            }
        })
    }
}

/// The precision policy of one layer: weight codec + filters per α̂/exponent
/// cluster. Constructed through [`LayerPolicy::new`], which rejects the
/// degenerate `cluster == 0` up front (the quantizers would otherwise
/// `div_ceil(0)`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerPolicy {
    pub codec: WeightCodec,
    pub cluster: usize,
}

impl LayerPolicy {
    /// Construct a policy, rejecting the degenerate `cluster == 0` and
    /// out-of-range DFP bit widths up front.
    pub fn new(codec: WeightCodec, cluster: usize) -> Result<Self> {
        ensure!(cluster >= 1, "layer policy: cluster size must be >= 1 (got 0)");
        if let WeightCodec::Dfp { bits } = codec {
            ensure!((3..=7).contains(&bits), "layer policy: dfp bits must be in 3..=7 (got {bits})");
        }
        Ok(Self { codec, cluster })
    }

    /// Storage bits per weight.
    pub fn w_bits(&self) -> u32 {
        self.codec.w_bits()
    }
}

/// Glob match with `*` as the only wildcard (matches any, possibly empty,
/// substring). `s2*` matches every stage-2 layer, `*proj` every projection.
fn glob_match(pat: &str, text: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    let t: Vec<char> = text.chars().collect();
    // dp[i][j]: p[..i] matches t[..j]
    let mut dp = vec![vec![false; t.len() + 1]; p.len() + 1];
    dp[0][0] = true;
    for i in 1..=p.len() {
        if p[i - 1] == '*' {
            dp[i][0] = dp[i - 1][0];
        }
        for j in 1..=t.len() {
            dp[i][j] = if p[i - 1] == '*' {
                dp[i - 1][j] || dp[i][j - 1]
            } else {
                dp[i - 1][j - 1] && p[i - 1] == t[j - 1]
            };
        }
    }
    dp[p.len()][t.len()]
}

/// A named mixed-precision configuration: activation bits, a default
/// [`LayerPolicy`], and an ordered list of `(pattern, policy)` overrides.
///
/// Resolution: [`Scheme::policy_for`] walks the overrides newest-first and
/// returns the first whose pattern (exact name or `*`-glob) matches; the
/// default applies when none does. The builder methods consume and return
/// `self` so schemes read as literals:
///
/// ```
/// use dfp_infer::quant::TernaryMode;
/// use dfp_infer::scheme::{LayerPolicy, Scheme, WeightCodec};
/// let tern = LayerPolicy::new(WeightCodec::Ternary { mode: TernaryMode::Support }, 4).unwrap();
/// let i8p = LayerPolicy::new(WeightCodec::I8, 4).unwrap();
/// let s = Scheme::uniform(8, tern)
///     .unwrap()
///     .with_override("stem", i8p.clone())
///     .unwrap()
///     .with_override("fc", i8p)
///     .unwrap();
/// assert_eq!(s.to_string(), "8a2w_n4@stem=i8@fc=i8");
/// assert_eq!(s.w_bits_for("stem"), 8);
/// assert_eq!(s.w_bits_for("s0b0c1"), 2); // default policy: ternary
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scheme {
    act_bits: u32,
    default_policy: LayerPolicy,
    overrides: Vec<(String, LayerPolicy)>,
}

impl Scheme {
    /// A scheme applying one policy to every layer (the legacy variants).
    pub fn uniform(act_bits: u32, default_policy: LayerPolicy) -> Result<Self> {
        ensure!((2..=8).contains(&act_bits), "scheme: activation bits must be in 2..=8 (got {act_bits})");
        Ok(Self { act_bits, default_policy, overrides: Vec::new() })
    }

    /// Builder: add a per-layer exception. `pattern` is an exact layer name
    /// (`"stem"`, `"fc"`) or a `*`-glob (`"s2*"`, `"*proj"`). Later
    /// overrides win over earlier ones.
    pub fn with_override(mut self, pattern: &str, policy: LayerPolicy) -> Result<Self> {
        ensure!(!pattern.is_empty(), "scheme override: empty layer pattern");
        ensure!(
            pattern.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.' | '*')),
            "scheme override: invalid layer pattern '{pattern}' (allowed: [A-Za-z0-9_.*-])"
        );
        self.overrides.push((pattern.to_string(), policy));
        Ok(self)
    }

    /// Activation bit width (the `<A>a` prefix of the grammar).
    pub fn act_bits(&self) -> u32 {
        self.act_bits
    }

    /// The policy applied to every layer no override matches.
    pub fn default_policy(&self) -> &LayerPolicy {
        &self.default_policy
    }

    /// The ordered `(pattern, policy)` overrides (oldest first).
    pub fn overrides(&self) -> &[(String, LayerPolicy)] {
        &self.overrides
    }

    /// Resolve the policy of a layer: last matching override, else default.
    pub fn policy_for(&self, layer: &str) -> &LayerPolicy {
        self.overrides
            .iter()
            .rev()
            .find(|(pat, _)| glob_match(pat, layer))
            .map(|(_, p)| p)
            .unwrap_or(&self.default_policy)
    }

    /// Storage bits per weight for a layer.
    pub fn w_bits_for(&self, layer: &str) -> u32 {
        self.policy_for(layer).w_bits()
    }

    /// Check every override against the model's actual layer names: a
    /// literal pattern must name a known layer, a glob must match at least
    /// one. Catches typos like `@stme=i8` before weights are quantized.
    pub fn validate_layers<'a>(&self, known: impl IntoIterator<Item = &'a str>) -> Result<()> {
        let known: Vec<&str> = known.into_iter().collect();
        for (pat, _) in &self.overrides {
            if pat.contains('*') {
                ensure!(
                    known.iter().any(|n| glob_match(pat, n)),
                    "scheme override '@{pat}=' matches no layer (known layers: {known:?})"
                );
            } else {
                ensure!(
                    known.iter().any(|n| *n == pat),
                    "scheme override names unknown layer '{pat}' (known layers: {known:?})"
                );
            }
        }
        Ok(())
    }

    /// [`Scheme::validate_layers`] against a network's conv names + `"fc"`.
    pub fn validate_for(&self, net: &crate::model::Network) -> Result<()> {
        self.validate_layers(net.layers.iter().map(|l| l.name.as_str()).chain(std::iter::once("fc")))
    }

    /// The scheme's canonical compact name (same as `to_string()`).
    pub fn name(&self) -> String {
        self.to_string()
    }

    /// Parse the compact grammar (see module docs). Canonical strings
    /// round-trip: `Scheme::parse(s)?.to_string() == s` whenever `s` uses
    /// the canonical codec spellings and omits `:nN` equal to the default
    /// cluster (non-canonical aliases like `@x=ternary` or a redundant
    /// `:n4` parse fine but print canonically).
    ///
    /// ```
    /// use dfp_infer::scheme::Scheme;
    /// // ternary default at N=64, i8 stem/fc, 4-bit stage-2 at N=4
    /// let s = Scheme::parse("8a2w_n64@stem=i8@s2*=i4:n4@fc=i8").unwrap();
    /// assert_eq!(s.act_bits(), 8);
    /// assert_eq!(s.default_policy().cluster, 64);
    /// assert_eq!(s.policy_for("s2b0c1").w_bits(), 4);
    /// assert_eq!(s.to_string(), "8a2w_n64@stem=i8@s2*=i4:n4@fc=i8");
    /// // malformed specs fail fast
    /// assert!(Scheme::parse("8a9w_n4").is_err());
    /// assert!(Scheme::parse("8a2w_n4@stem=i9").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Self> {
        let syntax = || format!("scheme '{s}': expected <A>a<W>w_n<N>[@layer=codec[:nN]]* (e.g. 8a2w_n4@stem=i8)");
        let mut parts = s.split('@');
        let base = parts.next().unwrap_or_default();
        let (act_s, rest) = base.split_once('a').with_context(syntax)?;
        let act_bits: u32 = act_s.parse().ok().with_context(syntax)?;
        let (wspec, n_s) = rest.split_once("_n").with_context(syntax)?;
        let (bits_s, paper) = match wspec.strip_suffix("wp") {
            Some(b) => (b, true),
            None => (wspec.strip_suffix('w').with_context(syntax)?, false),
        };
        let w_bits: u32 = bits_s.parse().ok().with_context(syntax)?;
        let cluster: usize = n_s.parse().ok().with_context(syntax)?;
        let codec = match (w_bits, paper) {
            (2, false) => WeightCodec::Ternary { mode: TernaryMode::Support },
            (2, true) => WeightCodec::Ternary { mode: TernaryMode::Paper },
            (8, false) => WeightCodec::I8,
            (b @ 3..=7, false) => WeightCodec::Dfp { bits: b },
            _ => bail!("scheme '{s}': unsupported weight spec '{wspec}' (valid: 2w|2wp|3w..7w|8w)"),
        };
        let mut scheme = Self::uniform(act_bits, LayerPolicy::new(codec, cluster)?)?;
        for ov in parts {
            let (pattern, policy_s) = ov
                .split_once('=')
                .with_context(|| format!("scheme '{s}': override '@{ov}' is not '@layer=codec[:nN]'"))?;
            let (codec_s, ov_cluster) = match policy_s.split_once(":n") {
                Some((c, n)) => {
                    (c, n.parse().ok().with_context(|| format!("scheme '{s}': bad override cluster ':{n}'"))?)
                }
                None => (policy_s, cluster),
            };
            scheme = scheme.with_override(pattern, LayerPolicy::new(codec_s.parse()?, ov_cluster)?)?;
        }
        Ok(scheme)
    }

    /// JSON form (for config files and result metadata):
    /// `{"name": "...", "act_bits": 8, "default": {...}, "overrides": [...]}`.
    pub fn to_json(&self) -> Json {
        let policy_json = |p: &LayerPolicy| {
            vec![("codec", Json::str(p.codec.to_string())), ("cluster", Json::num(p.cluster as u32))]
        };
        Json::obj(vec![
            ("name", Json::str(self.to_string())),
            ("act_bits", Json::num(self.act_bits)),
            ("default", Json::obj(policy_json(&self.default_policy))),
            (
                "overrides",
                Json::arr(
                    self.overrides
                        .iter()
                        .map(|(pat, p)| {
                            let mut fields = vec![("layer", Json::str(pat.clone()))];
                            fields.extend(policy_json(p));
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Inverse of [`Scheme::to_json`]. Accepts either the full object form
    /// or any object carrying a parseable `"name"` (which wins when present).
    pub fn from_json(j: &Json) -> Result<Self> {
        if let Some(name) = j.get("name").and_then(Json::as_str) {
            return Self::parse(name);
        }
        let policy = |o: &Json| -> Result<LayerPolicy> {
            let codec: WeightCodec = o.get("codec").and_then(Json::as_str).context("scheme json: codec")?.parse()?;
            let cluster = o.get("cluster").and_then(Json::as_i64).context("scheme json: cluster")?;
            LayerPolicy::new(codec, cluster as usize)
        };
        let act_bits = j.get("act_bits").and_then(Json::as_i64).context("scheme json: act_bits")? as u32;
        let mut scheme = Self::uniform(act_bits, policy(j.get("default").context("scheme json: default")?)?)?;
        if let Some(arr) = j.get("overrides").and_then(Json::as_arr) {
            for ov in arr {
                let pat = ov.get("layer").and_then(Json::as_str).context("scheme json: override layer")?;
                scheme = scheme.with_override(pat, policy(ov)?)?;
            }
        }
        Ok(scheme)
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let d = &self.default_policy;
        let wspec = match d.codec {
            WeightCodec::Ternary { mode: TernaryMode::Support } => "2w".to_string(),
            WeightCodec::Ternary { mode: TernaryMode::Paper } => "2wp".to_string(),
            WeightCodec::Dfp { bits } => format!("{bits}w"),
            WeightCodec::I8 => "8w".to_string(),
        };
        write!(f, "{}a{}_n{}", self.act_bits, wspec, d.cluster)?;
        for (pat, p) in &self.overrides {
            write!(f, "@{pat}={}", p.codec)?;
            if p.cluster != d.cluster {
                write!(f, ":n{}", p.cluster)?;
            }
        }
        Ok(())
    }
}

impl std::str::FromStr for Scheme {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tern(cluster: usize) -> LayerPolicy {
        LayerPolicy::new(WeightCodec::Ternary { mode: TernaryMode::Support }, cluster).unwrap()
    }

    fn i8p(cluster: usize) -> LayerPolicy {
        LayerPolicy::new(WeightCodec::I8, cluster).unwrap()
    }

    #[test]
    fn test_parse_legacy_variants() {
        for (s, bits, cluster) in [("8a2w_n4", 2, 4), ("8a4w_n4", 4, 4), ("8a8w_n4", 8, 4), ("8a2w_n64", 2, 64)] {
            let sch = Scheme::parse(s).unwrap();
            assert_eq!(sch.act_bits(), 8, "{s}");
            assert_eq!(sch.default_policy().w_bits(), bits, "{s}");
            assert_eq!(sch.default_policy().cluster, cluster, "{s}");
            assert!(sch.overrides().is_empty(), "{s}");
            assert_eq!(sch.to_string(), s);
        }
        assert_eq!(
            Scheme::parse("8a2wp_n4").unwrap().default_policy().codec,
            WeightCodec::Ternary { mode: TernaryMode::Paper }
        );
    }

    #[test]
    fn test_parse_rejects_garbage() {
        for s in ["fp32", "", "8a2w", "8a2w_n0", "8a9w_n4", "a2w_n4", "8a2w_n4@stem", "8a2w_n4@stem=i9",
                  "8a2w_n4@=i8", "8a2wp_n4@x=tq", "9a2w_n4", "8a2w_nx"] {
            assert!(Scheme::parse(s).is_err(), "'{s}' should not parse");
        }
    }

    #[test]
    fn test_override_resolution_last_wins() {
        let s = Scheme::uniform(8, tern(4))
            .unwrap()
            .with_override("s2*", i8p(4))
            .unwrap()
            .with_override("s2b0c1", tern(64))
            .unwrap();
        assert_eq!(s.policy_for("stem"), &tern(4));
        assert_eq!(s.policy_for("s2b0c2"), &i8p(4));
        // literal added after the glob wins for the layer it names
        assert_eq!(s.policy_for("s2b0c1"), &tern(64));
        assert_eq!(s.w_bits_for("s2b0c2"), 8);
    }

    #[test]
    fn test_glob_matching() {
        assert!(glob_match("*", "anything"));
        assert!(glob_match("s2*", "s2b0c1"));
        assert!(glob_match("*proj", "s1b0proj"));
        assert!(glob_match("s*c1", "s0b0c1"));
        assert!(!glob_match("s2*", "s1b0c1"));
        assert!(!glob_match("proj", "s1b0proj"));
        assert!(glob_match("fc", "fc"));
    }

    #[test]
    fn test_mixed_scheme_roundtrip_with_overrides() {
        for s in [
            "8a2w_n4@stem=i8@fc=i8",
            "8a2w_n64@stem=i8@s2*=i4:n4@fc=i8",
            "8a4w_n4@*proj=t",
            "8a2wp_n8@fc=tp:n2",
        ] {
            let sch = Scheme::parse(s).unwrap();
            assert_eq!(sch.to_string(), s, "round-trip of '{s}'");
            assert_eq!(Scheme::from_json(&sch.to_json()).unwrap(), sch, "json round-trip of '{s}'");
        }
    }

    #[test]
    fn test_cluster_zero_rejected_at_construction() {
        assert!(LayerPolicy::new(WeightCodec::I8, 0).is_err());
        assert!(Scheme::parse("8a2w_n0").is_err());
        assert!(Scheme::parse("8a2w_n4@fc=i8:n0").is_err());
    }

    #[test]
    fn test_validate_layers() {
        let known = ["stem", "s0b0c1", "s0b0c2", "fc"];
        let ok = Scheme::parse("8a2w_n4@stem=i8@s0*=i4@fc=i8").unwrap();
        ok.validate_layers(known).unwrap();
        let typo = Scheme::parse("8a2w_n4@stme=i8").unwrap();
        let err = typo.validate_layers(known).unwrap_err().to_string();
        assert!(err.contains("stme") && err.contains("stem"), "{err}");
        let dead_glob = Scheme::parse("8a2w_n4@s9*=i8").unwrap();
        assert!(dead_glob.validate_layers(known).is_err());
    }

    #[test]
    fn test_validate_for_network() {
        let net = crate::model::resnet_mini(8, &[4, 4, 4], 1, 3);
        Scheme::parse("8a2w_n4@stem=i8@fc=i8").unwrap().validate_for(&net).unwrap();
        assert!(Scheme::parse("8a2w_n4@conv9=i8").unwrap().validate_for(&net).is_err());
    }

    #[test]
    fn test_json_full_object_form() {
        let j = crate::json::parse(
            r#"{"act_bits": 8,
                "default": {"codec": "t", "cluster": 4},
                "overrides": [{"layer": "stem", "codec": "i8", "cluster": 4}]}"#,
        )
        .unwrap();
        let s = Scheme::from_json(&j).unwrap();
        assert_eq!(s.to_string(), "8a2w_n4@stem=i8");
        assert!(Scheme::from_json(&crate::json::parse(r#"{"default": {}}"#).unwrap()).is_err());
    }

    #[test]
    fn test_codec_parse_display() {
        for c in ["t", "tp", "i3", "i4", "i7", "i8"] {
            assert_eq!(c.parse::<WeightCodec>().unwrap().to_string(), c);
        }
        assert!("i2".parse::<WeightCodec>().is_err());
        assert!("i9".parse::<WeightCodec>().is_err());
        assert!("x".parse::<WeightCodec>().is_err());
        assert_eq!(WeightCodec::from_w_bits(2).unwrap().w_bits(), 2);
        assert_eq!(WeightCodec::from_w_bits(8).unwrap(), WeightCodec::I8);
        assert!(WeightCodec::from_w_bits(32).is_err());
    }
}
