//! Artifact manifest (`artifacts/manifest.json`) — written by
//! `python -m compile.aot`, read by the runtime and coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::{parse, Json};

/// One serving variant (a quantization configuration).
#[derive(Debug, Clone)]
pub struct VariantInfo {
    /// batch size -> artifact filename
    pub files: BTreeMap<usize, String>,
    /// offline eval accuracy recorded at export time
    pub eval_acc: f64,
    pub w_bits: u32,
    pub cluster: usize,
    /// version of the integer-requant tensors the variant's qweights
    /// export carries (0 = pre-versioning export: the loader derives the
    /// multipliers from the f32 scales instead — see
    /// [`crate::dfp::REQUANT_VERSION`]).
    pub requant_version: i32,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub img: usize,
    pub classes: usize,
    pub batch_sizes: Vec<usize>,
    pub variants: BTreeMap<String, VariantInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        // parse errors must name the file too: a fleet reload that fails on
        // one of several manifests is undiagnosable from "manifest: img"
        Self::from_json_text(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = parse(text)?;
        let img = j.get("img").and_then(Json::as_i64).context("manifest: img")? as usize;
        let classes = j.get("classes").and_then(Json::as_i64).context("manifest: classes")? as usize;
        let batch_sizes = j
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .context("manifest: batch_sizes")?
            .iter()
            .filter_map(Json::as_i64)
            .map(|b| b as usize)
            .collect();
        let mut variants = BTreeMap::new();
        for (name, v) in j.get("variants").and_then(Json::as_obj).context("manifest: variants")? {
            let mut files = BTreeMap::new();
            for (b, f) in v.get("files").and_then(Json::as_obj).context("variant files")? {
                files.insert(
                    b.parse::<usize>().context("batch key")?,
                    f.as_str().context("file name")?.to_string(),
                );
            }
            variants.insert(
                name.clone(),
                VariantInfo {
                    files,
                    eval_acc: v.get("eval_acc").and_then(Json::as_f64).unwrap_or(0.0),
                    w_bits: v.get("w_bits").and_then(Json::as_i64).unwrap_or(32) as u32,
                    cluster: v.get("cluster").and_then(Json::as_i64).unwrap_or(0) as usize,
                    requant_version: v.get("requant_version").and_then(Json::as_i64).unwrap_or(0)
                        as i32,
                },
            );
        }
        Ok(Self { img, classes, batch_sizes, variants })
    }

    /// Variant names sorted by weight precision descending (fp32 first).
    pub fn variants_by_precision(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.variants.keys().map(String::as_str).collect();
        names.sort_by_key(|n| std::cmp::Reverse(self.variants[*n].w_bits));
        names
    }

    /// Parse a variant name as a precision [`Scheme`](crate::scheme::Scheme),
    /// cross-checked against the bits/cluster the manifest records for it.
    /// `None` for unknown variants, non-scheme names (`fp32`), or when the
    /// name disagrees with the recorded metadata (a corrupt export).
    pub fn scheme_of(&self, name: &str) -> Option<crate::scheme::Scheme> {
        let v = self.variants.get(name)?;
        let s = crate::scheme::Scheme::parse(name).ok()?;
        let d = s.default_policy();
        (d.w_bits() == v.w_bits && d.cluster == v.cluster).then_some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "img": 24, "channels": [32, 64, 128], "classes": 10,
      "batch_sizes": [1, 8, 32],
      "variants": {
        "fp32": {"files": {"1": "model_fp32_b1.hlo.txt"}, "eval_acc": 0.9, "w_bits": 32, "cluster": 0},
        "8a2w_n4": {"files": {"1": "a.hlo.txt", "8": "b.hlo.txt"}, "eval_acc": 0.85, "w_bits": 2, "cluster": 4, "requant_version": 1}
      }
    }"#;

    #[test]
    fn test_parse_manifest() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.img, 24);
        assert_eq!(m.classes, 10);
        assert_eq!(m.batch_sizes, vec![1, 8, 32]);
        assert_eq!(m.variants.len(), 2);
        let v = &m.variants["8a2w_n4"];
        assert_eq!(v.files[&8], "b.hlo.txt");
        assert_eq!(v.w_bits, 2);
        assert!((v.eval_acc - 0.85).abs() < 1e-12);
        assert_eq!(v.requant_version, 1);
        // variants without the tag default to the pre-versioning 0
        assert_eq!(m.variants["fp32"].requant_version, 0);
    }

    #[test]
    fn test_precision_ordering() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        assert_eq!(m.variants_by_precision(), vec!["fp32", "8a2w_n4"]);
    }

    #[test]
    fn test_scheme_of() {
        let m = Manifest::from_json_text(SAMPLE).unwrap();
        let s = m.scheme_of("8a2w_n4").unwrap();
        assert_eq!(s.default_policy().w_bits(), 2);
        assert_eq!(s.default_policy().cluster, 4);
        assert!(m.scheme_of("fp32").is_none()); // not a scheme name
        assert!(m.scheme_of("8a4w_n4").is_none()); // not in the manifest
    }

    #[test]
    fn test_scheme_of_rejects_metadata_mismatch() {
        let text = SAMPLE.replace(r#""w_bits": 2, "cluster": 4"#, r#""w_bits": 4, "cluster": 4"#);
        let m = Manifest::from_json_text(&text).unwrap();
        assert!(m.scheme_of("8a2w_n4").is_none());
    }

    #[test]
    fn test_load_error_names_path() {
        let p = std::env::temp_dir().join(format!("dfp_manifest_bad_{}.json", std::process::id()));
        std::fs::write(&p, "{}").unwrap();
        let msg = format!("{:#}", Manifest::load(&p).unwrap_err());
        assert!(msg.contains("dfp_manifest_bad"), "{msg}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn test_rejects_incomplete() {
        assert!(Manifest::from_json_text("{}").is_err());
        assert!(Manifest::from_json_text("not json").is_err());
    }
}
