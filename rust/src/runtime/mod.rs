//! PJRT runtime — loads AOT HLO-text artifacts and executes them.
//!
//! The real backend wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT):
//! one [`Engine`] per process, one compiled executable per (variant, batch
//! size), interchanging HLO *text* (see `python/compile/aot.py` for why not
//! serialized protos). The `xla` crate cannot be vendored into this offline
//! build, so it is gated behind the `pjrt` cargo feature: without it this
//! module compiles a stub [`Engine`] that still reads manifests (so `info`
//! and routing work) but refuses to execute — serving then uses the
//! pure-Rust [`crate::coordinator::LpExecutor`] over the `kernels/` GEMMs.

pub mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use manifest::{Manifest, VariantInfo};

use crate::tensor::Tensor;

/// Error message returned by every execution entry point of the stub.
#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "PJRT backend unavailable: built without the `pjrt` feature \
     (use the pure-Rust executor: `dfp-infer serve --executor lp`)";

/// A compiled model executable with a fixed batch size.
pub struct Executable {
    pub variant: String,
    pub batch: usize,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    img: usize,
    classes: usize,
}

impl Executable {
    /// Run one batch. `x` must be (batch, img, img, 3) f32; returns logits.
    pub fn run(&self, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        let want = [self.batch, self.img, self.img, 3];
        if x.shape() != want {
            bail!("input shape {:?} != executable batch shape {:?}", x.shape(), want);
        }
        #[cfg(feature = "pjrt")]
        {
            let lit = xla::Literal::vec1(x.data()).reshape(&[
                self.batch as i64,
                self.img as i64,
                self.img as i64,
                3,
            ])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?; // lowered with return_tuple=True
            let vals = out.to_vec::<f32>()?;
            Tensor::new(&[self.batch, self.classes], vals)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = self.classes;
            bail!("{NO_PJRT}")
        }
    }
}

/// The PJRT engine: client + executable cache (stubbed without `pjrt`).
pub struct Engine {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
    cache: BTreeMap<(String, usize), Executable>,
}

impl Engine {
    /// Create a PJRT client (when built with `pjrt`) and read the manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading artifact manifest")?;
        Ok(Self {
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu().map_err(anyhow::Error::from)?,
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable (built without `pjrt`)".to_string()
        }
    }

    /// Compile (or fetch cached) the executable for (variant, batch).
    pub fn load(&mut self, variant: &str, batch: usize) -> Result<&Executable> {
        let key = (variant.to_string(), batch);
        if !self.cache.contains_key(&key) {
            let info = self
                .manifest
                .variants
                .get(variant)
                .with_context(|| format!("unknown variant '{variant}'"))?;
            let file = info
                .files
                .get(&batch)
                .with_context(|| format!("variant '{variant}' has no batch-{batch} artifact"))?;
            let path = self.artifacts_dir.join(file);
            #[cfg(feature = "pjrt")]
            {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .map_err(anyhow::Error::from)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp).map_err(anyhow::Error::from)?;
                self.cache.insert(
                    key.clone(),
                    Executable {
                        variant: variant.to_string(),
                        batch,
                        exe,
                        img: self.manifest.img,
                        classes: self.manifest.classes,
                    },
                );
            }
            #[cfg(not(feature = "pjrt"))]
            {
                bail!("cannot compile {}: {NO_PJRT}", path.display());
            }
        }
        Ok(&self.cache[&key])
    }

    /// Preload every (variant, batch) pair in the manifest.
    pub fn load_all(&mut self) -> Result<usize> {
        let pairs: Vec<(String, usize)> = self
            .manifest
            .variants
            .iter()
            .flat_map(|(v, info)| info.files.keys().map(move |&b| (v.clone(), b)))
            .collect();
        for (v, b) in &pairs {
            self.load(v, *b)?;
        }
        Ok(pairs.len())
    }

    /// Batch sizes available for a variant (ascending).
    pub fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.manifest
            .variants
            .get(variant)
            .map(|i| i.files.keys().copied().collect())
            .unwrap_or_default()
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn test_stub_engine_reads_manifest_but_refuses_to_execute() {
        let dir = std::env::temp_dir().join(format!("dfp_rt_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"img": 8, "classes": 4, "batch_sizes": [1],
                "variants": {"fp32": {"files": {"1": "a.hlo.txt"},
                             "eval_acc": 0.9, "w_bits": 32, "cluster": 0}}}"#,
        )
        .unwrap();
        let mut e = Engine::new(&dir).unwrap();
        assert_eq!(e.batch_sizes("fp32"), vec![1]);
        assert!(e.platform().contains("unavailable"));
        let err = format!("{:#}", e.load("fp32", 1).unwrap_err());
        assert!(err.contains("pjrt"), "{err}");
        assert!(e.load_all().is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
