//! PJRT runtime — loads AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): one
//! [`Engine`] per process, one compiled executable per
//! (variant, batch size). The interchange is HLO *text* (see
//! `python/compile/aot.py` for why not serialized protos).

pub mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use manifest::{Manifest, VariantInfo};

use crate::tensor::Tensor;

/// A compiled model executable with a fixed batch size.
pub struct Executable {
    pub variant: String,
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
    img: usize,
    classes: usize,
}

impl Executable {
    /// Run one batch. `x` must be (batch, img, img, 3) f32; returns logits.
    pub fn run(&self, x: &Tensor<f32>) -> Result<Tensor<f32>> {
        let want = [self.batch, self.img, self.img, 3];
        if x.shape() != want {
            bail!("input shape {:?} != executable batch shape {:?}", x.shape(), want);
        }
        let lit = xla::Literal::vec1(x.data()).reshape(
            &[self.batch as i64, self.img as i64, self.img as i64, 3],
        )?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?; // lowered with return_tuple=True
        let vals = out.to_vec::<f32>()?;
        Tensor::new(&[self.batch, self.classes], vals)
    }
}

/// The PJRT engine: client + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    pub manifest: Manifest,
    cache: BTreeMap<(String, usize), Executable>,
}

impl Engine {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().map_err(anyhow::Error::from)?;
        Ok(Self { client, artifacts_dir: artifacts_dir.to_path_buf(), manifest, cache: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for (variant, batch).
    pub fn load(&mut self, variant: &str, batch: usize) -> Result<&Executable> {
        let key = (variant.to_string(), batch);
        if !self.cache.contains_key(&key) {
            let info = self
                .manifest
                .variants
                .get(variant)
                .with_context(|| format!("unknown variant '{variant}'"))?;
            let file = info
                .files
                .get(&batch)
                .with_context(|| format!("variant '{variant}' has no batch-{batch} artifact"))?;
            let path = self.artifacts_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(anyhow::Error::from)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(anyhow::Error::from)?;
            self.cache.insert(
                key.clone(),
                Executable {
                    variant: variant.to_string(),
                    batch,
                    exe,
                    img: self.manifest.img,
                    classes: self.manifest.classes,
                },
            );
        }
        Ok(&self.cache[&key])
    }

    /// Preload every (variant, batch) pair in the manifest.
    pub fn load_all(&mut self) -> Result<usize> {
        let pairs: Vec<(String, usize)> = self
            .manifest
            .variants
            .iter()
            .flat_map(|(v, info)| info.files.keys().map(move |&b| (v.clone(), b)))
            .collect();
        for (v, b) in &pairs {
            self.load(v, *b)?;
        }
        Ok(pairs.len())
    }

    /// Batch sizes available for a variant (ascending).
    pub fn batch_sizes(&self, variant: &str) -> Vec<usize> {
        self.manifest
            .variants
            .get(variant)
            .map(|i| i.files.keys().copied().collect())
            .unwrap_or_default()
    }
}
