//! # dfp-infer
//!
//! Production-shaped reproduction of *"Mixed Low-precision Deep Learning
//! Inference using Dynamic Fixed Point"* (Mellempudi et al., 2017):
//! cluster-based ternary / 4-bit weight quantization with 8-bit dynamic
//! fixed point activations, served by a Rust coordinator over AOT-compiled
//! XLA artifacts (JAX + Pallas at build time, PJRT at run time).
//!
//! Layer map (see DESIGN.md):
//! * [`coordinator`] — request router, dynamic batcher, worker pool (L3);
//!   executors: PJRT (`pjrt` feature) or the pure-Rust `LpExecutor`.
//!   Overload-resilient: per-request deadlines, watermark-driven
//!   precision degradation down the §3.3 ladder, typed load shedding,
//!   panic-isolated workers with quarantine, deadline-bounded drain —
//!   every accepted request resolves with exactly one `ServeResult`
//!   (see DESIGN.md §coordinator; chaos harness in [`testing::chaos`]).
//! * [`runtime`]     — PJRT client wrapper: load HLO text artifacts, execute
//!   (stubbed without the `pjrt` feature — the `xla` crate is not vendorable).
//! * [`kernels`]     — packed-ternary execution engine: column-blocked 2-bit /
//!   i4 weight layouts, multiply-free cluster GEMM, a SIMD tier (AVX2 /
//!   NEON behind runtime feature detection, scalar fallback), scoped
//!   thread pool, the `KernelRegistry` runtime dispatch
//!   (`--kernel <encoding>[+<tier>]` override), and the fused integer
//!   requantization epilogue (`LayerRequant`).
//! * [`scheme`]      — typed per-layer precision schemes: `WeightCodec` /
//!   `LayerPolicy` / `Scheme` with the compact `8a2w_n4@stem=i8` grammar;
//!   every precision decision (quantizer, loader, dispatch, opcount,
//!   serving) is parameterized by a `Scheme`.
//! * [`quant`]       — paper Algorithms 1 & 2 (mirrors `python/compile/quantize.py`),
//!   plus `quantize_model(&Scheme, …)` — per-layer codec dispatch.
//! * [`dfp`]         — dynamic fixed point numerics (shared-exponent int8),
//!   the integer-only requantizer (`Requantizer`, fixed-point mult+shift)
//!   and the 2-bit/4-bit storage packing the kernels consume.
//! * [`graph`]       — layer DAG IR built from a `model::Network` (conv /
//!   pool / residual-add / GAP / FC nodes, typed build errors naming the
//!   first unsupported layer), deterministic topological scheduler, and
//!   the buffer liveness planner (interval coloring of tensor lifetimes
//!   onto one activation arena).
//! * [`lpinfer`]     — pure-Rust integer inference pipeline: i8 activations,
//!   i32 accumulators, fused integer requant, i64 residual lane — no f32
//!   tensor between layers (an f32 reference path remains for validation);
//!   `plan` lowers the scheduled graph to the load-time `ForwardPlan` +
//!   `ForwardWorkspace` arena (planned buffer offsets, 1×1 convs skip
//!   im2col) for the zero-allocation steady-state forward.
//! * [`telemetry`]   — engine observability: per-forward `ForwardProfile`
//!   slots carried in the workspace (zero-allocation steady state intact),
//!   drained into the global atomic `EngineMetrics`; kernel counters
//!   (rows skipped, dispatch, epilogue fallbacks, pool fan-out) feed the
//!   `profile` CLI, `serve --stats-every` and the serving bench.
//! * [`nn`]          — pure-Rust f32 reference pipeline (baseline).
//! * [`opcount`]     — analytic op-count / energy model (§3.3, 16× claim).
//! * [`model`]       — network descriptions incl. exact ResNet-18/50/101 tables.
//! * everything else — substrates built from scratch for the offline target
//!   (tensors, DFT container IO, JSON, CLI, PRNG/stats, bench + property
//!   testing harnesses).

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dfp;
pub mod graph;
pub mod io;
pub mod json;
pub mod kernels;
pub mod lpinfer;
pub mod model;
pub mod nn;
pub mod opcount;
pub mod quant;
pub mod runtime;
pub mod scheme;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod util;
