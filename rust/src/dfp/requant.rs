//! Integer-only requantization — the fixed-point rescale that lets the
//! activation path stay in integers end to end (no f32 tensor between
//! layers), gemmlowp-style.
//!
//! A positive real scale `s` is encoded as a [`Requantizer`]
//! `{ mult, shift }` with `s ≈ mult · 2^-shift` and `mult` normalized into
//! `[2^30, 2^31)`. Rescaling an `i32` GEMM accumulator is then one 64-bit
//! multiply plus a round-half-even right shift ([`fx_rescale`]) — the same
//! rounding the f32 reference path uses, so the two agree except within a
//! hair's breadth of a rounding boundary (see the error bound on
//! [`Requantizer::from_scale`]).
//!
//! The layer epilogue built on top of this lives in
//! [`crate::kernels::epilogue`]; this module is the scalar numeric core.

use std::fmt;

/// Fraction bits of the fixed-point bias lane (`bn_shift` in real units).
pub const BIAS_FRAC: i32 = 32;

/// Fraction bits of the integer residual/skip lane: skip tensors carry
/// `i64` values in units of `2^-SKIP_FRAC` output-grid steps of the layer
/// that consumes them. 16 fraction bits keep the skip quantization error
/// (≤ 2^-17 grid steps) far below the half-step rounding threshold while
/// the i64 range (±2^47 grid steps) makes saturation unreachable.
pub const SKIP_FRAC: i32 = 16;

/// Version tag of the exported integer-requant tensors
/// (`<layer>.rq_mult` / `.rq_shift` / `.rq_bias` + `meta.requant_version`).
/// Exports without the tag fall back to deriving the multipliers from the
/// f32 scales at load time; exports with a *newer* tag are rejected.
pub const REQUANT_VERSION: i32 = 1;

/// Typed failure of [`Requantizer::from_scale`]: integer requantization is
/// only defined for finite, strictly positive scales (signs are folded into
/// the multiplier by the layer epilogue, zero scales become a zero
/// multiplier there).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RequantError {
    /// scale was zero or negative
    NonPositive(f64),
    /// scale was NaN or infinite
    NonFinite(f64),
    /// scale magnitude beyond 2^±512 — far outside anything a real model
    /// produces, and unrepresentable without overflowing the derivation
    OutOfRange(f64),
}

impl fmt::Display for RequantError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RequantError::NonPositive(s) => {
                write!(f, "requantizer scale must be > 0 (got {s})")
            }
            RequantError::NonFinite(s) => {
                write!(f, "requantizer scale must be finite (got {s})")
            }
            RequantError::OutOfRange(s) => {
                write!(f, "requantizer scale magnitude must be within 2^±512 (got {s})")
            }
        }
    }
}

impl std::error::Error for RequantError {}

/// A positive real rescale factor in fixed point: `scale ≈ mult · 2^-shift`
/// with `mult` in `[2^30, 2^31)`.
///
/// Applying it to an accumulator is `fx_rescale(i64::from(acc) * i64::from(mult), shift)`
/// — one widening multiply and one rounding shift, no floating point.
///
/// ```
/// use dfp_infer::dfp::{fx_rescale, Requantizer};
/// let r = Requantizer::from_scale(0.0009765625).unwrap(); // 2^-10
/// assert_eq!(r.shift, 40);
/// // 3000 * 2^-10 = 2.93 -> rounds to 3
/// assert_eq!(fx_rescale(3000 * i64::from(r.mult), r.shift), 3);
/// // zero and negative scales are typed errors
/// assert!(Requantizer::from_scale(0.0).is_err());
/// assert!(Requantizer::from_scale(-1.5).is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requantizer {
    /// normalized mantissa in `[2^30, 2^31)`
    pub mult: i32,
    /// right-shift applied after the multiply; `scale = mult · 2^-shift`.
    /// Negative for scales ≥ 2^31 (then [`fx_rescale`] shifts left).
    pub shift: i32,
}

impl Requantizer {
    /// Derive the fixed-point encoding of `scale`.
    ///
    /// Errors (typed, [`RequantError`]) on zero, negative, NaN or infinite
    /// scales. Exactness bound: the encoded scale differs from the real one
    /// by at most one part in 2^31 (`|scale - mult·2^-shift| ≤ scale · 2^-31`),
    /// so a rescaled accumulator differs from the real product by at most
    /// `|acc·scale| · 2^-31 + 1/2` ULP of the target grid — requantized
    /// codes can disagree with an exact-arithmetic reference only when the
    /// real value lies within `|v|·2^-31` of a rounding boundary, i.e. by
    /// at most one code.
    pub fn from_scale(scale: f64) -> Result<Self, RequantError> {
        if !scale.is_finite() {
            return Err(RequantError::NonFinite(scale));
        }
        if scale <= 0.0 {
            return Err(RequantError::NonPositive(scale));
        }
        let e = scale.log2().floor() as i32;
        if e.abs() > 512 {
            return Err(RequantError::OutOfRange(scale));
        }
        let mut shift = 30 - e;
        let mut mult = (scale * 2f64.powi(shift)).round() as i64;
        if mult == 1 << 31 {
            // rounding bumped the mantissa out of range: renormalize
            mult >>= 1;
            shift -= 1;
        }
        debug_assert!((1 << 30..1 << 31).contains(&mult), "mult {mult} out of range");
        Ok(Self { mult: mult as i32, shift })
    }

    /// The real scale this encoding represents (`mult · 2^-shift`).
    pub fn as_f64(self) -> f64 {
        f64::from(self.mult) * 2f64.powi(-self.shift)
    }

    /// Rescale one accumulator to the target grid and clamp into the
    /// symmetric signed 8-bit range `[-127, 127]`.
    #[inline]
    pub fn apply_i8(self, acc: i32) -> i8 {
        fx_rescale(i64::from(acc) * i64::from(self.mult), self.shift).clamp(-127, 127) as i8
    }
}

/// Round-half-even fixed-point rescale: `x · 2^-shift` rounded to the
/// nearest integer, ties to even — the integer twin of
/// [`round_half_even`](crate::dfp::round_half_even). A negative `shift`
/// shifts left (exact, saturating at the i64 bounds).
///
/// Internally widens to i128 so any `i64` input and any shift amount is
/// handled without overflow; the result saturates to the `i64` range
/// (callers clamp far tighter — to i8 codes or the skip lane — so
/// saturation only occurs where the clamp already dominates).
#[inline]
pub fn fx_rescale(x: i64, shift: i32) -> i64 {
    let wide = i128::from(x);
    let v: i128 = if shift <= 0 {
        let l = (-shift).min(63) as u32;
        // i64 << 63 still fits i128; larger shifts saturate via the clamp
        if (-shift) > 63 && x != 0 {
            if x > 0 {
                i128::from(i64::MAX) + 1
            } else {
                i128::from(i64::MIN) - 1
            }
        } else {
            wide << l
        }
    } else {
        let s = shift.min(126) as u32;
        let floor = wide >> s;
        let rem = wide - (floor << s);
        let half = 1i128 << (s - 1);
        if rem > half {
            floor + 1
        } else if rem < half {
            floor
        } else if floor & 1 == 0 {
            floor
        } else {
            floor + 1
        }
    };
    v.clamp(i128::from(i64::MIN), i128::from(i64::MAX)) as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_from_scale_rejects_bad_scales_typed() {
        assert_eq!(Requantizer::from_scale(0.0), Err(RequantError::NonPositive(0.0)));
        assert_eq!(Requantizer::from_scale(-0.25), Err(RequantError::NonPositive(-0.25)));
        assert!(matches!(
            Requantizer::from_scale(f64::NAN),
            Err(RequantError::NonFinite(_))
        ));
        assert_eq!(
            Requantizer::from_scale(f64::INFINITY),
            Err(RequantError::NonFinite(f64::INFINITY))
        );
        assert!(matches!(
            Requantizer::from_scale(1e300),
            Err(RequantError::OutOfRange(_))
        ));
        assert!(matches!(
            Requantizer::from_scale(1e-300),
            Err(RequantError::OutOfRange(_))
        ));
        let msg = Requantizer::from_scale(-1.0).unwrap_err().to_string();
        assert!(msg.contains("> 0"), "{msg}");
    }

    #[test]
    fn test_from_scale_precision_bound() {
        // |scale - mult*2^-shift| <= scale * 2^-31 across magnitudes
        for &s in &[1e-9, 3.7e-4, 0.017, 0.5, 1.0, 1.5, 123.456, 7.0e8] {
            let r = Requantizer::from_scale(s).unwrap();
            assert!((1i64 << 30..1i64 << 31).contains(&i64::from(r.mult)), "scale {s}");
            let back = r.as_f64();
            assert!((back - s).abs() <= s * 2f64.powi(-31), "scale {s} -> {back}");
        }
    }

    #[test]
    fn test_from_scale_power_of_two_is_exact() {
        for e in [-20i32, -4, 0, 3, 17] {
            let r = Requantizer::from_scale(2f64.powi(e)).unwrap();
            assert_eq!(r.mult, 1 << 30);
            assert_eq!(r.shift, 30 - e);
            assert_eq!(r.as_f64(), 2f64.powi(e));
        }
    }

    #[test]
    fn test_fx_rescale_round_half_even_ties() {
        // x * 2^-1 with ties: 1/2 -> 0, 3/2 -> 2, 5/2 -> 2, -1/2 -> 0, -3/2 -> -2
        assert_eq!(fx_rescale(1, 1), 0);
        assert_eq!(fx_rescale(3, 1), 2);
        assert_eq!(fx_rescale(5, 1), 2);
        assert_eq!(fx_rescale(-1, 1), 0);
        assert_eq!(fx_rescale(-3, 1), -2);
        assert_eq!(fx_rescale(-5, 1), -2);
        // non-ties round to nearest
        assert_eq!(fx_rescale(7, 2), 2); // 1.75 -> 2
        assert_eq!(fx_rescale(-7, 2), -2);
        assert_eq!(fx_rescale(9, 3), 1); // 1.125 -> 1
    }

    #[test]
    fn test_fx_rescale_matches_float_reference() {
        use crate::dfp::round_half_even;
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(42);
        for _ in 0..4000 {
            // keep |x| <= 2^51 so the f64 reference is exact
            let x = (rng.next_u64() as i64) >> (12 + rng.next_below(28) as u32);
            let s = rng.next_below(40) as i32;
            let want = round_half_even(x as f64 * 2f64.powi(-s)) as i64;
            assert_eq!(fx_rescale(x, s), want, "x={x} s={s}");
        }
    }

    #[test]
    fn test_fx_rescale_extreme_shifts() {
        assert_eq!(fx_rescale(i64::MAX, 126), 0);
        assert_eq!(fx_rescale(i64::MIN, 126), 0);
        assert_eq!(fx_rescale(1, 200), 0);
        // left shifts saturate instead of wrapping
        assert_eq!(fx_rescale(1, -70), i64::MAX);
        assert_eq!(fx_rescale(-1, -70), i64::MIN);
        assert_eq!(fx_rescale(i64::MAX / 2, -2), i64::MAX);
        assert_eq!(fx_rescale(0, -100), 0);
        assert_eq!(fx_rescale(5, 0), 5);
        assert_eq!(fx_rescale(3, -2), 12);
    }

    #[test]
    fn test_apply_i8_clamps_at_symmetric_127() {
        let unit = Requantizer::from_scale(1.0).unwrap();
        assert_eq!(unit.apply_i8(127), 127);
        assert_eq!(unit.apply_i8(-127), -127);
        assert_eq!(unit.apply_i8(128), 127);
        assert_eq!(unit.apply_i8(-128), -127);
        assert_eq!(unit.apply_i8(i32::MAX), 127);
        assert_eq!(unit.apply_i8(i32::MIN), -127);
        assert_eq!(unit.apply_i8(0), 0);
        // half-scale ties round to even before the clamp
        let half = Requantizer::from_scale(0.5).unwrap();
        assert_eq!(half.apply_i8(1), 0);
        assert_eq!(half.apply_i8(3), 2);
        assert_eq!(half.apply_i8(255), 127); // 127.5 -> 128 -> clamp 127
    }

    #[test]
    fn test_requantizer_agrees_with_f64_reference() {
        use crate::dfp::round_half_even;
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(7);
        for _ in 0..4000 {
            let scale = 2f64.powi(rng.next_below(30) as i32 - 20)
                * (1.0 + rng.next_below(1000) as f64 / 1000.0);
            let r = Requantizer::from_scale(scale).unwrap();
            let acc = rng.next_u64() as i32 >> rng.next_below(16);
            let want = round_half_even(f64::from(acc) * scale).clamp(-127.0, 127.0) as i8;
            let got = r.apply_i8(acc);
            assert!(
                (i32::from(got) - i32::from(want)).abs() <= 1,
                "scale={scale} acc={acc}: fused {got} vs f64 {want}"
            );
        }
    }
}
