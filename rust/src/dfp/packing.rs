//! Sub-byte weight packing — the storage format the paper's "sub 8-bit"
//! claim implies: ternary weights at 2 bits each (4 per byte), 4-bit
//! weights at 2 per byte. Used by the lpinfer pipeline's memory-footprint
//! accounting and exercised by the compression benches.

/// Pack ternary codes {-1, 0, +1} at 2 bits each (00=0, 01=+1, 10=-1).
pub fn pack_ternary(codes: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    for (i, &c) in codes.iter().enumerate() {
        let bits: u8 = match c {
            0 => 0b00,
            1 => 0b01,
            -1 => 0b10,
            _ => panic!("non-ternary code {c}"),
        };
        out[i / 4] |= bits << ((i % 4) * 2);
    }
    out
}

/// Unpack ternary codes (inverse of [`pack_ternary`]); `n` = element count.
///
/// The `0b11` bit pattern is not produced by any encoder, so hitting one
/// means the stream is corrupt (truncated file, bad offset, bit flips).
/// Decoding it must fail loudly instead of silently yielding 0: this is
/// the deserialization guard for any on-disk/wire packed-weight path —
/// the `kernels/` matrices use the same 2-bit encoding but re-pack from
/// validated dense codes, and their GEMM mask decode would neutralize a
/// `0b11` to a 0 contribution rather than detect it, so corruption has to
/// be caught here at unpack time. This panics; use [`try_unpack_ternary`]
/// for a recoverable error.
pub fn unpack_ternary(packed: &[u8], n: usize) -> Vec<i8> {
    try_unpack_ternary(packed, n).expect("corrupt ternary stream")
}

/// Fallible variant of [`unpack_ternary`]: `Err` on the invalid `0b11`
/// pattern (with the element index) instead of panicking.
pub fn try_unpack_ternary(packed: &[u8], n: usize) -> anyhow::Result<Vec<i8>> {
    (0..n)
        .map(|i| match (packed[i / 4] >> ((i % 4) * 2)) & 0b11 {
            0b00 => Ok(0),
            0b01 => Ok(1),
            0b10 => Ok(-1),
            _ => anyhow::bail!(
                "corrupt ternary stream: invalid bit pattern 0b11 at element {i} (byte {})",
                i / 4
            ),
        })
        .collect()
}

/// Pack 4-bit signed codes [-7, 7] two per byte (low nibble first).
pub fn pack_i4(codes: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(2)];
    for (i, &c) in codes.iter().enumerate() {
        assert!((-8..=7).contains(&c), "non-4-bit code {c}");
        let nib = (c as u8) & 0x0F;
        out[i / 2] |= nib << ((i % 2) * 4);
    }
    out
}

/// Unpack 4-bit signed codes (inverse of [`pack_i4`]).
pub fn unpack_i4(packed: &[u8], n: usize) -> Vec<i8> {
    (0..n)
        .map(|i| {
            let nib = (packed[i / 2] >> ((i % 2) * 4)) & 0x0F;
            // sign-extend the nibble
            ((nib << 4) as i8) >> 4
        })
        .collect()
}

/// Bytes needed to store `n` weights at `bits` precision (+ per-cluster
/// scale overhead: one u8 mantissa + one i8 exponent per cluster).
pub fn storage_bytes(n: usize, bits: u32, n_clusters: usize) -> usize {
    let payload = match bits {
        2 => n.div_ceil(4),
        4 => n.div_ceil(2),
        8 => n,
        32 => n * 4,
        _ => (n * bits as usize).div_ceil(8),
    };
    payload + 2 * n_clusters
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn test_ternary_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let codes: Vec<i8> = (0..1001).map(|_| rng.next_below(3) as i8 - 1).collect();
        let packed = pack_ternary(&codes);
        assert_eq!(packed.len(), 251);
        assert_eq!(unpack_ternary(&packed, codes.len()), codes);
    }

    #[test]
    fn test_i4_roundtrip() {
        let mut rng = SplitMix64::new(2);
        let codes: Vec<i8> = (0..777).map(|_| rng.next_below(15) as i8 - 7).collect();
        let packed = pack_i4(&codes);
        assert_eq!(packed.len(), 389);
        assert_eq!(unpack_i4(&packed, codes.len()), codes);
    }

    #[test]
    #[should_panic]
    fn test_ternary_rejects_out_of_range() {
        pack_ternary(&[2]);
    }

    #[test]
    fn test_corruption_detected() {
        // flip a packed byte to the invalid 0b11 pattern: decode must fail
        let mut packed = pack_ternary(&[1, -1, 0, 1, 0, 0]);
        assert!(try_unpack_ternary(&packed, 6).is_ok());
        packed[1] |= 0b0011; // element 4 becomes 0b11
        let err = try_unpack_ternary(&packed, 6).unwrap_err();
        assert!(format!("{err}").contains("element 4"), "{err}");
        // elements before the corruption stay decodable
        assert_eq!(try_unpack_ternary(&packed, 4).unwrap(), vec![1, -1, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "corrupt ternary stream")]
    fn test_corruption_panics_on_infallible_path() {
        unpack_ternary(&[0b1111_1111], 4);
    }

    #[test]
    fn test_storage_accounting() {
        // 16x compression headline: 2-bit vs 32-bit, modulo scale overhead
        let fp32 = storage_bytes(1_000_000, 32, 0);
        let tern = storage_bytes(1_000_000, 2, 1_000_000 / 4 / 64); // N=4 filters, 64 elems each
        assert!(fp32 as f64 / tern as f64 > 15.0);
        assert_eq!(storage_bytes(8, 2, 1), 4);
        assert_eq!(storage_bytes(8, 4, 1), 6);
    }
}
