//! Dynamic fixed point numerics (the paper's §3 substrate).
//!
//! A DFP tensor is a vector of `bits`-wide signed integers sharing one
//! power-of-two exponent: `value = q * 2^exp`. Scaling factors (the cluster
//! α̂ of Algorithm 1) are stored as an 8-bit mantissa + exponent so *no*
//! datum in the pipeline is wider than 8 bits; accumulators are i32.
//!
//! Mirrors `python/compile/quantize.py` bit-for-bit (round-half-even),
//! which the cross-language integration test checks on real weights.
//!
//! [`requant`] holds the integer-only rescale core ([`Requantizer`] +
//! [`fx_rescale`]): fixed-point multiplier/shift encodings of real scales
//! that let the serving path requantize i32 accumulators to i8 codes with
//! no floating point (DESIGN.md §requant); [`packing`] the 2-bit/4-bit
//! storage formats the kernels consume.

pub mod packing;
pub mod requant;

pub use requant::{fx_rescale, Requantizer, RequantError, BIAS_FRAC, REQUANT_VERSION, SKIP_FRAC};

use crate::tensor::Tensor;

/// Largest magnitude representable in a signed `bits`-bit integer (symmetric).
#[inline]
pub fn qmax(bits: u32) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Smallest exponent `e` with `max_abs <= qmax(bits) * 2^e`.
pub fn choose_exp(max_abs: f32, bits: u32) -> i32 {
    if max_abs <= 0.0 {
        return 0;
    }
    (f64::from(max_abs) / f64::from(qmax(bits))).log2().ceil() as i32
}

/// Round half to even (banker's rounding) — matches numpy's `np.rint`.
#[inline]
pub fn round_half_even(x: f64) -> f64 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // exactly halfway: round to the even neighbour
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

/// Quantize `x` to `bits`-bit DFP. Returns (codes, exp).
///
/// ```
/// use dfp_infer::dfp::{dequantize, quantize};
/// // 8-bit DFP picks the smallest power-of-two grid covering max|x|
/// let (codes, exp) = quantize(&[0.5, -1.0, 0.25], 8, None);
/// assert_eq!(exp, -6); // smallest e with 1.0 <= 127 * 2^e
/// assert_eq!(codes, vec![32, -64, 16]);
/// // round-trip error is bounded by half a grid step
/// let back = dequantize(&codes, exp);
/// assert!((back[1] - -1.0).abs() <= 2f32.powi(exp - 1));
/// ```
pub fn quantize(x: &[f32], bits: u32, exp: Option<i32>) -> (Vec<i8>, i32) {
    let max_abs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let e = exp.unwrap_or_else(|| choose_exp(max_abs, bits));
    let scale = 2f64.powi(-e);
    let q = f64::from(qmax(bits));
    let codes = x
        .iter()
        .map(|&v| round_half_even(f64::from(v) * scale).clamp(-q, q) as i8)
        .collect();
    (codes, e)
}

/// Dequantize DFP codes back to f32.
pub fn dequantize(q: &[i8], exp: i32) -> Vec<f32> {
    let s = 2f32.powi(exp);
    q.iter().map(|&v| f32::from(v) * s).collect()
}

/// An 8-bit quantized positive scale: `alpha ≈ mant * 2^exp`, mant in [0,255]
/// normalized into [128, 255] (paper §3.1: scaling factors are re-quantized
/// to 8 bits so the pipeline never needs a wider multiplier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleU8 {
    pub mant: u8,
    pub exp: i32,
}

impl ScaleU8 {
    /// Quantize a positive scale to 8-bit mantissa + exponent form
    /// (relative error < 1/128); non-positive scales collapse to zero.
    pub fn quantize(alpha: f64) -> Self {
        if alpha <= 0.0 {
            return Self { mant: 0, exp: 0 };
        }
        let mut e = alpha.log2().floor() as i32 - 7; // mant in [128, 255]
        let mut m = (alpha / 2f64.powi(e)).round() as u32;
        if m > 255 {
            m /= 2;
            e += 1;
        }
        Self { mant: m as u8, exp: e }
    }

    /// The real scale this encoding represents (`mant · 2^exp`).
    pub fn dequantize(self) -> f64 {
        f64::from(self.mant) * 2f64.powi(self.exp)
    }
}

/// A whole DFP tensor (codes + shared exponent).
#[derive(Debug, Clone)]
pub struct DfpTensor {
    pub codes: Tensor<i8>,
    pub exp: i32,
    pub bits: u32,
}

impl DfpTensor {
    /// Quantize an f32 tensor to `bits`-bit DFP (auto-choosing the shared
    /// exponent unless `exp` pins it).
    pub fn from_f32(t: &Tensor<f32>, bits: u32, exp: Option<i32>) -> Self {
        let (codes, e) = quantize(t.data(), bits, exp);
        Self { codes: Tensor::new(t.shape(), codes).expect("same shape"), exp: e, bits }
    }

    /// Dequantize back to f32 (`codes · 2^exp`).
    pub fn to_f32(&self) -> Tensor<f32> {
        let data = dequantize(self.codes.data(), self.exp);
        Tensor::new(self.codes.shape(), data).expect("same shape")
    }

    /// Max elementwise |roundtrip error| bound: half a ULP of the grid.
    pub fn ulp(&self) -> f32 {
        2f32.powi(self.exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_qmax_values() {
        assert_eq!(qmax(2), 1);
        assert_eq!(qmax(4), 7);
        assert_eq!(qmax(8), 127);
    }

    #[test]
    fn test_choose_exp_fits() {
        for &v in &[0.001f32, 0.5, 1.0, 100.0, 12345.0] {
            for bits in [2u32, 4, 8] {
                let e = choose_exp(v, bits);
                assert!(f64::from(v) <= f64::from(qmax(bits)) * 2f64.powi(e) + 1e-9);
                assert!(f64::from(v) > f64::from(qmax(bits)) * 2f64.powi(e - 1) * 0.999);
            }
        }
        assert_eq!(choose_exp(0.0, 8), 0);
    }

    #[test]
    fn test_round_half_even() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(1.2), 1.0);
        assert_eq!(round_half_even(-1.7), -2.0);
    }

    #[test]
    fn test_quantize_roundtrip_bound() {
        let xs: Vec<f32> = (0..1000).map(|i| ((i * 37) % 401) as f32 / 100.0 - 2.0).collect();
        for bits in [4u32, 8] {
            let (q, e) = quantize(&xs, bits, None);
            let back = dequantize(&q, e);
            for (a, b) in xs.iter().zip(&back) {
                assert!((a - b).abs() <= 2f32.powi(e - 1) + 1e-9, "{a} vs {b} (e={e})");
            }
        }
    }

    #[test]
    fn test_quantize_saturates_with_forced_exp() {
        let (q, _) = quantize(&[1000.0, -1000.0], 8, Some(0));
        assert_eq!(q, vec![127, -127]);
    }

    #[test]
    fn test_scale_u8_precision() {
        for &a in &[1e-4f64, 0.03, 0.5, 1.0, 77.7, 1e5] {
            let s = ScaleU8::quantize(a);
            let back = s.dequantize();
            assert!((back - a).abs() / a < 1.0 / 128.0, "{a} -> {back}");
            assert!(s.mant >= 128 || s.mant == 0);
        }
        assert_eq!(ScaleU8::quantize(0.0), ScaleU8 { mant: 0, exp: 0 });
    }

    #[test]
    fn test_dfp_tensor_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![0.1f32, -0.2, 0.3, 1.5, -1.0, 0.0]).unwrap();
        let d = DfpTensor::from_f32(&t, 8, None);
        let back = d.to_f32();
        assert!(t.max_abs_diff(&back) <= d.ulp() / 2.0 + 1e-9);
        assert_eq!(back.shape(), t.shape());
    }
}
