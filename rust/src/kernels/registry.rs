//! Kernel selection: which GEMM implementation runs a given layer.
//!
//! Two orthogonal axes (see DESIGN.md §kernels):
//! * **encoding** — which weight format executes (packed-ternary,
//!   packed-i4, dense i8): an explicit choice (`--kernel`, `Config.kernel`)
//!   wins whenever the layer has the encoding it needs; a layer that can't
//!   satisfy it (e.g. an 8-bit stem under `--kernel ternary`) falls back to
//!   the auto rule so a forced run never aborts. Auto prefers the cheapest
//!   encoding the layer supports: packed-ternary > packed-i4 > dense i8
//!   zero-skip.
//! * **SIMD tier** — which instruction set executes the inner loops
//!   ([`SimdTier`]): the `+<tier>` suffix of `--kernel` forces one, the
//!   default picks the best the CPU supports at runtime
//!   (`is_x86_feature_detected!`), and an unavailable force falls back to
//!   the scalar kernels.
//!
//! Every kernel yields bit-identical `i32` accumulators and epilogue
//! outputs, so selection on *both* axes is a pure performance decision —
//! `forward_quant` logits are invariant under any choice (property-tested
//! in `rust/tests/kernels_equivalence.rs`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::epilogue::ResolvedEpilogue;
use super::gemm::{i4_row_block, MIN_ROWS_PER_BLOCK};
use super::packed::PackedLayer;
use super::simd::{self, SimdTier, TierChoice};
use super::threadpool::ThreadPool;

/// The GEMM implementations the registry can dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// dense i8 x i8 with the probed activation zero-skip branch
    I8ZeroSkip,
    /// dense i8 x i8, branch-free
    I8Dense,
    /// multiply-free 2-bit packed ternary engine
    PackedTernary,
    /// packed 4-bit engine
    PackedI4,
}

/// All kernels, in auto-preference order for sub-8-bit weights.
pub const ALL_KERNELS: [KernelKind; 4] =
    [KernelKind::PackedTernary, KernelKind::PackedI4, KernelKind::I8ZeroSkip, KernelKind::I8Dense];

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelKind::I8ZeroSkip => "i8",
            KernelKind::I8Dense => "i8-dense",
            KernelKind::PackedTernary => "ternary",
            KernelKind::PackedI4 => "i4",
        })
    }
}

impl std::str::FromStr for KernelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "i8" | "i8-zero-skip" => KernelKind::I8ZeroSkip,
            "i8-dense" | "dense" => KernelKind::I8Dense,
            "ternary" | "packed-ternary" => KernelKind::PackedTernary,
            "i4" | "packed-i4" => KernelKind::PackedI4,
            other => bail!(
                "unknown kernel '{other}' (try auto|i8|i8-dense|ternary|i4, \
                 optionally suffixed +scalar|+simd|+avx2|+neon)"
            ),
        })
    }
}

/// A resolved `--kernel` / `Config.kernel` setting: an encoding choice
/// (automatic per-layer dispatch or one forced kernel) plus a SIMD tier
/// request, written `<encoding>[+<tier>]` (`ternary`, `auto+scalar`,
/// `i8+avx2`, …). Parsing happens once, at config-resolve time, so a
/// typo'd name fails fast with the valid alternatives instead of surviving
/// as an arbitrary string until dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KernelChoice {
    /// forced GEMM encoding; `None` is the per-layer auto rule
    pub enc: Option<KernelKind>,
    /// SIMD tier request (default: best detected)
    pub tier: TierChoice,
}

impl KernelChoice {
    /// The per-layer auto rule at the best detected tier (the default).
    pub const fn auto() -> Self {
        Self { enc: None, tier: TierChoice::Auto }
    }

    /// Force one encoding wherever it exists (auto elsewhere), best tier.
    pub const fn forced(kind: KernelKind) -> Self {
        Self { enc: Some(kind), tier: TierChoice::Auto }
    }

    /// The forced encoding, if any.
    pub fn kind(self) -> Option<KernelKind> {
        self.enc
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.enc {
            None => f.write_str("auto")?,
            Some(k) => write!(f, "{k}")?,
        }
        if let TierChoice::Forced(t) = self.tier {
            write!(f, "+{t}")?;
        }
        Ok(())
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        let (enc_s, tier_s) = match s.split_once('+') {
            Some((e, t)) => (e, Some(t)),
            None => (s, None),
        };
        let enc = match enc_s {
            "" | "auto" => None,
            other => Some(other.parse()?),
        };
        let tier = match tier_s {
            None => TierChoice::Auto,
            Some(t) => t.parse()?,
        };
        Ok(Self { enc, tier })
    }
}

/// Runtime kernel dispatcher: an optional forced encoding, the SIMD tier
/// the inner loops run at (resolved once against the CPU at construction),
/// and the thread pool the kernels parallelize on.
#[derive(Debug, Clone)]
pub struct KernelRegistry {
    choice: Option<KernelKind>,
    tier: SimdTier,
    pool: ThreadPool,
}

impl Default for KernelRegistry {
    fn default() -> Self {
        Self::auto()
    }
}

impl KernelRegistry {
    /// Encoding choice + threads at the best detected SIMD tier.
    pub fn new(choice: Option<KernelKind>, threads: usize) -> Self {
        Self::with_tier(choice, TierChoice::Auto, threads)
    }

    /// Full construction: encoding choice, SIMD tier request, pool width.
    /// The tier resolves immediately — a forced-but-unavailable tier
    /// becomes [`SimdTier::Scalar`], so dispatch never re-probes the CPU.
    pub fn with_tier(choice: Option<KernelKind>, tier: TierChoice, threads: usize) -> Self {
        Self { choice, tier: tier.resolve(), pool: ThreadPool::new(threads) }
    }

    /// Auto selection, single-threaded (the library default — callers that
    /// want parallel GEMMs size the pool from `Config::kernel_registry`).
    pub fn auto() -> Self {
        Self::new(None, 1)
    }

    /// Build from a typed [`KernelChoice`] (the `Config.kernel` field).
    pub fn with_choice(choice: KernelChoice, threads: usize) -> Self {
        Self::with_tier(choice.enc, choice.tier, threads)
    }

    /// Like [`Self::with_tier`] but dispatching GEMMs on an existing
    /// persistent [`WorkerPool`](super::pool::WorkerPool) instead of
    /// spawning a fresh one — how multiple registries (or the serving
    /// coordinator's workers) share one set of GEMM threads.
    pub fn with_pool(
        choice: Option<KernelKind>,
        tier: TierChoice,
        pool: std::sync::Arc<super::pool::WorkerPool>,
    ) -> Self {
        Self { choice, tier: tier.resolve(), pool: ThreadPool::shared(pool) }
    }

    /// Parse a CLI/config kernel name; `"auto"` (or empty) means no force.
    pub fn parse(name: &str, threads: usize) -> Result<Self> {
        Ok(Self::with_choice(name.parse()?, threads))
    }

    pub fn choice(&self) -> Option<KernelKind> {
        self.choice
    }

    /// The SIMD tier the inner loops run at (already CPU-resolved).
    pub fn tier(&self) -> SimdTier {
        self.tier
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Resolve the kernel that will actually run for a layer with the given
    /// packed encodings available.
    pub fn select(&self, packed: &PackedLayer) -> KernelKind {
        match self.choice {
            Some(KernelKind::PackedTernary) if packed.ternary.is_some() => KernelKind::PackedTernary,
            Some(KernelKind::PackedI4) if packed.i4.is_some() => KernelKind::PackedI4,
            Some(k @ (KernelKind::I8ZeroSkip | KernelKind::I8Dense)) => k,
            _ => {
                // auto rule (also the fallback for an unsatisfiable force)
                if packed.ternary.is_some() {
                    KernelKind::PackedTernary
                } else if packed.i4.is_some() {
                    KernelKind::PackedI4
                } else {
                    KernelKind::I8ZeroSkip
                }
            }
        }
    }

    /// Output-channel count of the kernel [`Self::select`] will run: the
    /// packed matrix's `f` for a packed encoding, the last axis of the
    /// dense operand otherwise (so HWIO weight tensors work unreshaped).
    fn out_features(&self, packed: &PackedLayer, dense: &Tensor<i8>) -> usize {
        match self.select(packed) {
            KernelKind::PackedTernary => packed.ternary.as_ref().expect("selected").f,
            KernelKind::PackedI4 => packed.i4.as_ref().expect("selected").f,
            KernelKind::I8ZeroSkip | KernelKind::I8Dense => *dense.shape().last().unwrap_or(&0),
        }
    }

    /// Dispatch one GEMM: `a` (M,K) i8 activations, `dense` the layer's i8
    /// codes (any row-major ..×F layout whose trailing axis is the filter
    /// axis — (K,F) and HWIO both work; it is only read when no packed
    /// encoding is selected), `packed` the layer's packed encodings.
    /// Returns (M,F) i32. Allocating wrapper over [`Self::gemm_into`].
    pub fn gemm(&self, a: &Tensor<i8>, dense: &Tensor<i8>, packed: &PackedLayer) -> Tensor<i32> {
        let (m, k) = (a.dim(0), a.dim(1));
        let f = self.out_features(packed, dense);
        let mut out = Tensor::<i32>::zeros(&[m, f]);
        self.gemm_into(a.data(), m, k, f, packed, dense.data(), out.data_mut());
        out
    }

    /// Resolve the kernel [`Self::select`] picks into its row-block compute
    /// closure (`compute(row0, rows, acc)` accumulates rows `row0..row0+rows`
    /// into a zeroed block-local tile) and hand it to `run` — the one place
    /// the encoding dispatch and its shape asserts live, shared by every
    /// borrowed-output entry point. `entry` names the caller for assert
    /// messages.
    fn with_compute(
        &self,
        entry: &str,
        a: &[i8],
        k: usize,
        f: usize,
        packed: &PackedLayer,
        dense: &[i8],
        run: &mut dyn FnMut(&(dyn Fn(usize, usize, &mut [i32]) + Sync)),
    ) {
        let tier = self.tier;
        let kind = self.select(packed);
        crate::telemetry::record_gemm(kind);
        match kind {
            KernelKind::PackedTernary => {
                let w = packed.ternary.as_ref().expect("selected");
                assert_eq!((k, f), (w.k, w.f), "{entry}: ({k},{f}) vs packed ({}, {})", w.k, w.f);
                run(&|row0, rows, acc: &mut [i32]| {
                    simd::tern_row_block(tier, a, k, row0, rows, w, acc);
                });
            }
            KernelKind::PackedI4 => {
                let w = packed.i4.as_ref().expect("selected");
                assert_eq!((k, f), (w.k, w.f), "{entry}: ({k},{f}) vs packed ({}, {})", w.k, w.f);
                run(&|row0, rows, acc: &mut [i32]| {
                    i4_row_block(a, k, row0, rows, w, acc);
                });
            }
            kind @ (KernelKind::I8ZeroSkip | KernelKind::I8Dense) => {
                assert_eq!(
                    dense.len(),
                    k * f,
                    "{entry}: dense operand has {} codes for a ({k}, {f}) layer",
                    dense.len()
                );
                let zero_skip = kind == KernelKind::I8ZeroSkip;
                run(&|row0, rows, acc: &mut [i32]| {
                    simd::i8_row_block(tier, a, dense, k, f, row0, rows, acc, zero_skip);
                });
            }
        }
    }

    /// Borrowed-output GEMM: accumulate `a` (M×K, row-major) against the
    /// layer's weights into the caller's `out` (M×F, overwritten) — no
    /// allocation. `dense` is the flat (K,F) code slice (an HWIO weight
    /// buffer *is* this slice, so callers pass `wq.data()` — it is only
    /// read when no packed encoding is selected, and may be empty then).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_into(
        &self,
        a: &[i8],
        m: usize,
        k: usize,
        f: usize,
        packed: &PackedLayer,
        dense: &[i8],
        out: &mut [i32],
    ) {
        assert_eq!(a.len(), m * k, "gemm: A has {} codes for an {m}x{k} operand", a.len());
        assert_eq!(out.len(), m * f, "gemm: out has {} slots for an {m}x{f} result", out.len());
        self.with_compute("gemm", a, k, f, packed, dense, &mut |compute| {
            self.pool.run_row_blocks(&mut *out, m, f, MIN_ROWS_PER_BLOCK, |row0, rows, block| {
                block.fill(0);
                compute(row0, rows, block);
            });
        });
    }

    /// GEMM with the integer requantization epilogue fused in: the selected
    /// kernel accumulates each output-row block into a block-local i32
    /// scratch tile, and `epi` rescales the tile straight to i8 codes while
    /// it is still cache-hot — no f32 (and no full-size i32 tensor) is ever
    /// materialized. `skip`, if present, is the (M, F) integer residual
    /// lane (units of `2^-SKIP_FRAC` target-grid steps, see
    /// [`crate::dfp::SKIP_FRAC`]). Allocating wrapper over
    /// [`Self::gemm_fused_into`].
    pub fn gemm_fused(
        &self,
        a: &Tensor<i8>,
        packed: &PackedLayer,
        dense: &Tensor<i8>,
        epi: &ResolvedEpilogue,
        skip: Option<&[i64]>,
    ) -> Tensor<i8> {
        let (m, k) = (a.dim(0), a.dim(1));
        let f = self.out_features(packed, dense);
        let mut out = Tensor::<i8>::zeros(&[m, f]);
        let mut scratch = vec![0i32; m * f];
        self.gemm_fused_into(a.data(), m, k, f, packed, dense.data(), epi, skip, None, out.data_mut(), &mut scratch);
        out
    }

    /// Borrowed-output fused GEMM: like [`Self::gemm_fused`] but writing the
    /// i8 codes into the caller's `out` and accumulating into the caller's
    /// i32 `scratch` (length ≥ M×F; each row block gets the matching
    /// sub-slice, so tiles stay block-local and cache-hot exactly as in the
    /// allocating path) — zero allocations. `skip_max`, if present, carries
    /// the per-row max `|skip|` produced alongside the lane, replacing the
    /// vector-gate re-scan (see [`ResolvedEpilogue::apply_i8_with`]).
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_fused_into(
        &self,
        a: &[i8],
        m: usize,
        k: usize,
        f: usize,
        packed: &PackedLayer,
        dense: &[i8],
        epi: &ResolvedEpilogue,
        skip: Option<&[i64]>,
        skip_max: Option<&[i64]>,
        out: &mut [i8],
        scratch: &mut [i32],
    ) {
        assert_eq!(epi.len(), f, "epilogue has {} channels for an F={f} GEMM", epi.len());
        assert_eq!(a.len(), m * k, "gemm_fused: A has {} codes for {m}x{k}", a.len());
        assert_eq!(out.len(), m * f, "gemm_fused: out has {} slots for {m}x{f}", out.len());
        assert!(scratch.len() >= m * f, "gemm_fused: scratch {} < {m}x{f}", scratch.len());
        if let Some(s) = skip {
            assert_eq!(s.len(), m * f, "skip lane has {} elements for an {m}x{f} GEMM", s.len());
        }
        if let Some(mx) = skip_max {
            assert_eq!(mx.len(), m, "skip maxima carry {} rows for an M={m} GEMM", mx.len());
        }
        let scratch = &mut scratch[..m * f];
        let tier = self.tier;
        self.with_compute("gemm_fused", a, k, f, packed, dense, &mut |compute| {
            self.pool.run_row_blocks2(
                &mut *out,
                &mut *scratch,
                m,
                f,
                f,
                MIN_ROWS_PER_BLOCK,
                |row0, rows, oblk, ablk| {
                    ablk.fill(0);
                    compute(row0, rows, ablk);
                    epi.apply_i8_with(tier, ablk, row0, rows, f, skip, skip_max, oblk);
                },
            );
        });
    }

    /// Like [`Self::gemm_fused`] but the epilogue writes the i64 integer
    /// residual lane instead of i8 codes — the projection-conv path whose
    /// output feeds a later layer's skip connection. Allocating wrapper
    /// over [`Self::gemm_fused_skip_into`].
    pub fn gemm_fused_skip(
        &self,
        a: &Tensor<i8>,
        packed: &PackedLayer,
        dense: &Tensor<i8>,
        epi: &ResolvedEpilogue,
    ) -> Tensor<i64> {
        let (m, k) = (a.dim(0), a.dim(1));
        let f = self.out_features(packed, dense);
        let mut out = Tensor::<i64>::zeros(&[m, f]);
        let mut scratch = vec![0i32; m * f];
        self.gemm_fused_skip_into(a.data(), m, k, f, packed, dense.data(), epi, out.data_mut(), None, &mut scratch);
        out
    }

    /// Borrowed-output skip-lane GEMM. `row_max`, when provided (length M),
    /// receives the per-row max `|value|` of the produced lane — computed
    /// in one streaming pass right after the blocks complete (typically
    /// still cache-resident; worst case one sequential re-read), so the
    /// consuming [`Self::gemm_fused_into`] can gate its vector epilogue on
    /// `rows` maxima instead of branch-scanning the whole lane per
    /// consuming block after the intervening conv has evicted it.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_fused_skip_into(
        &self,
        a: &[i8],
        m: usize,
        k: usize,
        f: usize,
        packed: &PackedLayer,
        dense: &[i8],
        epi: &ResolvedEpilogue,
        out: &mut [i64],
        row_max: Option<&mut [i64]>,
        scratch: &mut [i32],
    ) {
        assert_eq!(epi.len(), f, "epilogue has {} channels for an F={f} GEMM", epi.len());
        assert_eq!(a.len(), m * k, "gemm_fused_skip: A has {} codes for {m}x{k}", a.len());
        assert_eq!(out.len(), m * f, "gemm_fused_skip: out has {} slots for {m}x{f}", out.len());
        assert!(scratch.len() >= m * f, "gemm_fused_skip: scratch {} < {m}x{f}", scratch.len());
        let scratch = &mut scratch[..m * f];
        let tier = self.tier;
        self.with_compute("gemm_fused_skip", a, k, f, packed, dense, &mut |compute| {
            self.pool.run_row_blocks2(
                &mut *out,
                &mut *scratch,
                m,
                f,
                f,
                MIN_ROWS_PER_BLOCK,
                |row0, rows, oblk, ablk| {
                    ablk.fill(0);
                    compute(row0, rows, ablk);
                    epi.apply_skip_with(tier, ablk, rows, f, oblk);
                },
            );
        });
        if let Some(mx) = row_max {
            assert_eq!(mx.len(), m, "row_max carries {} rows for an M={m} GEMM", mx.len());
            for (r, slot) in mx.iter_mut().enumerate() {
                *slot = out[r * f..(r + 1) * f]
                    .iter()
                    .fold(0i64, |acc, &v| acc.max(v.saturating_abs()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn tern_layer(k: usize, f: usize, seed: u64) -> (Tensor<i8>, PackedLayer) {
        let mut rng = SplitMix64::new(seed);
        let wd =
            Tensor::new(&[k, f], (0..k * f).map(|_| rng.next_below(3) as i8 - 1).collect()).unwrap();
        let packed = PackedLayer::build(&wd, &[], 0);
        (wd, packed)
    }

    /// Tier settings every test machine can exercise: forced scalar plus
    /// whatever the CPU actually supports.
    fn test_tiers() -> Vec<TierChoice> {
        vec![TierChoice::Forced(SimdTier::Scalar), TierChoice::Auto]
    }

    #[test]
    fn test_parse_and_display() {
        for k in ALL_KERNELS {
            assert_eq!(k.to_string().parse::<KernelKind>().unwrap(), k);
        }
        assert_eq!("packed-ternary".parse::<KernelKind>().unwrap(), KernelKind::PackedTernary);
        assert!("warp".parse::<KernelKind>().is_err());
        assert!(KernelRegistry::parse("auto", 1).unwrap().choice().is_none());
        assert!(KernelRegistry::parse("warp", 1).is_err());
        // tier suffixes parse end to end through the registry
        let reg = KernelRegistry::parse("ternary+scalar", 2).unwrap();
        assert_eq!(reg.choice(), Some(KernelKind::PackedTernary));
        assert_eq!(reg.tier(), SimdTier::Scalar);
        assert!(KernelRegistry::parse("ternary+warp", 1).is_err());
    }

    #[test]
    fn test_kernel_choice_parse_display_roundtrip() {
        assert_eq!("auto".parse::<KernelChoice>().unwrap(), KernelChoice::auto());
        assert_eq!("".parse::<KernelChoice>().unwrap(), KernelChoice::auto());
        assert_eq!(KernelChoice::default(), KernelChoice::auto());
        assert_eq!(KernelChoice::auto().kind(), None);
        for k in ALL_KERNELS {
            let c: KernelChoice = k.to_string().parse().unwrap();
            assert_eq!(c, KernelChoice::forced(k));
            assert_eq!(c.kind(), Some(k));
            assert_eq!(c.to_string().parse::<KernelChoice>().unwrap(), c);
            // with a tier suffix
            for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon] {
                let s = format!("{k}+{t}");
                let c: KernelChoice = s.parse().unwrap();
                assert_eq!(c.enc, Some(k));
                assert_eq!(c.tier, TierChoice::Forced(t));
                assert_eq!(c.to_string(), s);
            }
        }
        assert_eq!(
            "auto+simd".parse::<KernelChoice>().unwrap(),
            KernelChoice { enc: None, tier: TierChoice::Auto }
        );
        let err = "warp".parse::<KernelChoice>().unwrap_err().to_string();
        assert!(err.contains("auto|i8|i8-dense|ternary|i4"), "{err}");
        assert!("i8+sse9".parse::<KernelChoice>().is_err());
    }

    #[test]
    fn test_auto_prefers_cheapest_encoding() {
        let (_, tern) = tern_layer(4, 4, 1);
        let reg = KernelRegistry::auto();
        assert_eq!(reg.select(&tern), KernelKind::PackedTernary);

        let mut no_tern = tern.clone();
        no_tern.ternary = None;
        assert_eq!(reg.select(&no_tern), KernelKind::PackedI4);
        assert_eq!(reg.select(&PackedLayer::none()), KernelKind::I8ZeroSkip);
    }

    #[test]
    fn test_forced_choice_with_fallback() {
        let (_, tern) = tern_layer(4, 4, 2);
        let reg = KernelRegistry::new(Some(KernelKind::I8Dense), 1);
        assert_eq!(reg.select(&tern), KernelKind::I8Dense);
        // forcing ternary on a layer with no ternary encoding falls back
        let reg = KernelRegistry::new(Some(KernelKind::PackedTernary), 1);
        assert_eq!(reg.select(&PackedLayer::none()), KernelKind::I8ZeroSkip);
    }

    #[test]
    fn test_registry_tier_resolution() {
        // auto resolves to the detected tier; an unavailable force resolves
        // to scalar, and the registry keeps serving correct results
        assert_eq!(KernelRegistry::auto().tier(), SimdTier::detect());
        for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon] {
            let reg = KernelRegistry::with_tier(None, TierChoice::Forced(t), 1);
            if t.available() {
                assert_eq!(reg.tier(), t);
            } else {
                assert_eq!(reg.tier(), SimdTier::Scalar);
            }
            let (wd, packed) = tern_layer(9, 13, 5);
            let a = Tensor::new(&[3, 9], vec![1i8; 27]).unwrap();
            let want = KernelRegistry::with_tier(
                Some(KernelKind::I8Dense),
                TierChoice::Forced(SimdTier::Scalar),
                1,
            )
            .gemm(&a, &wd, &packed);
            assert_eq!(reg.gemm(&a, &wd, &packed).data(), want.data(), "tier {t}");
        }
    }

    #[test]
    fn test_gemm_fused_matches_unfused_epilogue_across_kernels() {
        use crate::kernels::epilogue::LayerRequant;
        let (k, f, m) = (27, 18, 37);
        let (wd, packed) = tern_layer(k, f, 30);
        let mut rng = SplitMix64::new(31);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|_| (rng.next_below(255) as i16 - 127) as i8).collect::<Vec<i8>>(),
        )
        .unwrap();
        let w_scale: Vec<f32> = (0..f).map(|i| 0.002 * (i + 1) as f32).collect();
        let bn_scale = vec![1.0f32; f];
        let bn_shift = vec![0.5f32; f];
        let skip: Vec<i64> =
            (0..m * f).map(|_| rng.next_below(1 << 20) as i64 - (1 << 19)).collect();
        let lr = LayerRequant::derive(&w_scale, &bn_scale, &bn_shift).unwrap();
        let epi = lr.resolve(-4, -4, true);
        // reference: whole unfused i32 accumulator, epilogue applied after
        let acc = KernelRegistry::new(Some(KernelKind::I8Dense), 1).gemm(&a, &wd, &packed);
        let mut want = vec![0i8; m * f];
        epi.apply_i8(acc.data(), 0, m, f, Some(&skip), &mut want);
        let mut want_skip = vec![0i64; m * f];
        epi.apply_skip(acc.data(), m, f, &mut want_skip);
        for kind in ALL_KERNELS {
            for tier in test_tiers() {
                for threads in [1usize, 3] {
                    let reg = KernelRegistry::with_tier(Some(kind), tier, threads);
                    let got = reg.gemm_fused(&a, &packed, &wd, &epi, Some(&skip));
                    assert_eq!(
                        got.data(),
                        &want[..],
                        "fused i8, kernel {kind} tier {tier} threads {threads}"
                    );
                    let got_skip = reg.gemm_fused_skip(&a, &packed, &wd, &epi);
                    assert_eq!(
                        got_skip.data(),
                        &want_skip[..],
                        "fused skip, kernel {kind} tier {tier} threads {threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn test_into_entry_points_ignore_stale_buffer_contents() {
        use crate::kernels::epilogue::LayerRequant;
        let (m, k, f) = (19, 11, 13);
        let (wd, packed) = tern_layer(k, f, 77);
        let mut rng = SplitMix64::new(78);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|_| (rng.next_below(255) as i16 - 127) as i8).collect::<Vec<i8>>(),
        )
        .unwrap();
        let w_scale: Vec<f32> = (0..f).map(|i| 0.004 * (1 + i % 3) as f32).collect();
        let ones = vec![1.0f32; f];
        let quarter = vec![0.25f32; f];
        let lr = LayerRequant::derive(&w_scale, &ones, &quarter).unwrap();
        let epi = lr.resolve(-4, -4, true);
        let reg = KernelRegistry::new(None, 2);
        let want = reg.gemm(&a, &wd, &packed);
        let want_fused = reg.gemm_fused(&a, &packed, &wd, &epi, None);
        let want_skip = reg.gemm_fused_skip(&a, &packed, &wd, &epi);
        // reused arena buffers arrive full of garbage: results must not
        // depend on prior contents of out or scratch
        let mut out_i32 = vec![i32::MIN; m * f];
        reg.gemm_into(a.data(), m, k, f, &packed, wd.data(), &mut out_i32);
        assert_eq!(&out_i32[..], want.data());
        let mut out_i8 = vec![-9i8; m * f];
        let mut scratch = vec![i32::MAX; m * f];
        reg.gemm_fused_into(a.data(), m, k, f, &packed, wd.data(), &epi, None, None, &mut out_i8, &mut scratch);
        assert_eq!(&out_i8[..], want_fused.data());
        let mut out_i64 = vec![i64::MIN + 1; m * f];
        let mut row_max = vec![-1i64; m];
        scratch.fill(12345);
        reg.gemm_fused_skip_into(
            a.data(),
            m,
            k,
            f,
            &packed,
            wd.data(),
            &epi,
            &mut out_i64,
            Some(&mut row_max),
            &mut scratch,
        );
        assert_eq!(&out_i64[..], want_skip.data());
        for (r, &mx) in row_max.iter().enumerate() {
            let want_mx = want_skip.data()[r * f..(r + 1) * f]
                .iter()
                .fold(0i64, |acc, &v| acc.max(v.saturating_abs()));
            assert_eq!(mx, want_mx, "row {r} max");
        }
    }

    #[test]
    fn test_dispatch_is_bit_exact_across_kernels_and_tiers() {
        let (k, f, m) = (27, 18, 5);
        let (wd, packed) = tern_layer(k, f, 3);
        let mut rng = SplitMix64::new(4);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|_| (rng.next_below(255) as i16 - 127) as i8).collect(),
        )
        .unwrap();
        let want = KernelRegistry::new(Some(KernelKind::I8Dense), 1).gemm(&a, &wd, &packed);
        for kind in ALL_KERNELS {
            for tier in test_tiers() {
                let reg = KernelRegistry::with_tier(Some(kind), tier, 2);
                assert_eq!(
                    reg.gemm(&a, &wd, &packed).data(),
                    want.data(),
                    "kernel {kind} tier {tier}"
                );
            }
        }
    }
}
