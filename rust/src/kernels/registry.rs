//! Kernel selection: which GEMM runs a given layer.
//!
//! Dispatch rules (see DESIGN.md §kernels):
//! * an explicit choice (`--kernel`, `Config.kernel`) wins whenever the
//!   layer has the encoding it needs; a layer that can't satisfy it (e.g.
//!   an 8-bit stem under `--kernel ternary`) falls back to the auto rule so
//!   a forced run never aborts mid-network;
//! * auto prefers the cheapest encoding the layer supports:
//!   packed-ternary > packed-i4 > dense i8 zero-skip.
//!
//! Every kernel yields bit-identical `i32` accumulators, so selection is a
//! pure performance decision — `forward_quant` logits are invariant under
//! any choice (property-tested in `rust/tests/kernels_equivalence.rs`).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::epilogue::ResolvedEpilogue;
use super::gemm::{
    gemm_i8, gemm_i8_dense, gemm_packed_i4, gemm_packed_ternary, i4_row_block, i8_row_block,
    tern_row_block, MIN_ROWS_PER_BLOCK,
};
use super::packed::PackedLayer;
use super::threadpool::ThreadPool;

/// The GEMM implementations the registry can dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// dense i8 x i8 with the activation zero-skip branch
    I8ZeroSkip,
    /// dense i8 x i8, branch-free (LLVM-vectorized inner loop)
    I8Dense,
    /// multiply-free 2-bit packed ternary engine
    PackedTernary,
    /// packed 4-bit engine
    PackedI4,
}

/// All kernels, in auto-preference order for sub-8-bit weights.
pub const ALL_KERNELS: [KernelKind; 4] =
    [KernelKind::PackedTernary, KernelKind::PackedI4, KernelKind::I8ZeroSkip, KernelKind::I8Dense];

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            KernelKind::I8ZeroSkip => "i8",
            KernelKind::I8Dense => "i8-dense",
            KernelKind::PackedTernary => "ternary",
            KernelKind::PackedI4 => "i4",
        })
    }
}

impl std::str::FromStr for KernelKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "i8" | "i8-zero-skip" => KernelKind::I8ZeroSkip,
            "i8-dense" | "dense" => KernelKind::I8Dense,
            "ternary" | "packed-ternary" => KernelKind::PackedTernary,
            "i4" | "packed-i4" => KernelKind::PackedI4,
            other => bail!("unknown kernel '{other}' (try auto|i8|i8-dense|ternary|i4)"),
        })
    }
}

/// A resolved `--kernel` / `Config.kernel` setting: automatic per-layer
/// dispatch or one forced kernel. Parsing happens once, at config-resolve
/// time, so a typo'd kernel name fails fast with the valid names instead of
/// surviving as an arbitrary string until dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// per-layer auto rule (cheapest encoding the layer supports)
    #[default]
    Auto,
    /// force one kernel wherever its encoding exists (auto elsewhere)
    Forced(KernelKind),
}

impl KernelChoice {
    /// The forced kind, if any.
    pub fn kind(self) -> Option<KernelKind> {
        match self {
            KernelChoice::Auto => None,
            KernelChoice::Forced(k) => Some(k),
        }
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelChoice::Auto => f.write_str("auto"),
            KernelChoice::Forced(k) => write!(f, "{k}"),
        }
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "" | "auto" => KernelChoice::Auto,
            other => KernelChoice::Forced(other.parse()?),
        })
    }
}

/// Runtime kernel dispatcher: an optional forced choice plus the thread
/// pool the packed kernels parallelize on.
#[derive(Debug, Clone)]
pub struct KernelRegistry {
    choice: Option<KernelKind>,
    pool: ThreadPool,
}

impl Default for KernelRegistry {
    fn default() -> Self {
        Self::auto()
    }
}

impl KernelRegistry {
    pub fn new(choice: Option<KernelKind>, threads: usize) -> Self {
        Self { choice, pool: ThreadPool::new(threads) }
    }

    /// Auto selection, single-threaded (the library default — callers that
    /// want parallel GEMMs size the pool from `Config::kernel_registry`).
    pub fn auto() -> Self {
        Self::new(None, 1)
    }

    /// Build from a typed [`KernelChoice`] (the `Config.kernel` field).
    pub fn with_choice(choice: KernelChoice, threads: usize) -> Self {
        Self::new(choice.kind(), threads)
    }

    /// Parse a CLI/config kernel name; `"auto"` (or empty) means no force.
    pub fn parse(name: &str, threads: usize) -> Result<Self> {
        Ok(Self::with_choice(name.parse()?, threads))
    }

    pub fn choice(&self) -> Option<KernelKind> {
        self.choice
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Resolve the kernel that will actually run for a layer with the given
    /// packed encodings available.
    pub fn select(&self, packed: &PackedLayer) -> KernelKind {
        match self.choice {
            Some(KernelKind::PackedTernary) if packed.ternary.is_some() => KernelKind::PackedTernary,
            Some(KernelKind::PackedI4) if packed.i4.is_some() => KernelKind::PackedI4,
            Some(k @ (KernelKind::I8ZeroSkip | KernelKind::I8Dense)) => k,
            _ => {
                // auto rule (also the fallback for an unsatisfiable force)
                if packed.ternary.is_some() {
                    KernelKind::PackedTernary
                } else if packed.i4.is_some() {
                    KernelKind::PackedI4
                } else {
                    KernelKind::I8ZeroSkip
                }
            }
        }
    }

    /// Dispatch one GEMM: `a` (M,K) i8 activations, `dense` the (K,F) i8
    /// codes, `packed` the layer's packed encodings. Returns (M,F) i32.
    pub fn gemm(&self, a: &Tensor<i8>, dense: &Tensor<i8>, packed: &PackedLayer) -> Tensor<i32> {
        self.gemm_with(a, packed, || dense.clone())
    }

    /// Like [`Self::gemm`] but the dense (K,F) operand is produced lazily —
    /// the packed kernels never touch it, so callers that keep weights
    /// packed (the lpinfer hot path) skip the dense materialization.
    pub fn gemm_with(
        &self,
        a: &Tensor<i8>,
        packed: &PackedLayer,
        dense: impl FnOnce() -> Tensor<i8>,
    ) -> Tensor<i32> {
        match self.select(packed) {
            KernelKind::I8ZeroSkip => gemm_i8(a, &dense()),
            KernelKind::I8Dense => gemm_i8_dense(a, &dense()),
            KernelKind::PackedTernary => {
                gemm_packed_ternary(a, packed.ternary.as_ref().expect("selected"), &self.pool)
            }
            KernelKind::PackedI4 => {
                gemm_packed_i4(a, packed.i4.as_ref().expect("selected"), &self.pool)
            }
        }
    }

    /// GEMM with the integer requantization epilogue fused in: the selected
    /// kernel accumulates each output-row block into a block-local i32
    /// scratch tile, and `epi` rescales the tile straight to i8 codes while
    /// it is still cache-hot — no f32 (and no full-size i32 tensor) is ever
    /// materialized. `skip`, if present, is the (M, F) integer residual
    /// lane (units of `2^-SKIP_FRAC` target-grid steps, see
    /// [`crate::dfp::SKIP_FRAC`]).
    pub fn gemm_fused(
        &self,
        a: &Tensor<i8>,
        packed: &PackedLayer,
        dense: impl FnOnce() -> Tensor<i8>,
        epi: &ResolvedEpilogue,
        skip: Option<&[i64]>,
    ) -> Tensor<i8> {
        let (m, k) = (a.dim(0), a.dim(1));
        let ad = a.data();
        match self.select(packed) {
            KernelKind::PackedTernary => {
                let w = packed.ternary.as_ref().expect("selected");
                assert_eq!(k, w.k, "gemm_fused: A is (.., {k}) but W is ({}, ..)", w.k);
                fused_i8(m, w.f, &self.pool, epi, skip, |row0, rows, acc| {
                    tern_row_block(ad, k, row0, rows, w, acc);
                })
            }
            KernelKind::PackedI4 => {
                let w = packed.i4.as_ref().expect("selected");
                assert_eq!(k, w.k, "gemm_fused: A is (.., {k}) but W is ({}, ..)", w.k);
                fused_i8(m, w.f, &self.pool, epi, skip, |row0, rows, acc| {
                    i4_row_block(ad, k, row0, rows, w, acc);
                })
            }
            kind @ (KernelKind::I8ZeroSkip | KernelKind::I8Dense) => {
                let b = dense();
                assert_eq!(k, b.dim(0), "gemm_fused: A is (.., {k}) but W is ({}, ..)", b.dim(0));
                let f = b.dim(1);
                let bd = b.data();
                let zero_skip = kind == KernelKind::I8ZeroSkip;
                fused_i8(m, f, &self.pool, epi, skip, |row0, rows, acc| {
                    i8_row_block(ad, bd, k, f, row0, rows, acc, zero_skip);
                })
            }
        }
    }

    /// Like [`Self::gemm_fused`] but the epilogue writes the i64 integer
    /// residual lane instead of i8 codes — the projection-conv path whose
    /// output feeds a later layer's skip connection.
    pub fn gemm_fused_skip(
        &self,
        a: &Tensor<i8>,
        packed: &PackedLayer,
        dense: impl FnOnce() -> Tensor<i8>,
        epi: &ResolvedEpilogue,
    ) -> Tensor<i64> {
        let (m, k) = (a.dim(0), a.dim(1));
        let ad = a.data();
        match self.select(packed) {
            KernelKind::PackedTernary => {
                let w = packed.ternary.as_ref().expect("selected");
                assert_eq!(k, w.k, "gemm_fused_skip: A is (.., {k}) but W is ({}, ..)", w.k);
                fused_skip(m, w.f, &self.pool, epi, |row0, rows, acc| {
                    tern_row_block(ad, k, row0, rows, w, acc);
                })
            }
            KernelKind::PackedI4 => {
                let w = packed.i4.as_ref().expect("selected");
                assert_eq!(k, w.k, "gemm_fused_skip: A is (.., {k}) but W is ({}, ..)", w.k);
                fused_skip(m, w.f, &self.pool, epi, |row0, rows, acc| {
                    i4_row_block(ad, k, row0, rows, w, acc);
                })
            }
            kind @ (KernelKind::I8ZeroSkip | KernelKind::I8Dense) => {
                let b = dense();
                assert_eq!(
                    k,
                    b.dim(0),
                    "gemm_fused_skip: A is (.., {k}) but W is ({}, ..)",
                    b.dim(0)
                );
                let f = b.dim(1);
                let bd = b.data();
                let zero_skip = kind == KernelKind::I8ZeroSkip;
                fused_skip(m, f, &self.pool, epi, |row0, rows, acc| {
                    i8_row_block(ad, bd, k, f, row0, rows, acc, zero_skip);
                })
            }
        }
    }
}

/// Run `compute` over output-row blocks with a block-local i32 accumulator
/// tile, applying the requant epilogue to each tile while it is cache-hot.
fn fused_i8(
    m: usize,
    f: usize,
    pool: &ThreadPool,
    epi: &ResolvedEpilogue,
    skip: Option<&[i64]>,
    compute: impl Fn(usize, usize, &mut [i32]) + Sync,
) -> Tensor<i8> {
    assert_eq!(epi.len(), f, "epilogue has {} channels for an F={f} GEMM", epi.len());
    if let Some(s) = skip {
        assert_eq!(s.len(), m * f, "skip lane has {} elements for an {m}x{f} GEMM", s.len());
    }
    let mut out = Tensor::<i8>::zeros(&[m, f]);
    pool.run_row_blocks(out.data_mut(), m, f, MIN_ROWS_PER_BLOCK, |row0, rows, block| {
        let mut acc = vec![0i32; rows * f];
        compute(row0, rows, &mut acc);
        epi.apply_i8(&acc, row0, rows, f, skip, block);
    });
    out
}

/// [`fused_i8`] writing the i64 residual lane instead of i8 codes.
fn fused_skip(
    m: usize,
    f: usize,
    pool: &ThreadPool,
    epi: &ResolvedEpilogue,
    compute: impl Fn(usize, usize, &mut [i32]) + Sync,
) -> Tensor<i64> {
    assert_eq!(epi.len(), f, "epilogue has {} channels for an F={f} GEMM", epi.len());
    let mut out = Tensor::<i64>::zeros(&[m, f]);
    pool.run_row_blocks(out.data_mut(), m, f, MIN_ROWS_PER_BLOCK, |row0, rows, block| {
        let mut acc = vec![0i32; rows * f];
        compute(row0, rows, &mut acc);
        epi.apply_skip(&acc, rows, f, block);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn tern_layer(k: usize, f: usize, seed: u64) -> (Tensor<i8>, PackedLayer) {
        let mut rng = SplitMix64::new(seed);
        let wd =
            Tensor::new(&[k, f], (0..k * f).map(|_| rng.next_below(3) as i8 - 1).collect()).unwrap();
        let packed = PackedLayer::build(&wd, &[], 0);
        (wd, packed)
    }

    #[test]
    fn test_parse_and_display() {
        for k in ALL_KERNELS {
            assert_eq!(k.to_string().parse::<KernelKind>().unwrap(), k);
        }
        assert_eq!("packed-ternary".parse::<KernelKind>().unwrap(), KernelKind::PackedTernary);
        assert!("warp".parse::<KernelKind>().is_err());
        assert!(KernelRegistry::parse("auto", 1).unwrap().choice().is_none());
        assert!(KernelRegistry::parse("warp", 1).is_err());
    }

    #[test]
    fn test_kernel_choice_parse_display_roundtrip() {
        assert_eq!("auto".parse::<KernelChoice>().unwrap(), KernelChoice::Auto);
        assert_eq!("".parse::<KernelChoice>().unwrap(), KernelChoice::Auto);
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
        assert_eq!(KernelChoice::Auto.kind(), None);
        for k in ALL_KERNELS {
            let c: KernelChoice = k.to_string().parse().unwrap();
            assert_eq!(c, KernelChoice::Forced(k));
            assert_eq!(c.kind(), Some(k));
            assert_eq!(c.to_string().parse::<KernelChoice>().unwrap(), c);
        }
        let err = "warp".parse::<KernelChoice>().unwrap_err().to_string();
        assert!(err.contains("auto|i8|i8-dense|ternary|i4"), "{err}");
    }

    #[test]
    fn test_auto_prefers_cheapest_encoding() {
        let (_, tern) = tern_layer(4, 4, 1);
        let reg = KernelRegistry::auto();
        assert_eq!(reg.select(&tern), KernelKind::PackedTernary);

        let mut no_tern = tern.clone();
        no_tern.ternary = None;
        assert_eq!(reg.select(&no_tern), KernelKind::PackedI4);
        assert_eq!(reg.select(&PackedLayer::none()), KernelKind::I8ZeroSkip);
    }

    #[test]
    fn test_forced_choice_with_fallback() {
        let (_, tern) = tern_layer(4, 4, 2);
        let reg = KernelRegistry::new(Some(KernelKind::I8Dense), 1);
        assert_eq!(reg.select(&tern), KernelKind::I8Dense);
        // forcing ternary on a layer with no ternary encoding falls back
        let reg = KernelRegistry::new(Some(KernelKind::PackedTernary), 1);
        assert_eq!(reg.select(&PackedLayer::none()), KernelKind::I8ZeroSkip);
    }

    #[test]
    fn test_gemm_fused_matches_unfused_epilogue_across_kernels() {
        use crate::kernels::epilogue::LayerRequant;
        let (k, f, m) = (27, 18, 37);
        let (wd, packed) = tern_layer(k, f, 30);
        let mut rng = SplitMix64::new(31);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|_| (rng.next_below(255) as i16 - 127) as i8).collect::<Vec<i8>>(),
        )
        .unwrap();
        let w_scale: Vec<f32> = (0..f).map(|i| 0.002 * (i + 1) as f32).collect();
        let bn_scale = vec![1.0f32; f];
        let bn_shift = vec![0.5f32; f];
        let skip: Vec<i64> =
            (0..m * f).map(|_| rng.next_below(1 << 20) as i64 - (1 << 19)).collect();
        let lr = LayerRequant::derive(&w_scale, &bn_scale, &bn_shift).unwrap();
        let epi = lr.resolve(-4, -4, true);
        // reference: whole unfused i32 accumulator, epilogue applied after
        let acc = KernelRegistry::new(Some(KernelKind::I8Dense), 1).gemm(&a, &wd, &packed);
        let mut want = vec![0i8; m * f];
        epi.apply_i8(acc.data(), 0, m, f, Some(&skip), &mut want);
        let mut want_skip = vec![0i64; m * f];
        epi.apply_skip(acc.data(), m, f, &mut want_skip);
        for kind in ALL_KERNELS {
            for threads in [1usize, 3] {
                let reg = KernelRegistry::new(Some(kind), threads);
                let got = reg.gemm_fused(&a, &packed, || wd.clone(), &epi, Some(&skip));
                assert_eq!(got.data(), &want[..], "fused i8, kernel {kind} threads {threads}");
                let got_skip = reg.gemm_fused_skip(&a, &packed, || wd.clone(), &epi);
                assert_eq!(
                    got_skip.data(),
                    &want_skip[..],
                    "fused skip, kernel {kind} threads {threads}"
                );
            }
        }
    }

    #[test]
    fn test_dispatch_is_bit_exact_across_kernels() {
        let (k, f, m) = (27, 18, 5);
        let (wd, packed) = tern_layer(k, f, 3);
        let mut rng = SplitMix64::new(4);
        let a = Tensor::new(
            &[m, k],
            (0..m * k).map(|_| (rng.next_below(255) as i16 - 127) as i8).collect(),
        )
        .unwrap();
        let want = KernelRegistry::new(Some(KernelKind::I8Dense), 1).gemm(&a, &wd, &packed);
        for kind in ALL_KERNELS {
            let reg = KernelRegistry::new(Some(kind), 2);
            assert_eq!(reg.gemm(&a, &wd, &packed).data(), want.data(), "kernel {kind}");
        }
    }
}
