//! Packed-ternary execution engine — the hot path of the "full sub-8-bit
//! compute pipeline" (paper §3.3/§5), operating *directly* on the 2-bit
//! packed weight format instead of unpacking to dense i8.
//!
//! Modules:
//! * [`packed`] — column-blocked [`PackedTernaryMatrix`] / [`PackedI4Matrix`]
//!   layouts with per-cluster `(α̂, exp)` scale metadata;
//! * [`gemm`] — the dense i8 kernels plus the multiply-free packed-ternary
//!   GEMM (2-bit codes decoded to ±1 lane masks, accumulated branch-free
//!   as `(a & pos) - (a & neg)`) and the packed-i4 GEMM, cache-blocked
//!   over (M, K, F) tiles;
//! * [`pool`] — [`WorkerPool`]: persistent parked worker threads with an
//!   intrusive stack-allocated job queue, so submitting a GEMM's row
//!   blocks allocates nothing and spawns nothing;
//! * [`threadpool`] — the row-block splitter over that pool, sized from
//!   [`crate::config::Config`]; clones share one `WorkerPool`;
//! * [`simd`] — the SIMD execution tier: AVX2 (x86_64) / NEON (aarch64)
//!   implementations of the ternary accumulate, the dense/sparse i8 inner
//!   loop and the requant epilogue, behind runtime CPU-feature detection
//!   with the scalar kernels as the guaranteed fallback;
//! * [`registry`] — [`KernelRegistry`]: runtime selection among the
//!   kernels by weight encoding *and* SIMD tier, with a `--kernel` CLI
//!   override (`<encoding>[+<tier>]`); every GEMM has a borrowed-output
//!   `*_into` form (caller-owned output + accumulator scratch, zero
//!   allocations) next to its allocating wrapper;
//! * [`epilogue`] — the fused integer requantization epilogue
//!   ([`LayerRequant`] / [`ResolvedEpilogue`]): folded batch-norm +
//!   activation rescale applied to each accumulator tile as fixed-point
//!   integer arithmetic while it is cache-hot, so the lpinfer activation
//!   path never materializes an f32 (or full-size i32) tensor.
//!
//! All kernels produce bit-identical `i32` accumulators, so the registry
//! can swap them per layer purely on performance grounds; `lpinfer`
//! dispatches every conv/FC GEMM through here, and
//! [`crate::coordinator::LpExecutor`] turns that pipeline into a serving
//! backend that needs no PJRT artifacts.

pub mod epilogue;
pub mod gemm;
pub mod packed;
pub mod pool;
pub mod registry;
pub mod simd;
pub mod threadpool;

pub use epilogue::{LayerRequant, ResolvedEpilogue};
pub use gemm::{gemm_i8, gemm_i8_dense, gemm_packed_i4, gemm_packed_ternary};
pub use packed::{PackedI4Matrix, PackedLayer, PackedTernaryMatrix, PANEL_F};
pub use pool::WorkerPool;
pub use registry::{KernelChoice, KernelKind, KernelRegistry, ALL_KERNELS};
pub use simd::{SimdTier, TierChoice};
pub use threadpool::ThreadPool;
