//! Row-block splitter over the persistent worker pool.
//!
//! GEMM calls parallelize over disjoint output-row blocks, so each "job"
//! is a `(row range, &mut output chunk)` pair. [`ThreadPool`] owns the
//! geometry — how many blocks a `(rows, min_rows)` problem splits into and
//! where the row boundaries fall — and hands the block bodies to a shared
//! [`WorkerPool`](super::pool::WorkerPool) of persistent parked threads
//! (see `kernels/pool.rs` for the lifecycle). Submitting a job allocates
//! nothing: the job record lives on the caller's stack and workers park on
//! a condvar between GEMMs, which is what lets the zero-allocation
//! steady-state guarantee (DESIGN.md §forward-plan) cover multi-threaded
//! registries — there is no per-call spawn left to allocate.
//!
//! Cloning a `ThreadPool` (and thus a `KernelRegistry`) shares the
//! underlying worker pool via `Arc`, so the serving coordinator's workers
//! all feed one set of GEMM threads instead of stacking pools.

use std::sync::Arc;

use super::pool::WorkerPool;

/// Covariant raw-pointer wrapper for handing disjoint sub-slices of one
/// buffer to pool workers. Safety rests on the row-block geometry: every
/// block index maps to a non-overlapping `[row0*cols, (row0+take)*cols)`
/// range, so no two workers ever alias.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// A fixed-width thread pool splitting row-major buffers into contiguous
/// row blocks. Cheap to clone — clones share one [`WorkerPool`].
#[derive(Debug, Clone)]
pub struct ThreadPool {
    pool: Arc<WorkerPool>,
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new(1)
    }
}

impl ThreadPool {
    /// `threads == 0` means "use all available cores". Spawns the
    /// persistent workers (`threads - 1` of them — the submitting thread
    /// is the last worker) immediately; they park until the first GEMM.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(usize::from).unwrap_or(1)
        } else {
            threads
        };
        Self { pool: Arc::new(WorkerPool::new(threads)) }
    }

    /// Wrap an existing worker pool — two registries built this way
    /// interleave their GEMMs on the same persistent threads.
    pub fn shared(pool: Arc<WorkerPool>) -> Self {
        Self { pool }
    }

    /// The shared persistent pool (for handing to [`Self::shared`]).
    pub fn worker_pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    pub fn threads(&self) -> usize {
        self.pool.width()
    }

    /// Split a row-major `(rows, cols)` output buffer into contiguous row
    /// blocks and run `body(first_row, n_rows, block)` on each, in parallel
    /// across up to `threads` pool workers. Blocks never shrink below
    /// `min_rows` rows (small problems stay single-threaded), and the body
    /// must fill its block independently of every other block.
    ///
    /// With one block (single thread, or too few rows) the body runs inline
    /// on the calling thread — no pool handoff — and with more the job is
    /// submitted from the caller's stack: either way the steady-state
    /// `forward_quant` path stays allocation-free end to end.
    pub fn run_row_blocks<T: Send>(
        &self,
        out: &mut [T],
        rows: usize,
        cols: usize,
        min_rows: usize,
        body: impl Fn(usize, usize, &mut [T]) + Sync,
    ) {
        // one splitter serves both entry points: zero-width aux of the same
        // element type, so every block's aux slice is empty
        self.run_row_blocks2(out, &mut [] as &mut [T], rows, cols, 0, min_rows, |row0, n, block, _aux| {
            body(row0, n, block)
        });
    }

    /// [`Self::run_row_blocks`] over *two* row-major buffers sharing the
    /// same row count (`cols_out` / `cols_aux` columns each): both are split
    /// at the same row boundaries and the body gets the matching pair of
    /// blocks. This is how the fused GEMMs thread a caller-owned i32
    /// accumulator scratch alongside the output without allocating a tile
    /// per block — each block's scratch is a disjoint sub-slice of one
    /// long-lived arena buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn run_row_blocks2<T: Send, U: Send>(
        &self,
        out: &mut [T],
        aux: &mut [U],
        rows: usize,
        cols_out: usize,
        cols_aux: usize,
        min_rows: usize,
        body: impl Fn(usize, usize, &mut [T], &mut [U]) + Sync,
    ) {
        assert_eq!(out.len(), rows * cols_out, "output buffer shape mismatch");
        assert_eq!(aux.len(), rows * cols_aux, "aux buffer shape mismatch");
        if rows == 0 {
            return;
        }
        // floor division keeps every block >= min_rows (the doc contract)
        let blocks = self.threads().min((rows / min_rows.max(1)).max(1));
        let rows_per = rows.div_ceil(blocks);
        let n_blocks = rows.div_ceil(rows_per);
        crate::telemetry::record_pool_run(n_blocks as u64);
        if n_blocks == 1 {
            body(0, rows, out, aux);
            return;
        }
        // disjoint row ranges per block index: workers rebuild their
        // non-overlapping sub-slices from the shared base pointers
        let out_base = SendPtr(out.as_mut_ptr());
        let aux_base = SendPtr(aux.as_mut_ptr());
        let body = &body;
        self.pool.run(n_blocks, &move |i| {
            let row0 = i * rows_per;
            let take = rows_per.min(rows - row0);
            // SAFETY: `[row0, row0+take)` ranges are pairwise disjoint
            // across block indices and in-bounds (asserted above); the
            // buffers outlive the job because `pool.run` completes before
            // this frame returns.
            let oblk = unsafe { std::slice::from_raw_parts_mut(out_base.0.add(row0 * cols_out), take * cols_out) };
            let ablk = unsafe { std::slice::from_raw_parts_mut(aux_base.0.add(row0 * cols_aux), take * cols_aux) };
            body(row0, take, oblk, ablk);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn test_zero_means_all_cores() {
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn test_blocks_cover_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for rows in [1usize, 2, 5, 16, 33] {
                let cols = 3;
                let mut out = vec![0u32; rows * cols];
                ThreadPool::new(threads).run_row_blocks(&mut out, rows, cols, 1, |r0, n, block| {
                    assert_eq!(block.len(), n * cols);
                    for (i, v) in block.iter_mut().enumerate() {
                        *v += (r0 * cols + i) as u32 + 1;
                    }
                });
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "threads={threads} rows={rows}");
                }
            }
        }
    }

    #[test]
    fn test_row_blocks2_pairs_cover_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for rows in [1usize, 2, 5, 16, 33] {
                let (co, ca) = (3usize, 2usize);
                let mut out = vec![0u32; rows * co];
                let mut aux = vec![0u64; rows * ca];
                ThreadPool::new(threads).run_row_blocks2(
                    &mut out,
                    &mut aux,
                    rows,
                    co,
                    ca,
                    1,
                    |r0, n, bo, ba| {
                        assert_eq!(bo.len(), n * co);
                        assert_eq!(ba.len(), n * ca);
                        for (i, v) in bo.iter_mut().enumerate() {
                            *v += (r0 * co + i) as u32 + 1;
                        }
                        for (i, v) in ba.iter_mut().enumerate() {
                            *v += (r0 * ca + i) as u64 + 1;
                        }
                    },
                );
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "out threads={threads} rows={rows}");
                }
                for (i, v) in aux.iter().enumerate() {
                    assert_eq!(*v, i as u64 + 1, "aux threads={threads} rows={rows}");
                }
            }
        }
    }

    #[test]
    fn test_min_rows_limits_parallelism() {
        let calls = AtomicUsize::new(0);
        let mut out = vec![0u8; 8 * 2];
        ThreadPool::new(8).run_row_blocks(&mut out, 8, 2, 8, |_, _, _| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1); // 8 rows / min 8 => one block
    }

    #[test]
    fn test_clones_share_one_worker_pool() {
        let a = ThreadPool::new(4);
        let b = a.clone();
        assert!(Arc::ptr_eq(a.worker_pool(), b.worker_pool()));
        let c = ThreadPool::shared(Arc::clone(a.worker_pool()));
        assert!(Arc::ptr_eq(a.worker_pool(), c.worker_pool()));
        // distinct constructions do not share
        let d = ThreadPool::new(4);
        assert!(!Arc::ptr_eq(a.worker_pool(), d.worker_pool()));
    }
}
