//! Scoped worker pool for data-parallel kernels.
//!
//! Deliberately tiny: GEMM calls parallelize over disjoint output-row
//! blocks, so each "job" is a `(row range, &mut output chunk)` pair and
//! `std::thread::scope` gives us borrow-checked access to the shared
//! operands without `Arc` or channels. Threads are spawned per call — a
//! conv-layer GEMM runs for hundreds of microseconds to milliseconds, so
//! spawn cost (~10 µs) is noise, and there are no idle workers burning CPU
//! between requests on the serving path.

/// A fixed-width scoped thread pool.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new(1)
    }
}

impl ThreadPool {
    /// `threads == 0` means "use all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(usize::from).unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split a row-major `(rows, cols)` output buffer into contiguous row
    /// blocks and run `body(first_row, n_rows, block)` on each, in parallel
    /// across up to `threads` scoped threads. Blocks never shrink below
    /// `min_rows` rows (small problems stay single-threaded), and the body
    /// must fill its block independently of every other block.
    ///
    /// With one block (single thread, or too few rows) the body runs inline
    /// on the calling thread — no spawn, no heap allocation — which is what
    /// lets the single-threaded `forward_quant` steady state stay
    /// allocation-free end to end.
    pub fn run_row_blocks<T: Send>(
        &self,
        out: &mut [T],
        rows: usize,
        cols: usize,
        min_rows: usize,
        body: impl Fn(usize, usize, &mut [T]) + Sync,
    ) {
        // one splitter serves both entry points: zero-width aux of the same
        // element type, so every block's aux slice is empty
        self.run_row_blocks2(out, &mut [] as &mut [T], rows, cols, 0, min_rows, |row0, n, block, _aux| {
            body(row0, n, block)
        });
    }

    /// [`Self::run_row_blocks`] over *two* row-major buffers sharing the
    /// same row count (`cols_out` / `cols_aux` columns each): both are split
    /// at the same row boundaries and the body gets the matching pair of
    /// blocks. This is how the fused GEMMs thread a caller-owned i32
    /// accumulator scratch alongside the output without allocating a tile
    /// per block — each block's scratch is a disjoint sub-slice of one
    /// long-lived arena buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn run_row_blocks2<T: Send, U: Send>(
        &self,
        out: &mut [T],
        aux: &mut [U],
        rows: usize,
        cols_out: usize,
        cols_aux: usize,
        min_rows: usize,
        body: impl Fn(usize, usize, &mut [T], &mut [U]) + Sync,
    ) {
        assert_eq!(out.len(), rows * cols_out, "output buffer shape mismatch");
        assert_eq!(aux.len(), rows * cols_aux, "aux buffer shape mismatch");
        if rows == 0 {
            return;
        }
        // floor division keeps every block >= min_rows (the doc contract)
        let blocks = self.threads.min((rows / min_rows.max(1)).max(1));
        crate::telemetry::record_pool_run(blocks as u64);
        if blocks == 1 {
            body(0, rows, out, aux);
            return;
        }
        let rows_per = rows.div_ceil(blocks);
        std::thread::scope(|s| {
            let body = &body;
            let mut rest_out = out;
            let mut rest_aux = aux;
            let mut row0 = 0;
            while row0 < rows {
                let take = rows_per.min(rows - row0);
                let tail = std::mem::take(&mut rest_out);
                let (block_out, tail) = tail.split_at_mut(take * cols_out);
                rest_out = tail;
                let tail = std::mem::take(&mut rest_aux);
                let (block_aux, tail) = tail.split_at_mut(take * cols_aux);
                rest_aux = tail;
                let first = row0;
                s.spawn(move || body(first, take, block_out, block_aux));
                row0 += take;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn test_zero_means_all_cores() {
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn test_blocks_cover_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for rows in [1usize, 2, 5, 16, 33] {
                let cols = 3;
                let mut out = vec![0u32; rows * cols];
                ThreadPool::new(threads).run_row_blocks(&mut out, rows, cols, 1, |r0, n, block| {
                    assert_eq!(block.len(), n * cols);
                    for (i, v) in block.iter_mut().enumerate() {
                        *v += (r0 * cols + i) as u32 + 1;
                    }
                });
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "threads={threads} rows={rows}");
                }
            }
        }
    }

    #[test]
    fn test_row_blocks2_pairs_cover_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for rows in [1usize, 2, 5, 16, 33] {
                let (co, ca) = (3usize, 2usize);
                let mut out = vec![0u32; rows * co];
                let mut aux = vec![0u64; rows * ca];
                ThreadPool::new(threads).run_row_blocks2(
                    &mut out,
                    &mut aux,
                    rows,
                    co,
                    ca,
                    1,
                    |r0, n, bo, ba| {
                        assert_eq!(bo.len(), n * co);
                        assert_eq!(ba.len(), n * ca);
                        for (i, v) in bo.iter_mut().enumerate() {
                            *v += (r0 * co + i) as u32 + 1;
                        }
                        for (i, v) in ba.iter_mut().enumerate() {
                            *v += (r0 * ca + i) as u64 + 1;
                        }
                    },
                );
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "out threads={threads} rows={rows}");
                }
                for (i, v) in aux.iter().enumerate() {
                    assert_eq!(*v, i as u64 + 1, "aux threads={threads} rows={rows}");
                }
            }
        }
    }

    #[test]
    fn test_min_rows_limits_parallelism() {
        let calls = AtomicUsize::new(0);
        let mut out = vec![0u8; 8 * 2];
        ThreadPool::new(8).run_row_blocks(&mut out, 8, 2, 8, |_, _, _| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1); // 8 rows / min 8 => one block
    }
}
