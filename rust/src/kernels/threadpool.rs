//! Scoped worker pool for data-parallel kernels.
//!
//! Deliberately tiny: GEMM calls parallelize over disjoint output-row
//! blocks, so each "job" is a `(row range, &mut output chunk)` pair and
//! `std::thread::scope` gives us borrow-checked access to the shared
//! operands without `Arc` or channels. Threads are spawned per call — a
//! conv-layer GEMM runs for hundreds of microseconds to milliseconds, so
//! spawn cost (~10 µs) is noise, and there are no idle workers burning CPU
//! between requests on the serving path.

/// A fixed-width scoped thread pool.
#[derive(Debug, Clone)]
pub struct ThreadPool {
    threads: usize,
}

impl Default for ThreadPool {
    fn default() -> Self {
        Self::new(1)
    }
}

impl ThreadPool {
    /// `threads == 0` means "use all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(usize::from).unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split a row-major `(rows, cols)` output buffer into contiguous row
    /// blocks and run `body(first_row, n_rows, block)` on each, in parallel
    /// across up to `threads` scoped threads. Blocks never shrink below
    /// `min_rows` rows (small problems stay single-threaded), and the body
    /// must fill its block independently of every other block.
    pub fn run_row_blocks<T: Send>(
        &self,
        out: &mut [T],
        rows: usize,
        cols: usize,
        min_rows: usize,
        body: impl Fn(usize, usize, &mut [T]) + Sync,
    ) {
        assert_eq!(out.len(), rows * cols, "output buffer shape mismatch");
        if rows == 0 {
            return;
        }
        // floor division keeps every block >= min_rows (the doc contract)
        let blocks = self.threads.min((rows / min_rows.max(1)).max(1));
        if blocks == 1 {
            body(0, rows, out);
            return;
        }
        let rows_per = rows.div_ceil(blocks);
        std::thread::scope(|s| {
            let body = &body;
            let mut rest = out;
            let mut row0 = 0;
            while row0 < rows {
                let take = rows_per.min(rows - row0);
                let tail = std::mem::take(&mut rest);
                let (block, tail) = tail.split_at_mut(take * cols);
                rest = tail;
                let first = row0;
                s.spawn(move || body(first, take, block));
                row0 += take;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn test_zero_means_all_cores() {
        assert!(ThreadPool::new(0).threads() >= 1);
        assert_eq!(ThreadPool::new(3).threads(), 3);
    }

    #[test]
    fn test_blocks_cover_exactly_once() {
        for threads in [1, 2, 4, 7] {
            for rows in [1usize, 2, 5, 16, 33] {
                let cols = 3;
                let mut out = vec![0u32; rows * cols];
                ThreadPool::new(threads).run_row_blocks(&mut out, rows, cols, 1, |r0, n, block| {
                    assert_eq!(block.len(), n * cols);
                    for (i, v) in block.iter_mut().enumerate() {
                        *v += (r0 * cols + i) as u32 + 1;
                    }
                });
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u32 + 1, "threads={threads} rows={rows}");
                }
            }
        }
    }

    #[test]
    fn test_min_rows_limits_parallelism() {
        let calls = AtomicUsize::new(0);
        let mut out = vec![0u8; 8 * 2];
        ThreadPool::new(8).run_row_blocks(&mut out, 8, 2, 8, |_, _, _| {
            calls.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1); // 8 rows / min 8 => one block
    }
}
